"""Vertica-in-JAX core: projections, encodings, storage, MVCC, K-safety.

The paper's §3-§5 as a library: see DESIGN.md for the architecture map.
"""
from .block_cache import BlockCache, CacheStats
from .catalog import Catalog
from .database import (AvailabilityError, NodeState, QueryRejectedError,
                       RecoverySourceLostError, SegmentUnavailableError,
                       Txn, VerticaDB)
from .encodings import (EncodedColumn, Encoding, decode_jnp, device_bytes,
                        encode, upload_jnp)
from .epochs import EpochManager
from .faults import (INJECTION_POINTS, CrashNode, FaultError,
                     FaultInjector, FaultTimeout, Hang, NodeCrashError,
                     NullInjector, Transient, TransientFaultError,
                     fire_with_retries, with_retries)
from .locks import COMPATIBLE, CONVERT, MODES, LockError, LockManager
from .partitioning import partition_keys
from .projection import (PrejoinSpec, ProjectionDef, super_projection)
from .segmentation import SegmentationSpec, hash_columns, rebalance_plan
from .sma import ColumnSMA
from .storage import DeleteVector, ROSContainer, WOS
from .tuple_mover import ProjectionStore, mergeout, moveout, run_tuple_mover
from .types import BLOCK_ROWS, ColumnDef, SQLType, TableSchema

__all__ = [
    "AvailabilityError", "BLOCK_ROWS", "BlockCache", "COMPATIBLE",
    "CONVERT", "CacheStats", "Catalog",
    "ColumnDef", "ColumnSMA", "CrashNode", "DeleteVector", "EncodedColumn",
    "Encoding", "EpochManager", "FaultError", "FaultInjector",
    "INJECTION_POINTS",
    "FaultTimeout", "Hang", "LockError", "LockManager", "MODES",
    "NodeCrashError", "NodeState", "NullInjector", "PrejoinSpec",
    "ProjectionDef", "ProjectionStore", "QueryRejectedError",
    "ROSContainer", "RecoverySourceLostError", "SQLType",
    "SegmentUnavailableError", "SegmentationSpec", "TableSchema",
    "Transient", "TransientFaultError", "Txn", "VerticaDB", "WOS",
    "decode_jnp", "device_bytes", "encode", "fire_with_retries",
    "hash_columns", "mergeout", "moveout", "partition_keys",
    "rebalance_plan", "run_tuple_mover", "super_projection", "upload_jnp",
    "with_retries",
]
