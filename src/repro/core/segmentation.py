"""Inter-node segmentation (paper §3.6) + local segments for elasticity.

A projection is either *replicated* (every node stores every tuple) or
*segmented* by an integral expression: the ring [0, C_MAX) is cut into N
contiguous node ranges, and within each node into ``n_local_segments``
sub-ranges. Elastic rebalance moves whole local segments between nodes
without re-splitting files -- exactly the paper's wholesale-transfer trick
(and the same mechanism our training stack reuses to re-shard data-parallel
ranks; see train/fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import C_MAX

_FNV_PRIME = np.uint64(1099511628211)
_FNV_OFFSET = np.uint64(14695981039346656037)


def hash_columns(*cols: np.ndarray) -> np.ndarray:
    """Deterministic 32-bit ring hash of one or more integral columns
    (vectorized FNV-1a over 8-byte words)."""
    h = np.full(cols[0].shape, _FNV_OFFSET, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for c in cols:
            v = np.asarray(c).astype(np.int64).view(np.uint64)
            for shift in (0, 16, 32, 48):
                h = h ^ ((v >> np.uint64(shift)) & np.uint64(0xFFFF))
                h = h * _FNV_PRIME
    return (h % C_MAX).astype(np.uint64)


def shard_of(ring: np.ndarray, n_shards: int) -> np.ndarray:
    """Offset-free ring-range assignment: the base map from a ring value to
    one of ``n_shards`` contiguous ranges.  The segmented executor
    (engine/segmented.py) uses this for *device* shard placement -- the
    same row must land on the same shard no matter which physical store
    (primary or ring-offset buddy) served it, so the buddy offset applies
    only to node routing, never here."""
    return (np.asarray(ring).astype(np.float64) * n_shards
            / float(C_MAX)).astype(np.int64).astype(np.int32)


# --------------------------------------------------------------------------
# Device twins of hash_columns / shard_of (jax, 32-bit safe)
# --------------------------------------------------------------------------
# The segmented executor builds its ROS slabs ON device (resegment over
# decoded device blocks), so ring values and shard assignments must be
# computable inside a jitted program -- bit-for-bit equal to the numpy
# originals above, because the host still places build sides and WOS
# batches with them and a co-located join relies on both agreeing.
#
# jax runs 32-bit by default (no uint64), so the 64-bit FNV state is kept
# as a (hi32, lo32) uint32 pair.  The FNV prime 0x100000001B3 splits into
# hi=0x100, lo=0x1B3; a 64x64 wrapping multiply by it needs only
#   lo' = lo * 0x1B3                          (wrapping u32)
#   hi' = mulhi32(lo, 0x1B3) + (lo << 8) + hi * 0x1B3
# and the 16-bit XOR words never touch the high half.  The final
# ``% C_MAX`` (C_MAX = 2^32) is just the low word.

_P_LO = 0x1B3          # low 32 bits of the FNV prime


def _mulhi32_small(a, m: int):
    """High 32 bits of (uint32 a) * (m < 2^16), in uint32 arithmetic."""
    import jax.numpy as jnp
    a = a.astype(jnp.uint32)
    a1 = a >> jnp.uint32(16)
    a0 = a & jnp.uint32(0xFFFF)
    t = a0 * jnp.uint32(m)
    u = a1 * jnp.uint32(m)
    return (u + (t >> jnp.uint32(16))) >> jnp.uint32(16)


def hash_columns_jnp(*cols):
    """Device twin of :func:`hash_columns`.  Accepts int/uint/bool columns
    (<= 32 bits wide, the slab canonicalization width) and returns the
    uint32 ring value, bit-identical to ``hash_columns(...) % C_MAX``."""
    import jax.numpy as jnp
    h_hi = jnp.full(cols[0].shape, 0xCBF29CE4, jnp.uint32)   # FNV offset
    h_lo = jnp.full(cols[0].shape, 0x84222325, jnp.uint32)
    for c in cols:
        signed = c.dtype.kind in "ib"
        v = c.astype(jnp.int32) if signed else c.astype(jnp.uint32)
        w0 = v.astype(jnp.uint32) & jnp.uint32(0xFFFF)
        w1 = (v >> 16).astype(jnp.uint32) & jnp.uint32(0xFFFF)
        # int64 sign extension: negative values fill words 2..3 with 1s
        ext = jnp.where(v < 0, jnp.uint32(0xFFFF), jnp.uint32(0)) \
            if signed else jnp.zeros_like(w0)
        for w in (w0, w1, ext, ext):
            h_lo = h_lo ^ w
            new_lo = h_lo * jnp.uint32(_P_LO)
            h_hi = (_mulhi32_small(h_lo, _P_LO) + (h_lo << jnp.uint32(8))
                    + h_hi * jnp.uint32(_P_LO))
            h_lo = new_lo
    return h_lo                                   # == full hash % 2^32


def shard_of_jnp(ring, n_shards: int):
    """Device twin of :func:`shard_of`: floor(ring * n / 2^32) via 16-bit
    limbs (exact for n_shards < 2^16, far beyond any mesh width)."""
    import jax.numpy as jnp
    r = ring.astype(jnp.uint32)
    r1 = r >> jnp.uint32(16)
    r0 = r & jnp.uint32(0xFFFF)
    t = r0 * jnp.uint32(n_shards)
    u = r1 * jnp.uint32(n_shards)
    return ((u + (t >> jnp.uint32(16))) >> jnp.uint32(16)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class SegmentationSpec:
    """SEGMENTED BY HASH(cols) ALL NODES / UNSEGMENTED (replicated)."""

    kind: str = "hash"                   # hash | replicated
    columns: Tuple[str, ...] = ()
    n_local_segments: int = 3            # per node, for elastic rebalance
    offset: int = 0                      # buddy projections: ring offset

    @property
    def replicated(self) -> bool:
        return self.kind == "replicated"

    def ring_values(self, data: Dict[str, np.ndarray]) -> np.ndarray:
        cols = [data[c] for c in self.columns]
        return hash_columns(*cols)

    def node_of(self, ring: np.ndarray, n_nodes: int) -> np.ndarray:
        """Ring range assignment with buddy offset (paper §5.2: a buddy
        projection's segmentation guarantees no row lands on the same node)."""
        base = shard_of(ring, n_nodes).astype(np.int64)
        return ((base + self.offset) % n_nodes).astype(np.int32)

    def local_segment_of(self, ring: np.ndarray, n_nodes: int) -> np.ndarray:
        """Sub-range within the node's slice."""
        width = float(C_MAX) / n_nodes
        within = ring.astype(np.float64) % width
        seg = (within * self.n_local_segments / width).astype(np.int64)
        return np.clip(seg, 0, self.n_local_segments - 1).astype(np.int32)

    def place(self, data: Dict[str, np.ndarray],
              n_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
        """(node, local_segment) per row; replicated raises (caller fans
        out to every node instead)."""
        nodes, segs, _ = self.place_with_ring(data, n_nodes)
        return nodes, segs

    def place_with_ring(self, data: Dict[str, np.ndarray], n_nodes: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(node, local_segment, ring) per row.  The ring value is the
        mesh-independent ownership coordinate: stores stamp it onto WOS
        batches at commit so the segmented executor can re-derive *device*
        shard ownership (shard_of) for any mesh width without re-hashing
        the segmentation columns."""
        assert not self.replicated
        ring = self.ring_values(data)
        return (self.node_of(ring, n_nodes),
                self.local_segment_of(ring, n_nodes), ring)


def rebalance_plan(n_old: int, n_new: int,
                   n_local: int) -> List[Tuple[int, int, int]]:
    """Moves of whole local segments when the cluster resizes.

    Returns [(old_node, local_segment, new_node), ...]: every (node, seg)
    slot of the old topology whose ring range now belongs to a different
    node. Only whole-segment moves -- no file splitting (paper §3.6)."""
    moves = []
    for node in range(n_old):
        for seg in range(n_local):
            # representative ring point at the center of this sub-range
            width = float(C_MAX) / n_old
            point = node * width + (seg + 0.5) * width / n_local
            new_node = int(point * n_new / float(C_MAX))
            new_node = min(new_node, n_new - 1)
            if new_node != node:
                moves.append((node, seg, new_node))
    return moves
