"""Inter-node segmentation (paper §3.6) + local segments for elasticity.

A projection is either *replicated* (every node stores every tuple) or
*segmented* by an integral expression: the ring [0, C_MAX) is cut into N
contiguous node ranges, and within each node into ``n_local_segments``
sub-ranges. Elastic rebalance moves whole local segments between nodes
without re-splitting files -- exactly the paper's wholesale-transfer trick
(and the same mechanism our training stack reuses to re-shard data-parallel
ranks; see train/fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import C_MAX

_FNV_PRIME = np.uint64(1099511628211)
_FNV_OFFSET = np.uint64(14695981039346656037)


def hash_columns(*cols: np.ndarray) -> np.ndarray:
    """Deterministic 32-bit ring hash of one or more integral columns
    (vectorized FNV-1a over 8-byte words)."""
    h = np.full(cols[0].shape, _FNV_OFFSET, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for c in cols:
            v = np.asarray(c).astype(np.int64).view(np.uint64)
            for shift in (0, 16, 32, 48):
                h = h ^ ((v >> np.uint64(shift)) & np.uint64(0xFFFF))
                h = h * _FNV_PRIME
    return (h % C_MAX).astype(np.uint64)


def shard_of(ring: np.ndarray, n_shards: int) -> np.ndarray:
    """Offset-free ring-range assignment: the base map from a ring value to
    one of ``n_shards`` contiguous ranges.  The segmented executor
    (engine/segmented.py) uses this for *device* shard placement -- the
    same row must land on the same shard no matter which physical store
    (primary or ring-offset buddy) served it, so the buddy offset applies
    only to node routing, never here."""
    return (np.asarray(ring).astype(np.float64) * n_shards
            / float(C_MAX)).astype(np.int64).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SegmentationSpec:
    """SEGMENTED BY HASH(cols) ALL NODES / UNSEGMENTED (replicated)."""

    kind: str = "hash"                   # hash | replicated
    columns: Tuple[str, ...] = ()
    n_local_segments: int = 3            # per node, for elastic rebalance
    offset: int = 0                      # buddy projections: ring offset

    @property
    def replicated(self) -> bool:
        return self.kind == "replicated"

    def ring_values(self, data: Dict[str, np.ndarray]) -> np.ndarray:
        cols = [data[c] for c in self.columns]
        return hash_columns(*cols)

    def node_of(self, ring: np.ndarray, n_nodes: int) -> np.ndarray:
        """Ring range assignment with buddy offset (paper §5.2: a buddy
        projection's segmentation guarantees no row lands on the same node)."""
        base = shard_of(ring, n_nodes).astype(np.int64)
        return ((base + self.offset) % n_nodes).astype(np.int32)

    def local_segment_of(self, ring: np.ndarray, n_nodes: int) -> np.ndarray:
        """Sub-range within the node's slice."""
        width = float(C_MAX) / n_nodes
        within = ring.astype(np.float64) % width
        seg = (within * self.n_local_segments / width).astype(np.int64)
        return np.clip(seg, 0, self.n_local_segments - 1).astype(np.int32)

    def place(self, data: Dict[str, np.ndarray],
              n_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
        """(node, local_segment) per row; replicated raises (caller fans
        out to every node instead)."""
        nodes, segs, _ = self.place_with_ring(data, n_nodes)
        return nodes, segs

    def place_with_ring(self, data: Dict[str, np.ndarray], n_nodes: int
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(node, local_segment, ring) per row.  The ring value is the
        mesh-independent ownership coordinate: stores stamp it onto WOS
        batches at commit so the segmented executor can re-derive *device*
        shard ownership (shard_of) for any mesh width without re-hashing
        the segmentation columns."""
        assert not self.replicated
        ring = self.ring_values(data)
        return (self.node_of(ring, n_nodes),
                self.local_segment_of(ring, n_nodes), ring)


def rebalance_plan(n_old: int, n_new: int,
                   n_local: int) -> List[Tuple[int, int, int]]:
    """Moves of whole local segments when the cluster resizes.

    Returns [(old_node, local_segment, new_node), ...]: every (node, seg)
    slot of the old topology whose ring range now belongs to a different
    node. Only whole-segment moves -- no file splitting (paper §3.6)."""
    moves = []
    for node in range(n_old):
        for seg in range(n_local):
            # representative ring point at the center of this sub-range
            width = float(C_MAX) / n_old
            point = node * width + (seg + 0.5) * width / n_local
            new_node = int(point * n_new / float(C_MAX))
            new_node = min(new_node, n_new - 1)
            if new_node != node:
                moves.append((node, seg, new_node))
    return moves
