"""Recovery, refresh, rebalance, backup (paper §5.2).

All four are online: the cluster keeps serving reads/writes from live nodes
while they run (our simulation is single-threaded, but the lock discipline
matches: historical phase lock-free, current phase under an S lock).

Recovery of a rejoining node, per projection segment:
  1. truncate everything past the node's LGE (WOS already lost),
  2. historical phase (no locks): copy committed rows in (LGE, E_h] from
     the buddy -- buddies share sort orders here, so this is the paper's
     'simply copies whole ROS containers and their delete vectors' path,
  3. current phase (S lock on the anchor table): copy (E_h, current].

There is no transaction log: data + epochs ARE the log.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from .database import (AvailabilityError, RecoverySourceLostError,
                       VerticaDB)
from .faults import (NodeCrashError, TransientFaultError,
                     fire_with_retries)
from .projection import ProjectionDef
from .segmentation import rebalance_plan
from .storage import DeleteVector, ROSContainer, WOS
from .tuple_mover import ProjectionStore


def _rows_with_delete_epochs(db: VerticaDB, store: ProjectionStore,
                             lo: int, hi: int, skip_ids=frozenset()):
    """All rows (incl. deleted ones) with commit epoch in (lo, hi], plus
    their delete epochs -- the replay stream.  ``skip_ids`` excludes
    containers already copied wholesale by incremental recovery."""
    parts, dparts, eparts = [], [], []
    for c in store.containers:
        if c.id in skip_ids:
            continue
        sel = (c.epochs > lo) & (c.epochs <= hi)
        if sel.any():
            rows = c.decode_all()
            parts.append({k: v[sel] for k, v in rows.items()})
            eparts.append(c.epochs[sel])
            dparts.append(store.delete_epochs_of(c)[sel])
    data, eps, _ = store.wos.snapshot()
    if len(eps):
        sel = (eps > lo) & (eps <= hi)
        if sel.any():
            dels = (np.concatenate(store.wos_delete_epochs)
                    if store.wos_delete_epochs
                    else np.zeros(len(eps), np.int64))
            parts.append({k: v[sel] for k, v in data.items()})
            eparts.append(eps[sel])
            dparts.append(dels[sel])
    if not parts:
        return None
    cols = {c: np.concatenate([p[c] for p in parts]) for c in parts[0]}
    return cols, np.concatenate(eparts), np.concatenate(dparts)


def _install_rows(db: VerticaDB, store: ProjectionStore, node_id: int,
                  rows, epochs, delete_epochs):
    """Build ROS containers on the recovering node from a replay stream,
    keeping only rows belonging to this node's ring range."""
    proj = store.proj
    entry = db.catalog.tables[proj.anchor]
    if not proj.segmentation.replicated:
        nodes, segs = proj.segmentation.place(rows, db.catalog.n_nodes)
        sel = nodes == node_id
        rows = {c: v[sel] for c, v in rows.items()}
        epochs, delete_epochs = epochs[sel], delete_epochs[sel]
        segs = segs[sel]
    else:
        segs = np.zeros(len(epochs), np.int32)
    if len(epochs) == 0:
        return
    tmp = ProjectionStore(proj, WOS(proj.name))
    tmp.wos.append(rows, epochs, segs)
    tmp.wos_delete_epochs = [delete_epochs]
    from .tuple_mover import moveout
    new = moveout(tmp, sql_types=db._sql_types(proj), ahm=db.epochs.ahm,
                  partition_expr=entry.partition_expr,
                  block_rows=db.block_rows)
    store.containers.extend(new)
    for c in new:
        if c.id in tmp.delete_vectors:
            store.delete_vectors[c.id] = tmp.delete_vectors[c.id]
    if new:
        store.invalidate_seg_slabs(require_ids=[c.id for c in new])


def _truncate_past(db: VerticaDB, store: ProjectionStore, epoch: int):
    """Drop rows committed after ``epoch``; clear newer delete marks."""
    kept = []
    for c in store.containers:
        sel = c.epochs <= epoch
        dvs = store.delete_vectors.pop(c.id, [])
        if sel.all():
            kept.append(c)
            ndvs = []
            for dv in dvs:
                keep = dv.delete_epochs <= epoch
                if keep.any():
                    ndvs.append(DeleteVector.build(
                        c.id, dv.positions[keep],
                        dv.delete_epochs[keep]).to_ros())
            if ndvs:
                store.delete_vectors[c.id] = ndvs
            continue
        if not sel.any():
            continue
        rows = c.decode_all()
        dels = store.delete_epochs_of(c)
        dels = np.where(dels <= epoch, dels, 0)
        nc = ROSContainer.build(
            store.proj, {k: v[sel] for k, v in rows.items()},
            c.epochs[sel], sql_types=db._sql_types(store.proj),
            partition_key=c.partition_key, local_segment=c.local_segment,
            presorted=True, block_rows=db.block_rows)
        kept.append(nc)
        dpos = np.flatnonzero(dels[sel] > 0)
        if dpos.size:
            store.delete_vectors[nc.id] = [DeleteVector.build(
                nc.id, dpos, dels[sel][dpos]).to_ros()]
    retired = {c.id for c in store.containers} - {c.id for c in kept}
    store.invalidate_cached(retired)   # truncation retires containers
    store.invalidate_seg_slabs(retired_ids=retired)
    store.containers = kept


def _replay_deletes(db: VerticaDB, store: ProjectionStore,
                    src: ProjectionStore, lo: int, hi: int, node_id: int):
    """Replay DELETEs of rows that the recovering node already has (commit
    epoch <= lo) but whose delete vector (delete epoch in (lo, hi]) it
    missed while down. Rows are matched by full-tuple hash -- the data +
    epoch IS the log, there are no row ids (paper §5.2)."""
    proj = store.proj
    from .segmentation import hash_columns
    from collections import Counter
    wanted: Counter = Counter()
    epochs_for = {}
    for c in src.containers:
        de = src.delete_epochs_of(c)
        sel = (de > lo) & (de <= hi) & (c.epochs <= lo)
        if not sel.any():
            continue
        rows = c.decode_all()
        if not proj.segmentation.replicated:
            nodes_arr, _ = proj.segmentation.place(rows, db.catalog.n_nodes)
            sel &= nodes_arr == node_id
        h = hash_columns(*[rows[col].astype(np.int64)
                           if rows[col].dtype.kind != "f"
                           else rows[col].view(np.int64)
                           for col in proj.columns])
        for hv, ep in zip(h[sel].tolist(), de[sel].tolist()):
            wanted[hv] += 1
            epochs_for[hv] = ep
    if not wanted:
        return
    for c in store.containers:
        rows = c.decode_all()
        h = hash_columns(*[rows[col].astype(np.int64)
                           if rows[col].dtype.kind != "f"
                           else rows[col].view(np.int64)
                           for col in proj.columns])
        already = store.deleted_mask(c)
        pos, eps = [], []
        for i, hv in enumerate(h.tolist()):
            if wanted.get(hv, 0) > 0 and not already[i]:
                wanted[hv] -= 1
                pos.append(i)
                eps.append(epochs_for[hv])
        if pos:
            store.delete_vectors.setdefault(c.id, []).append(
                DeleteVector.build(c.id, np.asarray(pos),
                                   np.asarray(eps, np.int64)).to_ros())


def rejoin_node(db: VerticaDB, node_id: int) -> Optional[int]:
    """Phase 0 of incremental recovery: bring a failed node back online
    *without* serving reads.  Its ROS is truncated back to the LGE (the
    WOS was already lost with the failure), and from here on it receives
    every new commit -- so the epoch range it must later replay is frozen
    at (LGE, rejoin_epoch] no matter how long recovery takes or how many
    trickle loads land meanwhile.  Reads keep routing to the buddy
    (``NodeState.serving``) until ``recover_node`` completes."""
    node = db.nodes[node_id]
    if node.up:
        return node.rejoin_epoch
    node.up = True
    node.recovering = True
    node.rejoin_epoch = db.epochs.latest_queryable()
    for proj_name, store in node.stores.items():
        _truncate_past(db, store, db.epochs.get_lge(proj_name, node_id))
    return node.rejoin_epoch


def _copy_epoch_range(db: VerticaDB, store: ProjectionStore,
                      src: ProjectionStore, node_id: int,
                      lo: int, hi: int) -> Tuple[int, int]:
    """Replay commits in (lo, hi] from the buddy.  Buddy containers are
    segment-aligned with the recovering store (same ring sub-range, same
    sort order -- a buddy host holds exactly the primary segment of the
    recovering node), so any container wholly inside the epoch window is
    adopted WHOLESALE: a fresh-id clone sharing the encoded payloads and
    its delete vectors, zero decode/sort/encode (paper §4.4 'simply
    copies whole ROS containers and their delete vectors').  Only rows in
    containers straddling the window boundary replay row-wise.  Returns
    (containers adopted, rows installed)."""
    if hi <= lo:
        return 0, 0
    adopted_ids = set()
    clone_ids = []
    rows = 0
    for c in src.containers:
        if c.n_rows == 0:
            continue
        if not ((c.epochs > lo).all() and (c.epochs <= hi).all()):
            continue
        nc = c.clone(projection=store.proj.name)
        store.containers.append(nc)
        for dv in src.delete_vectors.get(c.id, []):
            store.delete_vectors.setdefault(nc.id, []).append(
                DeleteVector.build(nc.id, dv.positions,
                                   dv.delete_epochs).to_ros())
        adopted_ids.add(c.id)
        clone_ids.append(nc.id)
        rows += c.n_rows
    if clone_ids:
        # adoption grows the container set exactly like a moveout does:
        # slabs built before it can never match a future lookup (their
        # keys lack the new ids) -- free their HBM now, precisely
        store.invalidate_seg_slabs(require_ids=clone_ids)
    stream = _rows_with_delete_epochs(db, src, lo, hi,
                                      skip_ids=adopted_ids)
    if stream:
        _install_rows(db, store, node_id, *stream)
        rows += len(stream[1])
    return len(adopted_ids), rows


def recover_node(db: VerticaDB, node_id: int, *,
                 historical_lag: int = 1) -> Dict[str, int]:
    """Recover a failed or rejoined node incrementally: replay ONLY the
    epochs it missed while down, (LGE, rejoin_epoch], from the buddy --
    commits after the rejoin already landed on it live.  Returns rows
    replayed per projection; adoption/replay counts land in
    ``node.last_recovery``."""
    node = db.nodes[node_id]
    if node.up and not node.recovering:
        return {}
    if not node.up:                     # direct call: rejoin now
        rejoin_node(db, node_id)
    e_join = node.rejoin_epoch
    current = db.epochs.latest_queryable()
    replayed: Dict[str, int] = {}
    adopted_total = 0
    complete = True
    failed: Dict[str, Tuple[int, ...]] = {}
    window_lo: Optional[int] = None
    for proj_name, store in node.stores.items():
        proj = db.catalog.projections[proj_name]
        lge = db.epochs.get_lge(proj_name, node_id)
        # the historical/current boundary must never fall below the LGE or
        # the current phase would re-install rows the node already has
        e_h = max(lge, e_join - historical_lag)
        try:
            # injection point fires BEFORE any replay state mutates: a
            # crash or exhausted transient here leaves this projection
            # cleanly un-replayed (its per-projection LGE is untouched,
            # so a later recover_node retry is idempotent)
            fire_with_retries(db, "recovery.replay", node=node_id,
                              projection=proj_name)
            src = _buddy_source(db, proj, node_id)
        except NodeCrashError as e:
            if e.node == node_id:
                raise       # the recovering node itself died again
            src = None      # the replay source crashed under us
        except TransientFaultError:
            src = None      # buddy unreachable after the retry budget
        if src is None:
            # no live replay source.  With K=0 (no buddy exists) there is
            # nothing to ever replay from -- proceed.  But if a buddy
            # EXISTS and is merely down/recovering, going back to serving
            # now would silently drop every epoch in (LGE, rejoin]: stay
            # in recovering state so a later recover_node can retry.
            if lge < e_join and _replay_source_exists(db, proj):
                complete = False
                failed[proj_name] = (node_id,)
                window_lo = lge if window_lo is None \
                    else min(window_lo, lge)
            continue
        # historical phase: (LGE, e_h], no locks
        total = 0
        a, r = _copy_epoch_range(db, store, src, node_id, lge, e_h)
        adopted_total += a
        total += r
        _replay_deletes(db, store, src, lge, e_h, node_id)
        db.epochs.set_lge(proj_name, node_id, e_h)
        # current phase: (e_h, rejoin] under a Shared lock; deletes replay
        # through `current` -- a delete committed while the node was
        # recovering targeted rows it did not have yet
        db.locks.acquire(proj.anchor, f"recover-{node_id}", "S")
        try:
            a, r = _copy_epoch_range(db, store, src, node_id, e_h, e_join)
            adopted_total += a
            total += r
            _replay_deletes(db, store, src, e_h, current, node_id)
            db.epochs.set_lge(proj_name, node_id, e_join)
        finally:
            db.locks.release_all(f"recover-{node_id}")
        replayed[proj_name] = total
    node.last_recovery = {"adopted_containers": adopted_total,
                          "replayed_rows": sum(replayed.values()),
                          "replay_hi": e_join,
                          "complete": complete}
    if complete:
        node.recovering = False
        node.rejoin_epoch = None
        node.stale_since = None
        return replayed
    # LOUD incomplete (never silently partial): the node STAYS in
    # recovering state -- buddies keep serving its segments where they
    # can, commits keep landing on it, and a later recover_node retry
    # (once the replay source is back) completes.  The typed error
    # carries exactly which projections/segments still owe which epochs.
    raise RecoverySourceLostError(node_id, failed,
                                  window=(window_lo, e_join))


def _replay_source_exists(db: VerticaDB, proj: ProjectionDef) -> bool:
    """Whether a replay source for this projection exists AT ALL (live or
    not) -- distinguishes 'buddy temporarily unavailable' (recovery must
    wait) from K=0 'no buddy was ever kept' (nothing to replay from)."""
    if proj.segmentation.replicated:
        return db.catalog.n_nodes > 1
    if proj.buddy_of is not None:
        return True
    return (proj.name + "_b1") in db.catalog.projections


def _buddy_source(db: VerticaDB, proj: ProjectionDef,
                  node_id: int) -> Optional[ProjectionStore]:
    """The live store that holds this node's rows: the buddy projection's
    store on the offset node (or, for a buddy/replicated projection, the
    primary's).  Opening the source is an injection point
    (``recovery.buddy_read``): transients retry with backoff; a crash or
    an exhausted budget propagates for recover_node to record the
    projection as source-lost."""
    if proj.segmentation.replicated:
        for n in db.nodes:
            if n.serving() and n.id != node_id:
                return _open_source(db, n.id, proj.name,
                                    n.stores[proj.name])
        return None
    if proj.buddy_of is not None:
        primary = db.catalog.projections[proj.buddy_of]
        # rows this buddy-node stores = primary segment of (node - offset)
        src_node = db.nodes[(node_id - proj.segmentation.offset)
                            % db.catalog.n_nodes]
        if src_node.serving():
            return _open_source(db, src_node.id, primary.name,
                                src_node.stores[primary.name])
        return None
    buddy = db.catalog.projections.get(proj.name + "_b1")
    if buddy is None:
        return None
    host = (node_id + buddy.segmentation.offset) % db.catalog.n_nodes
    if db.nodes[host].serving():
        return _open_source(db, host, buddy.name,
                            db.nodes[host].stores[buddy.name])
    return None


def _open_source(db: VerticaDB, host: int, proj_name: str,
                 store: ProjectionStore) -> ProjectionStore:
    fire_with_retries(db, "recovery.buddy_read", node=host,
                      projection=proj_name)
    return store


def refresh_projection(db: VerticaDB, proj_name: str):
    """Populate a projection created after its table was loaded (§5.2):
    historical phase from the super projection, current under S lock."""
    proj = db.catalog.projections[proj_name]
    current = db.epochs.latest_queryable()
    sp = db.catalog.super_of(proj.anchor)
    rows = db.read_projection(sp.name, as_of=current)
    base = {c: rows[c] for c in proj.columns if c in rows}
    if proj.prejoin is not None:
        base = db._project_rows(proj, rows)
    n = len(next(iter(base.values()))) if base else 0
    if n == 0:
        return
    epochs = np.full(n, max(current, 1), np.int64)
    dels = np.zeros(n, np.int64)
    db.locks.acquire(proj.anchor, "refresh", "S")
    try:
        for node in db.nodes:
            if not node.up:
                continue
            store = node.stores[proj_name]
            if proj.segmentation.replicated:
                _install_rows(db, store, node.id, base, epochs, dels)
            else:
                _install_rows(db, store, node.id, base, epochs, dels)
            db.epochs.set_lge(proj_name, node.id, current)
    finally:
        db.locks.release_all("refresh")


def rebalance(db: VerticaDB, new_n_nodes: int) -> int:
    """Elastic resize: move whole local segments to the new topology
    (paper §3.6 'local segments'), then re-register stores. Returns the
    number of segment moves."""
    old_n = db.catalog.n_nodes
    if new_n_nodes == old_n:
        return 0
    from .database import NodeState
    # snapshot all rows per projection before resizing
    snapshots = {}
    for proj in list(db.catalog.projections.values()):
        parts = []
        for node in db.nodes:
            st = node.stores.get(proj.name)
            if st is None:
                continue
            stream = _rows_with_delete_epochs(db, st, 0,
                                              db.epochs.latest_queryable())
            if stream:
                parts.append(stream)
        snapshots[proj.name] = parts
    moves = rebalance_plan(old_n, new_n_nodes, 3)
    # rebuild topology
    if new_n_nodes > old_n:
        for i in range(old_n, new_n_nodes):
            db.nodes.append(NodeState(i))
            for proj in db.catalog.projections.values():
                db.nodes[i].stores[proj.name] = ProjectionStore(
                    proj, WOS(proj.name))
    else:
        db.nodes = db.nodes[:new_n_nodes]
    db.catalog.n_nodes = new_n_nodes
    # redistribute (wholesale per projection; the plan above is the
    # accounting of which local segments physically move)
    for proj in db.catalog.projections.values():
        for node in db.nodes:
            node.stores[proj.name] = ProjectionStore(proj, WOS(proj.name))
        for rows, eps, dels in snapshots.get(proj.name, []):
            if proj.segmentation.replicated:
                for node in db.nodes:
                    _install_rows(db, node.stores[proj.name], node.id,
                                  rows, eps, dels)
            else:
                nodes_arr, _ = proj.segmentation.place(rows, new_n_nodes)
                for nid in np.unique(nodes_arr):
                    _install_rows(db, db.nodes[int(nid)].stores[proj.name],
                                  int(nid), rows, eps, dels)
        for node in db.nodes:
            db.epochs.set_lge(proj.name, node.id,
                              db.epochs.latest_queryable())
    return len(moves)


def backup(db: VerticaDB) -> Dict:
    """Snapshot backup: catalog + references to immutable containers (the
    'hard link' trick -- containers are never modified, so references
    suffice; no data copy)."""
    img = {"epoch": db.epochs.latest_queryable(), "catalog": db.catalog,
           "nodes": {}}
    for node in db.nodes:
        img["nodes"][node.id] = {
            p: {"containers": list(st.containers),
                "delete_vectors": {k: list(v) for k, v in
                                   st.delete_vectors.items()}}
            for p, st in node.stores.items()}
    return img


def restore(db: VerticaDB, img: Dict):
    db.catalog = img["catalog"]
    for node in db.nodes:
        for p, snap in img["nodes"].get(node.id, {}).items():
            st = node.stores[p]
            st.containers = list(snap["containers"])
            st.delete_vectors = {k: list(v) for k, v in
                                 snap["delete_vectors"].items()}
            st.wos.clear()
            st.wos_delete_epochs = []
    db.epochs.current_epoch = img["epoch"] + 1
    # the epoch counter rolls BACK: epoch-keyed valid@{epoch} cache
    # entries from the abandoned timeline would otherwise be revived
    # once the counter re-reaches their epoch -- drop everything
    db.block_cache.clear()
