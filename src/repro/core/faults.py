"""Deterministic, seeded fault injection for the simulated cluster.

The paper's availability claim (§4.3 K-safety, §4.9 recovery) is only as
good as the failure timings it survives.  This module lets tests (and the
chaos tier in scripts/verify.sh) splice failures into *named injection
points* threaded through the stack -- commit apply, tuple-mover passes,
recovery replay, buddy reads, per-shard slab builds, exchange
collectives, serving admission and shared scans (the canonical list is
:data:`INJECTION_POINTS`) -- with programmable schedules:

    inj = db.enable_faults(seed=7)
    inj.on("exchange.resegment", CrashNode(node=2), hit=3)
    inj.on("recovery.buddy_read", Transient(), times=2)
    inj.chaos(("commit.apply", "tuple_mover.moveout"),
              p=0.05, action=CrashNode())        # seeded probabilistic

Everything is deterministic given the seed: per-point hit counters drive
nth-hit schedules, and probabilistic rules draw from one
``np.random.default_rng(seed)`` in firing order.

Failure taxonomy (what a fired action raises):

* ``NodeCrashError`` -- a node died (the action already called
  ``db.fail_node``).  Never retried at the injection site; it propagates
  to the *query* level, where ``engine.pipeline.execute`` replans onto
  buddies at the same pinned epoch (bounded failover retry).
* ``TransientFaultError`` -- a recoverable blip (network hiccup, slow
  peer).  Injection sites wrap their work in :func:`with_retries`, which
  retries with exponential backoff; exhaustion escalates to the caller's
  typed degradation error (``QueryRejectedError`` for queries,
  ``RecoverySourceLostError`` for recovery).
* ``FaultTimeout`` -- an attempt exceeded the per-attempt timeout (e.g.
  a ``Hang`` action); subclasses ``TransientFaultError`` so it retries
  the same way.

The default ``db.faults`` is a :class:`NullInjector` whose ``fire`` is a
no-op -- production paths pay two attribute lookups, nothing else.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from contextlib import contextmanager
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# every named injection point threaded through the stack, the canonical
# registry for docs and chaos sweeps (tests iterate this so a new point
# cannot be forgotten by the chaos tier).  The serving.* points land in
# engine/serving.py: ``serving.admit`` fires per admission decision
# (before anything is pinned or queued), ``serving.rate_limit`` per
# token-bucket check (also pre-pin), ``serving.shared_scan`` once per
# coalesced scan attempt (a crash there exercises multi-query failover),
# ``serving.dispatch`` once per dispatch unit as its device programs
# launch, and ``serving.drain`` once per unit as its parked futures are
# harvested (a crash there exercises the mid-flight drain failover, a
# Hang there simulates a slow query stalling the drain stage).
INJECTION_POINTS = (
    "commit.apply",
    "tuple_mover.moveout",
    "tuple_mover.mergeout",
    "recovery.replay",
    "recovery.buddy_read",
    "segmented.slab_build",
    "segmented.buddy_read",
    "exchange.resegment",
    "exchange.broadcast",
    "serving.admit",
    "serving.rate_limit",
    "serving.shared_scan",
    "serving.dispatch",
    "serving.drain",
)


class FaultError(Exception):
    """Base class of injected failures."""


class TransientFaultError(FaultError):
    """A recoverable blip: the injection site retries with backoff."""


class FaultTimeout(TransientFaultError):
    """An attempt exceeded its per-attempt timeout budget."""

    def __init__(self, point: str, elapsed_s: float, budget_s: float):
        self.point, self.elapsed_s, self.budget_s = point, elapsed_s, \
            budget_s
        super().__init__(f"{point}: attempt took {elapsed_s:.3f}s "
                         f"(budget {budget_s:.3f}s)")


class NodeCrashError(FaultError):
    """A node failed at this point (``db.fail_node`` already ran)."""

    def __init__(self, node: int, point: str):
        self.node, self.point = node, point
        super().__init__(f"node {node} crashed at {point}")


# ---------------------------------------------------------------------------
# actions: callables (db, point, ctx, rng) -> None, raising to signal
# ---------------------------------------------------------------------------

class CrashNode:
    """Fail a node at the point.  ``node=None`` crashes the node named in
    the firing context (the one being operated on), falling back to a
    seeded-random up node for node-less points (exchange collectives).

    ``respect_k_safety=True`` turns the action into a no-op while any
    OTHER node is not serving: a second simultaneous failure would exceed
    K=1 (losing a buddy pair loses the WOS of both copies of a segment --
    the paper's cluster-down case, unrecoverable by design).  Chaos
    schedules over DML streams that must converge with a never-failed
    reference use this; query-only chaos may crash freely, because reads
    degrade to typed errors instead of losing state."""

    def __init__(self, node: Optional[int] = None, *,
                 respect_k_safety: bool = False):
        self.node = node
        self.respect_k_safety = respect_k_safety

    def __call__(self, db, point: str, ctx: dict, rng) -> None:
        nid = self.node
        if nid is None:
            nid = ctx.get("node")
        if nid is None:
            cands = [n.id for n in db.nodes if n.up]
            if not cands:
                return
            nid = int(cands[int(rng.integers(len(cands)))])
        if self.respect_k_safety and db is not None and \
                any(not n.serving() for n in db.nodes if n.id != nid):
            return
        if db is not None and db.nodes[nid].up:
            db.fail_node(nid)
        raise NodeCrashError(int(nid), point)

    def __repr__(self):
        return f"CrashNode(node={self.node})"


class Transient:
    """Raise a retryable TransientFaultError."""

    def __init__(self, message: str = "injected transient fault"):
        self.message = message

    def __call__(self, db, point: str, ctx: dict, rng) -> None:
        raise TransientFaultError(f"{point}: {self.message}")

    def __repr__(self):
        return "Transient()"


class Hang:
    """Stall the attempt (does not raise): the per-attempt timeout in
    :func:`with_retries` converts the slow attempt into a FaultTimeout,
    which retries like a transient -- a hung peer must fail the attempt,
    not wedge the query.

    When the firing context carries a ``clock`` (the serving layer passes
    its scheduler clock at ``serving.dispatch``/``serving.drain``), the
    hang sleeps on THAT clock -- under a virtual clock the stall advances
    simulated time with no wall-clock sleep, so slow-query schedules
    replay deterministically (engine/serving.VirtualClock)."""

    def __init__(self, seconds: float = 0.05):
        self.seconds = seconds

    def __call__(self, db, point: str, ctx: dict, rng) -> None:
        clock = ctx.get("clock")
        if clock is not None:
            clock.sleep(self.seconds)
        else:
            time.sleep(self.seconds)

    def __repr__(self):
        return f"Hang({self.seconds})"


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Rule:
    point: str
    action: Callable
    after: int = 0               # skip the first N eligible hits
    times: Optional[int] = None  # fire at most N times (None = forever)
    p: Optional[float] = None    # probabilistic (seeded) instead of nth-hit
    node: Optional[int] = None   # only hits whose ctx names this node
    seen: int = 0                # eligible hits observed
    fired: int = 0               # times actually fired


class NullInjector:
    """Default ``db.faults``: injection disabled, ``fire`` is a no-op."""

    is_null = True
    total_fired = 0
    paused = False

    def fire(self, point: str, **ctx) -> None:
        return None

    def fired(self, point: str) -> int:
        return 0

    def hit_count(self, point: str) -> int:
        return 0

    @contextmanager
    def suspended(self):
        yield self


NULL_INJECTOR = NullInjector()


class FaultInjector:
    """Seeded, deterministic fault scheduler (see module docstring).

    Retry policy knobs consumed by :func:`with_retries`:
    ``max_attempts`` (per injection site, default 3), ``backoff_s``
    (base of the exponential backoff, default 0 so tests stay fast) and
    ``attempt_timeout_s`` (per-attempt budget; None disables)."""

    is_null = False

    def __init__(self, db=None, seed: Optional[int] = None, *,
                 max_attempts: int = 3, backoff_s: float = 0.0,
                 attempt_timeout_s: Optional[float] = None):
        self.db = db
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.rules: List[_Rule] = []
        self.hits: Counter = Counter()     # per-point deterministic count
        self.log: List[Tuple[str, dict]] = []   # (point, ctx) per firing
        self.total_fired = 0
        self.paused = False
        self.max_attempts = max_attempts
        self.backoff_s = backoff_s
        self.attempt_timeout_s = attempt_timeout_s

    # ------------------------------------------------------- scheduling --

    def on(self, point: str, action: Callable, *, hit: Optional[int] = None,
           after: int = 0, times: Optional[int] = None,
           p: Optional[float] = None,
           node: Optional[int] = None) -> "FaultInjector":
        """Register a schedule: fire ``action`` at ``point``.

        ``hit=N`` fires exactly on the Nth eligible hit (sugar for
        ``after=N-1, times=1``); ``after``/``times`` window repeated
        firings; ``p`` makes the rule probabilistic (one seeded draw per
        eligible hit); ``node`` restricts to hits whose context names
        that node."""
        if hit is not None:
            after, times = hit - 1, 1
        self.rules.append(_Rule(point, action, after=after, times=times,
                                p=p, node=node))
        return self

    def chaos(self, points: Sequence[str], *, p: float,
              action: Optional[Callable] = None,
              times: Optional[int] = None) -> "FaultInjector":
        """Probabilistic schedule over many points at once."""
        act = action if action is not None else CrashNode()
        for pt in points:
            self.on(pt, act, p=p, times=times)
        return self

    @contextmanager
    def suspended(self):
        """Temporarily disable firing (e.g. while a test repairs the
        cluster between chaos rounds) without resetting counters."""
        prev, self.paused = self.paused, True
        try:
            yield self
        finally:
            self.paused = prev

    # ----------------------------------------------------------- firing --

    def fire(self, point: str, **ctx) -> None:
        """Hit an injection point.  Deterministically evaluates every
        matching rule; a triggered action may raise (see taxonomy)."""
        self.hits[point] += 1
        if self.paused:
            return
        for rule in self.rules:
            if rule.point != point:
                continue
            if rule.node is not None and ctx.get("node") != rule.node:
                continue
            rule.seen += 1
            if rule.times is not None and rule.fired >= rule.times:
                continue
            if rule.p is not None:
                if float(self.rng.random()) >= rule.p:
                    continue
            elif rule.seen <= rule.after:
                continue
            rule.fired += 1
            self.total_fired += 1
            self.log.append((point, dict(ctx)))
            rule.action(self.db, point, ctx, self.rng)

    def fired(self, point: str) -> int:
        return sum(r.fired for r in self.rules if r.point == point)

    def hit_count(self, point: str) -> int:
        return int(self.hits[point])


# ---------------------------------------------------------------------------
# retry-with-backoff wrapper used at transient-tolerant injection sites
# ---------------------------------------------------------------------------

def with_retries(db, point: str, fn: Callable, *, stats=None,
                 attempts: Optional[int] = None,
                 backoff_s: Optional[float] = None,
                 timeout_s: Optional[float] = None, **ctx):
    """Fire ``point`` then run ``fn()``, retrying TransientFaultError /
    per-attempt timeouts with exponential backoff.  NodeCrashError is
    never retried here (node loss is a *query*-level failover, not an
    attempt-level blip).  Exhausted attempts re-raise the last transient
    for the caller to escalate into its typed degradation error.  With
    the NullInjector this is exactly ``fn()``."""
    inj = getattr(db, "faults", None) if db is not None else None
    if inj is None or inj.is_null:
        return fn()
    n_attempts = attempts if attempts is not None else inj.max_attempts
    backoff = inj.backoff_s if backoff_s is None else backoff_s
    budget = inj.attempt_timeout_s if timeout_s is None else timeout_s
    last: Optional[TransientFaultError] = None
    for k in range(max(n_attempts, 1)):
        t0 = time.monotonic()
        try:
            inj.fire(point, **ctx)
            out = fn()
        except TransientFaultError as e:
            last = e
        else:
            elapsed = time.monotonic() - t0
            if budget is not None and elapsed > budget:
                last = FaultTimeout(point, elapsed, budget)
            else:
                return out
        if stats is not None and hasattr(stats, "fault_retries"):
            stats.fault_retries += 1
        if backoff and k + 1 < n_attempts:
            time.sleep(backoff * (2 ** k))
    raise TransientFaultError(
        f"{point}: {n_attempts} attempt(s) exhausted") from last


def fire_with_retries(db, point: str, *, stats=None, **ctx) -> None:
    """A bare injection point (no wrapped work): transients are absorbed
    by the retry loop, crashes and exhausted transients propagate."""
    with_retries(db, point, lambda: None, stats=stats, **ctx)
