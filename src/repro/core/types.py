"""Core columnar types for the Vertica-in-JAX engine.

Block geometry
--------------
Vertica stores column data in ~64KB disk blocks with a per-block position
index entry (min/max/start).  On TPU the analogous unit is a VMEM-tile-aligned
block of rows: every column in a ROS container is stored block-structured,
``(n_blocks, BLOCK_ROWS)`` after decode, so that block pruning (SMA min/max)
maps onto masking whole tiles and scan kernels can tile HBM->VMEM transfers.

Rows are identified by *position* (implicit ordinal within the container),
exactly as in the paper -- positions are never materialized.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Default rows per block.  8 sublanes x 128 lanes x 4 = TPU friendly; also a
# realistic analogue of Vertica's 64KB blocks (4096 x 8B ints = 32KB).
BLOCK_ROWS = 4096

# Ring size for segmentation.  The paper uses C_MAX = 2^64; we use 2^32
# because jax defaults to 32-bit integers (DESIGN.md deviation note).
C_MAX = np.uint64(1) << np.uint64(32)


class SQLType(enum.Enum):
    """Logical column types (the commercial system's FLOAT/VARCHAR lesson:
    C-Store supported only INTEGER; supporting real types is table stakes)."""

    INT = "int"          # stored int64 host-side, int32 on device when safe
    FLOAT = "float"      # stored float64 host-side, float32 on device
    VARCHAR = "varchar"  # dictionary-encoded to int codes at ingest

    @property
    def np_dtype(self) -> np.dtype:
        return {
            SQLType.INT: np.dtype(np.int64),
            SQLType.FLOAT: np.dtype(np.float64),
            SQLType.VARCHAR: np.dtype(np.int64),  # code space
        }[self]


@dataclasses.dataclass(frozen=True)
class ColumnDef:
    name: str
    sql_type: SQLType = SQLType.INT
    nullable: bool = False


@dataclasses.dataclass(frozen=True)
class TableSchema:
    name: str
    columns: Tuple[ColumnDef, ...]
    partition_by: Optional[str] = None  # expression name, see partitioning.py

    def column(self, name: str) -> ColumnDef:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"no column {name!r} in table {self.name!r}")

    def column_names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    def __contains__(self, name: str) -> bool:
        return any(c.name == name for c in self.columns)


def num_blocks(n_rows: int, block_rows: int = BLOCK_ROWS) -> int:
    return max(1, -(-n_rows // block_rows))


def pad_to_blocks(values: np.ndarray, block_rows: int = BLOCK_ROWS,
                  pad_value: Any = 0) -> np.ndarray:
    """Pad a 1-D array to a whole number of blocks and reshape to 2-D."""
    n = values.shape[0]
    nb = num_blocks(n, block_rows)
    padded = np.full(nb * block_rows, pad_value, dtype=values.dtype)
    padded[:n] = values
    return padded.reshape(nb, block_rows)


def nullable_to_sentinel(values: np.ndarray, mask: Optional[np.ndarray],
                         sql_type: SQLType) -> np.ndarray:
    """SQL NULL handling: NULLs are carried as a sentinel + validity mask.

    The paper lists "processing SQL NULLs, which often have to be special
    cased" among the features added over C-Store; we carry an explicit
    validity bitmap per column (see storage.EncodedColumn.valid).
    """
    if mask is None:
        return values
    out = values.copy()
    if sql_type == SQLType.FLOAT:
        out[~mask] = np.nan
    else:
        out[~mask] = 0
    return out
