"""Device-resident block cache: a byte-budgeted LRU over column blocks.

Vertica's execution engine is fast because the blocks it scans are already
sitting in the OS page cache, still encoded (paper §6: the EE operates on
encoded data wherever it can, and §7 credits warm scans for most of the
production speedup).  Our analog keeps *device* (HBM) copies of container
column payloads -- both the encoded arrays and the decoded
``(n_blocks, block_rows)`` blocks -- so a repeat query never re-uploads or
re-decodes a column it has already touched.

Keys are ``(container_id, column, kind)``.  ROS containers are immutable
(§3.7), which makes this cache trivially coherent: an entry can only go
stale when its container is *retired*, so invalidation hooks live exactly
where containers die --

  * ``tuple_mover.mergeout``    -- merged-away containers,
  * ``database._apply_delete``  -- containers gaining a delete vector
                                   (defensive: masks are keyed by epoch,
                                   but eager eviction keeps DV rewrites
                                   honest),
  * ``database.drop_partition`` -- dropped containers.

Budget accounting is by device bytes; eviction is two-tier LRU: derived
entries (decoded blocks, slabs, union scans) evict strictly LRU-first, and
only when none remain do the *packed* ``KIND_ENCODED`` payloads go -- they
are the compressed-domain executor's ground truth, typically 2-8x smaller
than their decoded form, and everything else can be recomputed from them
on device without another host upload (``protect_packed=False`` restores
the flat LRU for baseline measurements).  The cache is
deliberately jax-agnostic: values are opaque, sizes are passed in by the
caller (engine/executor.py computes them from array shapes), so host-only
storage code can import this module without pulling in jax.

See DESIGN.md §11 ("Block cache & plan cache").
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, Hashable, Iterable, Optional, Tuple

# (container_id, column, kind); container_id is an int for physical ROS
# containers, or a string namespace for derived entries ("dim:<table>"
# build sides, "seg:<projection>" partitioned slabs) whose column field
# may itself be a structured tuple key
CacheKey = Tuple[int, str, str]

# entry kinds used by the executor
KIND_ENCODED = "encoded"                  # dict of device payload arrays
KIND_DECODED = "decoded"                  # (n_blocks, block_rows) device array
KIND_SEG = "segmented"                    # per-shard partitioned scan slabs
KIND_WOS = "wos_slab"                     # per-shard device WOS buffers
KIND_UNION = "union_scan"                 # serving-tier assembled union scans


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    insertions: int = 0
    evictions: int = 0
    invalidations: int = 0
    bytes_in_use: int = 0
    # admission-control working-set reservations (engine/serving.py)
    reserved_bytes: int = 0
    peak_reserved_bytes: int = 0

    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class BlockCache:
    """Byte-budgeted LRU of device-resident column blocks."""

    def __init__(self, budget_bytes: int = 256 << 20, *,
                 protect_packed: bool = True):
        assert budget_bytes > 0
        self.budget_bytes = int(budget_bytes)
        self.protect_packed = protect_packed
        self.stats = CacheStats()
        # key -> (value, nbytes); insertion order == LRU order
        self._entries: "OrderedDict[CacheKey, Tuple[Any, int]]" = \
            OrderedDict()
        # container_id -> set of its keys (for O(keys-of-container)
        # invalidation when the tuple mover retires it)
        self._by_container: Dict[int, set] = {}

    # ------------------------------------------------------------ reads --

    def get(self, container_id: int, column: str, kind: str) -> Optional[Any]:
        key = (container_id, column, kind)
        hit = self._entries.get(key)
        if hit is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return hit[0]

    def get_or_put(self, container_id: int, column: str, kind: str,
                   factory, nbytes_of) -> Any:
        """Fetch, or build via ``factory()`` and insert with
        ``nbytes_of(value)`` bytes charged."""
        v = self.get(container_id, column, kind)
        if v is None:
            v = factory()
            self.put(container_id, column, kind, v, int(nbytes_of(v)))
        return v

    # ----------------------------------------------------------- writes --

    def put(self, container_id: int, column: str, kind: str, value: Any,
            nbytes: int) -> bool:
        """Insert (or refresh) an entry; returns False when the item alone
        exceeds the budget (never cached -- a scan larger than HBM budget
        must stream)."""
        nbytes = int(nbytes)
        if nbytes > self.budget_bytes:
            return False
        key = (container_id, column, kind)
        old = self._entries.pop(key, None)
        if old is not None:
            self.stats.bytes_in_use -= old[1]
        self._entries[key] = (value, nbytes)
        self._by_container.setdefault(container_id, set()).add(key)
        self.stats.bytes_in_use += nbytes
        self.stats.insertions += 1
        self._evict_to_budget()
        return True

    def _evict_to_budget(self):
        while self.stats.bytes_in_use > self.budget_bytes and self._entries:
            key = next(iter(self._entries))          # LRU head
            if self.protect_packed and key[2] == KIND_ENCODED:
                # packed payloads go last: evict the LRU-first *derived*
                # entry instead, if any derived entry remains
                key = next((k for k in self._entries
                            if k[2] != KIND_ENCODED), key)
            _, nbytes = self._entries.pop(key)
            self.stats.bytes_in_use -= nbytes
            self.stats.evictions += 1
            keys = self._by_container.get(key[0])
            if keys is not None:
                keys.discard(key)
                if not keys:
                    del self._by_container[key[0]]

    # ----------------------------------------- working-set reservations --
    # Admission control (engine/serving.py) charges each dispatched query
    # mix's estimated decoded working set here before executing it.
    # Reservations never insert or evict entries -- the LRU handles actual
    # residency -- they bound how much NEW working set concurrently
    # admitted queries may open at once against the same byte budget the
    # LRU answers to, which is the paper's "resource manager sizes
    # concurrent query budgets against physical memory" (§7).
    #
    # Under the pipelined serving core a reservation is held from device
    # DISPATCH until the drain stage harvests the unit's futures, so many
    # units' reservations overlap; ``take`` hands out a Reservation token
    # whose ``release`` is idempotent -- dispatch-crash, drain-crash and
    # normal-completion paths may all try to release, exactly one wins.

    def take(self, nbytes: int) -> "Reservation":
        self.reserve(nbytes)
        return Reservation(self, int(nbytes))

    def reserve(self, nbytes: int) -> int:
        self.stats.reserved_bytes += int(nbytes)
        self.stats.peak_reserved_bytes = max(self.stats.peak_reserved_bytes,
                                             self.stats.reserved_bytes)
        return self.stats.reserved_bytes

    def release(self, nbytes: int) -> int:
        self.stats.reserved_bytes = max(0,
                                        self.stats.reserved_bytes
                                        - int(nbytes))
        return self.stats.reserved_bytes

    def headroom(self) -> int:
        """Budget bytes not yet claimed by a live reservation."""
        return max(0, self.budget_bytes - self.stats.reserved_bytes)

    # ----------------------------------------------------- invalidation --

    def invalidate_container(self, container_id: int) -> int:
        """Drop every entry of one (retired) container; returns the number
        of entries evicted."""
        keys = self._by_container.pop(container_id, None)
        if not keys:
            return 0
        n = 0
        for key in keys:
            ent = self._entries.pop(key, None)
            if ent is not None:
                self.stats.bytes_in_use -= ent[1]
                self.stats.invalidations += 1
                n += 1
        return n

    def invalidate_containers(self, ids: Iterable[int]) -> int:
        return sum(self.invalidate_container(cid) for cid in ids)

    def invalidate_where(self, container_id, pred) -> int:
        """Drop the subset of one container-id's entries whose key
        satisfies ``pred(key)`` -- precise invalidation for composite
        entries (the segmented executor's ``seg:<projection>`` slabs key
        each entry by the exact (container set, WOS state, epoch, mesh)
        it was built from, so retiring ONE container evicts exactly the
        slabs that referenced it, not the projection's whole slab set)."""
        keys = self._by_container.get(container_id)
        if not keys:
            return 0
        dead = [k for k in keys if pred(k)]
        n = 0
        for key in dead:
            keys.discard(key)
            ent = self._entries.pop(key, None)
            if ent is not None:
                self.stats.bytes_in_use -= ent[1]
                self.stats.invalidations += 1
                n += 1
        if not keys:
            self._by_container.pop(container_id, None)
        return n

    def clear(self):
        self._entries.clear()
        self._by_container.clear()
        self.stats.bytes_in_use = 0

    # ------------------------------------------------------------- misc --

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries.keys())


class Reservation:
    """A live working-set reservation against one BlockCache budget.
    ``release()`` returns the bytes exactly once no matter how many
    failure/completion paths call it."""

    __slots__ = ("cache", "nbytes", "live")

    def __init__(self, cache: "BlockCache", nbytes: int):
        self.cache = cache
        self.nbytes = nbytes
        self.live = True

    def release(self) -> None:
        if self.live:
            self.live = False
            self.cache.release(self.nbytes)
