"""Projections (paper §3.1-§3.3): the only physical structure.

* Every table gets at least one *super projection* with all columns (the
  paper dropped C-Store's join indices -- so do we; there is no other way to
  reconstruct full tuples).
* Non-super projections carry a column subset with their own sort order and
  segmentation.
* Prejoin projections denormalize N:1 joins of the anchor table with
  dimension tables at load time.
* Every projection gets a *buddy* (ring-offset segmentation) when K-safety
  K >= 1; replicated projections are their own buddy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from .encodings import Encoding
from .segmentation import SegmentationSpec
from .types import TableSchema


@dataclasses.dataclass(frozen=True)
class PrejoinSpec:
    """Join the anchor's fact rows with one dimension table at load.

    anchor_key: FK column in the anchor table
    dim_table / dim_key: dimension table and its (unique) join key
    dim_columns: dimension attributes materialized into the projection,
                 stored under 'dimtable.col' names.
    """
    anchor_key: str
    dim_table: str
    dim_key: str
    dim_columns: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ProjectionDef:
    name: str
    anchor: str                          # anchoring table name
    columns: Tuple[str, ...]             # in storage order
    sort_order: Tuple[str, ...]          # prefix of columns to sort by
    segmentation: SegmentationSpec
    encodings: Dict[str, Encoding] = dataclasses.field(default_factory=dict)
    is_super: bool = False
    buddy_of: Optional[str] = None       # name of the primary this buddies
    prejoin: Optional[PrejoinSpec] = None

    def encoding_for(self, col: str) -> Encoding:
        return self.encodings.get(col, Encoding.AUTO)

    def buddy_def(self) -> "ProjectionDef":
        """The K=1 buddy: same columns/sort, ring offset +1 (paper §5.2)."""
        if self.segmentation.replicated:
            return self  # replicas are their own buddies
        seg = dataclasses.replace(self.segmentation,
                                  offset=self.segmentation.offset + 1)
        return dataclasses.replace(self, name=self.name + "_b1",
                                   segmentation=seg, buddy_of=self.name)


def super_projection(schema: TableSchema, sort_order: Tuple[str, ...],
                     seg_columns: Tuple[str, ...],
                     encodings: Optional[Dict[str, Encoding]] = None,
                     n_local_segments: int = 3) -> ProjectionDef:
    cols = schema.column_names()
    assert all(c in cols for c in sort_order)
    seg = SegmentationSpec("hash", tuple(seg_columns),
                           n_local_segments=n_local_segments) \
        if seg_columns else SegmentationSpec("replicated")
    return ProjectionDef(
        name=f"{schema.name}_super", anchor=schema.name, columns=cols,
        sort_order=tuple(sort_order), segmentation=seg,
        encodings=encodings or {}, is_super=True)
