"""The database facade: a simulated shared-nothing cluster with the paper's
transaction, distribution and availability semantics.

* N logical nodes, each holding per-projection physical state
  (WOS + ROS containers + delete vectors).
* Quorum commit without 2PC (paper §5): a commit succeeds iff >= N/2+1
  nodes are up; nodes that miss a commit are marked stale and must recover.
* K-safety (paper §5.3): every segmented projection gets a ring-offset
  buddy; reads route around down nodes via buddies; losing every replica of
  a segment (or quorum) shuts the database down.
* Inserts are transactional: data is staged per txn and becomes a WOS (or
  direct-ROS) write only at commit, with the commit epoch -- rollback simply
  discards the staging, exactly the paper's 'discard ROS/WOS created by the
  transaction'.
* Deletes create delete vectors; UPDATE = DELETE + INSERT. No in-place
  modification anywhere.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .block_cache import BlockCache, KIND_SEG, KIND_WOS
from .catalog import Catalog, TableEntry
from .epochs import EpochManager
from .faults import (NULL_INJECTOR, FaultInjector, NodeCrashError,
                     TransientFaultError, fire_with_retries)
from .locks import LockManager
from .projection import ProjectionDef, super_projection
from .segmentation import SegmentationSpec
from .storage import DeleteVector, ROSContainer, WOS
from .tuple_mover import (ProjectionStore, mergeout, moveout,
                          run_tuple_mover)
from .types import SQLType, TableSchema

_txn_ids = itertools.count(1)


class AvailabilityError(Exception):
    """Quorum lost or a segment has no live replica: database shutdown."""


class SegmentUnavailableError(AvailabilityError):
    """Every replica of one or more segments is down.  Carries exactly
    which ring segments are unserveable (and at which epoch, when known)
    so callers degrade loudly and precisely, never silently."""

    def __init__(self, projection: str, segments: Sequence[int], *,
                 epoch: Optional[int] = None, reason: str = ""):
        self.projection = projection
        self.segments: Tuple[int, ...] = tuple(sorted(set(segments)))
        self.epoch = epoch
        msg = (f"segment(s) {list(self.segments)} of {projection} "
               f"unavailable")
        if epoch is not None:
            msg += f" at epoch {epoch}"
        if reason:
            msg += f" ({reason})"
        super().__init__(msg)


class RecoverySourceLostError(AvailabilityError):
    """A recovering node's replay source is gone: recovery cannot
    complete.  The node STAYS in recovering state (its segments keep
    routing to whatever buddies remain; a later ``recover_node`` retry
    may succeed).  Carries which projections could not replay, the
    segments affected, and the epoch window (lge, rejoin] still owed."""

    def __init__(self, node: int,
                 projections: Dict[str, Tuple[int, ...]], *,
                 window: Optional[Tuple[int, int]] = None):
        self.node = node
        self.projections = dict(projections)
        self.segments: Tuple[int, ...] = tuple(sorted(
            {s for segs in self.projections.values() for s in segs}))
        self.window = window
        msg = (f"node {node} recovery incomplete: no replay source for "
               f"{sorted(self.projections)} (segments "
               f"{list(self.segments)})")
        if window is not None:
            msg += f", epochs ({window[0]}, {window[1]}] unreplayed"
        super().__init__(msg)


class QueryRejectedError(AvailabilityError):
    """A query exhausted its failover/retry budget.  The pinned snapshot
    epoch and attempt count ride along so the caller knows exactly what
    was refused -- the refusal is the guarantee: never a wrong answer."""

    def __init__(self, reason: str, *, epoch: Optional[int] = None,
                 attempts: int = 0,
                 segments: Sequence[int] = ()):
        self.reason = reason
        self.epoch = epoch
        self.attempts = attempts
        self.segments = tuple(segments)
        msg = f"query rejected: {reason}"
        if epoch is not None:
            msg += f" (pinned epoch {epoch}, {attempts} failover(s))"
        super().__init__(msg)


class TxnError(Exception):
    pass


@dataclasses.dataclass
class NodeState:
    id: int
    up: bool = True
    stores: Dict[str, ProjectionStore] = dataclasses.field(
        default_factory=dict)
    # commits missed while down (drives recovery)
    stale_since: Optional[int] = None
    # rejoined but not yet recovered: the node RECEIVES new commits (so it
    # stops falling further behind) but serves no reads -- the planner
    # routes its segments to the buddy until recover_node() completes
    recovering: bool = False
    rejoin_epoch: Optional[int] = None
    # incremental-recovery telemetry (core/recovery.py)
    last_recovery: Dict[str, int] = dataclasses.field(default_factory=dict)

    def serving(self) -> bool:
        return self.up and not self.recovering


@dataclasses.dataclass
class Txn:
    id: str
    # (projection, node) -> staged row dict
    staged: Dict[Tuple[str, int], Dict[str, np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    staged_segments: Dict[Tuple[str, int], np.ndarray] = \
        dataclasses.field(default_factory=dict)
    # (projection, node) -> segmentation ring value per staged row (None
    # for replicated projections); stamped onto the WOS at commit so the
    # segmented executor slabs trickle loads per device shard directly
    staged_rings: Dict[Tuple[str, int], Optional[np.ndarray]] = \
        dataclasses.field(default_factory=dict)
    deletes: List[Tuple[str, Callable]] = dataclasses.field(
        default_factory=list)
    direct_to_ros: bool = False


class VerticaDB:
    def __init__(self, n_nodes: int = 4, k_safety: int = 1,
                 block_rows: int = 256,
                 cache_budget_bytes: int = 256 << 20):
        assert k_safety in (0, 1)
        self.catalog = Catalog(n_nodes=n_nodes, k_safety=k_safety)
        self.nodes = [NodeState(i) for i in range(n_nodes)]
        self.epochs = EpochManager()
        self.locks = LockManager()
        self.block_rows = block_rows
        # device-resident block cache, shared by every store of this DB
        # (our HBM analog of Vertica leaning on the OS page cache)
        self.block_cache = BlockCache(cache_budget_bytes)
        # compressed-domain execution policy (engine/compressed.py):
        #   "auto"       -- code-domain scan only when the decoded working
        #                   set is not already device-resident
        #   "compressed" -- always, when the plan is eligible
        #   "decoded"    -- never (the legacy decode-then-filter scan)
        self.exec_mode = "auto"
        # device mesh for the segmented executor (engine/segmented.py);
        # None = single-device execution
        self.mesh = None
        self.mesh_axis = "data"
        # fault injection (core/faults.py): a no-op NullInjector unless a
        # test/chaos harness opts in via enable_faults(seed=...)
        self.faults = NULL_INJECTOR
        # bounded mid-query failover budget (engine/pipeline.py): how many
        # node-crash replans a single query absorbs before rejecting
        self.max_failover_retries = 2

    # ------------------------------------------------------------- DDL --

    def create_table(self, schema: TableSchema, *,
                     sort_order: Optional[Sequence[str]] = None,
                     segment_by: Optional[Sequence[str]] = None,
                     partition_by: Optional[Tuple[str, str]] = None):
        self.catalog.add_table(schema, partition_by)
        cols = schema.column_names()
        sp = super_projection(schema, tuple(sort_order or cols[:1]),
                              tuple(segment_by or ()))
        self.create_projection(sp)

    def create_projection(self, proj: ProjectionDef, *,
                          populate: bool = False):
        self.catalog.add_projection(proj)
        self._init_stores(proj)
        buddy = None
        if self.catalog.k_safety >= 1 and not proj.segmentation.replicated \
                and proj.buddy_of is None:
            buddy = proj.buddy_def()
            self.catalog.add_projection(buddy)
            self._init_stores(buddy)
        if populate:
            from .recovery import refresh_projection
            refresh_projection(self, proj.name)
            if buddy is not None:
                refresh_projection(self, buddy.name)

    def _init_stores(self, proj: ProjectionDef):
        for node in self.nodes:
            node.stores[proj.name] = ProjectionStore(
                proj, WOS(proj.name), cache=self.block_cache)

    # ----------------------------------------------------------- query --

    def attach_mesh(self, mesh=None, axis: str = "data"):
        """Route aggregate queries through the segmented multi-device
        executor (engine/segmented.py).  With no argument, builds a 1-D
        query mesh over every visible jax device.  Tuple-to-shard
        ownership follows each projection's SegmentationSpec hash ring
        (core/segmentation.shard_of)."""
        if mesh is None:
            from ..distributed.mesh import make_query_mesh
            mesh = make_query_mesh(axis=axis)
        self.mesh, self.mesh_axis = mesh, axis
        return mesh

    def detach_mesh(self):
        """Back to single-device execution."""
        self.mesh = None

    # ---------------------------------------------------------- faults --

    def enable_faults(self, seed: Optional[int] = None,
                      **cfg) -> FaultInjector:
        """Attach a seeded deterministic fault injector (core/faults.py);
        schedules registered on the returned injector fire at the named
        injection points threaded through commit, tuple mover, recovery
        and the segmented executor."""
        self.faults = FaultInjector(self, seed=seed, **cfg)
        return self.faults

    def disable_faults(self) -> None:
        self.faults = NULL_INJECTOR

    def query(self, table: str):
        """Fluent relational front-end (engine/builder.py):
        ``db.query("fact").where(...).join(...).group_by(...).agg(...)
        .collect()``.  Lowers to the logical-plan IR consumed by planner
        and executor."""
        if table not in self.catalog.tables:
            raise KeyError(f"unknown table {table!r}")
        from ..engine.builder import QueryBuilder
        return QueryBuilder(self, table)

    def serve(self, **kw):
        """Multi-tenant serving front door (engine/serving.py; paper §7
        workload management): admission control with interactive/batch
        priority queues, a bounded session pool, a concurrent-working-set
        memory budget charged against the block cache, and shared scans
        that coalesce queued queries over one projection + snapshot epoch
        into a single cache-resident scan.

            svc = db.serve(queue_depth=16)
            with svc.session("interactive") as s:
                t = s.submit(db.query("sales").group_by("cid")
                             .agg(n=("*", "count")))
            svc.drain()
            rows = t.result()
        """
        from ..engine.serving import QueryService
        return QueryService(self, **kw)

    # ------------------------------------------------------------- txn --

    def begin(self, *, direct_to_ros: bool = False) -> Txn:
        return Txn(f"txn{next(_txn_ids)}", direct_to_ros=direct_to_ros)

    def _sql_types(self, proj: ProjectionDef) -> Dict[str, SQLType]:
        schema = self.catalog.tables[proj.anchor].schema
        out = {}
        for c in proj.columns:
            if c in schema:
                out[c] = schema.column(c).sql_type
            else:  # prejoined dimension column
                out[c] = SQLType.INT
        return out

    def insert(self, txn: Txn, table: str, data: Dict[str, np.ndarray]):
        """Stage rows for every projection of the table (lock mode I)."""
        self.locks.acquire(table, txn.id, "I")
        n = len(next(iter(data.values())))
        for proj in self.catalog.projections_of(table):
            pdata = self._project_rows(proj, data)
            if proj.segmentation.replicated:
                placements = [(node.id, np.zeros(n, np.int32))
                              for node in self.nodes]
                sel_all = np.ones(n, bool)
                for node_id, segs in placements:
                    self._stage(txn, proj.name, node_id, pdata, sel_all,
                                segs, None)
            else:
                nodes, segs, ring = proj.segmentation.place_with_ring(
                    pdata, self.catalog.n_nodes)
                for node_id in np.unique(nodes):
                    sel = nodes == node_id
                    self._stage(txn, proj.name, int(node_id), pdata, sel,
                                segs[sel], ring[sel])

    def _project_rows(self, proj: ProjectionDef,
                      data: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        if proj.prejoin is None:
            return {c: np.asarray(data[c]) for c in proj.columns}
        # prejoin projection: join fact rows with the dimension table at load
        pj = proj.prejoin
        dim = self.read_table(pj.dim_table)
        keys = np.asarray(dim[pj.dim_key])
        order = np.argsort(keys)
        idx = order[np.searchsorted(keys[order], np.asarray(
            data[pj.anchor_key]))]
        out = {}
        for c in proj.columns:
            if "." in c:
                dcol = c.split(".", 1)[1]
                out[c] = np.asarray(dim[dcol])[idx]
            else:
                out[c] = np.asarray(data[c])
        return out

    def _stage(self, txn: Txn, proj: str, node_id: int,
               data: Dict[str, np.ndarray], sel: np.ndarray,
               segs: np.ndarray, ring: Optional[np.ndarray]):
        key = (proj, node_id)
        sub = {c: v[sel] for c, v in data.items()}
        if key in txn.staged:
            txn.staged[key] = {c: np.concatenate([txn.staged[key][c],
                                                  sub[c]]) for c in sub}
            txn.staged_segments[key] = np.concatenate(
                [txn.staged_segments[key], segs])
            prev = txn.staged_rings[key]
            txn.staged_rings[key] = None if prev is None or ring is None \
                else np.concatenate([prev, ring])
        else:
            txn.staged[key] = sub
            txn.staged_segments[key] = segs
            txn.staged_rings[key] = ring

    def delete(self, txn: Txn, table: str,
               predicate: Callable[[Dict[str, np.ndarray]], np.ndarray]):
        self.locks.acquire(table, txn.id, "X")
        txn.deletes.append((table, predicate))

    def update(self, txn: Txn, table: str, predicate,
               assign: Dict[str, np.ndarray or Callable]):
        """UPDATE = DELETE matching rows + INSERT updated copies (§3.7.1)."""
        rows = self.read_table(table)
        mask = predicate(rows)
        self.delete(txn, table, predicate)
        new = {c: np.asarray(v[mask]).copy() for c, v in rows.items()}
        for c, v in assign.items():
            new[c] = v(new) if callable(v) else np.full(
                int(mask.sum()), v, new[c].dtype)
        self.insert(txn, table, new)

    def commit(self, txn: Txn, *, fail_nodes_during_commit: Sequence[int]
               = ()) -> int:
        """Quorum commit without 2PC. Nodes failing mid-commit are ejected
        and must recover; the commit succeeds iff a quorum remains."""
        for nid in fail_nodes_during_commit:
            self.fail_node(nid)
        up = [n for n in self.nodes if n.up]
        quorum = self.catalog.n_nodes // 2 + 1
        if len(up) < quorum:
            self.locks.release_all(txn.id)
            raise AvailabilityError(
                f"quorum lost: {len(up)}/{self.catalog.n_nodes} up, "
                f"need {quorum}")
        # ---- phase 1: every up staged node acknowledges the commit.
        # This is the only window injected crashes / transient ejections
        # can land in, and NO state has mutated yet -- so a commit refused
        # below aborts cleanly and can simply be retried after repair.
        for (proj_name, node_id) in txn.staged:
            node = self.nodes[node_id]
            if not node.up:
                continue
            try:
                fire_with_retries(self, "commit.apply", node=node_id,
                                  projection=proj_name)
            except NodeCrashError:
                pass  # the crashed node misses the commit; survivors
                #       proceed (quorum is re-checked below)
            except TransientFaultError:
                # a node that cannot acknowledge a commit after the retry
                # budget is ejected (paper §5: it must recover)
                self.fail_node(node_id)
        up = [n for n in self.nodes if n.up]
        if len(up) < quorum:
            self.locks.release_all(txn.id)
            raise AvailabilityError(
                f"quorum lost during commit: {len(up)}/"
                f"{self.catalog.n_nodes} up, need {quorum}")
        # ---- redundancy check: every staged row set must still have at
        # least one live home.  Committing past this would silently DROP
        # the rows of any segment whose every copy-holder died above --
        # refuse the whole commit instead (typed, nothing applied).
        lost = self._staged_segments_without_live_copy(txn)
        if lost:
            proj_name, segs = lost
            self.locks.release_all(txn.id)
            raise SegmentUnavailableError(
                proj_name, segs, epoch=self.epochs.latest_queryable(),
                reason="commit refused: every copy-holder of these "
                       "staged segments is down")
        # ---- phase 2: apply (survivors only; failed nodes' misses are
        # replayed by incremental recovery from their buddies)
        epoch = self.epochs.advance()  # auto-advance on DML commit (§5.1)
        # deletes first: they target rows visible BEFORE this commit, so an
        # UPDATE's re-inserted rows are not swallowed by its own delete
        for table, predicate in txn.deletes:
            self._apply_delete(table, predicate, epoch)
        for (proj_name, node_id), data in txn.staged.items():
            node = self.nodes[node_id]
            if not node.up:
                continue  # node missed the commit; recovery will replay
            store = node.stores[proj_name]
            segs = txn.staged_segments[(proj_name, node_id)]
            ring = txn.staged_rings.get((proj_name, node_id))
            if txn.direct_to_ros:
                self._direct_ros(store, data, epoch, segs)
            else:
                store.wos.append(data, epoch, segs, ring=ring)
                n = len(segs)
                store.wos_delete_epochs.append(np.zeros(n, np.int64))
        # stream the fresh WOS batches into their per-shard device buffers
        # while the rows are hot: a trickle-load commit pre-pays the
        # segmented executor's delta slab, so the next query only uploads
        # a visibility mask (engine/segmented.prewarm_wos_buffer; no-op
        # without an attached mesh)
        if self.mesh is not None and not txn.direct_to_ros:
            from ..engine.segmented import prewarm_wos_buffer
            for (proj_name, node_id) in txn.staged:
                prewarm_wos_buffer(self, node_id, proj_name)
        self.locks.release_all(txn.id)
        return epoch

    def _staged_segments_without_live_copy(self, txn: Txn):
        """Segments whose EVERY staged copy-holder is down (so committing
        would lose their rows outright).  Returns (primary projection
        name, sorted segment list) for the affected projection, or None.
        Replicated projections are covered by the quorum check; K=0
        projections have no second copy, so a down owner is fatal.
        Up-but-recovering nodes count as live homes: they receive every
        commit from the moment they rejoin."""
        lost: Dict[str, set] = {}
        for (proj_name, node_id) in txn.staged:
            if self.nodes[node_id].up:
                continue
            proj = self.catalog.projections[proj_name]
            if proj.segmentation.replicated:
                continue
            if proj.buddy_of is not None:
                seg = (node_id - proj.segmentation.offset) \
                    % self.catalog.n_nodes
                partner = (proj.buddy_of, seg)
                primary = proj.buddy_of
            else:
                seg = node_id
                primary = proj_name
                buddy = self.catalog.projections.get(proj_name + "_b1")
                partner = None if buddy is None else \
                    (buddy.name,
                     (node_id + buddy.segmentation.offset)
                     % self.catalog.n_nodes)
            if partner is None or partner not in txn.staged \
                    or not self.nodes[partner[1]].up:
                lost.setdefault(primary, set()).add(seg)
        if not lost:
            return None
        primary = sorted(lost)[0]
        return primary, sorted(lost[primary])

    def rollback(self, txn: Txn):
        txn.staged.clear()
        txn.staged_segments.clear()
        txn.staged_rings.clear()
        txn.deletes.clear()
        self.locks.release_all(txn.id)

    def _direct_ros(self, store: ProjectionStore, data, epoch: int,
                    segs: np.ndarray):
        """Bulk loads tagged direct-to-ROS (§7): skip the WOS entirely."""
        entry = self.catalog.tables[store.proj.anchor]
        tmp = ProjectionStore(store.proj, WOS(store.proj.name))
        tmp.wos.append(data, epoch, segs)
        tmp.wos_delete_epochs.append(np.zeros(len(segs), np.int64))
        new = moveout(tmp, sql_types=self._sql_types(store.proj),
                      ahm=self.epochs.ahm,
                      partition_expr=entry.partition_expr,
                      block_rows=self.block_rows)
        store.containers.extend(new)
        for c in new:
            if c.id in tmp.delete_vectors:
                store.delete_vectors[c.id] = tmp.delete_vectors[c.id]
        if new:
            # slabs built before this bulk load never match again (the
            # container set grew): free their HBM now, precisely
            store.invalidate_seg_slabs(require_ids=[c.id for c in new])

    def _apply_delete(self, table: str, predicate, epoch: int):
        for proj in self.catalog.projections_of(table):
            for node in self.nodes:
                if not node.up:
                    continue
                store = node.stores[proj.name]
                for c in store.containers:
                    rows = c.decode_all()
                    try:
                        m = predicate(rows)
                    except KeyError:
                        continue  # projection lacks predicate columns
                    m &= ~store.deleted_mask(c)
                    pos = np.flatnonzero(m)
                    if pos.size:
                        store.delete_vectors.setdefault(c.id, []).append(
                            DeleteVector.build(
                                c.id, pos,
                                np.full(pos.size, epoch, np.int64)).to_ros())
                        # evict cached blocks of a container whose delete
                        # state changed (visibility is epoch-keyed, but
                        # eager eviction keeps DV rewrites honest)
                        store.invalidate_cached([c.id])
                data, eps, _ = store.wos.snapshot()
                if len(eps):
                    try:
                        m = predicate(data)
                    except KeyError:
                        continue
                    cur = (np.concatenate(store.wos_delete_epochs)
                           if store.wos_delete_epochs
                           else np.zeros(len(eps), np.int64))
                    cur = np.where(m & (cur == 0), epoch, cur)
                    store.wos_delete_epochs = [cur]
                    # WOS content-version covers delete state too: the
                    # segmented executor's device WOS buffers key on it
                    store.wos.version += 1

    # ----------------------------------------------------------- reads --

    def segment_owners(self, proj: ProjectionDef) -> Dict[int, str]:
        """ring-node -> projection (primary or buddy) that can serve it
        from a live node.  Raises SegmentUnavailableError carrying the
        COMPLETE set of lost segments (not just the first) when any
        segment has no serving replica."""
        owners = {}
        lost: List[int] = []
        buddy_name = proj.name + "_b1"
        buddy = self.catalog.projections.get(buddy_name)
        for seg_node in range(self.catalog.n_nodes):
            # a recovering node receives commits but serves no reads: its
            # segments route to the buddy until recover_node() completes
            if self.nodes[seg_node].serving():
                owners[seg_node] = proj.name
            elif buddy is not None:
                # the buddy stores segment s on node (s + offset) % N
                host = (seg_node + buddy.segmentation.offset) % \
                    self.catalog.n_nodes
                if self.nodes[host].serving():
                    owners[seg_node] = buddy_name
                else:
                    lost.append(seg_node)
            else:
                lost.append(seg_node)
        if lost:
            raise SegmentUnavailableError(
                proj.name, lost,
                epoch=self.epochs.latest_queryable(),
                reason="" if buddy is not None else "K=0, no buddy")
        return owners

    def read_projection(self, proj_name: str, *,
                        as_of: Optional[int] = None,
                        include_wos: bool = True) -> Dict[str, np.ndarray]:
        """Snapshot read of all visible rows (host-side; the EE uses
        container-level access instead, see engine/)."""
        proj = self.catalog.projections[proj_name]
        as_of = as_of if as_of is not None else self.epochs.latest_queryable()
        if proj.segmentation.replicated:
            first_up = next((n.id for n in self.nodes if n.serving()), None)
            if first_up is None:
                raise SegmentUnavailableError(
                    proj_name, range(self.catalog.n_nodes), epoch=as_of,
                    reason="no serving replica")
            sources = [(first_up, proj_name)]
        else:
            owners = self.segment_owners(proj)
            sources = []
            for seg_node, owner_proj in owners.items():
                host = seg_node
                if owner_proj != proj_name:
                    host = (seg_node + self.catalog.projections[
                        owner_proj].segmentation.offset) % \
                        self.catalog.n_nodes
                # one host may serve several segments (its own via the
                # primary AND a down neighbor's via the buddy store)
                if (host, owner_proj) not in sources:
                    sources.append((host, owner_proj))
        parts = []
        for host, owner_proj in sources:
            store = self.nodes[host].stores[owner_proj]
            parts.extend(self._store_rows(store, as_of, include_wos))
        if not parts:
            return {c: np.zeros(0, np.int64) for c in proj.columns}
        return {c: np.concatenate([p[c] for p in parts])
                for c in proj.columns}

    def _store_rows(self, store: ProjectionStore, as_of: int,
                    include_wos: bool) -> List[Dict[str, np.ndarray]]:
        out = []
        for c in store.containers:
            vis = (c.epochs <= as_of) & ~store.deleted_mask(c, as_of)
            if vis.any():
                rows = c.decode_all()
                out.append({k: v[vis] for k, v in rows.items()})
        if include_wos:
            data, eps, _ = store.wos.snapshot()
            if len(eps):
                dels = (np.concatenate(store.wos_delete_epochs)
                        if store.wos_delete_epochs
                        else np.zeros(len(eps), np.int64))
                vis = (eps <= as_of) & ~((dels > 0) & (dels <= as_of))
                if vis.any():
                    out.append({k: v[vis] for k, v in data.items()})
        return out

    def read_table(self, table: str, *,
                   as_of: Optional[int] = None) -> Dict[str, np.ndarray]:
        return self.read_projection(self.catalog.super_of(table).name,
                                    as_of=as_of)

    # ----------------------------------------------- maintenance / ops --

    def run_tuple_mover(self, *, force_moveout: bool = False,
                        do_mergeout: bool = True):
        stats = {"moveouts": 0, "mergeouts": 0}
        for node in self.nodes:
            if not node.serving():
                continue
            try:
                for store in node.stores.values():
                    entry = self.catalog.tables[store.proj.anchor]
                    # injection points fire BEFORE the pass touches the
                    # store: a crash here simply skips this node's moves
                    # (the tuple mover is opportunistic, §4.2)
                    self.faults.fire("tuple_mover.moveout", node=node.id,
                                     projection=store.proj.name)
                    if do_mergeout:
                        self.faults.fire("tuple_mover.mergeout",
                                         node=node.id,
                                         projection=store.proj.name)
                    self.locks.acquire(store.proj.anchor,
                                       f"tm-{node.id}", "U")
                    try:
                        s = run_tuple_mover(
                            store, sql_types=self._sql_types(store.proj),
                            ahm=self.epochs.ahm,
                            partition_expr=entry.partition_expr,
                            wos_row_limit=0 if force_moveout else 8192,
                            block_rows=self.block_rows,
                            do_mergeout=do_mergeout)
                        stats["moveouts"] += s["moveouts"]
                        stats["mergeouts"] += s["mergeouts"]
                    finally:
                        self.locks.release_all(f"tm-{node.id}")
                    # LGE semantics (§5.1): it may only advance to the
                    # newest epoch FULLY persisted in ROS -- rows still in
                    # the WOS are lost on failure, so epochs buffered
                    # there cap it
                    _, wos_eps, _ = store.wos.snapshot()
                    if len(wos_eps):
                        lge = int(wos_eps.min()) - 1
                    else:
                        lge = self.epochs.latest_queryable()
                    self.epochs.set_lge(store.proj.name, node.id, lge)
            except NodeCrashError:
                continue            # a node died mid-pass; survivors go on
            except TransientFaultError:
                continue            # node skipped this pass; next run moves
        # recovering/down nodes gate the AHM: their LGE must not advance
        # (they are still missing history) and the AHM must keep the
        # epochs they will replay.  Computed HERE, after the pass -- a
        # node crashing mid-pass (fault injection) must gate it too.
        any_down = any(not n.serving() for n in self.nodes)
        self.epochs.advance_ahm(nodes_down=any_down)
        return stats

    def drop_partition(self, table: str, partition_key: int):
        """Fast bulk delete: drop whole containers (lock mode O, §3.5)."""
        self.locks.acquire(table, "ddl", "O")
        try:
            for proj in self.catalog.projections_of(table):
                for node in self.nodes:
                    store = node.stores[proj.name]
                    drop = [c for c in store.containers
                            if c.partition_key == partition_key]
                    store.containers = [c for c in store.containers
                                        if c.partition_key != partition_key]
                    store.invalidate_cached([c.id for c in drop])
                    # evict exactly the partitioned scan slabs that
                    # referenced a dropped container (keys carry the
                    # container-id set) -- other epochs/meshes stay warm
                    store.invalidate_seg_slabs(
                        retired_ids=[c.id for c in drop])
                    for c in drop:
                        store.delete_vectors.pop(c.id, None)
            # dropping containers bypasses MVCC: cached join build sides
            # of this table (engine/executor.py) are stale at EVERY epoch
            self.block_cache.invalidate_container(f"dim:{table}")
        finally:
            self.locks.release_all("ddl")

    def fail_node(self, node_id: int):
        node = self.nodes[node_id]
        if not node.up:
            return
        node.up = False
        node.recovering = False
        node.rejoin_epoch = None
        node.stale_since = self.epochs.latest_queryable()
        for store in node.stores.values():
            store.wos.clear()          # WOS is memory: lost on failure
            store.wos_delete_epochs = []
        self._evict_failed_node_slabs(node_id)

    def _evict_failed_node_slabs(self, node_id: int) -> int:
        """Evict every KIND_SEG slab whose source set references the
        failed node.  Slab keys embed (host, owner, container-ids) items
        (engine/segmented._source_sig); a slab sourced from the dead
        node's placement predates the failover routing and a warm hit on
        it would silently serve a pre-failure mesh identity."""

        def references_node(key) -> bool:
            _, col, kind = key
            if kind == KIND_WOS:
                # (("wos", version, mesh_sig), host, owner): the buffer
                # is one store's rows -- the dead node's are gone with it
                try:
                    return col[1] == node_id
                except (TypeError, IndexError):
                    return True
            if kind != KIND_SEG:
                return False
            if not (isinstance(col, tuple) and len(col) >= 3):
                return True          # unknown key shape: evict, stay safe
            try:
                items = col[2][0]
                return any(host == node_id for host, _owner, _ids in items)
            except (TypeError, ValueError, IndexError):
                return True
        n = 0
        for proj in self.catalog.projections.values():
            if proj.buddy_of is not None:
                continue             # slabs are namespaced by the primary
            n += self.block_cache.invalidate_where(
                f"seg:{proj.name}", references_node)
        return n

    def rejoin_node(self, node_id: int):
        """Bring a failed node back ONLINE but not yet SERVING: it starts
        receiving new commits immediately (so it stops falling behind)
        while reads keep routing to its buddy; ``recovery.recover_node``
        then replays only the epochs it missed while down
        (LGE, rejoin_epoch] and flips it back to serving (paper §4.4)."""
        from .recovery import rejoin_node
        return rejoin_node(self, node_id)

    # epoch ceilings: the newest epoch that can affect a store's (or a
    # table's) visible state.  Epoch-keyed caches clamp a query's as-of to
    # this ceiling, so trickle-load commits elsewhere in the cluster do
    # not invalidate entries whose underlying data did not change.

    def table_epoch_ceiling(self, table: str, *,
                            include_wos: bool = True) -> int:
        proj = self.catalog.super_of(table)
        return max((node.stores[proj.name].epoch_ceiling(
            include_wos=include_wos)
            for node in self.nodes if proj.name in node.stores),
            default=0)

    def storage_report(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for proj in self.catalog.projections.values():
            total = raw = n = nc = 0
            for node in self.nodes:
                st = node.stores[proj.name]
                total += sum(c.storage_bytes() for c in st.containers)
                raw += sum(c.raw_bytes() for c in st.containers)
                n += st.ros_rows()
                nc += len(st.containers)
            out[proj.name] = {"rows": n, "containers": nc,
                              "stored_bytes": total, "raw_bytes": raw,
                              "ratio": raw / total if total else 0.0}
        return out
