"""Table locks: the paper's Tables 1 (compatibility) and 2 (conversion).

Modes: S (shared, serializable reads), I (insert -- compatible with itself:
parallel bulk loads), SI (shared-insert), X (exclusive: delete/update),
T (tuple mover short ops), U (usage: moveout/mergeout), O (owner: drop
partition / add column).

Most queries take NO lock at all (snapshot reads, §5); the lock manager
exists for writers and maintenance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Set

MODES = ("S", "I", "SI", "X", "T", "U", "O")

# Table 1: Lock Compatibility Matrix. COMPAT[requested][granted] -> bool
_C = {
    "S":  {"S": 1, "I": 0, "SI": 0, "X": 0, "T": 1, "U": 1, "O": 0},
    "I":  {"S": 0, "I": 1, "SI": 0, "X": 0, "T": 1, "U": 1, "O": 0},
    "SI": {"S": 0, "I": 0, "SI": 0, "X": 0, "T": 1, "U": 1, "O": 0},
    "X":  {"S": 0, "I": 0, "SI": 0, "X": 0, "T": 0, "U": 1, "O": 0},
    "T":  {"S": 1, "I": 1, "SI": 1, "X": 0, "T": 1, "U": 1, "O": 0},
    "U":  {"S": 1, "I": 1, "SI": 1, "X": 1, "T": 1, "U": 1, "O": 0},
    "O":  {"S": 0, "I": 0, "SI": 0, "X": 0, "T": 0, "U": 0, "O": 0},
}
COMPATIBLE = {r: {g: bool(v) for g, v in row.items()} for r, row in _C.items()}

# Table 2: Lock Conversion Matrix. CONVERT[requested][granted] -> result mode
CONVERT = {
    "S":  {"S": "S",  "I": "SI", "SI": "SI", "X": "X", "T": "S",  "U": "S",
           "O": "O"},
    "I":  {"S": "SI", "I": "I",  "SI": "SI", "X": "X", "T": "I",  "U": "I",
           "O": "O"},
    "SI": {"S": "SI", "I": "SI", "SI": "SI", "X": "X", "T": "SI", "U": "SI",
           "O": "O"},
    "X":  {"S": "X",  "I": "X",  "SI": "X",  "X": "X", "T": "X",  "U": "X",
           "O": "O"},
    "T":  {"S": "S",  "I": "I",  "SI": "SI", "X": "X", "T": "T",  "U": "T",
           "O": "O"},
    "U":  {"S": "S",  "I": "I",  "SI": "SI", "X": "X", "T": "T",  "U": "U",
           "O": "O"},
    "O":  {"S": "O",  "I": "O",  "SI": "O",  "X": "O", "T": "O",  "U": "O",
           "O": "O"},
}


class LockError(Exception):
    pass


@dataclasses.dataclass
class TableLock:
    mode: Optional[str] = None
    holders: Set[str] = dataclasses.field(default_factory=set)


class LockManager:
    """Per-table locks with the paper's semantics. Non-blocking: a request
    that cannot be granted raises (callers may retry/queue)."""

    def __init__(self):
        self._locks: Dict[str, TableLock] = {}

    def acquire(self, table: str, txn: str, mode: str) -> str:
        assert mode in MODES, mode
        lock = self._locks.setdefault(table, TableLock())
        if lock.mode is None or not lock.holders:
            lock.mode = mode
            lock.holders = {txn}
            return mode
        if lock.holders == {txn}:
            # same holder: convert per Table 2
            lock.mode = CONVERT[mode][lock.mode]
            return lock.mode
        if COMPATIBLE[mode][lock.mode]:
            lock.mode = CONVERT[mode][lock.mode]
            lock.holders.add(txn)
            return lock.mode
        raise LockError(
            f"{txn}: {mode} lock on {table!r} incompatible with granted "
            f"{lock.mode} held by {sorted(lock.holders)}")

    def release(self, table: str, txn: str):
        lock = self._locks.get(table)
        if not lock or txn not in lock.holders:
            return
        lock.holders.discard(txn)
        if not lock.holders:
            lock.mode = None

    def release_all(self, txn: str):
        for t in list(self._locks):
            self.release(t, txn)

    def granted_mode(self, table: str) -> Optional[str]:
        lock = self._locks.get(table)
        return lock.mode if lock and lock.holders else None
