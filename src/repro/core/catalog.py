"""Metadata catalog (paper §5.3).

'Unlike other databases, the catalog is not stored in database tables' --
it is a memory-resident structure with its own transactional persistence.
Here: plain dataclasses + atomic pickle-to-temp-then-rename, version-stamped
by epoch.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import tempfile
from typing import Dict, Optional, Tuple

from .projection import ProjectionDef
from .types import TableSchema


@dataclasses.dataclass
class TableEntry:
    schema: TableSchema
    partition_expr: Optional[Tuple[str, str]] = None  # (column, expr name)


@dataclasses.dataclass
class Catalog:
    tables: Dict[str, TableEntry] = dataclasses.field(default_factory=dict)
    projections: Dict[str, ProjectionDef] = dataclasses.field(
        default_factory=dict)
    n_nodes: int = 1
    k_safety: int = 1
    version_epoch: int = 0

    def add_table(self, schema: TableSchema,
                  partition_expr: Optional[Tuple[str, str]] = None):
        if schema.name in self.tables:
            raise KeyError(f"table {schema.name!r} exists")
        self.tables[schema.name] = TableEntry(schema, partition_expr)

    def add_projection(self, proj: ProjectionDef):
        if proj.name in self.projections:
            raise KeyError(f"projection {proj.name!r} exists")
        if proj.anchor not in self.tables:
            raise KeyError(f"anchor table {proj.anchor!r} missing")
        self.projections[proj.name] = proj

    def projections_of(self, table: str):
        return [p for p in self.projections.values() if p.anchor == table]

    def super_of(self, table: str) -> ProjectionDef:
        for p in self.projections.values():
            if p.anchor == table and p.is_super and p.buddy_of is None:
                return p
        raise KeyError(f"no super projection for {table!r}")

    # -- persistence ("own mechanism", transactional via atomic rename) --

    def save(self, path: str, epoch: int):
        self.version_epoch = epoch
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        with os.fdopen(fd, "wb") as f:
            pickle.dump(self, f)
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "Catalog":
        with open(path, "rb") as f:
            return pickle.load(f)
