"""ROS containers, WOS, delete vectors (paper §3.7).

A ROS container is immutable: per-column encoded data + a position index
(ColumnSMA min/max/count per block -- the paper's ~1/1000-size index; no
B-tree, containers never change). Positions are implicit ordinals. Every
row carries its commit epoch (the paper's implicit 64-bit epoch column).

Deletes never modify containers: a DeleteVector lists deleted positions with
their delete epochs; DVWOS (in-memory) -> DVROS (encoded, delta on sorted
positions) via the tuple mover.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .encodings import EncodedColumn, Encoding, encode
from .projection import ProjectionDef
from .sma import ColumnSMA
from .types import BLOCK_ROWS, SQLType, TableSchema

_next_container_id = itertools.count(1)


@dataclasses.dataclass
class ROSContainer:
    """Immutable sorted run of tuples for one projection segment."""

    id: int
    projection: str
    columns: Dict[str, EncodedColumn]
    smas: Dict[str, ColumnSMA]
    epochs: np.ndarray                  # (n_rows,) commit epoch per row
    n_rows: int
    partition_key: Optional[int] = None
    local_segment: int = 0
    _max_epoch: Optional[int] = None     # lazy cache, see max_epoch()

    @staticmethod
    def build(proj: ProjectionDef, data: Dict[str, np.ndarray],
              epochs: np.ndarray, *, sql_types: Dict[str, SQLType],
              partition_key: Optional[int] = None, local_segment: int = 0,
              presorted: bool = False,
              block_rows: int = BLOCK_ROWS) -> "ROSContainer":
        """Sort by the projection's sort order and encode every column."""
        n = len(epochs)
        if n and not presorted and proj.sort_order:
            order = np.lexsort(tuple(data[c] for c in
                                     reversed(proj.sort_order)))
            data = {c: v[order] for c, v in data.items()}
            epochs = epochs[order]
        cols, smas = {}, {}
        for c in proj.columns:
            v = data[c]
            cols[c] = encode(v, sql_types.get(c, SQLType.INT),
                             proj.encoding_for(c), block_rows=block_rows)
            smas[c] = ColumnSMA.build(v, block_rows)
        return ROSContainer(next(_next_container_id), proj.name, cols, smas,
                            np.asarray(epochs, np.int64), n,
                            partition_key, local_segment)

    def storage_bytes(self) -> float:
        return sum(c.storage_bytes() for c in self.columns.values())

    def raw_bytes(self) -> float:
        return sum(c.n_rows * 8 for c in self.columns.values())

    def decode_column(self, name: str) -> np.ndarray:
        return self.columns[name].decode()

    def decode_all(self) -> Dict[str, np.ndarray]:
        return {c: col.decode() for c, col in self.columns.items()}

    def max_epoch(self) -> int:
        """Newest commit epoch in this container (cached: the container is
        immutable).  Epoch-keyed caches use it to clamp a query's as-of to
        the newest epoch that can affect ROS visibility."""
        if self._max_epoch is None:
            self._max_epoch = int(self.epochs.max()) if self.n_rows else 0
        return self._max_epoch

    def clone(self, projection: Optional[str] = None) -> "ROSContainer":
        """A fresh-id copy sharing the (immutable) encoded columns, SMAs
        and epochs -- the paper's 'simply copies whole ROS containers'
        recovery path and the backup hard-link trick: no decode, no
        re-sort, no re-encode.  The new id keeps per-store cache identity
        (retiring the copy never invalidates the original's entries)."""
        return dataclasses.replace(
            self, id=next(_next_container_id),
            projection=projection if projection is not None
            else self.projection)


@dataclasses.dataclass
class DeleteVector:
    """Deleted positions of one container (or the WOS), with epochs."""

    container_id: int                   # -1 = targets the WOS
    positions: np.ndarray               # sorted unique positions
    delete_epochs: np.ndarray
    stored: Optional[EncodedColumn] = None  # DVROS: encoded positions

    @staticmethod
    def build(container_id: int, positions: np.ndarray,
              epochs: np.ndarray) -> "DeleteVector":
        order = np.argsort(positions, kind="stable")
        return DeleteVector(container_id, positions[order], epochs[order])

    def to_ros(self, block_rows: int = BLOCK_ROWS) -> "DeleteVector":
        """Encode (delta-range over sorted positions compresses superbly)."""
        stored = encode(self.positions, SQLType.INT, Encoding.DELTA_RANGE,
                        block_rows=block_rows)
        return dataclasses.replace(self, stored=stored)

    def mask(self, n_rows: int, as_of_epoch: Optional[int] = None
             ) -> np.ndarray:
        """Boolean deleted-mask over positions, at snapshot ``as_of_epoch``."""
        m = np.zeros(n_rows, bool)
        if as_of_epoch is None:
            m[self.positions] = True
        else:
            vis = self.delete_epochs <= as_of_epoch
            m[self.positions[vis]] = True
        return m


@dataclasses.dataclass
class WOS:
    """In-memory write-optimized store for one projection segment.

    Unencoded (paper: 'data is not encoded or compressed in the WOS'), but
    already segmented: each appended batch carries its local segment AND
    its segmentation *ring* value, so the segmented executor
    (engine/segmented.py) can slab trickle-loaded rows per device shard
    (core/segmentation.shard_of) without re-hashing the segmentation
    columns at query time.  Buffers inserts until moveout."""

    projection: str
    data: Dict[str, List[np.ndarray]] = dataclasses.field(
        default_factory=dict)
    epochs: List[np.ndarray] = dataclasses.field(default_factory=list)
    local_segments: List[np.ndarray] = dataclasses.field(default_factory=list)
    # per-batch ring values (uint64, core/segmentation.hash_columns), or
    # None for batches of replicated projections / legacy callers
    rings: List[Optional[np.ndarray]] = dataclasses.field(
        default_factory=list)
    # monotonic content-version: bumped on every mutation (append / clear /
    # truncate, and by the database when WOS delete epochs change).  The
    # segmented executor keys its commit-time per-shard device WOS buffers
    # (engine/segmented.py) by this counter, so a stale buffer simply
    # becomes an unreachable cache entry -- no explicit invalidation walk.
    version: int = 0

    @property
    def n_rows(self) -> int:
        return int(sum(len(e) for e in self.epochs))

    def max_epoch(self) -> int:
        return int(max((int(e.max()) for e in self.epochs if len(e)),
                       default=0))

    def append(self, data: Dict[str, np.ndarray], epoch_or_epochs,
               local_segment: np.ndarray,
               ring: Optional[np.ndarray] = None):
        n = len(next(iter(data.values()))) if data else 0
        if n == 0:
            return
        for c, v in data.items():
            self.data.setdefault(c, []).append(np.asarray(v))
        e = np.asarray(epoch_or_epochs)
        if e.ndim == 0:
            e = np.full(n, int(e), np.int64)
        self.epochs.append(e.astype(np.int64))
        self.local_segments.append(np.asarray(local_segment, np.int32))
        self.rings.append(None if ring is None
                          else np.asarray(ring, np.uint64))
        self.version += 1

    def snapshot(self) -> Tuple[Dict[str, np.ndarray], np.ndarray,
                                np.ndarray]:
        if not self.epochs:
            return {}, np.zeros(0, np.int64), np.zeros(0, np.int32)
        data = {c: np.concatenate(v) for c, v in self.data.items()}
        return data, np.concatenate(self.epochs), \
            np.concatenate(self.local_segments)

    def ring_snapshot(self) -> Optional[np.ndarray]:
        """Ring values aligned with ``snapshot()`` row order, or None when
        any batch was appended untagged (caller re-hashes)."""
        if not self.epochs:
            return np.zeros(0, np.uint64)
        if any(r is None for r in self.rings):
            return None
        return np.concatenate(self.rings)

    def truncate_after(self, epoch: int):
        """Drop rows committed after ``epoch`` (recovery: back to LGE)."""
        data, eps, segs = self.snapshot()
        rings = self.ring_snapshot()
        keep = eps <= epoch
        self.data = {c: [v[keep]] for c, v in data.items()}
        self.epochs = [eps[keep]]
        self.local_segments = [segs[keep]]
        self.rings = [None if rings is None else rings[keep]]
        self.version += 1

    def clear(self):
        self.data, self.epochs, self.local_segments = {}, [], []
        self.rings = []
        self.version += 1

    def memory_bytes(self) -> float:
        return sum(v.nbytes for arrs in self.data.values() for v in arrs)
