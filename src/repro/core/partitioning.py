"""Intra-node partitioning (paper §3.5): CREATE TABLE ... PARTITION BY expr.

Every ROS container holds rows of exactly one partition-expression value, so
bulk deletion = dropping files, and min/max pruning never sees intermixed
values. Partitioning is a *table* property (all projections partition the
same way, or bulk delete would not be fast).

Partition expressions are evaluated host-side on integral columns; the
common date-style expression (paper: 'extract month+year') is provided.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

PartitionFn = Callable[[np.ndarray], np.ndarray]

EXPRESSIONS: Dict[str, PartitionFn] = {
    # value used directly as the partition key
    "identity": lambda v: np.asarray(v, np.int64),
    # days-since-epoch -> YYYYMM style key
    "month_year": lambda v: (np.asarray(v, "datetime64[D]").astype(
        "datetime64[M]").astype(np.int64)),
    # integral bucketing for synthetic workloads
    "div_1000": lambda v: np.asarray(v, np.int64) // 1000,
}


def partition_keys(expr: Optional[str], column: Optional[np.ndarray]
                   ) -> Optional[np.ndarray]:
    if expr is None or column is None:
        return None
    fn = EXPRESSIONS.get(expr)
    if fn is None:
        raise KeyError(f"unknown partition expression {expr!r}; "
                       f"known: {sorted(EXPRESSIONS)}")
    return fn(column)
