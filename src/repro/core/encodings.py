"""Block-structured column encodings (paper §3.4).

Vertica's six encoding types, adapted for TPU-friendly fixed shapes:

1. AUTO              -- empirically picks the smallest encoding (the same
                        machinery the Database Designer's storage-optimization
                        phase uses, §6.3).
2. RLE               -- (value, run_length) pairs; best for low-cardinality
                        sorted columns.
3. DELTA_VALUE       -- difference from the smallest value in the block; best
                        for many-valued unsorted integers.
4. BLOCK_DICT        -- per-block dictionary + codes; best for few-valued
                        unsorted columns.
5. DELTA_RANGE       -- ("Compressed Delta Range") delta from the previous
                        value; best for many-valued sorted/range-bound data.
6. COMMON_DELTA      -- ("Compressed Common Delta") dictionary of deltas +
                        entropy-coded indexes; best for predictable sequences
                        (timestamps, primary keys).
(0. PLAIN            -- no encoding; the fallback.)

Encode runs host-side (numpy) at moveout/mergeout time, exactly as Vertica
encodes when writing ROS containers.  Decode has two implementations:

* ``decode()``      -- numpy, used by host-side storage management (mergeout).
* ``decode_jnp()``  -- jnp with static shapes, used by the execution engine on
                       device; the Pallas scan kernels fuse this decode with
                       filtering/aggregation (kernels/rle_scan_agg.py).

Byte accounting (``storage_bytes``) models the *packed* size: integer payloads
are charged at the narrowest {1,2,4,8}-byte width that fits, and COMMON_DELTA
code streams are charged at their Shannon-entropy size (we model the entropy
coder rather than implementing bit-IO; noted in DESIGN.md §9).  The in-memory
numpy arrays may be wider; compression ratios reported by benchmarks use
``storage_bytes``.

Losslessness: every encoding must round-trip bit-exactly.  For FLOAT columns,
delta encodings verify exact reconstruction at encode time and fall back to
PLAIN when floating-point cancellation would lose bits -- this mirrors the
DBD's empirical "try it on sample data" approach.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Tuple

import numpy as np

from .types import BLOCK_ROWS, SQLType, num_blocks, pad_to_blocks


class Encoding(enum.Enum):
    PLAIN = "plain"
    RLE = "rle"
    DELTA_VALUE = "delta_value"
    BLOCK_DICT = "block_dict"
    DELTA_RANGE = "delta_range"
    COMMON_DELTA = "common_delta"
    # beyond the paper's six (EXPERIMENTS.md §Perf DB-1): decimal-quantized
    # floats (meter readings, prices) scale exactly to integers and reuse
    # the full integer encoding family; verified-exact with PLAIN fallback.
    FLOAT_SCALED = "float_scaled"
    AUTO = "auto"


def _narrowest_uint(max_value: int) -> np.dtype:
    """Narrowest unsigned dtype holding values in [0, max_value]."""
    if max_value < (1 << 8):
        return np.dtype(np.uint8)
    if max_value < (1 << 16):
        return np.dtype(np.uint16)
    if max_value < (1 << 32):
        return np.dtype(np.uint32)
    return np.dtype(np.uint64)


def _narrowest_int(min_value: int, max_value: int) -> np.dtype:
    for dt in (np.int8, np.int16, np.int32, np.int64):
        info = np.iinfo(dt)
        if info.min <= min_value and max_value <= info.max:
            return np.dtype(dt)
    return np.dtype(np.int64)


def _entropy_bits(codes: np.ndarray) -> float:
    """Shannon entropy (bits/symbol) of a code stream -- models the entropy
    coder of COMMON_DELTA without implementing bit IO."""
    if codes.size == 0:
        return 0.0
    _, counts = np.unique(codes, return_counts=True)
    p = counts / counts.sum()
    return float(-(p * np.log2(p)).sum())


@dataclasses.dataclass
class EncodedColumn:
    """One column of one ROS container, encoded & block-structured.

    ``arrays`` hold scheme-specific payloads; every array has leading dim
    ``n_blocks`` so the whole container is a stack of fixed-shape blocks
    (TPU-friendly; see DESIGN.md hardware-adaptation table).
    """

    encoding: Encoding
    sql_type: SQLType
    n_rows: int
    block_rows: int
    arrays: Dict[str, np.ndarray]
    # validity bitmap for SQL NULLs (None = column has no NULLs)
    valid: Optional[np.ndarray] = None
    # modeled packed size in bytes (see module docstring)
    packed_bytes: float = 0.0
    # FLOAT_SCALED: the integer-encoded payload + decimal scale
    inner: Optional["EncodedColumn"] = None
    scale: float = 1.0

    @property
    def n_blocks(self) -> int:
        return num_blocks(self.n_rows, self.block_rows)

    def storage_bytes(self) -> float:
        b = self.packed_bytes
        if self.valid is not None:
            b += self.n_rows / 8.0  # 1-bit validity bitmap
        return b

    def decode(self) -> np.ndarray:
        """Round-trip decode to a flat 1-D numpy array of n_rows values."""
        if self.encoding == Encoding.FLOAT_SCALED:
            return self.inner.decode().astype(np.float64) / self.scale
        flat = _DECODERS[self.encoding](self.arrays, self.block_rows)
        return flat.reshape(-1)[: self.n_rows]

    def decode_blocks(self) -> np.ndarray:
        """Decode to (n_blocks, block_rows); tail block padded."""
        if self.encoding == Encoding.FLOAT_SCALED:
            return self.inner.decode_blocks().astype(np.float64) / self.scale
        return _DECODERS[self.encoding](self.arrays, self.block_rows)

    def valid_mask(self) -> Optional[np.ndarray]:
        if self.valid is None:
            return None
        return self.valid.reshape(-1)[: self.n_rows]


# ---------------------------------------------------------------------------
# Encoders.  All take a 1-D numpy array and return (arrays, packed_bytes).
# ---------------------------------------------------------------------------

def _encode_plain(values: np.ndarray, block_rows: int):
    isint = np.issubdtype(values.dtype, np.integer)
    if isint and values.size:
        store_dt = _narrowest_int(int(values.min()), int(values.max()))
    else:
        store_dt = values.dtype
    blocks = pad_to_blocks(values.astype(store_dt, copy=False), block_rows)
    return {"values": blocks}, float(values.size * store_dt.itemsize)


def _decode_plain(arrays, block_rows):
    return arrays["values"].astype(
        np.int64 if np.issubdtype(arrays["values"].dtype, np.integer)
        else np.float64)


def _rle_runs(block: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Run-length encode one block -> (run_values, run_lengths)."""
    if block.size == 0:
        return block, np.zeros(0, np.int64)
    change = np.empty(block.size, dtype=bool)
    change[0] = True
    np.not_equal(block[1:], block[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    lengths = np.diff(np.append(starts, block.size))
    return block[starts], lengths


def _encode_rle(values: np.ndarray, block_rows: int):
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    nb = blocks.shape[0]
    per_block = [_rle_runs(b) for b in blocks]
    max_runs = max(rv.size for rv, _ in per_block)
    run_values = np.zeros((nb, max_runs), dtype=values.dtype)
    run_lengths = np.zeros((nb, max_runs), dtype=np.int32)
    n_runs = np.zeros(nb, dtype=np.int32)
    packed = 0.0
    val_bytes = values.dtype.itemsize
    if np.issubdtype(values.dtype, np.integer) and values.size:
        val_bytes = _narrowest_int(int(values.min()), int(values.max())).itemsize
    for i, (rv, rl) in enumerate(per_block):
        run_values[i, : rv.size] = rv
        run_lengths[i, : rl.size] = rl
        n_runs[i] = rv.size
        packed += rv.size * (val_bytes +
                             _narrowest_uint(int(rl.max()) if rl.size else 0).itemsize)
    return ({"run_values": run_values, "run_lengths": run_lengths,
             "n_runs": n_runs}, packed)


def _decode_rle(arrays, block_rows):
    rv, rl = arrays["run_values"], arrays["run_lengths"]
    nb = rv.shape[0]
    out_dt = (np.int64 if np.issubdtype(rv.dtype, np.integer) else np.float64)
    out = np.zeros((nb, block_rows), dtype=out_dt)
    for i in range(nb):
        n = int(arrays["n_runs"][i])
        dec = np.repeat(rv[i, :n], rl[i, :n])
        out[i, : dec.size] = dec
    return out


def _encode_delta_value(values: np.ndarray, block_rows: int):
    # integer only (checked by choose/encode dispatcher)
    blocks = pad_to_blocks(values, block_rows)
    base = blocks.min(axis=1)
    deltas64 = blocks - base[:, None]
    dmax = int(deltas64.max()) if deltas64.size else 0
    dt = _narrowest_uint(dmax)
    # storage is BIT-packed per block (Vertica packs integers at the
    # narrowest bit width, not byte width); in-memory arrays stay byte-wide
    bits = max(1, int(np.ceil(np.log2(dmax + 1)))) if dmax else 1
    return ({"base": base, "deltas": deltas64.astype(dt)},
            float(values.size * bits / 8 + base.size * 8))


def _decode_delta_value(arrays, block_rows):
    return arrays["base"][:, None].astype(np.int64) + \
        arrays["deltas"].astype(np.int64)


def _encode_block_dict(values: np.ndarray, block_rows: int):
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    nb = blocks.shape[0]
    uniq_per_block = [np.unique(b) for b in blocks]
    dict_size = max(u.size for u in uniq_per_block)
    dict_values = np.zeros((nb, dict_size), dtype=values.dtype)
    codes = np.zeros((nb, block_rows), dtype=_narrowest_uint(dict_size - 1))
    dict_n = np.zeros(nb, dtype=np.int32)
    packed = 0.0
    for i, u in enumerate(uniq_per_block):
        dict_values[i, : u.size] = u
        codes[i] = np.searchsorted(u, blocks[i]).astype(codes.dtype)
        dict_n[i] = u.size
        code_bits = max(1, int(np.ceil(np.log2(max(u.size, 2)))))
        packed += u.size * values.dtype.itemsize + blocks.shape[1] * code_bits / 8
    return ({"dict_values": dict_values, "codes": codes, "dict_n": dict_n},
            packed)


def _decode_block_dict(arrays, block_rows):
    dv = arrays["dict_values"]
    out = np.take_along_axis(dv, arrays["codes"].astype(np.int64), axis=1)
    return out.astype(np.int64 if np.issubdtype(dv.dtype, np.integer)
                      else np.float64)


def _encode_delta_range(values: np.ndarray, block_rows: int):
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    first = blocks[:, 0].copy()
    deltas = np.diff(blocks, axis=1, prepend=first[:, None])
    if np.issubdtype(values.dtype, np.integer):
        dt = _narrowest_int(int(deltas.min()), int(deltas.max()))
        arrays = {"first": first, "deltas": deltas.astype(dt)}
        packed = values.size * dt.itemsize + first.size * 8
    else:
        # floats: try float32 deltas; verify exact round-trip, else reject
        d32 = deltas.astype(np.float32)
        recon = first[:, None] + np.cumsum(d32.astype(np.float64), axis=1) \
            - d32[:, :1].astype(np.float64)
        if not np.array_equal(recon, blocks):
            raise _Inexact()
        arrays = {"first": first, "deltas": d32}
        packed = values.size * 4 + first.size * 8
    return arrays, float(packed)


def _decode_delta_range(arrays, block_rows):
    d = arrays["deltas"].astype(
        np.int64 if np.issubdtype(arrays["deltas"].dtype, np.integer)
        else np.float64)
    first = arrays["first"][:, None].astype(d.dtype)
    return first + np.cumsum(d, axis=1) - d[:, :1]


def _encode_common_delta(values: np.ndarray, block_rows: int):
    # integer only: dictionary over the (few) distinct deltas, entropy-coded
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    nb = blocks.shape[0]
    first = blocks[:, 0].copy()
    deltas = np.diff(blocks, axis=1, prepend=first[:, None])
    uniq_per_block = [np.unique(d) for d in deltas]
    dict_size = max(u.size for u in uniq_per_block)
    delta_dict = np.zeros((nb, dict_size), dtype=np.int64)
    codes = np.zeros((nb, block_rows), dtype=_narrowest_uint(dict_size - 1))
    dict_n = np.zeros(nb, dtype=np.int32)
    packed = 0.0
    for i, u in enumerate(uniq_per_block):
        delta_dict[i, : u.size] = u
        codes[i] = np.searchsorted(u, deltas[i]).astype(codes.dtype)
        dict_n[i] = u.size
        packed += u.size * 8 + _entropy_bits(codes[i]) * block_rows / 8
    packed += first.size * 8
    return ({"first": first, "delta_dict": delta_dict, "codes": codes,
             "dict_n": dict_n}, packed)


def _decode_common_delta(arrays, block_rows):
    deltas = np.take_along_axis(arrays["delta_dict"],
                                arrays["codes"].astype(np.int64), axis=1)
    first = arrays["first"][:, None].astype(np.int64)
    return first + np.cumsum(deltas, axis=1) - deltas[:, :1]


class _Inexact(Exception):
    """Raised when a lossy-for-this-data encoding must be rejected."""


def _try_float_scaled(values: np.ndarray, sql_type, n_rows: int,
                      block_rows: int, valid) -> Optional["EncodedColumn"]:
    """Decimal-quantized floats -> scaled integers -> best int encoding.
    Exactness verified; returns None if any value fails round-trip."""
    if not np.issubdtype(values.dtype, np.floating) or values.size == 0:
        return None
    if not np.isfinite(values).all():
        return None
    for k in (0, 1, 2, 3):
        scale = 10.0 ** k
        scaled = values * scale
        ints = np.rint(scaled)
        if np.abs(ints).max() >= 2 ** 52:
            return None
        if not np.array_equal(ints.astype(np.int64) / scale, values):
            continue
        inner = encode(ints.astype(np.int64), SQLType.INT, Encoding.AUTO,
                       block_rows=block_rows)
        return EncodedColumn(Encoding.FLOAT_SCALED, sql_type, n_rows,
                             block_rows, {}, valid, inner.packed_bytes,
                             inner=inner, scale=scale)
    return None


_ENCODERS = {
    Encoding.PLAIN: _encode_plain,
    Encoding.RLE: _encode_rle,
    Encoding.DELTA_VALUE: _encode_delta_value,
    Encoding.BLOCK_DICT: _encode_block_dict,
    Encoding.DELTA_RANGE: _encode_delta_range,
    Encoding.COMMON_DELTA: _encode_common_delta,
}

_DECODERS = {
    Encoding.PLAIN: _decode_plain,
    Encoding.RLE: _decode_rle,
    Encoding.DELTA_VALUE: _decode_delta_value,
    Encoding.BLOCK_DICT: _decode_block_dict,
    Encoding.DELTA_RANGE: _decode_delta_range,
    Encoding.COMMON_DELTA: _decode_common_delta,
}

# Which encodings are even legal for a given dtype family
_INT_ENCODINGS = (Encoding.RLE, Encoding.COMMON_DELTA, Encoding.DELTA_VALUE,
                  Encoding.BLOCK_DICT, Encoding.DELTA_RANGE, Encoding.PLAIN)
_FLOAT_ENCODINGS = (Encoding.FLOAT_SCALED, Encoding.RLE,
                    Encoding.BLOCK_DICT, Encoding.DELTA_RANGE,
                    Encoding.PLAIN)


def encode(values: np.ndarray, sql_type: SQLType,
           encoding: Encoding = Encoding.AUTO,
           valid: Optional[np.ndarray] = None,
           block_rows: int = BLOCK_ROWS) -> EncodedColumn:
    """Encode a 1-D value array into an EncodedColumn.

    ``encoding=AUTO`` empirically tries every legal scheme and keeps the
    smallest (the DBD §6.3 storage-optimization step).  Explicit schemes that
    cannot represent the data exactly (float cancellation) or that do not
    apply to the dtype fall back to PLAIN.
    """
    values = np.ascontiguousarray(values)
    n_rows = int(values.size)
    if valid is not None:
        valid = pad_to_blocks(np.asarray(valid, dtype=bool), block_rows,
                              pad_value=False)

    isint = np.issubdtype(values.dtype, np.integer)
    values = values.astype(np.int64 if isint else np.float64, copy=False)

    def _try(enc: Encoding):
        if enc == Encoding.FLOAT_SCALED:
            return _try_float_scaled(values, sql_type, n_rows, block_rows,
                                     valid)
        try:
            arrays, packed = _ENCODERS[enc](values, block_rows)
        except (_Inexact, ValueError, OverflowError):
            return None
        return EncodedColumn(enc, sql_type, n_rows, block_rows, arrays,
                             valid, packed)

    if encoding == Encoding.AUTO:
        candidates = _INT_ENCODINGS if isint else _FLOAT_ENCODINGS
        best = None
        for enc in candidates:
            col = _try(enc)
            if col is not None and (best is None or
                                    col.packed_bytes < best.packed_bytes):
                best = col
        assert best is not None
        return best

    legal = _INT_ENCODINGS if isint else _FLOAT_ENCODINGS
    if encoding not in legal:
        encoding = Encoding.PLAIN
    col = _try(encoding)
    if col is None:  # inexact for this data -> PLAIN (always succeeds)
        col = _try(Encoding.PLAIN)
    return col


# ---------------------------------------------------------------------------
# jnp decode paths (static shapes) -- used by the execution engine / kernels.
# Imported lazily so host-only storage code never pulls in jax.
# ---------------------------------------------------------------------------

def upload_jnp(col: EncodedColumn) -> Dict[str, "object"]:
    """Upload the encoded payload arrays to device, once.  The returned
    dict can be kept in the block cache (core/block_cache.py) and handed
    back to ``decode_jnp(col, arrays=...)`` so repeat queries skip the
    host->device copy entirely.  FLOAT_SCALED stores its payload on the
    inner integer column, so that is what gets uploaded."""
    import jax.numpy as jnp

    if col.encoding == Encoding.FLOAT_SCALED:
        return upload_jnp(col.inner)
    return {k: jnp.asarray(v) for k, v in col.arrays.items()}


def device_bytes(arrays) -> int:
    """Device-byte footprint of an uploaded payload dict (or one array)."""
    if hasattr(arrays, "values") and not hasattr(arrays, "dtype"):
        return sum(int(v.size) * v.dtype.itemsize for v in arrays.values())
    return int(arrays.size) * arrays.dtype.itemsize


def decode_jnp(col: EncodedColumn, arrays=None):
    """Decode to a (n_blocks, block_rows) jnp array on device.

    ``arrays`` may carry pre-uploaded device copies of the encoded payload
    (from ``upload_jnp`` via the block cache); when omitted the payload is
    uploaded here, per call -- the cold path."""
    import jax.numpy as jnp

    if col.encoding == Encoding.FLOAT_SCALED:
        return decode_jnp(col.inner, arrays).astype(jnp.float32) / col.scale
    a = arrays if arrays is not None \
        else {k: jnp.asarray(v) for k, v in col.arrays.items()}
    br = col.block_rows
    enc = col.encoding
    if enc == Encoding.PLAIN:
        return a["values"].astype(jnp.int64
                                  if np.issubdtype(col.arrays["values"].dtype,
                                                   np.integer)
                                  else jnp.float64)
    if enc == Encoding.RLE:
        # positions p belong to run r iff cum_lengths[r-1] <= p < cum_lengths[r]
        cum = jnp.cumsum(a["run_lengths"], axis=1)
        pos = jnp.arange(br)[None, None, :]              # (1,1,br)
        run_idx = (pos >= cum[:, :, None]).sum(axis=1)   # (nb,br)
        run_idx = jnp.clip(run_idx, 0, a["run_values"].shape[1] - 1)
        return jnp.take_along_axis(a["run_values"], run_idx, axis=1)
    if enc == Encoding.DELTA_VALUE:
        return a["base"][:, None].astype(jnp.int64) + \
            a["deltas"].astype(jnp.int64)
    if enc == Encoding.BLOCK_DICT:
        return jnp.take_along_axis(a["dict_values"],
                                   a["codes"].astype(jnp.int32), axis=1)
    if enc == Encoding.DELTA_RANGE:
        isint = np.issubdtype(col.arrays["deltas"].dtype, np.integer)
        d = a["deltas"].astype(jnp.int64 if isint else jnp.float64)
        first = a["first"][:, None].astype(d.dtype)
        return first + jnp.cumsum(d, axis=1) - d[:, :1]
    if enc == Encoding.COMMON_DELTA:
        deltas = jnp.take_along_axis(a["delta_dict"],
                                     a["codes"].astype(jnp.int32), axis=1)
        first = a["first"][:, None].astype(jnp.int64)
        return first + jnp.cumsum(deltas, axis=1) - deltas[:, :1]
    raise ValueError(f"cannot decode {enc}")


def choose_encoding_stats(values: np.ndarray) -> Dict[str, float]:
    """Data statistics the DBD reports alongside its empirical choice."""
    n = values.size
    if n == 0:
        return {"n": 0, "n_distinct": 0, "sortedness": 1.0, "run_ratio": 0.0}
    nd = int(np.unique(values).size)
    sortedness = float(np.mean(values[1:] >= values[:-1])) if n > 1 else 1.0
    runs = 1 + int(np.sum(values[1:] != values[:-1])) if n > 1 else 1
    return {"n": n, "n_distinct": nd, "sortedness": sortedness,
            "run_ratio": runs / n}
