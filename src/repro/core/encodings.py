"""Block-structured column encodings (paper §3.4).

Vertica's six encoding types, adapted for TPU-friendly fixed shapes:

1. AUTO              -- empirically picks the smallest encoding (the same
                        machinery the Database Designer's storage-optimization
                        phase uses, §6.3).
2. RLE               -- (value, run_length) pairs; best for low-cardinality
                        sorted columns.
3. DELTA_VALUE       -- difference from the smallest value in the block; best
                        for many-valued unsorted integers.
4. BLOCK_DICT        -- per-block dictionary + codes; best for few-valued
                        unsorted columns.
5. DELTA_RANGE       -- ("Compressed Delta Range") delta from the previous
                        value; best for many-valued sorted/range-bound data.
6. COMMON_DELTA      -- ("Compressed Common Delta") dictionary of deltas +
                        bit-packed indexes; best for predictable sequences
                        (timestamps, primary keys).
(0. PLAIN            -- no encoding; the fallback.)

Encode runs host-side (numpy) at moveout/mergeout time, exactly as Vertica
encodes when writing ROS containers.  Decode has two implementations:

* ``decode()``      -- numpy, used by host-side storage management (mergeout).
* ``decode_jnp()``  -- jnp with static shapes, used by the execution engine on
                       device; packed streams are unpacked by the bit-unpack
                       kernel (kernels/bitunpack.py, dispatched via
                       kernels/ops.py) fused with delta/dict reconstruction.

Packed storage is REAL (DESIGN.md §9): BLOCK_DICT codes, COMMON_DELTA code
streams, and integer DELTA_VALUE / DELTA_RANGE deltas are stored as packed
little-endian uint32 word streams at ``ceil(log2(domain))`` bits per symbol
(``pack_words`` / ``unpack_words``).  Each group of 32 consecutive symbols
occupies exactly ``width`` uint32 words (32*width bits), so a block of
``block_rows`` symbols is ``ceil(block_rows/32) * width`` words and every
bit offset within a group is static per width -- the device unpack is pure
shift/mask with constant indices.  ``storage_bytes`` charges the actual
``nbytes`` of the packed streams; variable-length per-block metadata (RLE
runs, dictionary entries) is charged at its true occupied size -- the
rectangular padding of the in-memory arrays exists only for fixed-shape
device upload, like the SMA index it is not part of the disk image.
Streams whose symbol width would exceed 32 bits (deltas spanning > 2^32)
fall back to byte-wide storage, charged at actual nbytes.

BLOCK_DICT additionally carries a container-global dictionary
(``global_dict``) and a per-block code remap (``code_map``: block code ->
global code), derived at encode time.  These enable compressed-domain
execution: predicates rewritten to code ranges via dictionary binary
search, and GROUP BY on a dict column using global codes directly as a
dense domain.  Like the SMA they are derived indexes, not charged to
``storage_bytes``.

Losslessness: every encoding must round-trip bit-exactly.  For FLOAT columns,
delta encodings verify exact reconstruction at encode time and fall back to
PLAIN when floating-point cancellation would lose bits -- this mirrors the
DBD's empirical "try it on sample data" approach.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional, Tuple

import numpy as np

from .types import BLOCK_ROWS, SQLType, num_blocks, pad_to_blocks


class Encoding(enum.Enum):
    PLAIN = "plain"
    RLE = "rle"
    DELTA_VALUE = "delta_value"
    BLOCK_DICT = "block_dict"
    DELTA_RANGE = "delta_range"
    COMMON_DELTA = "common_delta"
    # beyond the paper's six (EXPERIMENTS.md §Perf DB-1): decimal-quantized
    # floats (meter readings, prices) scale exactly to integers and reuse
    # the full integer encoding family; verified-exact with PLAIN fallback.
    FLOAT_SCALED = "float_scaled"
    AUTO = "auto"


def _narrowest_uint(max_value: int) -> np.dtype:
    """Narrowest unsigned dtype holding values in [0, max_value]."""
    if max_value < (1 << 8):
        return np.dtype(np.uint8)
    if max_value < (1 << 16):
        return np.dtype(np.uint16)
    if max_value < (1 << 32):
        return np.dtype(np.uint32)
    return np.dtype(np.uint64)


def _narrowest_int(min_value: int, max_value: int) -> np.dtype:
    for dt in (np.int8, np.int16, np.int32, np.int64):
        info = np.iinfo(dt)
        if info.min <= min_value and max_value <= info.max:
            return np.dtype(dt)
    return np.dtype(np.int64)


# ---------------------------------------------------------------------------
# Bit-packing: little-endian uint32 word streams (DESIGN.md §9).
#
# Group format: symbols are processed in groups of 32.  A group of 32 w-bit
# symbols is exactly 32*w bits = w uint32 words; symbol s of a group starts
# at bit s*w, i.e. word (s*w)//32 bit (s*w)%32, possibly straddling into the
# next word.  Because the group size equals the word width, the (word, shift)
# pair for each of the 32 slots is a compile-time constant per width -- both
# the XLA and Pallas unpack paths use static indices and shifts only.
# ---------------------------------------------------------------------------

MAX_PACK_BITS = 32


def symbol_width(max_value: int) -> int:
    """Bits per symbol for values in [0, max_value]: ceil(log2(domain)), >=1."""
    return max(1, int(max_value).bit_length())


def pack_words(symbols: np.ndarray, width: int) -> np.ndarray:
    """Pack (n_blocks, block_rows) non-negative symbols < 2**width into
    little-endian uint32 words, shape (n_blocks, ceil(block_rows/32)*width)."""
    if not 1 <= width <= MAX_PACK_BITS:
        raise ValueError(f"width {width} out of range 1..{MAX_PACK_BITS}")
    nb, br = symbols.shape
    ng = (br + 31) // 32
    s = symbols.astype(np.uint64, copy=False)
    if ng * 32 != br:
        s = np.concatenate([s, np.zeros((nb, ng * 32 - br), np.uint64)],
                           axis=1)
    # bit-expand (LSB first per symbol), then packbits -> bytes -> words
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((s[..., None] >> shifts) & np.uint64(1)).astype(np.uint8)
    bits = bits.reshape(nb, ng, 32 * width)
    packed = np.packbits(bits, axis=-1, bitorder="little")  # (nb, ng, 4*width)
    words = np.ascontiguousarray(packed).view("<u4")
    return words.reshape(nb, ng * width).astype(np.uint32, copy=False)


def _slot_tables(width: int):
    """Static per-slot (of 32) word index / shift tables for one width."""
    slot = np.arange(32)
    bit = slot * width
    lo = bit // 32                      # word holding the symbol's low bits
    sh = (bit % 32).astype(np.uint64)   # shift within that word
    straddle = (bit % 32) + width > 32  # symbol continues into word lo+1
    hi = np.minimum(lo + 1, width - 1)  # clipped: only read when straddling
    hi_shift = ((32 - (bit % 32)) % 32).astype(np.uint64)
    return lo, sh, hi, hi_shift, straddle


def unpack_words(words: np.ndarray, width: int, block_rows: int) -> np.ndarray:
    """Inverse of pack_words -> (n_blocks, block_rows) int64 symbols."""
    nb, nw = words.shape
    ng = max(1, nw // max(width, 1))
    lo, sh, hi, hi_shift, straddle = _slot_tables(width)
    g = words.reshape(nb, ng, width).astype(np.uint64)
    vals = g[:, :, lo] >> sh
    vals |= np.where(straddle, g[:, :, hi] << hi_shift, np.uint64(0))
    mask = np.uint64((1 << width) - 1) if width < 64 else np.uint64(-1)
    syms = (vals & mask).reshape(nb, ng * 32)[:, :block_rows]
    return syms.astype(np.int64)


def _packed_width(arrays: Dict[str, np.ndarray], key: str,
                  block_rows: int) -> int:
    """Recover the symbol width of a packed stream from its word count."""
    ng = (block_rows + 31) // 32
    return arrays[key].shape[1] // ng


@dataclasses.dataclass
class EncodedColumn:
    """One column of one ROS container, encoded & block-structured.

    ``arrays`` hold scheme-specific payloads; every array has leading dim
    ``n_blocks`` so the whole container is a stack of fixed-shape blocks
    (TPU-friendly; see DESIGN.md hardware-adaptation table).  Packed streams
    (``*_packed`` keys) are uint32 word streams; ``widths`` maps each packed
    stream to its bits-per-symbol (part of the plan signature so dictionary
    domain growth misses the plan cache correctly).
    """

    encoding: Encoding
    sql_type: SQLType
    n_rows: int
    block_rows: int
    arrays: Dict[str, np.ndarray]
    # validity bitmap for SQL NULLs (None = column has no NULLs)
    valid: Optional[np.ndarray] = None
    # actual packed size in bytes (see module docstring)
    packed_bytes: float = 0.0
    # FLOAT_SCALED: the integer-encoded payload + decimal scale
    inner: Optional["EncodedColumn"] = None
    scale: float = 1.0
    # bits per symbol for each packed stream in ``arrays``
    widths: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def n_blocks(self) -> int:
        return num_blocks(self.n_rows, self.block_rows)

    def storage_bytes(self) -> float:
        b = self.packed_bytes
        if self.valid is not None:
            b += self.n_rows / 8.0  # 1-bit validity bitmap
        return b

    def width_signature(self) -> Tuple[Tuple[str, int], ...]:
        """Hashable (stream, bits) pairs for plan signatures."""
        inner = self.inner.width_signature() if self.inner is not None else ()
        return tuple(sorted(self.widths.items())) + inner

    def decode(self) -> np.ndarray:
        """Round-trip decode to a flat 1-D numpy array of n_rows values."""
        if self.encoding == Encoding.FLOAT_SCALED:
            return self.inner.decode().astype(np.float64) / self.scale
        flat = _DECODERS[self.encoding](self.arrays, self.block_rows)
        return flat.reshape(-1)[: self.n_rows]

    def decode_blocks(self) -> np.ndarray:
        """Decode to (n_blocks, block_rows); tail block padded."""
        if self.encoding == Encoding.FLOAT_SCALED:
            return self.inner.decode_blocks().astype(np.float64) / self.scale
        return _DECODERS[self.encoding](self.arrays, self.block_rows)

    def valid_mask(self) -> Optional[np.ndarray]:
        if self.valid is None:
            return None
        return self.valid.reshape(-1)[: self.n_rows]


# ---------------------------------------------------------------------------
# Encoders.  All take a 1-D numpy array and return
# (arrays, packed_bytes, widths).
# ---------------------------------------------------------------------------

def _encode_plain(values: np.ndarray, block_rows: int):
    isint = np.issubdtype(values.dtype, np.integer)
    if isint and values.size:
        store_dt = _narrowest_int(int(values.min()), int(values.max()))
    else:
        store_dt = values.dtype
    blocks = pad_to_blocks(values.astype(store_dt, copy=False), block_rows)
    return {"values": blocks}, float(blocks.nbytes), {}


def _decode_plain(arrays, block_rows):
    return arrays["values"].astype(
        np.int64 if np.issubdtype(arrays["values"].dtype, np.integer)
        else np.float64)


def _rle_runs(block: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Run-length encode one block -> (run_values, run_lengths)."""
    if block.size == 0:
        return block, np.zeros(0, np.int64)
    change = np.empty(block.size, dtype=bool)
    change[0] = True
    np.not_equal(block[1:], block[:-1], out=change[1:])
    starts = np.flatnonzero(change)
    lengths = np.diff(np.append(starts, block.size))
    return block[starts], lengths


def _encode_rle(values: np.ndarray, block_rows: int):
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    nb = blocks.shape[0]
    per_block = [_rle_runs(b) for b in blocks]
    max_runs = max(rv.size for rv, _ in per_block)
    run_values = np.zeros((nb, max_runs), dtype=values.dtype)
    run_lengths = np.zeros((nb, max_runs), dtype=np.int32)
    n_runs = np.zeros(nb, dtype=np.int32)
    packed = 0.0
    val_bytes = values.dtype.itemsize
    if np.issubdtype(values.dtype, np.integer) and values.size:
        val_bytes = _narrowest_int(int(values.min()), int(values.max())).itemsize
    for i, (rv, rl) in enumerate(per_block):
        run_values[i, : rv.size] = rv
        run_lengths[i, : rl.size] = rl
        n_runs[i] = rv.size
        packed += rv.size * (val_bytes +
                             _narrowest_uint(int(rl.max()) if rl.size else 0).itemsize)
    return ({"run_values": run_values, "run_lengths": run_lengths,
             "n_runs": n_runs}, packed, {})


def _decode_rle(arrays, block_rows):
    rv, rl = arrays["run_values"], arrays["run_lengths"]
    nb = rv.shape[0]
    out_dt = (np.int64 if np.issubdtype(rv.dtype, np.integer) else np.float64)
    out = np.zeros((nb, block_rows), dtype=out_dt)
    for i in range(nb):
        n = int(arrays["n_runs"][i])
        dec = np.repeat(rv[i, :n], rl[i, :n])
        out[i, : dec.size] = dec
    return out


def _encode_delta_value(values: np.ndarray, block_rows: int):
    # integer only (checked by choose/encode dispatcher)
    blocks = pad_to_blocks(values, block_rows)
    base = blocks.min(axis=1)
    deltas64 = blocks - base[:, None]
    dmax = int(deltas64.max()) if deltas64.size else 0
    w = symbol_width(dmax)
    if w <= MAX_PACK_BITS:
        words = pack_words(deltas64, w)
        return ({"base": base, "deltas_packed": words},
                float(words.nbytes + base.nbytes), {"deltas_packed": w})
    # deltas span more than 2^32: byte-wide fallback
    dt = _narrowest_uint(dmax)
    return ({"base": base, "deltas": deltas64.astype(dt)},
            float(deltas64.size * dt.itemsize + base.nbytes), {})


def _decode_delta_value(arrays, block_rows):
    if "deltas_packed" in arrays:
        w = _packed_width(arrays, "deltas_packed", block_rows)
        deltas = unpack_words(arrays["deltas_packed"], w, block_rows)
    else:
        deltas = arrays["deltas"].astype(np.int64)
    return arrays["base"][:, None].astype(np.int64) + deltas


def _encode_block_dict(values: np.ndarray, block_rows: int):
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    nb = blocks.shape[0]
    uniq_per_block = [np.unique(b) for b in blocks]
    dict_size = max(u.size for u in uniq_per_block)
    w = symbol_width(dict_size - 1)
    dict_values = np.zeros((nb, dict_size), dtype=values.dtype)
    codes = np.zeros((nb, block_rows), dtype=np.int64)
    dict_n = np.zeros(nb, dtype=np.int32)
    # container-global dictionary + per-block remap: derived indexes that
    # let the executor evaluate predicates and GROUP BY in the code domain
    global_dict = np.unique(blocks)
    code_map = np.zeros((nb, dict_size), dtype=np.int32)
    packed = 0.0
    for i, u in enumerate(uniq_per_block):
        dict_values[i, : u.size] = u
        codes[i] = np.searchsorted(u, blocks[i])
        dict_n[i] = u.size
        code_map[i, : u.size] = np.searchsorted(global_dict, u)
        packed += u.size * values.dtype.itemsize
    words = pack_words(codes, w)
    packed += words.nbytes + dict_n.nbytes
    return ({"dict_values": dict_values, "codes_packed": words,
             "dict_n": dict_n, "global_dict": global_dict,
             "code_map": code_map},
            packed, {"codes_packed": w})


def _decode_block_dict(arrays, block_rows):
    dv = arrays["dict_values"]
    if "codes_packed" in arrays:
        w = _packed_width(arrays, "codes_packed", block_rows)
        codes = unpack_words(arrays["codes_packed"], w, block_rows)
    else:
        codes = arrays["codes"].astype(np.int64)
    out = np.take_along_axis(dv, codes, axis=1)
    return out.astype(np.int64 if np.issubdtype(dv.dtype, np.integer)
                      else np.float64)


def _encode_delta_range(values: np.ndarray, block_rows: int):
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    first = blocks[:, 0].copy()
    deltas = np.diff(blocks, axis=1, prepend=first[:, None])
    if np.issubdtype(values.dtype, np.integer):
        delta_min = deltas.min(axis=1)
        rel = deltas - delta_min[:, None]
        w = symbol_width(int(rel.max()) if rel.size else 0)
        if w <= MAX_PACK_BITS:
            words = pack_words(rel, w)
            return ({"first": first, "delta_min": delta_min,
                     "deltas_packed": words},
                    float(words.nbytes + first.nbytes + delta_min.nbytes),
                    {"deltas_packed": w})
        dt = _narrowest_int(int(deltas.min()), int(deltas.max()))
        return ({"first": first, "deltas": deltas.astype(dt)},
                float(deltas.size * dt.itemsize + first.nbytes), {})
    # floats: try float32 deltas; verify exact round-trip, else reject
    d32 = deltas.astype(np.float32)
    recon = first[:, None] + np.cumsum(d32.astype(np.float64), axis=1) \
        - d32[:, :1].astype(np.float64)
    if not np.array_equal(recon, blocks):
        raise _Inexact()
    return ({"first": first, "deltas": d32},
            float(d32.nbytes + first.nbytes), {})


def _decode_delta_range(arrays, block_rows):
    if "deltas_packed" in arrays:
        w = _packed_width(arrays, "deltas_packed", block_rows)
        rel = unpack_words(arrays["deltas_packed"], w, block_rows)
        d = rel + arrays["delta_min"][:, None].astype(np.int64)
    else:
        d = arrays["deltas"].astype(
            np.int64 if np.issubdtype(arrays["deltas"].dtype, np.integer)
            else np.float64)
    first = arrays["first"][:, None].astype(d.dtype)
    return first + np.cumsum(d, axis=1) - d[:, :1]


def _encode_common_delta(values: np.ndarray, block_rows: int):
    # integer only: dictionary over the (few) distinct deltas + bit-packed
    # code stream at ceil(log2(dict size)) bits per symbol
    blocks = pad_to_blocks(values, block_rows,
                           pad_value=values[-1] if values.size else 0)
    nb = blocks.shape[0]
    first = blocks[:, 0].copy()
    deltas = np.diff(blocks, axis=1, prepend=first[:, None])
    uniq_per_block = [np.unique(d) for d in deltas]
    dict_size = max(u.size for u in uniq_per_block)
    w = symbol_width(dict_size - 1)
    delta_dict = np.zeros((nb, dict_size), dtype=np.int64)
    codes = np.zeros((nb, block_rows), dtype=np.int64)
    dict_n = np.zeros(nb, dtype=np.int32)
    packed = 0.0
    for i, u in enumerate(uniq_per_block):
        delta_dict[i, : u.size] = u
        codes[i] = np.searchsorted(u, deltas[i])
        dict_n[i] = u.size
        packed += u.size * 8
    words = pack_words(codes, w)
    packed += words.nbytes + first.nbytes + dict_n.nbytes
    return ({"first": first, "delta_dict": delta_dict,
             "codes_packed": words, "dict_n": dict_n},
            packed, {"codes_packed": w})


def _decode_common_delta(arrays, block_rows):
    if "codes_packed" in arrays:
        w = _packed_width(arrays, "codes_packed", block_rows)
        codes = unpack_words(arrays["codes_packed"], w, block_rows)
    else:
        codes = arrays["codes"].astype(np.int64)
    deltas = np.take_along_axis(arrays["delta_dict"], codes, axis=1)
    first = arrays["first"][:, None].astype(np.int64)
    return first + np.cumsum(deltas, axis=1) - deltas[:, :1]


class _Inexact(Exception):
    """Raised when a lossy-for-this-data encoding must be rejected."""


def _try_float_scaled(values: np.ndarray, sql_type, n_rows: int,
                      block_rows: int, valid) -> Optional["EncodedColumn"]:
    """Decimal-quantized floats -> scaled integers -> best int encoding.
    Exactness verified; returns None if any value fails round-trip."""
    if not np.issubdtype(values.dtype, np.floating) or values.size == 0:
        return None
    if not np.isfinite(values).all():
        return None
    for k in (0, 1, 2, 3):
        scale = 10.0 ** k
        scaled = values * scale
        ints = np.rint(scaled)
        if np.abs(ints).max() >= 2 ** 52:
            return None
        if not np.array_equal(ints.astype(np.int64) / scale, values):
            continue
        inner = encode(ints.astype(np.int64), SQLType.INT, Encoding.AUTO,
                       block_rows=block_rows)
        return EncodedColumn(Encoding.FLOAT_SCALED, sql_type, n_rows,
                             block_rows, {}, valid, inner.packed_bytes,
                             inner=inner, scale=scale)
    return None


_ENCODERS = {
    Encoding.PLAIN: _encode_plain,
    Encoding.RLE: _encode_rle,
    Encoding.DELTA_VALUE: _encode_delta_value,
    Encoding.BLOCK_DICT: _encode_block_dict,
    Encoding.DELTA_RANGE: _encode_delta_range,
    Encoding.COMMON_DELTA: _encode_common_delta,
}

_DECODERS = {
    Encoding.PLAIN: _decode_plain,
    Encoding.RLE: _decode_rle,
    Encoding.DELTA_VALUE: _decode_delta_value,
    Encoding.BLOCK_DICT: _decode_block_dict,
    Encoding.DELTA_RANGE: _decode_delta_range,
    Encoding.COMMON_DELTA: _decode_common_delta,
}

# Which encodings are even legal for a given dtype family
_INT_ENCODINGS = (Encoding.RLE, Encoding.COMMON_DELTA, Encoding.DELTA_VALUE,
                  Encoding.BLOCK_DICT, Encoding.DELTA_RANGE, Encoding.PLAIN)
_FLOAT_ENCODINGS = (Encoding.FLOAT_SCALED, Encoding.RLE,
                    Encoding.BLOCK_DICT, Encoding.DELTA_RANGE,
                    Encoding.PLAIN)


def encode(values: np.ndarray, sql_type: SQLType,
           encoding: Encoding = Encoding.AUTO,
           valid: Optional[np.ndarray] = None,
           block_rows: int = BLOCK_ROWS) -> EncodedColumn:
    """Encode a 1-D value array into an EncodedColumn.

    ``encoding=AUTO`` empirically tries every legal scheme and keeps the
    smallest (the DBD §6.3 storage-optimization step).  Explicit schemes that
    cannot represent the data exactly (float cancellation) or that do not
    apply to the dtype fall back to PLAIN.
    """
    values = np.ascontiguousarray(values)
    n_rows = int(values.size)
    if valid is not None:
        valid = pad_to_blocks(np.asarray(valid, dtype=bool), block_rows,
                              pad_value=False)

    isint = np.issubdtype(values.dtype, np.integer)
    values = values.astype(np.int64 if isint else np.float64, copy=False)

    def _try(enc: Encoding):
        if enc == Encoding.FLOAT_SCALED:
            return _try_float_scaled(values, sql_type, n_rows, block_rows,
                                     valid)
        try:
            arrays, packed, widths = _ENCODERS[enc](values, block_rows)
        except (_Inexact, ValueError, OverflowError):
            return None
        return EncodedColumn(enc, sql_type, n_rows, block_rows, arrays,
                             valid, packed, widths=widths)

    if encoding == Encoding.AUTO:
        candidates = _INT_ENCODINGS if isint else _FLOAT_ENCODINGS
        best = None
        for enc in candidates:
            col = _try(enc)
            if col is not None and (best is None or
                                    col.packed_bytes < best.packed_bytes):
                best = col
        assert best is not None
        return best

    legal = _INT_ENCODINGS if isint else _FLOAT_ENCODINGS
    if encoding not in legal:
        encoding = Encoding.PLAIN
    col = _try(encoding)
    if col is None:  # inexact for this data -> PLAIN (always succeeds)
        col = _try(Encoding.PLAIN)
    return col


# ---------------------------------------------------------------------------
# jnp decode paths (static shapes) -- used by the execution engine / kernels.
# Imported lazily so host-only storage code never pulls in jax.
# ---------------------------------------------------------------------------

def upload_jnp(col: EncodedColumn) -> Dict[str, "object"]:
    """Upload the encoded payload arrays to device, once.  The returned
    dict can be kept in the block cache (core/block_cache.py) and handed
    back to ``decode_jnp(col, arrays=...)`` so repeat queries skip the
    host->device copy entirely.  Packed streams upload as uint32 words, so
    the cache-resident footprint is the real packed size.  FLOAT_SCALED
    stores its payload on the inner integer column, so that is what gets
    uploaded."""
    import jax.numpy as jnp

    if col.encoding == Encoding.FLOAT_SCALED:
        return upload_jnp(col.inner)
    return {k: jnp.asarray(v) for k, v in col.arrays.items()}


def device_bytes(arrays) -> int:
    """Device-byte footprint of an uploaded payload dict (or one array)."""
    if hasattr(arrays, "values") and not hasattr(arrays, "dtype"):
        return sum(int(v.size) * v.dtype.itemsize for v in arrays.values())
    return int(arrays.size) * arrays.dtype.itemsize


def _unpack_jnp(a, col: EncodedColumn, key: str, base=None):
    """Device bit-unpack of a packed stream via the kernel dispatcher."""
    from ..kernels import ops as kops

    w = col.widths.get(key) or _packed_width(col.arrays, key, col.block_rows)
    return kops.bitunpack(a[key], w, col.block_rows, base=base)


def decode_jnp(col: EncodedColumn, arrays=None):
    """Decode to a (n_blocks, block_rows) jnp array on device.

    ``arrays`` may carry pre-uploaded device copies of the encoded payload
    (from ``upload_jnp`` via the block cache); when omitted the payload is
    uploaded here, per call -- the cold path."""
    import jax.numpy as jnp

    if col.encoding == Encoding.FLOAT_SCALED:
        return decode_jnp(col.inner, arrays).astype(jnp.float32) / col.scale
    a = arrays if arrays is not None \
        else {k: jnp.asarray(v) for k, v in col.arrays.items()}
    br = col.block_rows
    enc = col.encoding
    if enc == Encoding.PLAIN:
        return a["values"].astype(jnp.int64
                                  if np.issubdtype(col.arrays["values"].dtype,
                                                   np.integer)
                                  else jnp.float64)
    if enc == Encoding.RLE:
        # positions p belong to run r iff cum_lengths[r-1] <= p < cum_lengths[r]
        cum = jnp.cumsum(a["run_lengths"], axis=1)
        pos = jnp.arange(br)[None, None, :]              # (1,1,br)
        run_idx = (pos >= cum[:, :, None]).sum(axis=1)   # (nb,br)
        run_idx = jnp.clip(run_idx, 0, a["run_values"].shape[1] - 1)
        return jnp.take_along_axis(a["run_values"], run_idx, axis=1)
    if enc == Encoding.DELTA_VALUE:
        if "deltas_packed" in col.arrays:
            # bit-unpack fused with the base-offset reconstruction
            return _unpack_jnp(a, col, "deltas_packed",
                               base=a["base"]).astype(jnp.int64)
        return a["base"][:, None].astype(jnp.int64) + \
            a["deltas"].astype(jnp.int64)
    if enc == Encoding.BLOCK_DICT:
        if "codes_packed" in col.arrays:
            codes = _unpack_jnp(a, col, "codes_packed")
        else:
            codes = a["codes"].astype(jnp.int32)
        return jnp.take_along_axis(a["dict_values"], codes, axis=1)
    if enc == Encoding.DELTA_RANGE:
        if "deltas_packed" in col.arrays:
            d = _unpack_jnp(a, col, "deltas_packed",
                            base=a["delta_min"]).astype(jnp.int64)
        else:
            isint = np.issubdtype(col.arrays["deltas"].dtype, np.integer)
            d = a["deltas"].astype(jnp.int64 if isint else jnp.float64)
        first = a["first"][:, None].astype(d.dtype)
        return first + jnp.cumsum(d, axis=1) - d[:, :1]
    if enc == Encoding.COMMON_DELTA:
        if "codes_packed" in col.arrays:
            codes = _unpack_jnp(a, col, "codes_packed")
        else:
            codes = a["codes"].astype(jnp.int32)
        deltas = jnp.take_along_axis(a["delta_dict"], codes, axis=1)
        first = a["first"][:, None].astype(jnp.int64)
        return first + jnp.cumsum(deltas, axis=1) - deltas[:, :1]
    raise ValueError(f"cannot decode {enc}")


# ---------------------------------------------------------------------------
# Compressed-domain access helpers (executor late materialization).
# ---------------------------------------------------------------------------

def random_access_jnp(col: EncodedColumn) -> bool:
    """True when single rows can be decoded on device without reconstructing
    whole blocks (no cumsum / run expansion)."""
    if col.encoding == Encoding.FLOAT_SCALED:
        return random_access_jnp(col.inner)
    if col.encoding in (Encoding.PLAIN, Encoding.DELTA_VALUE,
                        Encoding.BLOCK_DICT):
        return True
    return False


def gather_decode_jnp(col: EncodedColumn, a, b_idx, r_idx):
    """Decode only the rows (block b_idx[i], row r_idx[i]) on device.

    The late-materialization path: survivor positions from a code-domain
    predicate gather straight out of the packed payload, so non-predicate
    columns never materialize full blocks.  Only valid for encodings where
    ``random_access_jnp`` is True."""
    import jax.numpy as jnp

    from ..kernels.bitunpack import gather_unpack

    enc = col.encoding
    if enc == Encoding.FLOAT_SCALED:
        return gather_decode_jnp(col.inner, a, b_idx, r_idx) \
            .astype(jnp.float32) / col.scale
    if enc == Encoding.PLAIN:
        return a["values"][b_idx, r_idx]
    if enc == Encoding.DELTA_VALUE:
        if "deltas_packed" in col.arrays:
            w = _packed_width(col.arrays, "deltas_packed", col.block_rows)
            d = gather_unpack(a["deltas_packed"], w, b_idx, r_idx)
        else:
            d = a["deltas"][b_idx, r_idx].astype(jnp.int32)
        return (a["base"][b_idx].astype(jnp.int32) + d).astype(jnp.int64)
    if enc == Encoding.BLOCK_DICT:
        if "codes_packed" in col.arrays:
            w = _packed_width(col.arrays, "codes_packed", col.block_rows)
            codes = gather_unpack(a["codes_packed"], w, b_idx, r_idx)
        else:
            codes = a["codes"][b_idx, r_idx].astype(jnp.int32)
        return a["dict_values"][b_idx, codes]
    raise ValueError(f"{enc} is not randomly accessible on device")


def choose_encoding_stats(values: np.ndarray) -> Dict[str, float]:
    """Data statistics the DBD reports alongside its empirical choice."""
    n = values.size
    if n == 0:
        return {"n": 0, "n_distinct": 0, "sortedness": 1.0, "run_ratio": 0.0}
    nd = int(np.unique(values).size)
    sortedness = float(np.mean(values[1:] >= values[:-1])) if n > 1 else 1.0
    runs = 1 + int(np.sum(values[1:] != values[:-1])) if n > 1 else 1
    return {"n": n, "n_distinct": nd, "sortedness": sortedness,
            "run_ratio": runs / n}
