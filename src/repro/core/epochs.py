"""Epoch management (paper §5, §5.1).

Commits advance the epoch (the post-C-Store change: automatic advancement on
DML commit, fixing the READ COMMITTED visibility confusion). Snapshot reads
need no locks: a query targets ``current_epoch - 1`` by default and sees
exactly the rows with commit_epoch <= target < delete_epoch.

LGE (Last Good Epoch): per (projection, node) -- everything up to it has
been moved out of the WOS to disk; data past it is lost if the node dies.
AHM (Ancient History Mark): history before it may be purged by mergeout;
it does not advance while nodes are down (they will need the history to
replay).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass
class EpochManager:
    current_epoch: int = 1
    ahm: int = 0
    # (projection, node) -> last good epoch
    lge: Dict[Tuple[str, int], int] = dataclasses.field(default_factory=dict)

    def advance(self) -> int:
        """Commit boundary: every committed txn gets the pre-advance epoch."""
        e = self.current_epoch
        self.current_epoch += 1
        return e

    def latest_queryable(self) -> int:
        return self.current_epoch - 1

    def set_lge(self, projection: str, node: int, epoch: int):
        key = (projection, node)
        self.lge[key] = max(self.lge.get(key, 0), epoch)

    def get_lge(self, projection: str, node: int) -> int:
        return self.lge.get((projection, node), 0)

    def cluster_lge(self, projection: str, nodes) -> int:
        return min((self.get_lge(projection, n) for n in nodes), default=0)

    def advance_ahm(self, to_epoch: Optional[int] = None, *,
                    nodes_down: bool = False):
        """AHM policy: advance to the min cluster LGE (or explicit target),
        never past it, and never while nodes are down (paper §5.1)."""
        if nodes_down:
            return
        target = to_epoch if to_epoch is not None else \
            min(self.lge.values(), default=0)
        self.ahm = max(self.ahm, min(target, self.latest_queryable()))

    def visible(self, commit_epochs, delete_mask_epochs=None,
                as_of: Optional[int] = None):
        """Row visibility at a snapshot (vectorized over numpy arrays)."""
        e = as_of if as_of is not None else self.latest_queryable()
        vis = commit_epochs <= e
        if delete_mask_epochs is not None:
            vis &= ~delete_mask_epochs
        return vis
