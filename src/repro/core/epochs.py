"""Epoch management (paper §5, §5.1).

Commits advance the epoch (the post-C-Store change: automatic advancement on
DML commit, fixing the READ COMMITTED visibility confusion). Snapshot reads
need no locks: a query targets ``current_epoch - 1`` by default and sees
exactly the rows with commit_epoch <= target < delete_epoch.

LGE (Last Good Epoch): per (projection, node) -- everything up to it has
been moved out of the WOS to disk; data past it is lost if the node dies.
AHM (Ancient History Mark): history before it may be purged by mergeout;
it does not advance while nodes are down (they will need the history to
replay).

Cluster snapshot epochs: a query *pins* its snapshot epoch for its whole
lifetime (``snapshot()``), so trickle-load commits advancing
``current_epoch`` concurrently can never shift what the query sees, and
the AHM never advances past a pinned snapshot -- mergeout may not purge
history a running query still reads.
"""
from __future__ import annotations

import contextlib
import dataclasses
from collections import Counter
from typing import Dict, Iterator, Optional, Tuple


@dataclasses.dataclass
class EpochManager:
    current_epoch: int = 1
    ahm: int = 0
    # (projection, node) -> last good epoch
    lge: Dict[Tuple[str, int], int] = dataclasses.field(default_factory=dict)
    # epoch -> number of live query snapshots pinned at it
    pins: Counter = dataclasses.field(default_factory=Counter)

    def advance(self) -> int:
        """Commit boundary: every committed txn gets the pre-advance epoch."""
        e = self.current_epoch
        self.current_epoch += 1
        return e

    def latest_queryable(self) -> int:
        return self.current_epoch - 1

    # ------------------------------------------------- snapshot pinning --

    def pin(self, epoch: Optional[int] = None) -> int:
        """Pin a cluster snapshot epoch for a running query.  Commits may
        keep advancing ``current_epoch``; the pinned epoch stays a
        consistent read point and caps the AHM until released."""
        e = epoch if epoch is not None else self.latest_queryable()
        self.pins[e] += 1
        return e

    def unpin(self, epoch: int) -> None:
        self.pins[epoch] -= 1
        if self.pins[epoch] <= 0:
            del self.pins[epoch]

    def oldest_pinned(self) -> Optional[int]:
        return min(self.pins) if self.pins else None

    def n_pinned(self) -> int:
        """Total live snapshot pins across all epochs.  Zero means nothing
        is holding the AHM back -- the serving layer's pin-lifecycle
        invariant (every admitted/rejected/timed-out query released its
        pin) is asserted against this."""
        return int(sum(self.pins.values()))

    @contextlib.contextmanager
    def snapshot(self, epoch: Optional[int] = None) -> Iterator[int]:
        """``with epochs.snapshot() as e:`` -- a pinned consistent read."""
        e = self.pin(epoch)
        try:
            yield e
        finally:
            self.unpin(e)

    def set_lge(self, projection: str, node: int, epoch: int):
        key = (projection, node)
        self.lge[key] = max(self.lge.get(key, 0), epoch)

    def get_lge(self, projection: str, node: int) -> int:
        return self.lge.get((projection, node), 0)

    def cluster_lge(self, projection: str, nodes) -> int:
        return min((self.get_lge(projection, n) for n in nodes), default=0)

    def advance_ahm(self, to_epoch: Optional[int] = None, *,
                    nodes_down: bool = False):
        """AHM policy: advance to the min cluster LGE (or explicit target),
        never past it, never while nodes are down (paper §5.1), and never
        past the oldest pinned query snapshot -- purging history a live
        snapshot still reads would un-MVCC the read."""
        if nodes_down:
            return
        target = to_epoch if to_epoch is not None else \
            min(self.lge.values(), default=0)
        pinned = self.oldest_pinned()
        if pinned is not None:
            target = min(target, pinned - 1)
        self.ahm = max(self.ahm, min(target, self.latest_queryable()))

    def visible(self, commit_epochs, delete_mask_epochs=None,
                as_of: Optional[int] = None):
        """Row visibility at a snapshot (vectorized over numpy arrays)."""
        e = as_of if as_of is not None else self.latest_queryable()
        vis = commit_epochs <= e
        if delete_mask_epochs is not None:
            vis &= ~delete_mask_epochs
        return vis
