"""The Tuple Mover (paper §4): moveout (WOS -> ROS) and mergeout (strata).

Semantics implemented from the paper:
  * moveout drains the WOS into new ROS containers, one per
    (partition key, local segment) -- never intermixing WOS and ROS data
    (unlike C-Store), so a tuple is merged a strongly bounded number of
    times.
  * mergeout quantizes containers into exponential strata by size and only
    merges within a stratum; merging >= 2 same-stratum containers always
    produces a container at least one stratum up, so each tuple is
    (re)merged O(log(total/initial)) times. A max container size caps the
    strata count. Partition and local-segment boundaries are never crossed.
  * rows deleted at an epoch <= AHM are elided during any rewrite; delete
    vectors are re-mapped to the merged container's new positions.
  * operations are per-node autonomous (no cluster coordination): two nodes
    holding the same rows may have different container layouts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .block_cache import BlockCache, KIND_SEG
from .projection import ProjectionDef
from .storage import DeleteVector, ROSContainer, WOS
from .types import SQLType

MERGE_FANIN = 4            # max containers merged per operation
STRATUM_BASE = 1 << 14     # bytes of the smallest stratum
MAX_CONTAINER_BYTES = 1 << 31  # scaled-down analogue of the paper's 2TB


@dataclasses.dataclass
class ProjectionStore:
    """One node's physical state for one projection."""

    proj: ProjectionDef
    wos: WOS
    containers: List[ROSContainer] = dataclasses.field(default_factory=list)
    # container_id -> delete vectors (possibly several, as in the paper)
    delete_vectors: Dict[int, List[DeleteVector]] = dataclasses.field(
        default_factory=dict)
    # WOS delete epochs aligned to the WOS snapshot order (0 = live)
    wos_delete_epochs: List[np.ndarray] = dataclasses.field(
        default_factory=list)
    # device block cache shared across the node (set by VerticaDB); entries
    # of a container must be dropped when the container is retired
    cache: Optional[BlockCache] = None

    def invalidate_cached(self, container_ids) -> None:
        if self.cache is not None:
            self.cache.invalidate_containers(container_ids)

    def invalidate_seg_slabs(self, retired_ids=(), require_ids=()) -> int:
        """Precise invalidation of the segmented executor's partitioned
        scan slabs (``seg:<projection>`` / KIND_SEG).  Each slab key
        carries the exact container-id set it was built from, so we evict
        exactly the slabs that referenced a retired container
        (``retired_ids``: mergeout, truncate, drop_partition) or that
        predate a moveout (``require_ids``: every post-moveout lookup
        includes the new containers, so slabs without them are
        unreachable garbage holding HBM) -- never the projection's whole
        slab set, and never slabs of other (epoch, mesh, container-set)
        combinations that are still live."""
        if self.cache is None:
            return 0
        retired, required = set(retired_ids), set(require_ids)
        if not retired and not required:
            return 0

        def dead(key) -> bool:
            _, col, kind = key
            if kind != KIND_SEG:
                return False
            if not (isinstance(col, tuple) and len(col) >= 3
                    and isinstance(col[1], frozenset)):
                return True          # unknown key shape: evict, stay safe
            if retired & col[1]:     # container ids are globally unique
                return True
            if required:
                # post-moveout staleness is per-STORE: only entries that
                # sourced THIS projection's stores and predate the new
                # containers are unreachable; entries built purely from
                # other stores (e.g. buddy routing) stay live
                try:
                    items = col[2][0]
                except (TypeError, IndexError):
                    return True
                for _host, owner, ids in items:
                    if owner == self.proj.name \
                            and not (required & set(ids)):
                        return True
            return False

        # slabs are namespaced by the PRIMARY projection the planner
        # chose (buddies are never plan candidates), so a buddy store's
        # containers live under its primary's namespace
        primary = self.proj.buddy_of or self.proj.name
        return self.cache.invalidate_where(f"seg:{primary}", dead)

    def ros_rows(self) -> int:
        return sum(c.n_rows for c in self.containers)

    def epoch_ceiling(self, *, include_wos: bool = True) -> int:
        """Newest epoch affecting this store's visible state: container
        commit epochs, delete-vector epochs and (optionally) WOS rows.
        Visibility at any as-of >= ceiling equals visibility at the
        ceiling, so epoch-keyed caches clamp to it -- a trickle commit
        that only touched OTHER stores advances the cluster epoch without
        invalidating this store's cached scans."""
        hi = 0
        for c in self.containers:
            hi = max(hi, c.max_epoch())
        for dvs in self.delete_vectors.values():
            for dv in dvs:
                if len(dv.delete_epochs):
                    hi = max(hi, int(dv.delete_epochs.max()))
        if include_wos:
            hi = max(hi, self.wos.max_epoch())
            for de in self.wos_delete_epochs:
                if len(de):
                    hi = max(hi, int(de.max()))
        return hi

    def deleted_mask(self, c: ROSContainer,
                     as_of: Optional[int] = None) -> np.ndarray:
        m = np.zeros(c.n_rows, bool)
        for dv in self.delete_vectors.get(c.id, []):
            m |= dv.mask(c.n_rows, as_of)
        return m

    def delete_epochs_of(self, c: ROSContainer) -> np.ndarray:
        """Per-position delete epoch (0 = live)."""
        out = np.zeros(c.n_rows, np.int64)
        for dv in self.delete_vectors.get(c.id, []):
            out[dv.positions] = dv.delete_epochs
        return out


def moveout(store: ProjectionStore, *, sql_types: Dict[str, SQLType],
            ahm: int, partition_of: Optional[Dict[str, np.ndarray]] = None,
            partition_expr=None,
            block_rows: int = 4096) -> List[ROSContainer]:
    """Drain the WOS into ROS containers. Returns the new containers.

    Rows already deleted at epochs <= AHM are elided; later-deleted rows are
    written with a delete vector so historical queries still see them."""
    data, epochs, segs = store.wos.snapshot()
    if len(epochs) == 0:
        return []
    del_eps = (np.concatenate(store.wos_delete_epochs)
               if store.wos_delete_epochs else np.zeros(len(epochs),
                                                        np.int64))
    keep = ~((del_eps > 0) & (del_eps <= ahm))
    data = {c: v[keep] for c, v in data.items()}
    epochs, segs, del_eps = epochs[keep], segs[keep], del_eps[keep]

    pkeys = None
    if partition_expr is not None:
        from .partitioning import partition_keys
        pcol, expr = partition_expr
        pkeys = partition_keys(expr, data[pcol])

    new = []
    for seg in np.unique(segs):
        seg_sel = segs == seg
        pvals = [None] if pkeys is None else list(np.unique(pkeys[seg_sel]))
        for pv in pvals:
            sel = seg_sel if pv is None else seg_sel & (pkeys == pv)
            if not sel.any():
                continue
            sub = {c: v[sel] for c, v in data.items()}
            sub_eps, sub_del = epochs[sel], del_eps[sel]
            # sort now so we can map delete epochs to sorted positions
            if store.proj.sort_order:
                order = np.lexsort(tuple(sub[c] for c in
                                         reversed(store.proj.sort_order)))
                sub = {c: v[order] for c, v in sub.items()}
                sub_eps, sub_del = sub_eps[order], sub_del[order]
            c = ROSContainer.build(
                store.proj, sub, sub_eps, sql_types=sql_types,
                partition_key=None if pv is None else int(pv),
                local_segment=int(seg), presorted=True,
                block_rows=block_rows)
            store.containers.append(c)
            new.append(c)
            dpos = np.flatnonzero(sub_del > 0)
            if dpos.size:
                store.delete_vectors.setdefault(c.id, []).append(
                    DeleteVector.build(c.id, dpos, sub_del[dpos]).to_ros())
    store.wos.clear()
    store.wos_delete_epochs = []
    if new:
        # post-moveout slab lookups always include the new containers:
        # slabs built before this drain are unreachable -- evict precisely
        store.invalidate_seg_slabs(require_ids=[c.id for c in new])
    return new


def stratum_of(c: ROSContainer) -> int:
    b = max(c.raw_bytes(), 1)
    return max(0, int(math.log2(b / STRATUM_BASE))) if b > STRATUM_BASE \
        else 0


def plan_mergeout(store: ProjectionStore) -> Optional[List[ROSContainer]]:
    """Pick >= 2 same-stratum containers within one
    (partition, local_segment) group; smallest stratum first."""
    groups: Dict[Tuple, Dict[int, List[ROSContainer]]] = {}
    for c in store.containers:
        key = (c.partition_key, c.local_segment)
        groups.setdefault(key, {}).setdefault(stratum_of(c), []).append(c)
    best = None
    for strata in groups.values():
        for s in sorted(strata):
            cand = strata[s]
            if len(cand) < 2:
                continue
            cand = sorted(cand, key=lambda c: c.raw_bytes())[:MERGE_FANIN]
            if sum(c.raw_bytes() for c in cand) > MAX_CONTAINER_BYTES:
                continue
            if best is None or s < best[0]:
                best = (s, cand)
    return best[1] if best else None


def mergeout(store: ProjectionStore, *, sql_types: Dict[str, SQLType],
             ahm: int, block_rows: int = 4096) -> Optional[ROSContainer]:
    """One mergeout operation: merge one planned group. Each input tuple is
    read once and written (at most) once; AHM-deleted rows are elided."""
    cand = plan_mergeout(store)
    if not cand:
        return None
    datas, epochs, del_eps = [], [], []
    for c in cand:
        d = c.decode_all()
        de = store.delete_epochs_of(c)
        keep = ~((de > 0) & (de <= ahm))          # AHM elision
        datas.append({k: v[keep] for k, v in d.items()})
        epochs.append(c.epochs[keep])
        del_eps.append(de[keep])
    data = {c: np.concatenate([d[c] for d in datas])
            for c in cand[0].columns}
    eps = np.concatenate(epochs)
    dels = np.concatenate(del_eps)
    if store.proj.sort_order:
        order = np.lexsort(tuple(data[c] for c in
                                 reversed(store.proj.sort_order)))
        data = {c: v[order] for c, v in data.items()}
        eps, dels = eps[order], dels[order]
    merged = ROSContainer.build(
        store.proj, data, eps, sql_types=sql_types,
        partition_key=cand[0].partition_key,
        local_segment=cand[0].local_segment, presorted=True,
        block_rows=block_rows)
    ids = {c.id for c in cand}
    store.containers = [c for c in store.containers if c.id not in ids]
    store.invalidate_cached(ids)   # merged-away containers are retired
    store.invalidate_seg_slabs(retired_ids=ids)
    for cid in ids:
        store.delete_vectors.pop(cid, None)
    store.containers.append(merged)
    dpos = np.flatnonzero(dels > 0)
    if dpos.size:
        store.delete_vectors.setdefault(merged.id, []).append(
            DeleteVector.build(merged.id, dpos, dels[dpos]).to_ros())
    return merged


def run_tuple_mover(store: ProjectionStore, *, sql_types, ahm,
                    partition_expr=None, wos_row_limit: int = 8192,
                    block_rows: int = 4096,
                    do_mergeout: bool = True) -> Dict[str, int]:
    """Policy loop: moveout when the WOS is saturated, then (unless
    ``do_mergeout=False`` -- moveout and mergeout are independent
    services, paper §4) mergeout until no stratum has >= 2 containers
    (or caps block further merging)."""
    stats = {"moveouts": 0, "mergeouts": 0}
    if store.wos.n_rows >= wos_row_limit:
        if moveout(store, sql_types=sql_types, ahm=ahm,
                   partition_expr=partition_expr, block_rows=block_rows):
            stats["moveouts"] += 1
    while do_mergeout and mergeout(store, sql_types=sql_types, ahm=ahm,
                                   block_rows=block_rows) is not None:
        stats["mergeouts"] += 1
    return stats
