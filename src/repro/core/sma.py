"""Position index / Small Materialized Aggregates (paper §3.7, [22]).

Vertica stores, per column file, a position index ~1/1000 the size of the
data holding per-disk-block metadata (start position, min, max).  Here each
ROS container column carries a ``(n_blocks,)`` min/max/count triple; the
engine uses it for:

* container-level pruning at plan time (paper §3.5: partitioning makes
  min/max pruning more effective), and
* block-level pruning inside a scan, which on TPU becomes *masking whole
  VMEM tiles* -- pruned blocks are never touched, saving HBM->VMEM traffic.

Positions remain implicit (ordinal within container), exactly as in the
paper: fast tuple reconstruction = aligned indexing across column arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .types import BLOCK_ROWS, num_blocks


@dataclasses.dataclass(frozen=True)
class ColumnSMA:
    """Per-block min/max/count for one column of one ROS container."""

    mins: np.ndarray    # (n_blocks,)
    maxs: np.ndarray    # (n_blocks,)
    counts: np.ndarray  # (n_blocks,) valid rows per block (tail may be short)

    @staticmethod
    def build(values: np.ndarray, block_rows: int = BLOCK_ROWS) -> "ColumnSMA":
        n = values.size
        nb = num_blocks(n, block_rows)
        mins = np.empty(nb, dtype=values.dtype)
        maxs = np.empty(nb, dtype=values.dtype)
        counts = np.empty(nb, dtype=np.int32)
        for i in range(nb):
            blk = values[i * block_rows: min((i + 1) * block_rows, n)]
            counts[i] = blk.size
            if blk.size:
                mins[i] = blk.min()
                maxs[i] = blk.max()
            else:  # empty container edge case
                mins[i] = 0
                maxs[i] = 0
        return ColumnSMA(mins, maxs, counts)

    @property
    def n_rows(self) -> int:
        return int(self.counts.sum())

    def container_min(self):
        return self.mins.min()

    def container_max(self):
        return self.maxs.max()

    def prune_blocks(self, lo=None, hi=None) -> np.ndarray:
        """Block mask: True = block may contain rows with lo <= v <= hi.

        This is the §3.5 pruning predicate applied per block.  ``None``
        bounds are open.
        """
        keep = np.ones(self.mins.shape[0], dtype=bool)
        if lo is not None:
            keep &= self.maxs >= lo
        if hi is not None:
            keep &= self.mins <= hi
        return keep

    def prunes_container(self, lo=None, hi=None) -> bool:
        """True when the whole container provably fails the predicate."""
        return not bool(self.prune_blocks(lo, hi).any())


def interval_of_predicate(op: str, literal) -> Tuple[Optional[float],
                                                     Optional[float]]:
    """Map a comparison predicate to the (lo, hi) interval it accepts."""
    if op == "==":
        return literal, literal
    if op == "<":
        return None, literal
    if op == "<=":
        return None, literal
    if op == ">":
        return literal, None
    if op == ">=":
        return literal, None
    return None, None  # !=, etc: cannot prune
