"""Mamba2-130M [arXiv:2405.21060; unverified] — pure SSM (SSD).

24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
d_inner = expand*d_model = 1536; 24 SSD heads of dim 64.
State-space duality: chunked block-matmul form for train/prefill,
O(1)-per-token recurrent form for decode. Sub-quadratic => long_500k runs.
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,                       # attention-free
    n_kv_heads=0,
    d_ff=0,                          # no FFN; SSD mixer only (paper spec)
    vocab_size=50280,                # padded to 50432 on device
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2405.21060; unverified",
)
