"""Registry of the assigned architectures (+ the paper's own workload cfg).

Each module defines ``CONFIG: ArchConfig`` with the exact published numbers
from the assignment table. ``get(name)`` and ``all_archs()`` are the public
API; the launcher's ``--arch`` flag resolves through here.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (ALL_SHAPES, SHAPES_BY_NAME, ArchConfig, MoEConfig,
                   RunConfig, ShapeConfig, SSMConfig)

_ARCH_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "qwen3-4b": "qwen3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-3-8b": "granite3_8b",
    "phi3-mini-3.8b": "phi3_mini",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get(name[: -len("-reduced")]).reduced()
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f".{_ARCH_MODULES[name]}", __package__)
    return mod.CONFIG


def all_archs() -> List[ArchConfig]:
    return [get(n) for n in ARCH_NAMES]


__all__ = ["ALL_SHAPES", "SHAPES_BY_NAME", "ARCH_NAMES", "ArchConfig",
           "MoEConfig", "RunConfig", "ShapeConfig", "SSMConfig", "get",
           "all_archs"]
