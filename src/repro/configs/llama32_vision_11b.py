"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
Cross-attention image layers every 5th layer (8 of 40). The vision frontend
is a STUB per the assignment: input_specs() provides precomputed patch
embeddings (B, 6404, d_model) = 4 tiles x 1601 patches, already projected.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    n_frontend_tokens=6404,          # 4 tiles x 1601 patches
    sharding_mode="tp",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
