"""Architecture + run configuration system.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``
with the exact published numbers; ``repro.configs.get(name)`` resolves them.
``reduced()`` derives the CPU-smoke-test variant of any config (same family,
small dims), and ``ShapeConfig`` describes the four assigned input shapes.

Awkward head counts (starcoder2's 36, hymba's 25 on a 16-way model axis)
are handled by the TP-even HeadLayout (models/attention.py, DESIGN.md §10),
so every arch shares the same sharding rules; per-cell rule overrides live
in distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The four assigned LM shapes (identical across archs; applicability differs).
TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "scatter": capacity-buffer dispatch under GSPMD (baseline)
    # "a2a":     shard_map all_to_all resegmentation (paper-style Send/Recv;
    #            the optimized path, see EXPERIMENTS.md §Perf)
    dispatch: str = "scatter"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256  # SSD chunk length (multiple of 128 for MXU alignment)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention details
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    window: Optional[int] = None          # sliding-window size (None = full)
    global_layers: Tuple[int, ...] = ()   # layers forced to full attention
    mlp: str = "swiglu"                   # swiglu | gelu
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec / vlm frontends (stubs provide precomputed embeddings)
    n_encoder_layers: int = 0
    cross_attn_every: int = 0             # vlm: 1 cross-attn per N layers
    n_frontend_tokens: int = 0            # audio frames / image patches
    # distribution policy marker (all archs resolve through the same
    # rules + HeadLayout; kept for per-arch overrides)
    sharding_mode: str = "tp"
    # whether attention is sub-quadratic (SSM/hybrid) => long_500k runs
    subquadratic: bool = False
    # citation tag from the assignment table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def supports_shape(self, shape: ShapeConfig) -> Tuple[bool, str]:
        """(runs?, reason) for an assigned cell. long_500k needs
        sub-quadratic attention per the assignment."""
        if shape.name == "long_500k" and not self.subquadratic:
            return False, "full attention is O(S^2); skipped per assignment"
        return True, ""

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests (not dry-run)."""
        def shrink_layers(n):
            return max(2, min(n, 2))
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2 if not self.global_layers else 3,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab_size=256,
            head_dim=16 if self.n_heads else None,
        )
        if self.global_layers:
            kw["global_layers"] = (0,)
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
        if self.n_frontend_tokens:
            kw["n_frontend_tokens"] = 16
        if self.window:
            kw["window"] = 16
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving hyperparameters independent of architecture."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    param_dtype: str = "float32"     # master weights
    compute_dtype: str = "bfloat16"
    remat_policy: str = "minimal"    # minimal | dots | none
    zero1: bool = True               # shard optimizer moments over data axis
    microbatches: int = 1            # gradient accumulation
    # gradient compression (paper tie-in: the §3.4 encodings applied to the
    # DP all-reduce payload; see train/fault_tolerance.py)
    grad_compression: str = "none"   # none | int8
