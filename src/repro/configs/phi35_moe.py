"""Phi-3.5-MoE-42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) per-expert d_ff=6400 vocab=32064,
MoE 16 experts top-2. 6.6B active / 42B total.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,                       # per-expert FFN width
    vocab_size=32064,
    head_dim=128,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
    sharding_mode="tp",
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)
