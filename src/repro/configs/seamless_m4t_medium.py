"""SeamlessM4T-medium [arXiv:2308.11596; hf] — enc-dec, multimodal backbone.

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
12 encoder + 12 decoder layers; the audio frontend is a STUB per the
assignment: input_specs() provides precomputed frame embeddings
(B, seq, d_model) to the encoder.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                     # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,               # padded to 256256 on device
    head_dim=64,
    mlp="gelu",
    rope_theta=10_000.0,
    sharding_mode="tp",
    source="arXiv:2308.11596; hf",
)
