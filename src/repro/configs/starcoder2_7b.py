"""StarCoder2-7B [arXiv:2402.19173; hf] — dense, GQA, RoPE.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

36 heads do not divide the 16-way model axis; the HeadLayout machinery
(models/attention.py) pads q/o to (16 kv_eff x 3 group) slots with
hard-masked dead heads, keeping TP sharding even with exact math; the
<=11% padding waste is visible in the roofline useful-ratio (DESIGN.md §10).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    mlp="gelu",
    rope_theta=1_000_000.0,
    source="arXiv:2402.19173; hf",
)
