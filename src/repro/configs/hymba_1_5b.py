"""Hymba-1.5B [arXiv:2411.13676; hf] — hybrid: parallel attn + mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention (1024) in all but 3 global layers {first, middle,
last}; SSM heads run in parallel with attention heads in every layer and the
two branches are mean-fused (per the paper). Sub-quadratic => long_500k runs.

25 heads do not divide the 16-way model axis; HeadLayout pads to
(16 kv_eff x 2 group) slots with hard-masked dead heads (DESIGN.md §10).
"""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,                # padded to 32256 on device
    head_dim=64,
    window=1024,
    global_layers=(0, 15, 31),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    subquadratic=True,
    source="arXiv:2411.13676; hf",
)
