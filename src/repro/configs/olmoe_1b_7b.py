"""OLMoE-1B-7B [arXiv:2409.02060; hf] — MoE, 64 experts top-8.

16L d_model=2048 16H (GQA kv=16) per-expert d_ff=1024 vocab=50304.
Every layer is MoE (no shared dense FFN); 1B active / 7B total params.
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,                       # per-expert FFN width
    vocab_size=50304,
    head_dim=128,
    qk_norm=True,                    # OLMoE uses QK-norm
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024),
    sharding_mode="tp",              # 16 heads / 16-way model axis
    source="arXiv:2409.02060; hf",
)
