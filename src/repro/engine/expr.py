"""Expression / predicate algebra -> vectorized evaluation (paper: ExprEval).

The paper JIT-compiles expression evaluation to avoid type-dispatch
branching; here XLA *is* that JIT -- expressions build jnp computations and
whole plans compile to one program (engine/pipeline.py).

Predicates additionally expose ``bounds()``: the (lo, hi) interval per
column they imply, which Scan uses for SMA container/block pruning (§3.5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np


class Expr:
    def __call__(self, cols: Dict[str, Any]):
        raise NotImplementedError

    # -- operator sugar ---------------------------------------------------
    def _bin(self, other, op):
        return BinOp(op, self, _wrap(other))

    def __add__(self, o): return self._bin(o, "+")
    def __sub__(self, o): return self._bin(o, "-")
    def __mul__(self, o): return self._bin(o, "*")
    def __truediv__(self, o): return self._bin(o, "/")
    def __lt__(self, o): return self._bin(o, "<")
    def __le__(self, o): return self._bin(o, "<=")
    def __gt__(self, o): return self._bin(o, ">")
    def __ge__(self, o): return self._bin(o, ">=")
    def __eq__(self, o): return self._bin(o, "==")   # noqa: PYI032
    def __ne__(self, o): return self._bin(o, "!=")   # noqa: PYI032
    def __and__(self, o): return self._bin(o, "&")
    def __or__(self, o): return self._bin(o, "|")
    __hash__ = None  # type: ignore[assignment]

    def bounds(self) -> Dict[str, Tuple[Optional[float], Optional[float]]]:
        """col -> (lo, hi) interval implied by this predicate (for SMA
        pruning); empty when nothing can be inferred."""
        return {}

    def columns(self) -> set:
        return set()

    def signature(self) -> str:
        """Stable structural key (shape + literals) for the executor's
        plan cache: two predicates with equal signatures build identical
        jnp programs."""
        raise NotImplementedError


def _wrap(v) -> "Expr":
    return v if isinstance(v, Expr) else Lit(v)


@dataclasses.dataclass(eq=False)
class Col(Expr):
    name: str

    def __call__(self, cols):
        return cols[self.name]

    def columns(self):
        return {self.name}

    def signature(self):
        return f"c:{self.name}"


@dataclasses.dataclass(eq=False)
class Lit(Expr):
    value: Any

    def __call__(self, cols):
        return self.value

    def signature(self):
        return f"l:{self.value!r}"


_OPS: Dict[str, Callable] = {
    "+": lambda a, b: a + b, "-": lambda a, b: a - b,
    "*": lambda a, b: a * b, "/": lambda a, b: a / b,
    "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
    "==": lambda a, b: a == b, "!=": lambda a, b: a != b,
    "&": lambda a, b: a & b, "|": lambda a, b: a | b,
}


@dataclasses.dataclass(eq=False)
class BinOp(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def __call__(self, cols):
        return _OPS[self.op](self.lhs(cols), self.rhs(cols))

    def columns(self):
        return self.lhs.columns() | self.rhs.columns()

    def signature(self):
        return f"({self.lhs.signature()}{self.op}{self.rhs.signature()})"

    def bounds(self):
        # comparison of a column against a literal
        if isinstance(self.lhs, Col) and isinstance(self.rhs, Lit):
            v = self.rhs.value
            iv = {"==": (v, v), "<": (None, v), "<=": (None, v),
                  ">": (v, None), ">=": (v, None)}.get(self.op)
            return {self.lhs.name: iv} if iv else {}
        if isinstance(self.rhs, Col) and isinstance(self.lhs, Lit):
            v = self.lhs.value
            iv = {"==": (v, v), ">": (None, v), ">=": (None, v),
                  "<": (v, None), "<=": (v, None)}.get(self.op)
            return {self.rhs.name: iv} if iv else {}
        if self.op == "&":
            out = dict(self.lhs.bounds())
            for c, (lo, hi) in self.rhs.bounds().items():
                plo, phi = out.get(c, (None, None))
                out[c] = (_tighter(plo, lo, max), _tighter(phi, hi, min))
            return out
        return {}


def _tighter(a, b, pick):
    if a is None:
        return b
    if b is None:
        return a
    return pick(a, b)


def exact_int_interval(e: Expr):
    """If ``e`` is exactly a conjunction of integer comparisons on ONE
    column, return (col, lo, hi) with INCLUSIVE bounds (None = open side);
    else None. Unlike bounds() -- which is conservative and fine for SMA
    pruning -- this is exact, as required by the RLE-scalar COUNT path."""
    if not isinstance(e, BinOp):
        return None
    if e.op == "&":
        a = exact_int_interval(e.lhs)
        b = exact_int_interval(e.rhs)
        if a is None or b is None or a[0] != b[0]:
            return None
        col_ = a[0]
        lo = a[1] if b[1] is None else (b[1] if a[1] is None
                                        else max(a[1], b[1]))
        hi = a[2] if b[2] is None else (b[2] if a[2] is None
                                        else min(a[2], b[2]))
        return (col_, lo, hi)
    lhs, rhs, op = e.lhs, e.rhs, e.op
    if isinstance(rhs, Col) and isinstance(lhs, Lit):
        flip = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "=="}
        if op not in flip:
            return None
        lhs, rhs, op = rhs, lhs, flip[op]
    if not (isinstance(lhs, Col) and isinstance(rhs, Lit)):
        return None
    v = rhs.value
    if not isinstance(v, (int, np.integer)):
        return None
    v = int(v)
    iv = {"==": (v, v), "<": (None, v - 1), "<=": (None, v),
          ">": (v + 1, None), ">=": (v, None)}.get(op)
    return (lhs.name, iv[0], iv[1]) if iv else None


def interval_decompose(e: Expr
                       ) -> Optional[Dict[str, Tuple[Optional[int],
                                                     Optional[int]]]]:
    """Exact multi-column decomposition: if ``e`` is a conjunction of
    integer comparisons, each on a single column, return
    ``{col: (lo, hi)}`` with INCLUSIVE bounds (None = open side); else
    None.  The compressed-domain executor rewrites these intervals into
    dictionary code ranges, so -- like ``exact_int_interval`` -- this must
    be exact, not conservative; any untranslatable part rejects the whole
    predicate."""
    if not isinstance(e, BinOp):
        return None
    if e.op == "&":
        a = interval_decompose(e.lhs)
        b = interval_decompose(e.rhs)
        if a is None or b is None:
            return None
        out = dict(a)
        for c, (lo, hi) in b.items():
            plo, phi = out.get(c, (None, None))
            out[c] = (_tighter(plo, lo, max), _tighter(phi, hi, min))
        return out
    one = exact_int_interval(e)
    if one is None:
        return None
    return {one[0]: (one[1], one[2])}


def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Lit:
    return Lit(v)
