"""Concurrent query serving: admission control, priority queues, shared
scans, and pipelined async dispatch (paper §7 "workload management").

Everything below this module executes ONE query at a time; Vertica
presents a classical relational interface at web scale by putting a
workload-management layer in front of that engine.  This is that layer:
a bounded, prioritized, memory-budgeted front door that turns the
single-query executor into a multi-tenant service.

Mechanisms (DESIGN.md §16, §18):

* **Admission control** -- a bounded session pool and two priority
  queues (``interactive`` served ahead of ``batch``, with an
  anti-starvation boost so a saturating interactive stream cannot starve
  batch forever).  Queue-depth caps and queued-past-timeout expiry
  reject with the typed ``QueryRejectedError`` -- the same
  refusal-over-wrong-answer contract the failover path uses.  Every
  admission decision fires the ``serving.admit`` injection point, so
  chaos schedules cover the front door too.
* **Cost-based admission** -- a ticket is priced from its projection's
  SMA block statistics and its predicate's bounds (the same pruning
  math the scan itself runs), NOT from raw row counts: a heavily-pruned
  scan over a huge table is cheap, an unpredicated scan over a
  fragmented store is expensive (tail-block padding included).  The
  SMA price feeds the memory-budget reservation, the optional
  ``max_cost_bytes`` hard ceiling, and the ``boost_cost_bytes``
  priority boost that lets a provably-cheap batch query jump into the
  interactive queue.  This is the "exploit the column store's own
  metadata" argument of *Teaching an Old Elephant New Tricks*
  (arxiv 0909.1758) applied to workload management.
* **Shared scans** -- queued queries over the same projection whose
  pinned snapshots clamp to the same effective epoch coalesce into ONE
  cache-resident scan (no SMA pruning, no predicate pushdown: the scan
  is shared), with each member applying its own predicate mask +
  aggregation as a plan-cached jitted program
  (executor.execute_shared_fused_deferred).  The plan cache is thereby
  exploited *across* concurrent queries, not only across repeats of one
  query; a coalesced group charges the memory budget once.  A
  differential test (tests/test_serving.py) proves coalesced results
  byte-identical to independent execution -- see ``_shared_once_async``
  for why that holds.
* **Pipelined dispatch / drain** -- jax dispatch is asynchronous: a
  jitted program call returns device futures immediately while the
  backend computes.  Dispatch therefore parks a unit's device results
  in an in-flight queue and returns to admission, so the NEXT unit's
  planning/scan dispatch overlaps the previous unit's device compute.
  A separate drain stage harvests completed flights in arrival order
  and performs ONE batched device-to-host transfer per unit
  (``jax.device_get`` over the whole unit's pytree) -- there are no
  per-column ``np.asarray`` syncs on the serving path.  Work that the
  fused subset cannot express (WOS side-scans, segmented meshes,
  RLE-direct shapes) falls back to synchronous execution inside
  dispatch, preserving exact single-query semantics.
* **Bulkheads** -- ``max_in_flight`` bounds how many tickets of each
  priority class may be in flight (dispatched, not yet drained) at
  once, so a batch flood cannot exhaust the device memory and future
  slots that interactive sessions rely on.  Admission simply skips a
  class at its cap; its queue drains as flights are harvested.
* **Rate limiting** -- each session may carry a token bucket
  (``rate_limit=(rate_per_s, burst)``); an over-rate submit is refused
  with a typed ``QueryRejectedError`` whose reason starts with
  ``rate_limited`` BEFORE any snapshot epoch is pinned, so abusive
  clients cannot stall the AHM by being refused.
* **Memory budget** -- each dispatch unit reserves its SMA-priced
  working set against the block-cache budget (``BlockCache.take``);
  under the pipelined core a reservation is held from dispatch until
  drain, so overlapping units' reservations accumulate and admission
  stops opening new work when the pool is exhausted, bounding the
  concurrent working set to what HBM holds.

Concurrency model: cooperative and deterministic, like the rest of the
simulated cluster.  ``submit()`` pins the query's snapshot epoch and
enqueues; ``step()`` runs one scheduler round (expire timed-out tickets
-> harvest ready flights in arrival order -> admit up to
``max_concurrent`` units under budget + bulkheads -> dispatch them,
parking async results); ``drain()`` steps until idle.  The service
takes an injectable ``clock`` -- ``VirtualClock`` replaces wall time in
tests so overlap, rate-limit refill and bulkhead schedules replay
byte-identically with no sleeps (FaultInjector.Hang sleeps on this
clock at the ``serving.dispatch``/``serving.drain`` points).

The load-bearing invariant is the epoch-pin lifecycle: a pin taken at
submit is released on EXACTLY ONE of completion / timeout / fault
rejection (queue-full and rate-limit rejections happen before pinning),
so no rejected or abandoned query can stall the AHM.
tests/test_serving.py floods the queue and asserts
``EpochManager.n_pinned() == 0`` afterward; the drain stage's failure
matrix (crash/transient between dispatch and harvest) is in
DESIGN.md §18.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_cache import KIND_UNION
from ..core.database import AvailabilityError, QueryRejectedError, VerticaDB
from ..core.encodings import device_bytes
from ..core.faults import (NodeCrashError, TransientFaultError,
                           fire_with_retries)
from .logical import as_ir
from . import executor as fused_exec
from . import operators as ops
from .pipeline import (ExecStats, _empty_result, _finalize, _run_groupby,
                       execute, rle_direct_eligible, wos_scan_results)

PRIORITIES = ("interactive", "batch")

# Module-wide device->host transfer odometer: bumped once per batched
# ``jax.device_get`` the drain stage performs.  The transfer-counting
# test fixture (tests/test_serving_async.py) snapshots it around a
# serving run to assert the collect path does ONE transfer per unit --
# no stray per-column syncs.
_DEVICE_TRANSFERS = 0


def device_transfer_count() -> int:
    """Total batched device->host transfers the serving drain stage has
    performed in this process (monotonic; diff across a run)."""
    return _DEVICE_TRANSFERS


# ---------------------------------------------------------------------------
# clocks: wall by default, virtual for deterministic schedules
# ---------------------------------------------------------------------------

class WallClock:
    """Real time (the default service clock)."""

    def now(self) -> float:
        return time.time()

    def sleep(self, seconds: float) -> None:
        time.sleep(seconds)


_WALL = WallClock()


class VirtualClock:
    """Deterministic scheduler clock: ``now()`` only moves when
    something calls ``sleep``/``advance``, so timeout expiry, token
    refill and injected Hangs replay identically run-over-run with no
    wall-clock sleeps.  Pass to ``db.serve(clock=VirtualClock())``;
    FaultInjector.Hang sleeps on this clock when the firing context
    carries one (``serving.dispatch``/``serving.drain``/
    ``serving.rate_limit`` pass it)."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def sleep(self, seconds: float) -> None:
        self._now += float(seconds)

    # alias: tests advancing time explicitly read better with this name
    advance = sleep


class TokenBucket:
    """Per-session rate limiter: ``burst`` tokens capacity refilled at
    ``rate`` tokens/second on the given clock.  ``try_consume`` is the
    whole protocol -- deterministic given the clock, which is what the
    property test exercises under a VirtualClock."""

    def __init__(self, rate: float, burst: float, *, clock=None):
        assert rate > 0 and burst > 0
        self.rate = float(rate)
        self.burst = float(burst)
        self.clock = clock if clock is not None else _WALL
        self.tokens = float(burst)
        self._last = self.clock.now()

    def try_consume(self, n: float = 1.0) -> bool:
        now = self.clock.now()
        if now > self._last:
            self.tokens = min(self.burst,
                              self.tokens + (now - self._last) * self.rate)
        self._last = now
        if self.tokens + 1e-9 >= n:
            self.tokens -= n
            return True
        return False


@dataclasses.dataclass
class ServingStats:
    """Per-query serving telemetry, one per Ticket (the serving-layer
    analog of pipeline.ExecStats, which rides along in ``exec_stats``)."""
    priority: str = "interactive"
    admitted: bool = False
    rejected_reason: str = ""       # "queue_full"/"timeout"/"admission"/
    #                                 "unavailable"/"cost" ("" = not rejected)
    queue_wait_s: float = 0.0       # submit -> dispatch
    exec_s: float = 0.0             # dispatch -> result
    total_s: float = 0.0            # submit -> done (closed-loop latency)
    shared_scan: str = ""           # "leader"/"member" when coalesced
    share_group: int = 1            # tickets in this dispatch unit
    dispatch_seq: int = -1          # global dispatch order (priority tests)
    snapshot_epoch: int = 0         # the pinned epoch this query read
    reserved_bytes: int = 0         # working set charged at admission
    cost_bytes: int = 0             # SMA-priced admission cost
    cost_boosted: bool = False      # cheap batch query ran interactive
    oversized: bool = False         # working set alone exceeds the budget
    async_dispatch: bool = False    # parked in flight (vs sync fallback)
    failovers: int = 0              # mid-dispatch node crashes absorbed
    exec_stats: Optional[ExecStats] = None


@dataclasses.dataclass
class ServiceStats:
    """Service-wide counters (benchmarks/serving.py reads these)."""
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    rejected_queue_full: int = 0
    rejected_timeout: int = 0
    rejected_admission: int = 0
    rejected_unavailable: int = 0
    rejected_rate_limited: int = 0
    rejected_cost: int = 0          # SMA price above max_cost_bytes
    dispatches: int = 0             # dispatch units executed
    shared_scans: int = 0           # units that coalesced >= 2 queries
    shared_hits: int = 0            # completed queries served coalesced
    coalesced_max: int = 0
    batch_boosts: int = 0           # anti-starvation picks of batch
    cost_boosts: int = 0            # cheap batch queries run interactive
    async_units: int = 0            # units parked in flight at dispatch
    deduped: int = 0                # identical in-group queries collapsed
    drains: int = 0                 # flights harvested by the drain stage
    device_transfers: int = 0       # batched device->host gets performed
    peak_in_flight: Dict[str, int] = dataclasses.field(default_factory=dict)

    def shared_hit_rate(self) -> float:
        return self.shared_hits / self.completed if self.completed else 0.0


class Ticket:
    """A submitted query's handle: state machine
    ``queued -> running -> done|rejected``, its pinned snapshot epoch,
    and its ServingStats.  ``result()`` cooperatively drives the service
    until this ticket settles."""

    def __init__(self, service: "QueryService", q, priority: str,
                 timeout_s: Optional[float], seq: int):
        self.service = service
        self.q = q
        self.priority = priority
        self.timeout_s = timeout_s
        self.id = seq
        self.submitted_at = service.clock.now()
        self.pinned: Optional[int] = None
        self.state = "queued"
        self.stats = ServingStats(priority=priority)
        self._result: Optional[Dict[str, np.ndarray]] = None
        self._error: Optional[Exception] = None
        # dispatch-time physical choices (set at admission)
        self.plan = None
        self.scan_need: Tuple[str, ...] = ()

    @property
    def done(self) -> bool:
        return self.state in ("done", "rejected")

    @property
    def error(self) -> Optional[Exception]:
        return self._error

    def result(self) -> Dict[str, np.ndarray]:
        """Block (cooperatively) until this query settles; returns its
        rows or raises its typed rejection error."""
        guard = 0
        while not self.done:
            self.service.step(waiting_on=self)
            guard += 1
            if guard > 1_000_000:   # pragma: no cover - defensive
                raise RuntimeError("serving made no progress")
        if self._error is not None:
            raise self._error
        return self._result


class Session:
    """One client's bounded handle on the service (the session pool is
    the paper's connection limit): carries a default priority/timeout
    and optionally a token-bucket rate limit, counts against
    ``max_sessions`` until closed."""

    def __init__(self, service: "QueryService", priority: str,
                 timeout_s: Optional[float] = None,
                 rate_limit: Optional[Tuple[float, float]] = None):
        self.service = service
        self.priority = priority
        self.timeout_s = timeout_s
        self.bucket = (TokenBucket(*rate_limit, clock=service.clock)
                       if rate_limit else None)
        self.closed = False

    def submit(self, q, *, priority: Optional[str] = None,
               timeout_s: Optional[float] = None) -> Ticket:
        if self.closed:
            raise QueryRejectedError("session is closed")
        svc = self.service
        if self.bucket is not None and not self.bucket.try_consume():
            # over rate: refuse BEFORE anything pins an epoch -- a
            # throttled client must never stall the AHM
            svc.stats.submitted += 1
            svc.stats.rejected += 1
            svc.stats.rejected_rate_limited += 1
            try:
                fire_with_retries(svc.db, "serving.rate_limit",
                                  priority=priority or self.priority,
                                  clock=svc.clock)
            except (NodeCrashError, TransientFaultError):
                pass   # the refusal stands regardless of injected noise
            raise QueryRejectedError(
                f"rate_limited: session over {self.bucket.rate:g}/s "
                f"(burst {self.bucket.burst:g})",
                epoch=svc.db.epochs.latest_queryable())
        return svc.submit(
            q, priority=priority or self.priority,
            timeout_s=timeout_s if timeout_s is not None else self.timeout_s)

    def close(self) -> None:
        if not self.closed:
            self.closed = True
            self.service._sessions.discard(self)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass
class _Unit:
    """One dispatch unit: a single query or a coalesced shared-scan
    group, with its plan, effective snapshot epoch and reservation
    token (held from dispatch until the drain stage harvests it)."""
    tickets: List[Ticket]
    plan: object
    epoch: int
    reserved: int
    res: object                     # block_cache.Reservation (idempotent)
    oversized: bool


@dataclasses.dataclass
class _Member:
    """One ticket's parked work inside a flight.  ``mode``:

    * ``ready``        -- materialized at dispatch (WOS side-scans,
                          non-fused groupbys); ``out`` holds the final
                          host result.
    * ``fused_solo``    -- a dedicated fused program's device pytree is
                          in the flight's fetch slot; ``finish`` shapes
                          the harvested host arrays.
    * ``fused_shared``  -- same, for a shared-scan member program;
                          ``cols``/``valid`` retain the device scan for
                          the rare sort-overflow fallback at drain.
    * ``select``        -- projection-only member: fetch slot holds
                          ``(valid, cols)`` device refs, drain applies
                          the mask host-side.
    * ``dup``           -- identical (query object, effective epoch) to
                          an earlier member of the SAME group: no
                          program of its own, drain reuses member
                          ``ref``'s completed result (common-query
                          elimination inside one scan pass).
    """
    ticket: Ticket
    mode: str
    es: ExecStats
    out: Optional[Dict[str, np.ndarray]] = None
    finish: Optional[object] = None
    cols: Optional[dict] = None
    valid: Optional[object] = None
    ref: int = -1


@dataclasses.dataclass
class _Flight:
    """A dispatched unit whose device results are parked in the
    in-flight queue awaiting the drain stage."""
    unit: _Unit
    t0: float                       # dispatch time (exec_s baseline)
    members: List[_Member]
    fetch: list                     # per-member device payloads (or None)

    def ready(self) -> bool:
        """True when every parked device array has materialized (the
        drain stage can harvest without blocking)."""
        for leaf in jax.tree_util.tree_leaves(self.fetch):
            probe = getattr(leaf, "is_ready", None)
            if probe is not None and not probe():
                return False
        return True


class QueryService:
    """The serving front door (module docstring has the full design).

    Construct via ``db.serve(...)``.  Knobs:

    * ``max_concurrent`` -- dispatch units admitted per step (the
      concurrency the memory budget is sized against).
    * ``queue_depth`` -- per-priority-class cap; beyond it ``submit``
      rejects typed *before* pinning anything.
    * ``max_sessions`` -- session-pool bound.
    * ``max_coalesce`` -- shared-scan group size cap (1 disables
      coalescing entirely).
    * ``memory_budget_bytes`` -- concurrent-working-set bound, default
      the block cache's byte budget (reservations and cached blocks
      answer to the same HBM).
    * ``max_in_flight`` -- bulkhead: max tickets of a priority class in
      flight (dispatched, not yet drained) at once.  An int applies to
      both classes; a dict sets them separately; None (default) leaves
      the class unbounded.
    * ``rate_limit`` -- default ``(rate_per_s, burst)`` token bucket for
      new sessions (per-session override in ``session()``); None
      disables.
    * ``max_cost_bytes`` -- hard ceiling on a leader ticket's SMA-priced
      scan cost; above it the ticket is rejected typed (``"cost"``).
      Queries riding a shared scan are not re-priced: their marginal
      cost IS the point of coalescing.
    * ``boost_cost_bytes`` -- a batch submit priced at or under this is
      enqueued on the interactive queue (its class, bulkhead and stats
      identity stay ``batch``): provably-cheap batch work shouldn't
      wait behind expensive batch work.
    * ``batch_boost_after`` -- after N consecutive interactive picks
      with batch waiting, pick batch once (anti-starvation).
    * ``default_timeout_s`` -- queued-past-this => typed rejection
      (per-submit override available).
    * ``clock`` -- scheduler clock; pass ``VirtualClock()`` for
      deterministic no-sleep schedules in tests.
    """

    def __init__(self, db: VerticaDB, *, max_concurrent: int = 4,
                 queue_depth: int = 32, max_sessions: int = 64,
                 max_coalesce: int = 8,
                 memory_budget_bytes: Optional[int] = None,
                 max_in_flight: Union[int, Dict[str, int], None] = None,
                 rate_limit: Optional[Tuple[float, float]] = None,
                 max_cost_bytes: Optional[int] = None,
                 boost_cost_bytes: Optional[int] = None,
                 batch_boost_after: int = 4,
                 default_timeout_s: Optional[float] = None,
                 clock=None):
        self.db = db
        self.max_concurrent = int(max_concurrent)
        self.queue_depth = int(queue_depth)
        self.max_sessions = int(max_sessions)
        self.max_coalesce = int(max_coalesce)
        self.memory_budget_bytes = int(
            memory_budget_bytes if memory_budget_bytes is not None
            else db.block_cache.budget_bytes)
        if max_in_flight is None:
            self.max_in_flight: Dict[str, int] = {}
        elif isinstance(max_in_flight, dict):
            self.max_in_flight = {p: int(v) for p, v in max_in_flight.items()}
        else:
            self.max_in_flight = {p: int(max_in_flight) for p in PRIORITIES}
        self.rate_limit = rate_limit
        self.max_cost_bytes = max_cost_bytes
        self.boost_cost_bytes = boost_cost_bytes
        self.batch_boost_after = int(batch_boost_after)
        self.default_timeout_s = default_timeout_s
        self.clock = clock if clock is not None else _WALL
        self.stats = ServiceStats()
        self._queues: Dict[str, deque] = {p: deque() for p in PRIORITIES}
        self._sessions: set = set()
        self._consec_interactive = 0
        self._seq = itertools.count(1)
        self._dispatch_seq = itertools.count(0)
        # the in-flight queue: dispatched units whose device results are
        # parked until the drain stage harvests them (arrival order)
        self._inflight: deque = deque()
        self._inflight_by_class: Dict[str, int] = {p: 0 for p in PRIORITIES}

    # -------------------------------------------------------- front door --

    def session(self, priority: str = "interactive", *,
                timeout_s: Optional[float] = None,
                rate_limit: Optional[Tuple[float, float]] = None) -> Session:
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}")
        if len(self._sessions) >= self.max_sessions:
            raise QueryRejectedError(
                f"session pool exhausted ({self.max_sessions} active)")
        s = Session(self, priority, timeout_s,
                    rate_limit if rate_limit is not None else self.rate_limit)
        self._sessions.add(s)
        return s

    def submit(self, q, *, priority: str = "interactive",
               timeout_s: Optional[float] = None) -> Ticket:
        """Admit a query: fire the admission injection point, enforce the
        queue-depth cap, then pin its snapshot epoch and enqueue.  Order
        matters -- every rejection here happens BEFORE the pin, so a
        refused query cannot stall the AHM."""
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}")
        q = as_ir(q)
        self.stats.submitted += 1
        try:
            fire_with_retries(self.db, "serving.admit", priority=priority,
                              clock=self.clock)
        except NodeCrashError:
            pass   # a node died during admission; dispatch replans around it
        except TransientFaultError as e:
            self.stats.rejected += 1
            self.stats.rejected_admission += 1
            raise QueryRejectedError(f"admission failed: {e}") from e
        target = priority
        boosted = False
        if priority == "batch" and self.boost_cost_bytes is not None:
            price = self._price_query(q)
            if price is not None and price <= self.boost_cost_bytes:
                target, boosted = "interactive", True
        queue = self._queues[target]
        if len(queue) >= self.queue_depth:
            self.stats.rejected += 1
            self.stats.rejected_queue_full += 1
            raise QueryRejectedError(
                f"{target} queue full ({self.queue_depth} deep)",
                epoch=self.db.epochs.latest_queryable())
        t = Ticket(self, q, priority,
                   timeout_s if timeout_s is not None
                   else self.default_timeout_s, next(self._seq))
        if boosted:
            t.stats.cost_boosted = True
            self.stats.cost_boosts += 1
        # pin at SUBMISSION: trickle commits while this query waits in
        # the queue can never shift what it sees (§5 snapshot isolation)
        t.pinned = self.db.epochs.pin()
        t.stats.snapshot_epoch = t.pinned
        queue.append(t)
        return t

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def in_flight(self, priority: Optional[str] = None) -> int:
        """Tickets dispatched and not yet drained (per class or total)."""
        if priority is None:
            return sum(self._inflight_by_class.values())
        return self._inflight_by_class[priority]

    def step(self, waiting_on: Optional[Ticket] = None) -> int:
        """One scheduler round: expire timeouts, harvest ready flights
        (arrival order), admit + dispatch new units.  If nothing settled
        and nothing could be admitted while flights are parked, force-
        harvest a flight so cooperative callers always make progress --
        the flight carrying ``waiting_on`` when given (``result()``
        passes its own ticket so an interactive waiter never pays for a
        batch unit's drain), the oldest otherwise.  Returns how many
        tickets settled."""
        settled0 = self.stats.completed + self.stats.rejected
        self._expire_timeouts()
        self._harvest(waiter=waiting_on)
        # an interactive waiter's cooperative steps never LEAD batch
        # rounds either -- batch admission (host-side scan assembly)
        # happens on neutral steps (``drain()``, bare ``step()``) or a
        # batch waiter's own; this is the single-threaded analog of
        # batch work never running on the interactive thread
        hold = (frozenset({"batch"})
                if waiting_on is not None
                and waiting_on.priority == "interactive"
                else frozenset())
        units = self._admit_round(hold)
        for unit in units:
            self._dispatch(unit)
        settled = self.stats.completed + self.stats.rejected - settled0
        if settled == 0 and not units and self._inflight:
            self._harvest(force=True, prefer=waiting_on)
            settled = self.stats.completed + self.stats.rejected - settled0
        return settled

    def drain(self) -> "QueryService":
        """Step until every queued ticket has settled and every flight
        has been harvested."""
        while self.pending() or self._inflight:
            if self.step() == 0 and not self._inflight:
                # pragma: no cover - defensive
                raise RuntimeError("serving stalled with queued tickets")
        return self

    # --------------------------------------------------- ticket lifecycle --

    def _reject(self, t: Ticket, err: Exception, kind: str) -> None:
        if t.pinned is not None:
            self.db.epochs.unpin(t.pinned)
            t.pinned = None
        t.state = "rejected"
        t._error = err
        t.stats.rejected_reason = kind
        t.stats.total_s = self.clock.now() - t.submitted_at
        self.stats.rejected += 1
        if kind == "timeout":
            self.stats.rejected_timeout += 1
        elif kind == "unavailable":
            self.stats.rejected_unavailable += 1
        elif kind == "cost":
            self.stats.rejected_cost += 1

    def _complete(self, t: Ticket, out, es: ExecStats) -> None:
        self.db.epochs.unpin(t.pinned)
        t.pinned = None
        t.state = "done"
        t._result = out
        t.stats.admitted = True
        t.stats.exec_stats = es
        t.stats.total_s = self.clock.now() - t.submitted_at
        self.stats.completed += 1
        if t.stats.shared_scan:
            self.stats.shared_hits += 1

    def _expire_timeouts(self) -> None:
        now = self.clock.now()
        for pr in PRIORITIES:
            queue = self._queues[pr]
            keep: deque = deque()
            while queue:
                t = queue.popleft()
                if t.timeout_s is not None and \
                        now - t.submitted_at > t.timeout_s:
                    self._reject(t, QueryRejectedError(
                        f"queued past timeout ({t.timeout_s:.3f}s)",
                        epoch=t.pinned), kind="timeout")
                else:
                    keep.append(t)
            self._queues[pr] = keep

    # -------------------------------------------------------- admission --

    def _class_headroom(self, cls: str, round_new: Dict[str, int]
                        ) -> Optional[int]:
        """Bulkhead headroom for a class this round (None = unbounded):
        cap minus tickets already in flight minus tickets admitted into
        units this round (they dispatch immediately after)."""
        cap = self.max_in_flight.get(cls)
        if cap is None:
            return None
        return cap - self._inflight_by_class[cls] - round_new[cls]

    def _pick_queue(self, blocked=frozenset()) -> Optional[str]:
        inter_ok = ("interactive" not in blocked
                    and bool(self._queues["interactive"]))
        batch_ok = "batch" not in blocked and bool(self._queues["batch"])
        if inter_ok and batch_ok and \
                self._consec_interactive >= self.batch_boost_after:
            self.stats.batch_boosts += 1
            return "batch"
        if inter_ok:
            return "interactive"
        if batch_ok:
            return "batch"
        return None

    def _plan(self, t: Ticket):
        """Plan a ticket, converting planner refusals (lost redundancy,
        no covering projection) into a typed per-ticket rejection rather
        than letting them crash the admission round."""
        from ..planner.planner import plan_query
        try:
            return plan_query(self.db, t.q)
        except (AvailabilityError, ValueError) as e:
            self._reject(t, e, kind="unavailable")
            return None

    def _effective_epoch(self, t: Ticket) -> int:
        """The ticket's pin clamped to its table's epoch ceiling: two
        queries pinned at different cluster epochs still read IDENTICAL
        table snapshots when no commit touched the table in between, so
        they may share one scan (the same clamp the block cache uses to
        keep entries warm across unrelated trickle commits)."""
        return min(t.pinned, self.db.table_epoch_ceiling(t.q.table))

    def _shareable(self, q) -> bool:
        """Single-table query shapes a shared scan can serve: joins need
        build sides + SIP pushdown that are per-query by construction."""
        return not q.joins and bool(q.aggs or q.group_by or q.columns
                                    or q.derived)

    # ------------------------------------------------------- cost model --

    def _raw_working_set_bytes(self, plan, need) -> int:
        """The pre-cost-model price: rows behind the plan's sources x
        (8-byte device lanes per needed column + 1 mask byte).  Kept as
        the comparison baseline the cost-model differential test prices
        against -- raw row counts ignore both SMA pruning (overcharges
        selective scans) and tail-block padding (undercharges fragmented
        stores)."""
        rows = 0
        for host, owner in plan.sources:
            store = self.db.nodes[host].stores[owner]
            rows += store.ros_rows() + store.wos.n_rows
        return rows * (8 * max(len(need), 1) + 1)

    def _sma_cost_bytes(self, plan, need, bounds: Dict) -> int:
        """SMA-priced working set: the bytes the scan will actually open.
        Two terms, matching what really lands in device memory:

        * decoded lanes for surviving ROS blocks -- counted with the same
          per-container SMA keep-mask the scan's pruning runs
          (``ColumnSMA.prune_blocks`` against the predicate's bounds), at
          FULL block granularity (a decoded block is ``block_rows`` lanes
          whether or not its tail is padding) -- plus unpruned WOS rows;
        * the REAL packed payload bytes of every needed column
          (``EncodedColumn.packed_bytes``, the actual uint32 word streams
          of DESIGN §9): that is the footprint the block cache holds
          resident, whole-container, regardless of pruning.

        Pass empty bounds for a shared group: its one scan is unpruned
        by construction, so the union price carries no predicate."""
        db = self.db
        lane = 8 * max(len(need), 1) + 1
        rows = 0
        packed = 0.0
        for host, owner in plan.sources:
            store = db.nodes[host].stores[owner]
            rows += store.wos.n_rows
            for c in store.containers:
                col = next(iter(c.columns.values()), None)
                if col is None:
                    continue
                keep = np.ones(col.n_blocks, dtype=bool)
                for colname, (lo, hi) in bounds.items():
                    if colname in c.smas:
                        keep &= c.smas[colname].prune_blocks(lo, hi)
                rows += int(keep.sum()) * db.block_rows
                for name in need:
                    if name in c.columns:
                        packed += c.columns[name].packed_bytes
        return int(rows * lane + packed)

    def _scan_bounds(self, q, proj) -> Dict:
        sp = q.scan_predicate(proj.columns)
        return sp.bounds() if sp is not None else {}

    def _price_query(self, q) -> Optional[int]:
        """Best-effort SMA price of a query at submit time (used only by
        the ``boost_cost_bytes`` decision; admission re-prices with the
        ticket's actual plan)."""
        from ..planner.planner import plan_query
        try:
            plan = plan_query(self.db, q)
            proj = self.db.catalog.projections[plan.projection]
            need = tuple(sorted(q.scan_columns(proj)))
            return self._sma_cost_bytes(plan, need,
                                        self._scan_bounds(q, proj))
        except Exception:
            return None   # unplannable here; admission rejects it typed

    # ------------------------------------------------------- admit round --

    def _admit_round(self, hold: frozenset = frozenset()) -> List[_Unit]:
        """Admit up to ``max_concurrent`` dispatch units under the memory
        budget and per-class bulkheads: pick an unblocked priority class,
        pop its head as unit leader, price it from SMA statistics, then
        coalesce compatible queued queries (any class) into its scan up
        to ``max_coalesce``.  The first unit always admits when NOTHING
        is in flight -- otherwise an oversized query could wedge the
        queue -- and its reservation marks it ``oversized`` instead;
        with flights parked, admission defers instead (their release at
        drain is guaranteed progress)."""
        cache = self.db.block_cache
        budget = self.memory_budget_bytes
        units: List[_Unit] = []
        round_new = {p: 0 for p in PRIORITIES}
        round_cls: Optional[str] = None
        while len(units) < self.max_concurrent:
            blocked = {p for p in PRIORITIES
                       if (lambda h: h is not None and h <= 0)(
                           self._class_headroom(p, round_new))}
            blocked |= hold
            if round_cls is not None:
                # rounds are class-homogeneous: once an interactive unit
                # leads the round, batch leaders wait for the next round
                # (their host-side scan assembly would ride ahead of the
                # interactive unit's drain) -- batch queries still join
                # this round as shared-scan mates, which costs nothing
                blocked |= {p for p in PRIORITIES if p != round_cls}
            cls = self._pick_queue(blocked)
            if cls is None:
                break
            queue = self._queues[cls]
            leader = queue.popleft()
            plan = self._plan(leader)
            if plan is None:
                continue   # rejected typed; try the next head
            proj = self.db.catalog.projections[plan.projection]
            leader.plan = plan
            leader.scan_need = tuple(sorted(leader.q.scan_columns(proj)))
            need_union = set(leader.scan_need)
            cost = self._sma_cost_bytes(plan, leader.scan_need,
                                        self._scan_bounds(leader.q, proj))
            leader.stats.cost_bytes = cost
            if self.max_cost_bytes is not None and cost > self.max_cost_bytes:
                self._reject(leader, QueryRejectedError(
                    f"admission cost {cost}B exceeds max_cost_bytes "
                    f"({self.max_cost_bytes}B)", epoch=leader.pinned),
                    kind="cost")
                continue
            ws = cost
            if (units or self._inflight) \
                    and cache.stats.reserved_bytes + ws > budget:
                queue.appendleft(leader)   # no headroom: close the round
                break
            if cls == "interactive":
                self._consec_interactive += 1
            else:
                self._consec_interactive = 0
            round_cls = cls
            round_new[leader.priority] += 1
            group = [leader]
            eff = self._effective_epoch(leader)
            if self.max_coalesce > 1 and self._shareable(leader.q) \
                    and self.db.mesh is None and leader.scan_need:
                ws = self._gather_mates(group, plan, eff, need_union, ws,
                                        round_new, hold)
            oversized = ws > budget
            units.append(_Unit(group, plan, eff, ws, cache.take(ws),
                               oversized))
        return units

    def _gather_mates(self, group: List[Ticket], plan, eff: int,
                      need_union: set, ws: int,
                      round_new: Dict[str, int],
                      hold: frozenset = frozenset()) -> int:
        """Pull queued queries compatible with the leader's scan into its
        group: same table, same projection + sources, same effective
        epoch, shareable shape, bulkhead headroom in the mate's class,
        and the enlarged column union still fits the memory budget (a
        GROUP's price is the unpruned union scan -- sharing forfeits
        pruning).  Scans both classes -- a batch query riding an
        interactive scan is the cheapest batch query there is -- EXCEPT
        classes in ``hold``: an interactive waiter's round must not pay
        for piggybacked batch members' programs and materialization."""
        cache = self.db.block_cache
        budget = self.memory_budget_bytes
        leader = group[0]
        for cls in PRIORITIES:
            if cls in hold:
                continue
            queue = self._queues[cls]
            kept: deque = deque()
            while queue and len(group) < self.max_coalesce:
                t = queue.popleft()
                q = t.q
                if q.table != leader.q.table or not self._shareable(q) \
                        or self._effective_epoch(t) != eff:
                    kept.append(t)
                    continue
                headroom = self._class_headroom(t.priority, round_new)
                if headroom is not None and headroom <= 0:
                    kept.append(t)   # mate's bulkhead is full
                    continue
                mplan = self._plan(t)
                if mplan is None:
                    continue   # rejected typed
                if mplan.projection != plan.projection \
                        or mplan.sources != plan.sources:
                    kept.append(t)
                    continue
                mproj = self.db.catalog.projections[mplan.projection]
                mneed = tuple(sorted(q.scan_columns(mproj)))
                if not mneed:
                    kept.append(t)
                    continue
                new_union = need_union | set(mneed)
                nws = self._sma_cost_bytes(plan, new_union, {})
                if cache.stats.reserved_bytes + nws > budget:
                    kept.append(t)   # the widened unit won't fit: an
                    continue         # over-budget scan gathers no mates
                t.plan, t.scan_need = mplan, mneed
                need_union |= set(mneed)
                ws = nws
                round_new[t.priority] += 1
                group.append(t)
            kept.extend(queue)
            self._queues[cls] = kept
        return ws

    # --------------------------------------------------------- dispatch --

    def _dispatch(self, unit: _Unit) -> None:
        """Dispatch one unit.  The async paths park device futures in
        the in-flight queue (the reservation rides along until drain);
        shapes the fused subset cannot express run synchronously here
        with exact single-query semantics, releasing on the spot."""
        seq = next(self._dispatch_seq)
        self.stats.dispatches += 1
        now = self.clock.now()
        for t in unit.tickets:
            t.state = "running"
            t.stats.dispatch_seq = seq
            t.stats.queue_wait_s = now - t.submitted_at
            t.stats.reserved_bytes = unit.reserved
            t.stats.oversized = unit.oversized
            t.stats.share_group = len(unit.tickets)
        try:
            fire_with_retries(self.db, "serving.dispatch",
                              group=len(unit.tickets), clock=self.clock)
        except NodeCrashError:
            pass   # execution replans around the dead node below
        except TransientFaultError as e:
            err = QueryRejectedError(f"dispatch failed: {e}",
                                     epoch=unit.epoch)
            for t in unit.tickets:
                self._reject(t, err, kind="unavailable")
            unit.res.release()
            return
        flight = None
        try:
            if len(unit.tickets) == 1:
                flight = self._dispatch_solo(unit)
            else:
                flight = self._dispatch_shared(unit)
        finally:
            if flight is None:
                unit.res.release()   # sync path done (or all rejected)
        if flight is not None:
            self._park(flight)

    def _park(self, flight: _Flight) -> None:
        self._inflight.append(flight)
        self.stats.async_units += 1
        for t in flight.unit.tickets:
            t.stats.async_dispatch = True
            self._inflight_by_class[t.priority] += 1
        for p in PRIORITIES:
            cur = self._inflight_by_class[p]
            if cur > self.stats.peak_in_flight.get(p, 0):
                self.stats.peak_in_flight[p] = cur

    def _dispatch_solo(self, unit: _Unit) -> Optional[_Flight]:
        """Un-coalesced dispatch.  Fused-subset shapes dispatch their
        cached program and park the device result (no host sync);
        everything else -- segmented meshes, RLE-direct shapes, WOS
        side-scans, non-fused queries -- falls through to the ordinary
        synchronous pipeline, which carries its own failover loop."""
        t, plan, db = unit.tickets[0], unit.plan, self.db
        t0 = self.clock.now()
        if db.mesh is None and not plan.scalar_rle \
                and not rle_direct_eligible(t.q, plan):
            es = ExecStats(projection=plan.projection,
                           groupby_algorithm=plan.groupby_algorithm,
                           join_strategy=plan.join_strategy)
            es.snapshot_epoch = t.pinned
            bc = db.block_cache.stats
            h0, m0 = bc.hits, bc.misses
            try:
                d = fused_exec.execute_fused_deferred(db, t.q, plan,
                                                      t.pinned, es)
            except NodeCrashError:
                # a node died under the deferred scan: the sync fallback
                # replans with its own (fresh) failover budget
                t.stats.failovers += 1
                d = None
            except TransientFaultError:
                d = None   # sync fallback re-runs with per-point retries
            if d is not None:
                res, finish = d
                es.block_cache_hits = bc.hits - h0
                es.block_cache_misses = bc.misses - m0
                member = _Member(t, "fused_solo", es, finish=finish)
                return _Flight(unit, t0, [member], [res])
        self._run_solo(t, plan)
        return None

    def _run_solo(self, t: Ticket, plan) -> None:
        """Synchronous single-query execution at the ticket's pinned
        epoch (the ordinary pipeline, which carries its own failover
        loop).  ``plan=None`` replans -- the drain-failover path uses
        that to route around a node that died while the ticket's device
        results were parked."""
        t0 = self.clock.now()
        try:
            out, es = execute(self.db, t.q, as_of=t.pinned, plan=plan)
        except (QueryRejectedError, AvailabilityError) as e:
            self._reject(t, e, kind="unavailable")
            return
        t.stats.exec_s = self.clock.now() - t0
        t.stats.failovers += es.failovers
        self._complete(t, out, es)

    def _dispatch_shared(self, unit: _Unit) -> Optional[_Flight]:
        """Coalesced dispatch with group-level failover: a node crash at
        the ``serving.shared_scan`` point replans the whole group at the
        SAME effective epoch (buddies hold identical rows, §4.3); if the
        replanned group no longer co-plans, members fall back to solo
        execution; exhausted budgets reject every member typed.  On
        success the group's device programs are parked as ONE flight."""
        db = self.db
        tickets, plan, eff = unit.tickets, unit.plan, unit.epoch
        retries_left = int(getattr(db, "max_failover_retries", 2))
        t0 = self.clock.now()
        while True:
            try:
                fire_with_retries(db, "serving.shared_scan",
                                  projection=plan.projection,
                                  group=len(tickets), clock=self.clock)
                flight = self._shared_once_async(unit, t0)
                break
            except NodeCrashError as e:
                for t in tickets:
                    t.stats.failovers += 1
                if retries_left <= 0:
                    err = QueryRejectedError(
                        f"failover budget exhausted (node {e.node} "
                        f"crashed at {e.point})", epoch=eff,
                        attempts=tickets[0].stats.failovers)
                    for t in tickets:
                        self._reject(t, err, kind="unavailable")
                    return None
                retries_left -= 1
                plan, eff = self._replan_group(unit)
                if plan is None:
                    # the group diverged after the crash: each survivor
                    # finishes solo (with its own failover budget)
                    for t in unit.tickets:
                        if t.state == "running":
                            self._run_solo(t, t.plan)
                    return None
            except TransientFaultError as e:
                err = QueryRejectedError(
                    f"shared scan transient budget exhausted: {e}",
                    epoch=eff)
                for t in tickets:
                    self._reject(t, err, kind="unavailable")
                return None
        self.stats.shared_scans += 1
        self.stats.coalesced_max = max(self.stats.coalesced_max,
                                       len(tickets))
        return flight

    def _replan_group(self, unit: _Unit):
        """Replan every group member after a mid-scan crash.  Returns the
        new (plan, effective epoch) when the group still co-plans onto
        identical sources, else (None, None) to trigger solo fallback."""
        leader = unit.tickets[0]
        plan = self._plan(leader)
        if plan is None:
            return None, None
        proj = self.db.catalog.projections[plan.projection]
        leader.plan = plan
        leader.scan_need = tuple(sorted(leader.q.scan_columns(proj)))
        eff = self._effective_epoch(leader)
        ok = True
        for t in unit.tickets[1:]:
            mplan = self._plan(t)
            if mplan is None:
                ok = False
                continue
            t.plan = mplan
            mproj = self.db.catalog.projections[mplan.projection]
            t.scan_need = tuple(sorted(t.q.scan_columns(mproj)))
            if mplan.projection != plan.projection \
                    or mplan.sources != plan.sources \
                    or self._effective_epoch(t) != eff:
                ok = False
        if not ok:
            return None, None
        unit.plan, unit.epoch = plan, eff
        return plan, eff

    # ------------------------------------------------------ shared scan --

    def _scan_would_be_empty(self, t: Ticket) -> bool:
        """Would this query's OWN scan -- with its SMA pruning pushed
        down -- yield no blocks and no WOS rows?  Independent execution
        returns the structured ``_empty_result`` in that case, which is
        NOT always bitwise-equal to aggregating an all-false mask (a
        fully-pruned scalar min is 0-length-empty, a fully-masked one is
        a sentinel), so the coalesced path must detect it explicitly to
        stay byte-identical.  Host-side and cheap: reads only SMA
        arrays, exactly like the pruning it mirrors."""
        db, q, plan = self.db, t.q, t.plan
        proj = db.catalog.projections[plan.projection]
        scan_pred = q.scan_predicate(proj.columns)
        if scan_pred is None:
            bounds = {}
        else:
            bounds = scan_pred.bounds()
        need = t.scan_need
        for host, owner in plan.sources:
            store = db.nodes[host].stores[owner]
            if store.wos.n_rows:
                return False
            for c in store.containers:
                if not need:
                    continue
                nb = c.columns[need[0]].n_blocks
                keep = np.ones(nb, dtype=bool)
                for colname, (lo, hi) in bounds.items():
                    if colname in c.smas:
                        keep &= c.smas[colname].prune_blocks(lo, hi)
                if keep.any():
                    return False
        return True

    def _shared_once_async(self, unit: _Unit, t0: float) -> _Flight:
        """ONE unpruned scan of the group's column union at the effective
        epoch, then one DISPATCHED (not materialized) mask->aggregate
        program per member, parked as a single flight.

        Why results are byte-identical to independent execution: the only
        rows present here and absent from a member's own scan are rows of
        blocks its SMA pruning would have dropped -- every such row fails
        the member's predicate, so it enters aggregation masked-invalid,
        and the aggregation kernels give invalid rows exactly-zero /
        sentinel contributions (operators._prep_agg).  Adding exact zeros
        changes no sum bitwise; group ordering is by key value, identical
        under packing either way; and the per-member programs make the
        same algorithm/domain choices as the dedicated path
        (executor.fused_plan_params).  The one asymmetry -- a scan pruned
        to NOTHING returns the structured empty result -- is mirrored by
        ``_scan_would_be_empty``.  Members outside the fused subset (WOS
        side-scans pending, non-fused groupbys) materialize here at
        dispatch, exactly the code the solo pipeline runs; select-only
        members park their (mask, columns) device refs for the drain
        stage's one batched transfer."""
        db = self.db
        tickets, plan, eff = unit.tickets, unit.plan, unit.epoch
        need_union = sorted(set().union(*(set(t.scan_need)
                                          for t in tickets)))
        scan_stats = ExecStats(projection=plan.projection)
        bc = db.block_cache.stats
        bc_h0, bc_m0 = bc.hits, bc.misses
        scans = []
        ros = self._ros_union_scan(plan, need_union, eff, scan_stats)
        if ros is not None:
            scans.append(ros)
        wos_parts = wos_scan_results(db, plan, need_union, None, None, eff)
        scans.extend(wos_parts)
        merged = ops.concat_scans(scans)
        has_wos = bool(wos_parts)

        members: List[_Member] = []
        fetch: list = []
        seen: Dict[int, int] = {}     # id(query IR) -> primary member idx
        for i, t in enumerate(tickets):
            q = t.q
            es = ExecStats(projection=plan.projection,
                           groupby_algorithm=t.plan.groupby_algorithm)
            es.snapshot_epoch = t.pinned
            es.containers_scanned = scan_stats.containers_scanned
            es.blocks_total = scan_stats.blocks_total
            t.stats.shared_scan = "leader" if i == 0 else "member"
            prim = seen.get(id(q))
            if prim is not None:
                # identical query at the group's one effective epoch:
                # its result is the primary's, bitwise -- don't build a
                # second program (the ticket objects stay distinct)
                members.append(_Member(t, "dup", es, ref=prim))
                fetch.append(None)
                self.stats.deduped += 1
                continue
            seen[id(q)] = i
            if merged is None or self._scan_would_be_empty(t):
                members.append(_Member(t, "ready", es,
                                       out=_finalize(q, _empty_result(q))))
                fetch.append(None)
                continue
            es.rows_scanned = int(merged.valid.shape[0])
            es.block_cache_hits = bc.hits - bc_h0
            es.block_cache_misses = bc.misses - bc_m0
            cols = {c: merged.columns[c] for c in t.scan_need}
            valid = merged.valid
            if not has_wos:
                # same eligibility gate as the dedicated fused path: WOS
                # rows ride an unencoded side-scan the program can't take
                d = fused_exec.execute_shared_fused_deferred(
                    db, q, t.plan, cols, valid, es)
                if d is not None:
                    res, finish = d
                    members.append(_Member(t, "fused_shared", es,
                                           finish=finish, cols=cols,
                                           valid=valid))
                    fetch.append(res)
                    continue
            if q.group_by or q.aggs:
                # general (untraced) operators -- the same code the solo
                # pipeline runs after its scan; materializes at dispatch
                out = self._shared_general(q, t.plan, cols, valid, es)
                members.append(_Member(t, "ready", es,
                                       out=_finalize(q, out)))
                fetch.append(None)
            else:
                # select-only member: apply derived/predicate on device,
                # park the (mask, columns) refs -- the drain stage slices
                # them host-side after its one batched transfer
                dcols = dict(cols)
                for name, e in q.derived:
                    dcols[name] = e(dcols)
                v = valid
                if q.predicate is not None:
                    v = v & jnp.asarray(q.predicate(dcols), bool)
                keep = set(q.columns) | {n for n, _ in q.derived}
                sel = {c: cv for c, cv in dcols.items()
                       if (c in keep) or (not keep and c != "_matched")}
                members.append(_Member(t, "select", es))
                fetch.append((v, sel))
        return _Flight(unit, t0, members, fetch)

    def _ros_union_scan(self, plan, need_union, eff: int, scan_stats):
        """The group's assembled ROS union scan, cached in the block
        cache across groups (and services sharing the db).  A shared
        scan is unpruned -- no per-query predicate reaches it -- so the
        assembled columns depend only on (column union, exact source
        container ids, per-container effective visibility epochs), which
        IS the cache key: ROS containers are immutable, a mergeout that
        retires one changes the id tuple, a delete moves that
        container's visibility ceiling -- stale entries become
        unreachable LRU garbage exactly like §17's WOS device buffers.
        This is the serving tier's warm-scan story: concurrent queries
        share one scan within a group (space) and across groups (time);
        the solo pipeline can't reuse assemblies because its per-query
        SMA pruning makes each scan predicate-shaped."""
        db = self.db
        cache = getattr(db, "block_cache", None)
        if cache is None:
            return fused_exec.scan_stores_batched(
                db, plan, need_union, None, None, eff, scan_stats)
        cids: List[int] = []
        effs: List[int] = []
        for host, owner in plan.sources:
            store = db.nodes[host].stores[owner]
            for c in store.containers:
                cids.append(c.id)
                effs.append(min(eff,
                                fused_exec._container_ceiling(store, c)))
        ns = f"scan:{plan.projection}"
        key = (tuple(need_union), tuple(cids), tuple(effs))
        hit = cache.get(ns, key, KIND_UNION)
        if hit is not None:
            ros, n_containers, n_blocks = hit
            scan_stats.containers_scanned += n_containers
            scan_stats.blocks_total += n_blocks
            return ros
        c0, b0 = scan_stats.containers_scanned, scan_stats.blocks_total
        ros = fused_exec.scan_stores_batched(
            db, plan, need_union, None, None, eff, scan_stats)
        value = (ros, scan_stats.containers_scanned - c0,
                 scan_stats.blocks_total - b0)
        nbytes = 0
        if ros is not None:
            nbytes = sum(device_bytes(v) for v in ros.columns.values())
            nbytes += device_bytes(ros.valid)
        cache.put(ns, key, KIND_UNION, value, nbytes)
        return ros

    def _shared_general(self, q, plan, cols, valid, es: ExecStats
                        ) -> Dict[str, np.ndarray]:
        """The untraced per-member path over an already-merged scan --
        the byte-identity reference the fused member programs are tested
        against, and the fallback when a member's shape (or a sort-cap
        overflow at drain) exits the fused subset."""
        cols = dict(cols)
        for name, e in q.derived:
            cols[name] = e(cols)
        if q.predicate is not None:
            valid = valid & jnp.asarray(q.predicate(cols), bool)
        if q.group_by or q.aggs:
            return _run_groupby(q, plan, cols, valid, es)
        mask = np.asarray(valid)
        keep = set(q.columns) | {n for n, _ in q.derived}
        return {c: np.asarray(v)[mask] for c, v in cols.items()
                if (c in keep) or (not keep and c != "_matched")}

    # ------------------------------------------------------ drain stage --

    def _harvest(self, *, force: bool = False,
                 prefer: Optional[Ticket] = None,
                 waiter: Optional[Ticket] = None) -> int:
        """Harvest every flight whose device arrays report ready, in
        arrival order among themselves; an unready flight never blocks a
        ready one behind it (head-of-line blocking would let a slow
        batch unit hold an already-finished interactive probe hostage in
        the drain stage).  An interactive ``waiter``'s sweep leaves
        all-batch flights parked -- their host materialization waits for
        a neutral or batch-driven step.  ``force`` additionally drains
        ONE flight unconditionally regardless of class -- the one
        carrying ``prefer`` if it is parked, else the oldest -- the
        progress guarantee behind ``Ticket.result()``/``drain()``;
        ``jax.device_get`` blocks until the backend finishes."""
        settled = 0
        if force and self._inflight:
            at = 0
            if prefer is not None:
                for j, fl in enumerate(self._inflight):
                    if prefer in fl.unit.tickets:
                        at = j
                        break
            settled += self._harvest_at(at)
        skip_batch = (waiter is not None
                      and waiter.priority == "interactive")
        i = 0
        while i < len(self._inflight):
            fl = self._inflight[i]
            if skip_batch and waiter not in fl.unit.tickets and \
                    all(t.priority == "batch" for t in fl.unit.tickets):
                i += 1
            elif fl.ready():
                settled += self._harvest_at(i)
            else:
                i += 1
        return settled

    def _harvest_at(self, i: int) -> int:
        fl = self._inflight[i]
        del self._inflight[i]
        for t in fl.unit.tickets:
            self._inflight_by_class[t.priority] -= 1
        return self._harvest_one(fl)

    def _fetch(self, tree):
        """ONE batched device->host transfer for a whole flight."""
        global _DEVICE_TRANSFERS
        _DEVICE_TRANSFERS += 1
        self.stats.device_transfers += 1
        return jax.device_get(tree)

    def _harvest_one(self, fl: _Flight) -> int:
        """Drain one flight: fire ``serving.drain`` (the failure matrix
        lives here -- see DESIGN.md §18), perform the unit's single
        batched transfer, then finish every member.

        * NodeCrashError at drain: the parked device results may live on
          the dead node; fail over ONCE by re-running each member through
          the solo pipeline at its still-pinned epoch (replans onto
          buddies holding identical rows -- byte-identical by the
          differential property).
        * TransientFaultError (retry budget already spent): every member
          rejects typed.
        * Sort-cap overflow surfacing at materialization: the signature
          is poisoned, the member re-runs down the general path."""
        unit = fl.unit
        try:
            try:
                fire_with_retries(self.db, "serving.drain",
                                  group=len(unit.tickets), clock=self.clock)
            except NodeCrashError:
                for t in unit.tickets:
                    if t.state == "running":
                        t.stats.failovers += 1
                        self._run_solo(t, None)
                return len(unit.tickets)
            except TransientFaultError as e:
                err = QueryRejectedError(f"drain failed: {e}",
                                         epoch=unit.epoch)
                for t in unit.tickets:
                    if t.state == "running":
                        self._reject(t, err, kind="unavailable")
                return len(unit.tickets)
            host = self._fetch(fl.fetch)
            now = self.clock.now()
            for m, h in zip(fl.members, host):
                t = m.ticket
                t.stats.exec_s = now - fl.t0
                if m.mode == "ready":
                    self._complete(t, m.out, m.es)
                elif m.mode == "fused_solo":
                    out = m.finish(h)
                    if out is None:
                        # overflow at materialization: sig poisoned, the
                        # sync re-run takes the general path
                        self._run_solo(t, t.plan)
                    else:
                        m.es.fused = True
                        self._complete(t, _finalize(t.q, out), m.es)
                elif m.mode == "fused_shared":
                    out = m.finish(h)
                    if out is None:
                        out = self._shared_general(t.q, t.plan, m.cols,
                                                   m.valid, m.es)
                    else:
                        m.es.fused = True
                    self._complete(t, _finalize(t.q, out), m.es)
                elif m.mode == "dup":
                    # members are harvested in group order, so the
                    # primary (earlier index) has already settled
                    pm = fl.members[m.ref]
                    pt = pm.ticket
                    if pt.state == "done":
                        m.es.fused = pm.es.fused
                        m.es.rows_scanned = pm.es.rows_scanned
                        self._complete(t, pt._result, m.es)
                    else:   # primary rejected/failed: run this one solo
                        self._run_solo(t, t.plan)
                else:   # select
                    v, sel = h
                    out = {c: arr[v] for c, arr in sel.items()}
                    self._complete(t, _finalize(t.q, out), m.es)
            self.stats.drains += 1
            return len(unit.tickets)
        finally:
            unit.res.release()
