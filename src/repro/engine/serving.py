"""Concurrent query serving: admission control, priority queues, shared
scans (paper §7 "workload management").

Everything below this module executes ONE query at a time; Vertica
presents a classical relational interface at web scale by putting a
workload-management layer in front of that engine.  This is that layer:
a bounded, prioritized, memory-budgeted front door that turns the
single-query executor into a multi-tenant service.

Three mechanisms (DESIGN.md §16):

* **Admission control** -- a bounded session pool and two priority
  queues (``interactive`` served ahead of ``batch``, with an
  anti-starvation boost so a saturating interactive stream cannot starve
  batch forever).  Queue-depth caps and queued-past-timeout expiry
  reject with the typed ``QueryRejectedError`` -- the same
  refusal-over-wrong-answer contract the failover path uses.  Every
  admission decision fires the ``serving.admit`` injection point, so
  chaos schedules cover the front door too.
* **Shared scans** -- queued queries over the same projection whose
  pinned snapshots clamp to the same effective epoch coalesce into ONE
  cache-resident scan (no SMA pruning, no predicate pushdown: the scan
  is shared), with each member applying its own predicate mask +
  aggregation as a plan-cached jitted program
  (executor.execute_shared_fused).  The plan cache is thereby exploited
  *across* concurrent queries, not only across repeats of one query; a
  coalesced group charges the memory budget once.  A differential test
  (tests/test_serving.py) proves coalesced results byte-identical to
  independent execution -- see ``_shared_once`` for why that holds.
* **Memory budget** -- each dispatch reserves its estimated decoded
  working set against the block-cache budget (BlockCache.reserve);
  admission stops opening new work when the reservation pool is
  exhausted, bounding the concurrent working set to what HBM holds.

Concurrency model: cooperative and deterministic, like the rest of the
simulated cluster.  ``submit()`` pins the query's snapshot epoch and
enqueues; ``step()`` runs one admission round (expire timed-out tickets
-> admit up to ``max_concurrent`` dispatch units under the memory
budget -> execute them); ``drain()`` steps until idle.  The latency a
ticket observes therefore includes real queue wait, which is what
benchmarks/serving.py reports as p50/p95/p99.

The load-bearing invariant is the epoch-pin lifecycle: a pin taken at
submit is released on EXACTLY ONE of completion / timeout / fault
rejection (queue-full rejection happens before pinning), so no rejected
or abandoned query can stall the AHM.  tests/test_serving.py floods the
queue and asserts ``EpochManager.n_pinned() == 0`` afterward.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.database import AvailabilityError, QueryRejectedError, VerticaDB
from ..core.faults import (NodeCrashError, TransientFaultError,
                           fire_with_retries)
from .logical import as_ir
from . import executor as fused_exec
from . import operators as ops
from .pipeline import (ExecStats, _empty_result, _finalize, _run_groupby,
                       execute, wos_scan_results)

PRIORITIES = ("interactive", "batch")


@dataclasses.dataclass
class ServingStats:
    """Per-query serving telemetry, one per Ticket (the serving-layer
    analog of pipeline.ExecStats, which rides along in ``exec_stats``)."""
    priority: str = "interactive"
    admitted: bool = False
    rejected_reason: str = ""       # "queue_full"/"timeout"/"admission"/
    #                                 "unavailable" ("" = not rejected)
    queue_wait_s: float = 0.0       # submit -> dispatch
    exec_s: float = 0.0             # dispatch -> result
    total_s: float = 0.0            # submit -> done (closed-loop latency)
    shared_scan: str = ""           # "leader"/"member" when coalesced
    share_group: int = 1            # tickets in this dispatch unit
    dispatch_seq: int = -1          # global dispatch order (priority tests)
    snapshot_epoch: int = 0         # the pinned epoch this query read
    reserved_bytes: int = 0         # working set charged at admission
    oversized: bool = False         # working set alone exceeds the budget
    failovers: int = 0              # mid-dispatch node crashes absorbed
    exec_stats: Optional[ExecStats] = None


@dataclasses.dataclass
class ServiceStats:
    """Service-wide counters (benchmarks/serving.py reads these)."""
    submitted: int = 0
    completed: int = 0
    rejected: int = 0
    rejected_queue_full: int = 0
    rejected_timeout: int = 0
    rejected_admission: int = 0
    rejected_unavailable: int = 0
    dispatches: int = 0             # dispatch units executed
    shared_scans: int = 0           # units that coalesced >= 2 queries
    shared_hits: int = 0            # completed queries served coalesced
    coalesced_max: int = 0
    batch_boosts: int = 0           # anti-starvation picks of batch

    def shared_hit_rate(self) -> float:
        return self.shared_hits / self.completed if self.completed else 0.0


class Ticket:
    """A submitted query's handle: state machine
    ``queued -> running -> done|rejected``, its pinned snapshot epoch,
    and its ServingStats.  ``result()`` cooperatively drives the service
    until this ticket settles."""

    def __init__(self, service: "QueryService", q, priority: str,
                 timeout_s: Optional[float], seq: int):
        self.service = service
        self.q = q
        self.priority = priority
        self.timeout_s = timeout_s
        self.id = seq
        self.submitted_at = time.time()
        self.pinned: Optional[int] = None
        self.state = "queued"
        self.stats = ServingStats(priority=priority)
        self._result: Optional[Dict[str, np.ndarray]] = None
        self._error: Optional[Exception] = None
        # dispatch-time physical choices (set at admission)
        self.plan = None
        self.scan_need: Tuple[str, ...] = ()

    @property
    def done(self) -> bool:
        return self.state in ("done", "rejected")

    @property
    def error(self) -> Optional[Exception]:
        return self._error

    def result(self) -> Dict[str, np.ndarray]:
        """Block (cooperatively) until this query settles; returns its
        rows or raises its typed rejection error."""
        guard = 0
        while not self.done:
            self.service.step()
            guard += 1
            if guard > 1_000_000:   # pragma: no cover - defensive
                raise RuntimeError("serving made no progress")
        if self._error is not None:
            raise self._error
        return self._result


class Session:
    """One client's bounded handle on the service (the session pool is
    the paper's connection limit): carries a default priority/timeout,
    counts against ``max_sessions`` until closed."""

    def __init__(self, service: "QueryService", priority: str,
                 timeout_s: Optional[float] = None):
        self.service = service
        self.priority = priority
        self.timeout_s = timeout_s
        self.closed = False

    def submit(self, q, *, priority: Optional[str] = None,
               timeout_s: Optional[float] = None) -> Ticket:
        if self.closed:
            raise QueryRejectedError("session is closed")
        return self.service.submit(
            q, priority=priority or self.priority,
            timeout_s=timeout_s if timeout_s is not None else self.timeout_s)

    def close(self) -> None:
        if not self.closed:
            self.closed = True
            self.service._sessions.discard(self)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass
class _Unit:
    """One dispatch unit: a single query or a coalesced shared-scan
    group, with its plan, effective snapshot epoch and reservation."""
    tickets: List[Ticket]
    plan: object
    epoch: int
    reserved: int
    oversized: bool


class QueryService:
    """The serving front door (module docstring has the full design).

    Construct via ``db.serve(...)``.  Knobs:

    * ``max_concurrent`` -- dispatch units admitted per step (the
      concurrency the memory budget is sized against).
    * ``queue_depth`` -- per-priority-class cap; beyond it ``submit``
      rejects typed *before* pinning anything.
    * ``max_sessions`` -- session-pool bound.
    * ``max_coalesce`` -- shared-scan group size cap (1 disables
      coalescing entirely).
    * ``memory_budget_bytes`` -- concurrent-working-set bound, default
      the block cache's byte budget (reservations and cached blocks
      answer to the same HBM).
    * ``batch_boost_after`` -- after N consecutive interactive picks
      with batch waiting, pick batch once (anti-starvation).
    * ``default_timeout_s`` -- queued-past-this => typed rejection
      (per-submit override available).
    """

    def __init__(self, db: VerticaDB, *, max_concurrent: int = 4,
                 queue_depth: int = 32, max_sessions: int = 64,
                 max_coalesce: int = 8,
                 memory_budget_bytes: Optional[int] = None,
                 batch_boost_after: int = 4,
                 default_timeout_s: Optional[float] = None):
        self.db = db
        self.max_concurrent = int(max_concurrent)
        self.queue_depth = int(queue_depth)
        self.max_sessions = int(max_sessions)
        self.max_coalesce = int(max_coalesce)
        self.memory_budget_bytes = int(
            memory_budget_bytes if memory_budget_bytes is not None
            else db.block_cache.budget_bytes)
        self.batch_boost_after = int(batch_boost_after)
        self.default_timeout_s = default_timeout_s
        self.stats = ServiceStats()
        self._queues: Dict[str, deque] = {p: deque() for p in PRIORITIES}
        self._sessions: set = set()
        self._consec_interactive = 0
        self._seq = itertools.count(1)
        self._dispatch_seq = itertools.count(0)

    # -------------------------------------------------------- front door --

    def session(self, priority: str = "interactive", *,
                timeout_s: Optional[float] = None) -> Session:
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}")
        if len(self._sessions) >= self.max_sessions:
            raise QueryRejectedError(
                f"session pool exhausted ({self.max_sessions} active)")
        s = Session(self, priority, timeout_s)
        self._sessions.add(s)
        return s

    def submit(self, q, *, priority: str = "interactive",
               timeout_s: Optional[float] = None) -> Ticket:
        """Admit a query: fire the admission injection point, enforce the
        queue-depth cap, then pin its snapshot epoch and enqueue.  Order
        matters -- every rejection here happens BEFORE the pin, so a
        refused query cannot stall the AHM."""
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}")
        q = as_ir(q)
        self.stats.submitted += 1
        try:
            fire_with_retries(self.db, "serving.admit", priority=priority)
        except NodeCrashError:
            pass   # a node died during admission; dispatch replans around it
        except TransientFaultError as e:
            self.stats.rejected += 1
            self.stats.rejected_admission += 1
            raise QueryRejectedError(f"admission failed: {e}") from e
        queue = self._queues[priority]
        if len(queue) >= self.queue_depth:
            self.stats.rejected += 1
            self.stats.rejected_queue_full += 1
            raise QueryRejectedError(
                f"{priority} queue full ({self.queue_depth} deep)",
                epoch=self.db.epochs.latest_queryable())
        t = Ticket(self, q, priority,
                   timeout_s if timeout_s is not None
                   else self.default_timeout_s, next(self._seq))
        # pin at SUBMISSION: trickle commits while this query waits in
        # the queue can never shift what it sees (§5 snapshot isolation)
        t.pinned = self.db.epochs.pin()
        t.stats.snapshot_epoch = t.pinned
        queue.append(t)
        return t

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def step(self) -> int:
        """One admission round; returns how many tickets settled."""
        settled0 = self.stats.completed + self.stats.rejected
        self._expire_timeouts()
        for unit in self._admit_round():
            self._dispatch(unit)
        return self.stats.completed + self.stats.rejected - settled0

    def drain(self) -> "QueryService":
        """Step until every queued ticket has settled."""
        while self.pending():
            if self.step() == 0:   # pragma: no cover - defensive
                raise RuntimeError("serving stalled with queued tickets")
        return self

    # --------------------------------------------------- ticket lifecycle --

    def _reject(self, t: Ticket, err: Exception, kind: str) -> None:
        if t.pinned is not None:
            self.db.epochs.unpin(t.pinned)
            t.pinned = None
        t.state = "rejected"
        t._error = err
        t.stats.rejected_reason = kind
        t.stats.total_s = time.time() - t.submitted_at
        self.stats.rejected += 1
        if kind == "timeout":
            self.stats.rejected_timeout += 1
        elif kind == "unavailable":
            self.stats.rejected_unavailable += 1

    def _complete(self, t: Ticket, out, es: ExecStats) -> None:
        self.db.epochs.unpin(t.pinned)
        t.pinned = None
        t.state = "done"
        t._result = out
        t.stats.admitted = True
        t.stats.exec_stats = es
        t.stats.total_s = time.time() - t.submitted_at
        self.stats.completed += 1
        if t.stats.shared_scan:
            self.stats.shared_hits += 1

    def _expire_timeouts(self) -> None:
        now = time.time()
        for pr in PRIORITIES:
            queue = self._queues[pr]
            keep: deque = deque()
            while queue:
                t = queue.popleft()
                if t.timeout_s is not None and \
                        now - t.submitted_at > t.timeout_s:
                    self._reject(t, QueryRejectedError(
                        f"queued past timeout ({t.timeout_s:.3f}s)",
                        epoch=t.pinned), kind="timeout")
                else:
                    keep.append(t)
            self._queues[pr] = keep

    # -------------------------------------------------------- admission --

    def _pick_queue(self) -> Optional[str]:
        inter, batch = self._queues["interactive"], self._queues["batch"]
        if inter and batch and \
                self._consec_interactive >= self.batch_boost_after:
            self.stats.batch_boosts += 1
            return "batch"
        if inter:
            return "interactive"
        if batch:
            return "batch"
        return None

    def _plan(self, t: Ticket):
        """Plan a ticket, converting planner refusals (lost redundancy,
        no covering projection) into a typed per-ticket rejection rather
        than letting them crash the admission round."""
        from ..planner.planner import plan_query
        try:
            return plan_query(self.db, t.q)
        except (AvailabilityError, ValueError) as e:
            self._reject(t, e, kind="unavailable")
            return None

    def _effective_epoch(self, t: Ticket) -> int:
        """The ticket's pin clamped to its table's epoch ceiling: two
        queries pinned at different cluster epochs still read IDENTICAL
        table snapshots when no commit touched the table in between, so
        they may share one scan (the same clamp the block cache uses to
        keep entries warm across unrelated trickle commits)."""
        return min(t.pinned, self.db.table_epoch_ceiling(t.q.table))

    def _shareable(self, q) -> bool:
        """Single-table query shapes a shared scan can serve: joins need
        build sides + SIP pushdown that are per-query by construction."""
        return not q.joins and bool(q.aggs or q.group_by or q.columns
                                    or q.derived)

    def _working_set_bytes(self, plan, need) -> int:
        """Decoded working-set estimate for one dispatch unit: rows
        behind the plan's sources x (8-byte device lanes per needed
        column + 1 mask byte).  The union of a coalesced group's columns
        is charged ONCE -- sharing the scan is what makes N queries cost
        one working set."""
        rows = 0
        for host, owner in plan.sources:
            store = self.db.nodes[host].stores[owner]
            rows += store.ros_rows() + store.wos.n_rows
        return rows * (8 * max(len(need), 1) + 1)

    def _admit_round(self) -> List[_Unit]:
        """Admit up to ``max_concurrent`` dispatch units under the memory
        budget: pick a priority class, pop its head as unit leader, then
        coalesce compatible queued queries (any class) into its scan up
        to ``max_coalesce``.  The first unit always admits -- otherwise
        an oversized query could wedge the queue -- and its reservation
        marks it ``oversized`` instead."""
        cache = self.db.block_cache
        budget = self.memory_budget_bytes
        units: List[_Unit] = []
        while len(units) < self.max_concurrent:
            cls = self._pick_queue()
            if cls is None:
                break
            queue = self._queues[cls]
            leader = queue.popleft()
            plan = self._plan(leader)
            if plan is None:
                continue   # rejected typed; try the next head
            proj = self.db.catalog.projections[plan.projection]
            leader.plan = plan
            leader.scan_need = tuple(sorted(leader.q.scan_columns(proj)))
            need_union = set(leader.scan_need)
            ws = self._working_set_bytes(plan, need_union)
            if units and cache.stats.reserved_bytes + ws > budget:
                queue.appendleft(leader)   # no headroom: close the round
                break
            if cls == "interactive":
                self._consec_interactive += 1
            else:
                self._consec_interactive = 0
            group = [leader]
            eff = self._effective_epoch(leader)
            if self.max_coalesce > 1 and self._shareable(leader.q) \
                    and self.db.mesh is None and leader.scan_need:
                ws = self._gather_mates(group, plan, eff, need_union, ws)
            oversized = ws > budget
            cache.reserve(ws)
            units.append(_Unit(group, plan, eff, ws, oversized))
        return units

    def _gather_mates(self, group: List[Ticket], plan, eff: int,
                      need_union: set, ws: int) -> int:
        """Pull queued queries compatible with the leader's scan into its
        group: same table, same projection + sources, same effective
        epoch, shareable shape, and the enlarged column union still fits
        the memory budget.  Scans both classes -- a batch query riding an
        interactive scan is the cheapest batch query there is."""
        cache = self.db.block_cache
        budget = self.memory_budget_bytes
        leader = group[0]
        for cls in PRIORITIES:
            queue = self._queues[cls]
            kept: deque = deque()
            while queue and len(group) < self.max_coalesce:
                t = queue.popleft()
                q = t.q
                if q.table != leader.q.table or not self._shareable(q) \
                        or self._effective_epoch(t) != eff:
                    kept.append(t)
                    continue
                mplan = self._plan(t)
                if mplan is None:
                    continue   # rejected typed
                if mplan.projection != plan.projection \
                        or mplan.sources != plan.sources:
                    kept.append(t)
                    continue
                mproj = self.db.catalog.projections[mplan.projection]
                mneed = tuple(sorted(q.scan_columns(mproj)))
                if not mneed:
                    kept.append(t)
                    continue
                new_union = need_union | set(mneed)
                nws = self._working_set_bytes(plan, new_union)
                if cache.stats.reserved_bytes + nws > budget:
                    kept.append(t)   # the widened unit won't fit: an
                    continue         # over-budget scan gathers no mates
                t.plan, t.scan_need = mplan, mneed
                need_union |= set(mneed)
                ws = nws
                group.append(t)
            kept.extend(queue)
            self._queues[cls] = kept
        return ws

    # --------------------------------------------------------- dispatch --

    def _dispatch(self, unit: _Unit) -> None:
        seq = next(self._dispatch_seq)
        self.stats.dispatches += 1
        now = time.time()
        for t in unit.tickets:
            t.state = "running"
            t.stats.dispatch_seq = seq
            t.stats.queue_wait_s = now - t.submitted_at
            t.stats.reserved_bytes = unit.reserved
            t.stats.oversized = unit.oversized
            t.stats.share_group = len(unit.tickets)
        try:
            if len(unit.tickets) == 1:
                self._run_solo(unit.tickets[0], unit.plan)
            else:
                self._run_shared(unit)
        finally:
            self.db.block_cache.release(unit.reserved)

    def _run_solo(self, t: Ticket, plan) -> None:
        """Un-coalesced dispatch: the ordinary single-query pipeline at
        the ticket's pinned epoch (it carries its own failover loop)."""
        t0 = time.time()
        try:
            out, es = execute(self.db, t.q, as_of=t.pinned, plan=plan)
        except (QueryRejectedError, AvailabilityError) as e:
            self._reject(t, e, kind="unavailable")
            return
        t.stats.exec_s = time.time() - t0
        t.stats.failovers += es.failovers
        self._complete(t, out, es)

    def _run_shared(self, unit: _Unit) -> None:
        """Coalesced dispatch with group-level failover: a node crash at
        the ``serving.shared_scan`` point replans the whole group at the
        SAME effective epoch (buddies hold identical rows, §4.3); if the
        replanned group no longer co-plans, members fall back to solo
        execution; exhausted budgets reject every member typed."""
        db = self.db
        tickets, plan, eff = unit.tickets, unit.plan, unit.epoch
        retries_left = int(getattr(db, "max_failover_retries", 2))
        t0 = time.time()
        while True:
            try:
                fire_with_retries(db, "serving.shared_scan",
                                  projection=plan.projection,
                                  group=len(tickets))
                results = self._shared_once(tickets, plan, eff)
                break
            except NodeCrashError as e:
                for t in tickets:
                    t.stats.failovers += 1
                if retries_left <= 0:
                    err = QueryRejectedError(
                        f"failover budget exhausted (node {e.node} "
                        f"crashed at {e.point})", epoch=eff,
                        attempts=tickets[0].stats.failovers)
                    for t in tickets:
                        self._reject(t, err, kind="unavailable")
                    return
                retries_left -= 1
                plan, eff = self._replan_group(unit)
                if plan is None:
                    # the group diverged after the crash: each survivor
                    # finishes solo (with its own failover budget)
                    for t in unit.tickets:
                        if t.state == "running":
                            self._run_solo(t, t.plan)
                    return
            except TransientFaultError as e:
                err = QueryRejectedError(
                    f"shared scan transient budget exhausted: {e}",
                    epoch=eff)
                for t in tickets:
                    self._reject(t, err, kind="unavailable")
                return
        exec_s = time.time() - t0
        self.stats.shared_scans += 1
        self.stats.coalesced_max = max(self.stats.coalesced_max,
                                       len(tickets))
        for t, (out, es) in zip(tickets, results):
            t.stats.exec_s = exec_s
            self._complete(t, out, es)

    def _replan_group(self, unit: _Unit):
        """Replan every group member after a mid-scan crash.  Returns the
        new (plan, effective epoch) when the group still co-plans onto
        identical sources, else (None, None) to trigger solo fallback."""
        leader = unit.tickets[0]
        plan = self._plan(leader)
        if plan is None:
            return None, None
        proj = self.db.catalog.projections[plan.projection]
        leader.plan = plan
        leader.scan_need = tuple(sorted(leader.q.scan_columns(proj)))
        eff = self._effective_epoch(leader)
        ok = True
        for t in unit.tickets[1:]:
            mplan = self._plan(t)
            if mplan is None:
                ok = False
                continue
            t.plan = mplan
            mproj = self.db.catalog.projections[mplan.projection]
            t.scan_need = tuple(sorted(t.q.scan_columns(mproj)))
            if mplan.projection != plan.projection \
                    or mplan.sources != plan.sources \
                    or self._effective_epoch(t) != eff:
                ok = False
        if not ok:
            return None, None
        unit.plan, unit.epoch = plan, eff
        return plan, eff

    # ------------------------------------------------------ shared scan --

    def _scan_would_be_empty(self, t: Ticket) -> bool:
        """Would this query's OWN scan -- with its SMA pruning pushed
        down -- yield no blocks and no WOS rows?  Independent execution
        returns the structured ``_empty_result`` in that case, which is
        NOT always bitwise-equal to aggregating an all-false mask (a
        fully-pruned scalar min is 0-length-empty, a fully-masked one is
        a sentinel), so the coalesced path must detect it explicitly to
        stay byte-identical.  Host-side and cheap: reads only SMA
        arrays, exactly like the pruning it mirrors."""
        db, q, plan = self.db, t.q, t.plan
        proj = db.catalog.projections[plan.projection]
        scan_pred = q.scan_predicate(proj.columns)
        if scan_pred is None:
            bounds = {}
        else:
            bounds = scan_pred.bounds()
        need = t.scan_need
        for host, owner in plan.sources:
            store = db.nodes[host].stores[owner]
            if store.wos.n_rows:
                return False
            for c in store.containers:
                if not need:
                    continue
                nb = c.columns[need[0]].n_blocks
                keep = np.ones(nb, dtype=bool)
                for colname, (lo, hi) in bounds.items():
                    if colname in c.smas:
                        keep &= c.smas[colname].prune_blocks(lo, hi)
                if keep.any():
                    return False
        return True

    def _shared_once(self, tickets: List[Ticket], plan, eff: int
                     ) -> List[Tuple[Dict[str, np.ndarray], ExecStats]]:
        """ONE unpruned scan of the group's column union at the effective
        epoch, then one mask->aggregate pass per member.

        Why results are byte-identical to independent execution: the only
        rows present here and absent from a member's own scan are rows of
        blocks its SMA pruning would have dropped -- every such row fails
        the member's predicate, so it enters aggregation masked-invalid,
        and the aggregation kernels give invalid rows exactly-zero /
        sentinel contributions (operators._prep_agg).  Adding exact zeros
        changes no sum bitwise; group ordering is by key value, identical
        under packing either way; and the per-member programs make the
        same algorithm/domain choices as the dedicated path
        (executor.fused_plan_params).  The one asymmetry -- a scan pruned
        to NOTHING returns the structured empty result -- is mirrored by
        ``_scan_would_be_empty``."""
        db = self.db
        need_union = sorted(set().union(*(set(t.scan_need)
                                          for t in tickets)))
        scan_stats = ExecStats(projection=plan.projection)
        bc = db.block_cache.stats
        bc_h0, bc_m0 = bc.hits, bc.misses
        scans = []
        ros = fused_exec.scan_stores_batched(db, plan, need_union, None,
                                             None, eff, scan_stats)
        if ros is not None:
            scans.append(ros)
        wos_parts = wos_scan_results(db, plan, need_union, None, None, eff)
        scans.extend(wos_parts)
        merged = ops.concat_scans(scans)
        has_wos = bool(wos_parts)

        results = []
        for i, t in enumerate(tickets):
            q = t.q
            es = ExecStats(projection=plan.projection,
                           groupby_algorithm=t.plan.groupby_algorithm)
            es.snapshot_epoch = t.pinned
            es.containers_scanned = scan_stats.containers_scanned
            es.blocks_total = scan_stats.blocks_total
            t.stats.shared_scan = "leader" if i == 0 else "member"
            if merged is None or self._scan_would_be_empty(t):
                results.append((_finalize(q, _empty_result(q)), es))
                continue
            es.rows_scanned = int(merged.valid.shape[0])
            cols = {c: merged.columns[c] for c in t.scan_need}
            valid = merged.valid
            out = None
            if not has_wos:
                # same eligibility gate as the dedicated fused path: WOS
                # rows ride an unencoded side-scan the program can't take
                out = fused_exec.execute_shared_fused(db, q, t.plan, cols,
                                                      valid, es)
                if out is not None:
                    es.fused = True
            if out is None:
                # general (untraced) operators -- the same code the solo
                # pipeline runs after its scan
                cols = dict(cols)
                for name, e in q.derived:
                    cols[name] = e(cols)
                if q.predicate is not None:
                    valid = valid & jnp.asarray(q.predicate(cols), bool)
                if q.group_by or q.aggs:
                    out = _run_groupby(q, t.plan, cols, valid, es)
                else:
                    mask = np.asarray(valid)
                    keep = set(q.columns) | {n for n, _ in q.derived}
                    out = {c: np.asarray(v)[mask] for c, v in cols.items()
                           if (c in keep) or (not keep and c != "_matched")}
            es.block_cache_hits = bc.hits - bc_h0
            es.block_cache_misses = bc.misses - bc_m0
            results.append((_finalize(q, out), es))
        return results
