"""Warm-path executor: batched container scans + a plan-signature compile
cache (paper §6 "run fast on data already near the processor", §7 "plan
once, execute many").

Cold path (engine/pipeline.py before this module existed): every
``execute()`` re-uploaded each encoded column host->device, re-decoded it,
and re-traced the scan->predicate->mask->aggregate program, per container,
per query.  Repeat queries -- the heavy-traffic scenario in ROADMAP.md --
paid full cold-start cost each time.

Warm path, three pieces:

  1. **Block cache** (core/block_cache.py): encoded payloads and decoded
     ``(n_blocks, block_rows)`` blocks stay device-resident keyed by
     ``(container_id, column)``; ROS immutability makes entries coherent
     until the tuple mover retires the container.
  2. **Batched scan**: instead of one Python loop iteration (and one
     device round-trip) per container, the SMA-surviving blocks of *all*
     containers are gathered from the cache and concatenated into one
     flat array per column -- a single device program regardless of how
     fragmented the ROS is.
  3. **Plan cache**: the fused join-chain->derived->predicate->mask->
     groupby program is built once per *plan signature* -- the logical
     IR's canonical ``LogicalQuery.signature()`` (engine/logical.py)
     plus the physical choices (projection, algorithm, static domain,
     block shape) -- and memoized; the second occurrence of any query
     shape skips closure construction and hits jax's compile cache
     instead of re-tracing.

See DESIGN.md §11 ("Block cache & plan cache").
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_cache import BlockCache, KIND_DECODED, KIND_ENCODED
from ..core.database import VerticaDB
from ..core.encodings import decode_jnp, device_bytes, upload_jnp
from ..core.storage import ROSContainer
from . import operators as ops
from .expr import Expr

KIND_VALID = "valid"      # per-(container, as_of) visibility blocks
KIND_BUILD = "build"      # per-(dim_table, as_of, join-sig) build sides


# ---------------------------------------------------------------------------
# Plan cache: plan signature -> fused compiled program
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PlanCacheStats:
    hits: int = 0
    misses: int = 0


class PlanCache:
    """Bounded memo of fused executables keyed by plan signature.  The
    signature is the IR's canonical form plus the physical choices, so it
    captures everything that changes the traced program -- joins, derived
    expressions, predicate shape *and* literals, group keys, groupby
    algorithm and domain, agg set -- and a hit is exactly 'this query
    shape has run before'."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self.stats = PlanCacheStats()
        self._fns: "OrderedDict[tuple, Callable]" = OrderedDict()

    def get_or_build(self, sig: tuple, build: Callable[[], Callable]
                     ) -> Tuple[Callable, bool]:
        fn = self._fns.get(sig)
        if fn is not None:
            self._fns.move_to_end(sig)
            self.stats.hits += 1
            return fn, True
        fn = build()
        self._fns[sig] = fn
        if len(self._fns) > self.max_entries:
            self._fns.popitem(last=False)
        self.stats.misses += 1
        return fn, False

    def clear(self):
        self._fns.clear()


# one process-wide plan cache: plans are keyed by projection name and
# query shape, not by DB identity, and jitted programs are shareable
PLAN_CACHE = PlanCache()

# negative cache: plan signatures whose sort-path GroupBy overflowed
# max_groups -- repeats skip the doomed fused attempt and go straight to
# the general pipeline (which lands on the exact host GroupBy)
_SORT_OVERFLOWED: set = set()


# ---------------------------------------------------------------------------
# Cached device blocks
# ---------------------------------------------------------------------------

def cached_decoded(cache: Optional[BlockCache], c: ROSContainer,
                   name: str) -> jax.Array:
    """(n_blocks, block_rows) decoded device blocks of one column, via the
    cache: encoded payload uploaded once, decoded blocks kept resident."""
    col = c.columns[name]
    if cache is None:
        return decode_jnp(col)

    def _decode():
        enc = cache.get_or_put(c.id, name, KIND_ENCODED,
                               lambda: upload_jnp(col), device_bytes)
        return decode_jnp(col, enc)

    return cache.get_or_put(c.id, name, KIND_DECODED, _decode, device_bytes)


def _valid_blocks_np(store, c: ROSContainer, as_of: int,
                     counts: np.ndarray) -> np.ndarray:
    """(n_blocks, block_rows) bool: inside n_rows, epoch-visible, not
    deleted as of the snapshot."""
    first = next(iter(c.columns.values()))
    nb, br = first.n_blocks, first.block_rows
    pos = np.arange(br)[None, :]
    valid = pos < counts[:, None]                     # inside n_rows
    dead = store.deleted_mask(c, as_of) | (c.epochs > as_of)
    if dead.any():
        flat = np.zeros(nb * br, bool)
        flat[np.flatnonzero(dead)] = True
        valid &= ~flat.reshape(nb, br)
    return valid


def _container_ceiling(store, c: ROSContainer) -> int:
    """Newest epoch affecting this container's visibility (commit epochs
    + its delete-vector epochs).  Visibility at any as-of >= ceiling
    equals visibility at the ceiling."""
    hi = c.max_epoch()
    for dv in store.delete_vectors.get(c.id, []):
        if len(dv.delete_epochs):
            hi = max(hi, int(dv.delete_epochs.max()))
    return hi


def cached_valid(cache: Optional[BlockCache], store, c: ROSContainer,
                 as_of: int, counts: np.ndarray) -> jax.Array:
    """Device copy of the container's visibility blocks at ``as_of``.
    Keyed by the *effective* epoch -- as-of clamped to the container's
    epoch ceiling -- so trickle-load commits that only touched the WOS
    (or other stores) keep every container's visibility entry warm; a
    commit or delete hitting THIS container moves its ceiling and misses
    naturally (a delete additionally invalidates the container's entries
    outright)."""
    eff = min(as_of, _container_ceiling(store, c))
    if cache is None:
        return jnp.asarray(_valid_blocks_np(store, c, eff, counts))
    return cache.get_or_put(
        c.id, f"@{eff}", KIND_VALID,
        lambda: jnp.asarray(_valid_blocks_np(store, c, eff, counts)),
        device_bytes)


# ---------------------------------------------------------------------------
# Batched scan over all containers of a plan
# ---------------------------------------------------------------------------

def scan_stores_batched(db: VerticaDB, plan, need: Sequence[str],
                        predicate: Optional[Expr], sip, as_of: int,
                        stats) -> Optional[ops.ScanResult]:
    """Gather the SMA-surviving blocks of every ROS container behind
    ``plan.sources`` straight from the device cache and concatenate them
    into one flat array per column.  Pruning decisions stay host-side
    (they read tiny SMA arrays); all row-level work happens in one device
    program downstream.  Returns None when everything was pruned."""
    need = sorted(set(need) | (predicate.columns() if predicate else set()))
    cache = getattr(db, "block_cache", None)
    col_parts: Dict[str, List[jax.Array]] = {name: [] for name in need}
    valid_parts: List[jax.Array] = []
    pruned = total = 0
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        for c in store.containers:
            if not need:
                continue
            first = c.columns[need[0]]
            nb = first.n_blocks
            total += nb
            # --- SMA block pruning (paper §3.5), host-side ---
            keep = np.ones(nb, dtype=bool)
            if predicate is not None:
                for colname, (lo, hi) in predicate.bounds().items():
                    if colname in c.smas:
                        keep &= c.smas[colname].prune_blocks(lo, hi)
            kept_idx = np.flatnonzero(keep)
            pruned += nb - kept_idx.size
            if kept_idx.size == 0:
                continue
            stats.containers_scanned += 1
            whole = kept_idx.size == nb
            for name in need:
                blocks = cached_decoded(cache, c, name)
                col_parts[name].append(blocks if whole
                                       else blocks[kept_idx])
            counts = c.smas[need[0]].counts
            vb = cached_valid(cache, store, c, as_of, counts)
            valid_parts.append(vb if whole else vb[kept_idx])
    stats.blocks_pruned, stats.blocks_total = pruned, total
    if not valid_parts:
        return None
    if len(valid_parts) == 1:
        cols = {n: p[0].reshape(-1) for n, p in col_parts.items()}
        valid = valid_parts[0].reshape(-1)
    else:
        cols = {n: jnp.concatenate(p).reshape(-1)
                for n, p in col_parts.items()}
        valid = jnp.concatenate(valid_parts).reshape(-1)
    if predicate is not None:
        valid = valid & jnp.asarray(predicate(cols), bool)
    if sip is not None:
        valid = valid & sip(cols)
    return ops.ScanResult({k: v for k, v in cols.items()}, valid,
                          pruned, total)


def wos_visible(store, as_of: int
                ) -> Optional[Tuple[Dict[str, np.ndarray], np.ndarray]]:
    """(rows, visibility mask) of a store's WOS at a snapshot epoch, or
    None when the WOS is empty: committed at-or-before ``as_of`` and not
    deleted by then.  THE single definition of WOS MVCC visibility for
    the execution paths -- the segmented and single-node pipelines must
    agree on exactly these rows."""
    data, eps, _ = store.wos.snapshot()
    if not len(eps):
        return None
    dels = (np.concatenate(store.wos_delete_epochs)
            if store.wos_delete_epochs
            else np.zeros(len(eps), np.int64))
    return data, (eps <= as_of) & ~((dels > 0) & (dels <= as_of))


def wos_scan_host(db: VerticaDB, plan, need: Sequence[str], as_of: int
                  ) -> Optional[Tuple[Dict[str, np.ndarray], np.ndarray,
                                      Optional[np.ndarray]]]:
    """(cols, visibility, ring-values-or-None) of every pending WOS row
    behind ``plan.sources``.  Ring values were stamped at commit
    (core/database._stage -> WOS.append), so the segmented executor can
    place trickle-loaded rows on their owning device shard without
    re-hashing; None means some batch was untagged (caller re-hashes)."""
    need = sorted(set(need))
    parts: List[Dict[str, np.ndarray]] = []
    valids: List[np.ndarray] = []
    rings: List[Optional[np.ndarray]] = []
    tagged = True
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        wos = wos_visible(store, as_of)
        if wos is None:
            continue
        data, vis = wos
        parts.append({c: np.asarray(data[c]) for c in need})
        valids.append(vis)
        r = store.wos.ring_snapshot()
        tagged &= r is not None
        rings.append(r)
    if not parts:
        return None
    cols = {c: np.concatenate([p[c] for p in parts]) for c in need}
    ring = np.concatenate(rings) if tagged else None
    return cols, np.concatenate(valids), ring


def snapshot_scan_device(db: VerticaDB, plan, need: Sequence[str],
                         as_of: int, stats
                         ) -> Optional[Tuple[Dict[str, jax.Array],
                                             np.ndarray]]:
    """Device-side ROS snapshot for the segmented slab build: the decoded
    blocks of every container behind ``plan.sources`` are concatenated
    into one flat DEVICE array per column -- the columns never round-trip
    through the host (engine/segmented.py hashes, partitions and
    resegments them with on-device twins).  Only the visibility mask
    comes back as numpy: it is computed from host-side delete bitmaps and
    epoch arrays anyway, and uploading one bool array is the cheap
    direction.  No SMA pruning here -- the slab caches ALL visible rows;
    per-query predicate pruning happens at slab-block granularity
    downstream."""
    need = sorted(set(need))
    cache = getattr(db, "block_cache", None)
    col_parts: Dict[str, List[jax.Array]] = {name: [] for name in need}
    valid_parts: List[np.ndarray] = []
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        for c in store.containers:
            if not need:
                continue
            stats.containers_scanned += 1
            for name in need:
                col_parts[name].append(cached_decoded(cache, c, name))
            counts = c.smas[need[0]].counts
            eff = min(as_of, _container_ceiling(store, c))
            valid_parts.append(_valid_blocks_np(store, c, eff, counts))
    if not valid_parts:
        return None
    if len(valid_parts) == 1:
        cols = {n: p[0].reshape(-1) for n, p in col_parts.items()}
    else:
        cols = {n: jnp.concatenate([b.reshape(-1) for b in p])
                for n, p in col_parts.items()}
    valid = np.concatenate([v.reshape(-1) for v in valid_parts])
    return cols, valid


def snapshot_scan_host(db: VerticaDB, plan, need: Sequence[str],
                       as_of: int, stats, *, include_wos: bool = True
                       ) -> Optional[Tuple[Dict[str, np.ndarray],
                                           np.ndarray]]:
    """Host-side snapshot of every row behind ``plan.sources`` (ROS via
    the device block cache, plus pending WOS rows unless
    ``include_wos=False`` -- the segmented executor slabs WOS rows
    separately so trickle loads don't invalidate its cached ROS slabs),
    as flat numpy arrays with a visibility mask.  Partitioning rows onto
    mesh shards is host work, so the columns come back as numpy, but the
    decode itself still runs through the cached device blocks."""
    need = sorted(set(need))
    ros = scan_stores_batched(db, plan, need, None, None, as_of, stats)
    parts: List[Dict[str, np.ndarray]] = []
    valids: List[np.ndarray] = []
    if ros is not None:
        parts.append({c: np.asarray(v) for c, v in ros.columns.items()})
        valids.append(np.asarray(ros.valid))
    if include_wos:
        wos = wos_scan_host(db, plan, need, as_of)
        if wos is not None:
            parts.append(wos[0])
            valids.append(wos[1])
    if not parts:
        return None
    cols = {c: np.concatenate([p[c] for p in parts]) for c in need}
    return cols, np.concatenate(valids)


# ---------------------------------------------------------------------------
# Fused scan -> joins -> predicate -> mask -> aggregate (one jitted program)
# ---------------------------------------------------------------------------

def _plan_signature(db: VerticaDB, q, plan, algo: str, domain: int,
                    domains: Tuple[int, ...], br: int) -> tuple:
    """The IR's canonical exec signature (device-program identity; HAVING/
    ORDER BY/LIMIT shape host-side and are excluded) plus the physical
    choices (projection, algorithm, static domain, per-key pack radices,
    block shape).  Two distinct logical programs therefore can never
    collide, and a repeated query shape always hits.  The radices must be
    part of the key: the closure bakes them into pack_keys, so SMA-domain
    growth after new commits has to miss."""
    return ("fused", plan.projection, q.exec_signature(), algo,
            int(domain), tuple(domains), br)


def build_join_sides(db: VerticaDB, q, as_of: int
                     ) -> List[Dict[str, jax.Array]]:
    """Build sides for the IR's join list: snapshot-read each dimension,
    apply its dim predicate, upload key + carried columns.  Shared by the
    fused and general pipelines, and kept device-resident in the block
    cache keyed by (dim table, join signature, snapshot epoch) -- MVCC
    makes a fixed-epoch read immutable, so a repeat join query skips the
    host decode + upload entirely (drop_partition, the one non-MVCC
    mutation, invalidates the table's entries)."""
    cache = getattr(db, "block_cache", None)
    builds = []
    for spec in q.joins:
        def make(spec=spec):
            dim_rows = db.read_table(spec.dim_table, as_of=as_of)
            if spec.dim_predicate is not None:
                m = np.asarray(spec.dim_predicate(dim_rows), bool)
                dim_rows = {c: v[m] for c, v in dim_rows.items()}
            return {c: jnp.asarray(dim_rows[c])
                    for c in (spec.dim_key,) + tuple(spec.dim_columns)}
        if cache is None:
            builds.append(make())
        else:
            # effective-epoch key: as-of clamped to the dim table's epoch
            # ceiling, so trickle loads into OTHER tables advance the
            # cluster epoch without evicting this build side
            eff = min(as_of, db.table_epoch_ceiling(spec.dim_table))
            builds.append(cache.get_or_put(
                f"dim:{spec.dim_table}", f"{spec.signature()}@{eff}",
                KIND_BUILD, make, device_bytes))
    return builds


def _build_fused(ir, predicate: Optional[Expr], algo: str,
                 domains: Tuple[int, ...], domain: int,
                 aggs: Tuple[Tuple[str, str, str], ...]) -> Callable:
    """One XLA program: hash joins (build sides passed as runtime pytree
    args), derived projections, predicate eval, composite-key packing,
    groupby/aggregate.  The expression trees and join chain are traced
    *inside* the jit so the whole pipeline fuses; groupby_dense/
    groupby_sort inline (nested jit) rather than launching separately."""

    values_cols = tuple(sorted({c for _, c, kind in aggs
                                if kind != "count" and c != "*"}))
    group_by = ir.group_by

    @jax.jit
    def fused(cols: Dict[str, jax.Array], valid: jax.Array,
              builds: Tuple[Dict[str, jax.Array], ...]):
        cols = dict(cols)
        for spec, build in zip(ir.joins, builds):
            cols, valid = ops.hash_join(build, spec.dim_key, cols,
                                        spec.fact_key, valid, how=spec.how)
        for name, e in ir.derived:
            cols[name] = e(cols)
        if predicate is not None:
            valid = valid & jnp.asarray(predicate(cols), bool)
        values = {c: cols[c] for c in values_cols}
        if not group_by:
            keys = jnp.zeros(valid.shape[0], jnp.int32)
            return ops.groupby_dense(keys, valid, values, 1, aggs)
        keys = ops.pack_keys([cols[g] for g in group_by], domains) \
            if len(group_by) > 1 else cols[group_by[0]]
        if algo == "dense":
            return ops.groupby_dense(keys.astype(jnp.int32), valid,
                                     values, domain, aggs)
        return ops.groupby_sort(keys, valid, values, domain, aggs)

    return fused


def _stores_have_wos(db: VerticaDB, plan) -> bool:
    return any(db.nodes[host].stores[owner].wos.n_rows
               for host, owner in plan.sources)


def fused_plan_params(q, plan, stats=None, key_domains=None
                      ) -> Optional[Tuple[str, int, Tuple[int, ...]]]:
    """Static groupby algorithm + domain selection for a jit-compiled
    program: dense/packing need per-key domains from container SMAs;
    unknown/oversized falls to sort for one key and to the cold path
    (runtime bounds) for composite keys.  Returns ``(algo, domain,
    domains)`` or None when the shape is outside the fused subset.
    Factored out so the dedicated fused path and the serving shared-scan
    path (engine/serving.py) make IDENTICAL choices -- the differential
    byte-identity guarantee leans on this.  ``key_domains`` overrides the
    plan's SMA-derived domains (the compressed-domain path groups dict
    columns on union codes, whose domain is the dictionary size)."""
    if not (q.aggs or q.group_by):
        return None
    if any(j.how != "inner" for j in q.joins):
        return None   # left-join NULL groups need runtime key bounds
    algo = plan.groupby_algorithm
    if algo == "rle":
        algo = "sort"
    domain, domains = 1, ()
    if q.group_by:
        doms = key_domains if key_domains is not None \
            else (plan.key_domains or (None,) * len(q.group_by))
        if len(q.group_by) == 1:
            dom = doms[0]
            if algo == "dense" and (dom is None
                                    or dom > plan.dense_domain_limit):
                algo = "sort"
                if stats is not None:
                    stats.groupby_algorithm = "sort (runtime switch)"
            domains = (int(dom),) if dom is not None else (0,)
            domain = int(dom) if algo == "dense" else plan.max_groups
        else:
            if any(d is None for d in doms):
                return None   # composite packing needs static bounds
            total = 1
            for d in doms:
                total *= int(d)
            if total >= 1 << 31:
                return None   # packed key would overflow device int32
            if algo == "dense" and total > plan.dense_domain_limit:
                algo = "sort"
                if stats is not None:
                    stats.groupby_algorithm = "sort (runtime switch)"
            domains = tuple(int(d) for d in doms)
            domain = total if algo == "dense" else plan.max_groups
    return algo, domain, domains


def _shape_fused_result(q, res, algo: str, domain: int,
                        domains: Tuple[int, ...], stats,
                        sigs: Tuple[tuple, ...] = ()
                        ) -> Optional[Dict[str, np.ndarray]]:
    """Host-side shaping of a fused program's output (small results);
    HAVING/ORDER/LIMIT are applied by pipeline._finalize, shared with the
    cold path.  A sort-cap overflow negative-caches every signature in
    ``sigs`` and returns None -- the caller falls back to the general
    pipeline (which lands on the exact host GroupBy)."""
    aggs = tuple(q.aggs)
    if not q.group_by:
        return {name: np.asarray(v)[:1] for name, v in res.items()}
    if algo == "dense":
        counts = np.asarray(res["group_count"])
        sel = counts > 0
        gkeys = np.flatnonzero(sel)
        out = {"group_count": counts[sel]}
        for name, _, _ in aggs:
            out[name] = np.asarray(res[name])[sel]
    else:
        n = int(res["n_groups"])
        if n > domain:
            # distinct groups exceed the sort cap: results would be
            # silently merged -- fall back to the general pipeline
            # (which lands on the host GroupBy) and remember the shape
            if len(_SORT_OVERFLOWED) > 512:
                _SORT_OVERFLOWED.clear()
            _SORT_OVERFLOWED.update(sigs)
            stats.plan_cache = ""
            return None
        gkeys = np.asarray(res["group_keys"])[:n]
        out = {"group_count": np.asarray(res["group_count"])[:n]}
        for name, _, _ in aggs:
            out[name] = np.asarray(res[name])[:n]
    if len(q.group_by) > 1:
        for g, kv in zip(q.group_by, ops.unpack_keys(gkeys, domains)):
            out[g] = kv
    else:
        out[q.group_by[0]] = gkeys
    return out


def execute_fused_deferred(db: VerticaDB, q, plan, as_of: int, stats
                           ) -> Optional[Tuple[Dict[str, jax.Array],
                                               Callable]]:
    """Futures-returning twin of :func:`execute_fused`: dispatch the
    cached fused program and return ``(device_result, finish)`` WITHOUT
    any host synchronization -- jax dispatch is async, so the caller
    (the serving layer's pipelined dispatch stage, engine/serving.py)
    can park the result and immediately dispatch the next query; device
    compute overlaps the host-side planning/admission of its successors.
    ``finish(host_result)`` does the host-side shaping on the
    already-materialized arrays (one batched transfer, done by the drain
    stage) and may return None on sort-cap overflow, in which case the
    caller falls back to the general pipeline exactly as ``execute``
    would.  Returns None when the shape is outside the fused subset."""
    if _stores_have_wos(db, plan):
        return None   # WOS rows need the unencoded side-scan
    proj = db.catalog.projections[plan.projection]
    need = sorted(q.scan_columns(proj))
    scan_pred = q.scan_predicate(proj.columns)

    # plan-time code-domain rewrite (engine/compressed.py): predicates on
    # dict columns become code ranges, group keys stay codes, payloads
    # late-materialize for survivors only
    from .compressed import plan_compressed_scan
    cplan = plan_compressed_scan(db, q, plan, need, scan_pred, as_of)
    params = fused_plan_params(q, plan, stats,
                               key_domains=cplan.key_domains(q, plan)
                               if cplan is not None else None)
    if params is None:
        return None
    algo, domain, domains = params

    br = db.block_rows
    sig = _plan_signature(db, q, plan, algo, domain, domains, br)
    if cplan is not None:
        sig = sig + cplan.sig_suffix
    if sig in _SORT_OVERFLOWED:
        return None   # known to exceed the sort cap: don't re-try

    if cplan is not None:
        scan = cplan.scan(db, scan_pred, None, stats)
        stats.compressed_scan = scan is not None
    else:
        scan = scan_stores_batched(db, plan, need, scan_pred, None, as_of,
                                   stats)
        if scan is not None:
            stats.rows_scanned = int(scan.valid.shape[0])
    if scan is None:
        return None   # fully pruned; pipeline builds the empty result

    # build sides host-side (small dims); the dim predicate filters here,
    # which is the SIP effect pushed all the way into the probe program
    builds = build_join_sides(db, q, as_of)
    if q.joins:
        stats.sip_applied = stats.sip_applied or plan.use_sip

    # the scan already masked a projection-covered predicate; only a
    # deferred one (join/derived columns) re-evaluates inside the program
    # (deterministic from pred+projection, both already in the signature)
    fused_pred = q.predicate if scan_pred is None else None
    fused, hit = PLAN_CACHE.get_or_build(
        sig, lambda: _build_fused(q, fused_pred, algo, domains, domain,
                                  tuple(q.aggs)))
    stats.plan_cache = "hit" if hit else "miss"
    res = fused(scan.columns, scan.valid, tuple(builds))

    def finish(host_res) -> Optional[Dict[str, np.ndarray]]:
        out = _shape_fused_result(q, host_res, algo, domain, domains,
                                  stats, sigs=(sig,))
        return cplan.translate(out) if cplan is not None else out

    return res, finish


def execute_fused(db: VerticaDB, q, plan, as_of: int,
                  stats) -> Optional[Dict[str, np.ndarray]]:
    """Run an aggregate query as one cached fused program, materializing
    the result immediately (the synchronous wrapper around
    :func:`execute_fused_deferred`).  Returns None when the query shape
    is outside the fused subset (WOS rows pending, no aggregation, or
    composite keys without static SMA domains) or on sort-cap overflow
    -- the caller falls back to the general pipeline."""
    d = execute_fused_deferred(db, q, plan, as_of, stats)
    if d is None:
        return None
    res, finish = d
    return finish(jax.device_get(res))


def execute_shared_fused_deferred(db: VerticaDB, q, plan,
                                  cols: Dict[str, jax.Array],
                                  valid: jax.Array, stats
                                  ) -> Optional[Tuple[Dict[str, jax.Array],
                                                      Callable]]:
    """Futures-returning per-query mask->aggregate stage of a serving
    shared scan (engine/serving.py): the coalesced batch's ONE unpruned
    scan is already device-resident; this dispatches the query's own
    predicate + groupby over it as a plan-cached jitted program and
    returns ``(device_result, finish)`` with no host sync -- the drain
    stage harvests every member of the group in one batched transfer.
    The predicate is evaluated INSIDE the program -- a shared scan cannot
    push any single query's predicate down -- so the cache key carries a
    ``"shared"`` prefix to keep these programs distinct from the
    dedicated fused path (same exec signature, different predicate
    placement).  Algorithm and domain choices come from the same
    ``fused_plan_params`` the dedicated path uses, which is what makes
    results byte-identical.  Returns None outside the fused subset;
    ``finish`` returns None on sort-cap overflow -- the caller falls back
    to the general (untraced) operators, exactly as pipeline does."""
    if q.joins:
        return None   # shared scans coalesce single-table queries only
    params = fused_plan_params(q, plan, stats)
    if params is None:
        return None
    algo, domain, domains = params
    base_sig = _plan_signature(db, q, plan, algo, domain, domains,
                               db.block_rows)
    sig = ("shared",) + base_sig[1:]
    if sig in _SORT_OVERFLOWED or base_sig in _SORT_OVERFLOWED:
        return None   # known to exceed the sort cap: don't re-try
    fused, hit = PLAN_CACHE.get_or_build(
        sig, lambda: _build_fused(q, q.predicate, algo, domains, domain,
                                  tuple(q.aggs)))
    stats.plan_cache = "hit" if hit else "miss"
    res = fused(cols, valid, ())

    def finish(host_res) -> Optional[Dict[str, np.ndarray]]:
        # overflow poisons BOTH signatures: the dedicated path would
        # overflow on the same data, so a later solo dispatch shouldn't
        # re-try either
        return _shape_fused_result(q, host_res, algo, domain, domains,
                                   stats, sigs=(sig, base_sig))

    return res, finish


def execute_shared_fused(db: VerticaDB, q, plan, cols: Dict[str, jax.Array],
                         valid: jax.Array, stats
                         ) -> Optional[Dict[str, np.ndarray]]:
    """Synchronous wrapper around
    :func:`execute_shared_fused_deferred` (kept for solo fallbacks and
    direct callers): dispatch, materialize, shape."""
    d = execute_shared_fused_deferred(db, q, plan, cols, valid, stats)
    if d is None:
        return None
    res, finish = d
    return finish(jax.device_get(res))
