"""Query pipeline: logical IR -> chosen plan -> execution (paper §6).

The front-end is the logical-plan IR (engine/logical.py): ``LogicalQuery``
carries scan/filter/a *list* of joins/derived projections/multi-column
group-by/HAVING/multi-key sort/limit.  The planner (planner/planner.py)
picks the projection, per-join strategy, SIP filters and the GroupBy
algorithm; this module runs the physical plan over a VerticaDB's live
nodes and returns numpy results.

Composite group-by keys are packed into one dense integer domain
(operators.pack_keys) so the single-key GroupBy machinery -- dense
scatter, sort-based, the fused plan-cached executor and the Pallas
kernels -- applies unchanged; keys unpack on the (small) output.

Runtime algorithm switching (§6.1): the GroupBy starts on the planner's
choice but falls back from dense-hash to sort-based when the observed key
domain exceeds the table budget -- the paper's hash->sort-merge switch --
and to a host-side unique-based GroupBy when even packed keys would
overflow the device integer width.

DEPRECATED SHIMS: ``Query`` and ``JoinSpec`` predate the IR (one join,
one group-by column).  They remain importable from ``repro.engine`` as
thin constructors that lower via ``Query.to_ir()``; new code should use
``db.query(...)`` (engine/builder.py) or LogicalQuery directly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.database import VerticaDB
from ..core.encodings import Encoding
from .expr import Col, Expr
from .logical import LogicalJoin, LogicalQuery, as_ir
from . import executor as fused_exec
from . import operators as ops
from .sip import sip_filter

# DEPRECATED back-compat alias: JoinSpec always matched the IR's join
# shape field-for-field, so the shim IS LogicalJoin.  New code should
# spell it ``LogicalJoin`` (engine/logical.py) or -- better -- use the
# fluent ``db.query(...).join(...)`` builder (engine/builder.py).
JoinSpec = LogicalJoin

_PACK_LIMIT = 1 << 31   # packed keys live in device int32 by default

_shim_warned = False


@dataclasses.dataclass(frozen=True, eq=False)
class Query:
    """DEPRECATED pre-IR front-end (single join, single group-by column),
    frozen at its PR-1 feature set.  Kept only as a thin shim for old
    call sites: ``to_ir()`` lowers to the ``LogicalQuery`` consumed
    everywhere, and ``execute``/``plan_query`` accept it transparently
    (emitting one ``DeprecationWarning`` per process).  New code should
    use the fluent builder -- ``db.query("t").where(...).join(...)
    .group_by(...).agg(...).collect()`` (engine/builder.py) -- or build
    ``LogicalQuery`` directly; both support multi-join, multi-column
    GROUP BY, derived columns, HAVING and multi-key ORDER BY, which this
    shim never will."""
    table: str
    columns: Tuple[str, ...] = ()
    predicate: Optional[Expr] = None
    join: Optional[LogicalJoin] = None
    group_by: Optional[str] = None
    aggs: Tuple[Tuple[str, str, str], ...] = ()   # (out, col, kind)
    order_by: Optional[str] = None
    descending: bool = False
    limit: Optional[int] = None

    def to_ir(self) -> LogicalQuery:
        global _shim_warned
        if not _shim_warned:
            _shim_warned = True
            import warnings
            warnings.warn(
                "repro.engine.Query is a deprecated shim; use "
                "db.query(...) (engine/builder.py) or LogicalQuery",
                DeprecationWarning, stacklevel=2)
        return LogicalQuery(
            table=self.table, columns=tuple(self.columns),
            predicate=self.predicate,
            joins=(self.join,) if self.join is not None else (),
            group_by=(self.group_by,) if self.group_by else (),
            aggs=tuple(self.aggs),
            order_by=((self.order_by, self.descending),)
            if self.order_by else (),
            limit=self.limit).validate()

    def needed_columns(self) -> set:
        return self.to_ir().needed_columns()


@dataclasses.dataclass
class ExecStats:
    projection: str = ""
    groupby_algorithm: str = ""
    join_strategy: str = ""
    containers_scanned: int = 0
    blocks_pruned: int = 0
    blocks_total: int = 0
    rows_scanned: int = 0
    sip_applied: bool = False
    wall_s: float = 0.0
    frontend_s: float = 0.0         # lowering + planning time
    # warm-path telemetry (engine/executor.py)
    fused: bool = False
    plan_cache: str = ""            # "hit" / "miss" / "" (not attempted)
    block_cache_hits: int = 0
    block_cache_misses: int = 0
    # compressed-domain execution telemetry (engine/compressed.py)
    compressed_scan: bool = False   # code-domain scan + late materialization
    rows_materialized: int = 0      # survivor rows actually decoded
    # per-stage wall times of the segmented path (engine/segmented.py):
    # slab_build / exchange_join / preagg / final_merge, in milliseconds
    stage_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    # segmented-execution telemetry (engine/segmented.py)
    segmented: bool = False
    n_shards: int = 0
    exchange: str = ""              # ";"-joined per-join exchange ops
    reseg_overflow: int = 0         # tuples that hit a full exchange slot
    seg_slab: str = ""              # ROS slab "hit"/"miss", "+wos" when a
    #                                 trickle-load delta slab was appended
    snapshot_epoch: int = 0         # pinned cluster snapshot this query read
    # fault/failover telemetry (core/faults.py): failovers = mid-query
    # node crashes absorbed by replanning onto buddies at the pinned
    # epoch; fault_retries = transient-fault attempt retries; injected =
    # fault actions fired while this query ran
    failovers: int = 0
    fault_retries: int = 0
    faults_injected: int = 0


def execute(db: VerticaDB, q, *, as_of: Optional[int] = None,
            plan=None, mesh=None,
            mesh_axis: str = "data"
            ) -> Tuple[Dict[str, np.ndarray], ExecStats]:
    """Run a logical plan (LogicalQuery, node tree, builder, or the legacy
    Query shim).  ``plan`` (from planner.plan_query) may be supplied;
    otherwise the planner is invoked.

    When a ``mesh`` is passed -- or the database has one attached
    (``db.attach_mesh()``) -- aggregate queries route through the
    segmented multi-device executor (engine/segmented.py) and fall back
    here for shapes outside its subset."""
    from ..planner.planner import plan_query

    t0 = time.time()
    q = as_ir(q)
    if plan is None:
        plan = plan_query(db, q)
    if mesh is None:
        mesh = getattr(db, "mesh", None)
        mesh_axis = getattr(db, "mesh_axis", mesh_axis)
    frontend_s = time.time() - t0
    from ..core.database import QueryRejectedError
    from ..core.faults import NodeCrashError, TransientFaultError

    stats = ExecStats(projection=plan.projection,
                      groupby_algorithm=plan.groupby_algorithm,
                      join_strategy=plan.join_strategy,
                      frontend_s=frontend_s)
    faults = getattr(db, "faults", None)
    f0 = faults.total_fired if faults is not None else 0
    # pin the cluster snapshot epoch for the query's lifetime (§5):
    # trickle-load commits advancing the epoch concurrently cannot shift
    # what this query sees, and the AHM cannot purge the history it
    # reads.  EVERYTHING past the pin -- including failover replans --
    # runs inside the try so no failure path can leak a pin and freeze
    # the AHM forever.
    as_of = db.epochs.pin(as_of)
    try:
        stats.snapshot_epoch = as_of
        bc = db.block_cache.stats
        bc_h0, bc_m0 = bc.hits, bc.misses

        def _finish(out, *, final: bool = True):
            if final:
                out = _finalize(q, out)
            stats.block_cache_hits = bc.hits - bc_h0
            stats.block_cache_misses = bc.misses - bc_m0
            if faults is not None:
                stats.faults_injected = faults.total_fired - f0
            stats.wall_s = time.time() - t0
            return out, stats

        retries_left = int(getattr(db, "max_failover_retries", 2))
        while True:
            try:
                return _execute_attempt(db, q, plan, as_of, mesh,
                                        mesh_axis, stats, _finish)
            except NodeCrashError as e:
                # mid-query node failure: bounded query-level failover.
                # Replan at the SAME pinned epoch -- the planner routes
                # the dead node's segments to buddies (identical rows at
                # as_of, §4.3), so the retried query reads the identical
                # snapshot; exhausted redundancy surfaces the planner's
                # SegmentUnavailableError instead.
                stats.failovers += 1
                if retries_left <= 0:
                    raise QueryRejectedError(
                        f"failover budget exhausted (node {e.node} "
                        f"crashed at {e.point})",
                        epoch=as_of, attempts=stats.failovers) from e
                retries_left -= 1
                plan = plan_query(db, q)
                stats.projection = plan.projection
                stats.groupby_algorithm = plan.groupby_algorithm
                stats.join_strategy = plan.join_strategy
            except TransientFaultError as e:
                # per-point retry budgets already ran (faults.with_retries)
                raise QueryRejectedError(
                    f"transient retry budget exhausted: {e}",
                    epoch=as_of, attempts=stats.failovers) from e
    finally:
        db.epochs.unpin(as_of)


def _execute_attempt(db: VerticaDB, q: LogicalQuery, plan, as_of: int,
                     mesh, mesh_axis: str, stats: ExecStats, _finish):
    """One execution attempt of a pinned-epoch query (the body of
    ``execute``'s failover retry loop)."""
    try:
        # --- segmented multi-device path (explicit opt-in via mesh) ---
        if mesh is not None:
            from . import segmented
            res = segmented.execute_segmented(db, q, plan, as_of, mesh,
                                              mesh_axis, stats)
            if res is not None:
                return _finish(res)

        # --- scalar COUNT directly on RLE runs (predicate on sort leader) ---
        if plan.scalar_rle:
            res = _rle_scalar_count(db, q, plan, as_of)
            if res is not None:
                stats.groupby_algorithm = "rle-scalar"
                return _finish(res)

        # --- RLE-direct fast path: aggregate on encoded data, zero decode ---
        if rle_direct_eligible(q, plan):
            res = _rle_groupby(db, q, plan, as_of)
            if res is not None:
                return _finish(res)
            stats.groupby_algorithm = "sort (rle fallback)"
            plan = dataclasses.replace(plan, groupby_algorithm="sort")

        # --- warm path: cached fused scan->join->predicate->aggregate ---
        res = fused_exec.execute_fused(db, q, plan, as_of, stats)
        if res is not None:
            stats.fused = True
            return _finish(res)

        # --- build sides + SIP (§6.1), one per join in plan order ---
        builds = fused_exec.build_join_sides(db, q, as_of)
        sips: List[Callable] = []
        for ji, spec in enumerate(q.joins):
            if plan.sip_joins and plan.sip_joins[ji]:
                sips.append(sip_filter(builds[ji][spec.dim_key],
                                       spec.fact_key))
                stats.sip_applied = True
        sip = _combine_sips(sips)

        # --- scan (SMA pruning + predicate + SIP pushed down) ---
        proj = db.catalog.projections[plan.projection]
        need = q.scan_columns(proj)
        # predicates over join outputs / derived columns defer past the scan
        scan_pred = q.scan_predicate(proj.columns)
        scans = []
        # ROS containers: one batched device-cached scan over every source
        # (engine/executor.py), replacing the per-container Python loop
        ros = fused_exec.scan_stores_batched(db, plan, sorted(need),
                                             scan_pred, sip, as_of, stats)
        if ros is not None:
            scans.append(ros)
        scans.extend(wos_scan_results(db, plan, need, scan_pred, sip,
                                      as_of))
        merged = ops.concat_scans(scans)
        if merged is None:
            return _finish(_empty_result(q))
        stats.blocks_pruned = merged.pruned_blocks
        stats.blocks_total = merged.total_blocks
        cols, valid = dict(merged.columns), merged.valid
        stats.rows_scanned = int(cols[next(iter(cols))].shape[0])

        # --- joins (in plan order; later probes may use earlier outputs) ---
        for spec, build in zip(q.joins, builds):
            cols, valid = ops.hash_join(build, spec.dim_key, cols,
                                        spec.fact_key, valid, how=spec.how)

        # --- derived projections, then any deferred predicate ---
        for name, e in q.derived:
            cols[name] = e(cols)
        if scan_pred is None and q.predicate is not None:
            valid = valid & jnp.asarray(q.predicate(cols), bool)

        # --- groupby / aggregate / plain select ---
        if q.group_by or q.aggs:
            out = _run_groupby(q, plan, cols, valid, stats)
        else:
            mask = np.asarray(valid)
            keep = set(q.columns) | {n for n, _ in q.derived}
            out = {c: np.asarray(v)[mask] for c, v in cols.items()
                   if (c in keep) or (not keep and c != "_matched")}
        return _finish(out)
    finally:
        # per-attempt bookkeeping only; the epoch pin is released by
        # ``execute`` (one pin covers every failover attempt, so the
        # retried query replans at the identical snapshot)
        pass


def wos_scan_results(db: VerticaDB, plan, need, scan_pred, sip,
                     as_of: int) -> List[ops.ScanResult]:
    """Unencoded side-scans of every pending WOS behind ``plan.sources``
    (rows the tuple mover hasn't drained yet participate in queries
    immediately).  Shared by the single-query pipeline and the serving
    shared-scan path (engine/serving.py) so trickle-loaded rows are
    byte-identically visible to both."""
    scans: List[ops.ScanResult] = []
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        wos = fused_exec.wos_visible(store, as_of)
        if wos is not None:
            data, vis = wos
            cols = {c: jnp.asarray(data[c]) for c in need}
            valid = jnp.asarray(vis)
            if scan_pred is not None:
                valid = valid & jnp.asarray(scan_pred(cols), bool)
            if sip is not None:
                valid = valid & sip(cols)
            scans.append(ops.ScanResult(cols, valid))
    return scans


# ---------------------------------------------------------------------------
# result shaping shared by every path (incl. the fused executor)
# ---------------------------------------------------------------------------

def _finalize(q: LogicalQuery, out: Dict[str, np.ndarray]
              ) -> Dict[str, np.ndarray]:
    """HAVING -> ORDER BY (multi-key, per-key direction) -> LIMIT, on the
    (small) host-side result."""
    if q.having is not None and out:
        n = len(next(iter(out.values())))
        if n:
            m = np.asarray(q.having(out), bool)
            out = {c: np.asarray(v)[m] for c, v in out.items()}
    if q.order_by and out:
        n = len(next(iter(out.values())))
        if n:
            keys = []
            for c, desc in reversed(q.order_by):
                k = np.asarray(out[c])
                if desc:
                    # descending without precision loss: bit-complement
                    # for ints/bools (= -k-1, never overflows), negate
                    # floats
                    k = ~k if k.dtype.kind in "bui" else -k
                keys.append(k)
            order = np.lexsort(keys)       # last key = primary
            out = {c: np.asarray(v)[order] for c, v in out.items()}
    if q.limit is not None:
        out = {c: v[: q.limit] for c, v in out.items()}
    return out


def _empty_result(q: LogicalQuery) -> Dict[str, np.ndarray]:
    """Structured empty output for a fully pruned / empty scan (same key
    set as the non-empty path)."""
    out = {c: np.zeros(0, np.int64) for c in q.columns}
    for name, _ in q.derived:
        out[name] = np.zeros(0)
    for g in q.group_by:
        out[g] = np.zeros(0, np.int64)
    if q.group_by:
        out["group_count"] = np.zeros(0, np.int64)
    for name, _, kind in q.aggs:
        out[name] = np.zeros(1) if not q.group_by else np.zeros(0)
    return out


def _combine_sips(sips: List[Callable]) -> Optional[Callable]:
    if not sips:
        return None
    if len(sips) == 1:
        return sips[0]

    def apply(cols):
        m = sips[0](cols)
        for s in sips[1:]:
            m = m & s(cols)
        return m

    return apply


# ---------------------------------------------------------------------------
# RLE-direct paths (single-column group keys on encoded data)
# ---------------------------------------------------------------------------

def rle_direct_eligible(q: LogicalQuery, plan) -> bool:
    """Shape test for the RLE-direct GroupBy route, shared by the
    single-node dispatch below and the segmented executor (which routes
    the same queries per node and merges, instead of slabbing 2M decoded
    rows across the mesh only to count runs it already had encoded)."""
    return plan.groupby_algorithm == "rle" and not q.joins \
        and q.predicate is None


def _rle_scalar_count(db: VerticaDB, q: LogicalQuery, plan, as_of: int
                      ) -> Optional[Dict[str, np.ndarray]]:
    """COUNT(*) with a range predicate on the RLE-encoded sort leader:
    sum run lengths whose value passes -- O(runs), no decode (§6.1; the
    Pallas twin is kernels/rle_scan_agg.py)."""
    from .expr import exact_int_interval

    proj = db.catalog.projections[plan.projection]
    leader = proj.sort_order[0]
    if q.predicate is not None:
        iv = exact_int_interval(q.predicate)
        if iv is None or iv[0] != leader:
            return None
        _, lo, hi = iv
    else:
        lo = hi = None
    lo = -np.inf if lo is None else lo
    hi = np.inf if hi is None else hi
    total = 0
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        if store.wos.n_rows:
            return None
        for c in store.containers:
            if store.delete_vectors.get(c.id) or (c.epochs > as_of).any():
                return None
            colenc = c.columns[leader]
            if colenc.encoding != Encoding.RLE:
                return None
            rv = colenc.arrays["run_values"].reshape(-1)
            rl = colenc.arrays["run_lengths"].reshape(-1)
            m = (rv >= lo) & (rv <= hi) & (rl > 0)
            cnt = int(rl[m].sum())
            pad = colenc.n_blocks * colenc.block_rows - c.n_rows
            if pad and c.n_rows:
                last = rv[np.flatnonzero(rl)[-1]]
                if lo <= last <= hi:
                    cnt -= pad
            total += cnt
    out = {}
    for name, _, _ in q.aggs:
        out[name] = np.asarray([total])
    return out


def _rle_groupby(db: VerticaDB, q: LogicalQuery, plan, as_of: int
                 ) -> Optional[Dict[str, np.ndarray]]:
    """COUNT GROUP BY key straight off RLE runs (§6.1 'operate directly on
    encoded data'). Requires no pending deletes and fully-committed
    containers; otherwise returns None and the caller decodes."""
    from ..planner.planner import _domain_estimate

    group = q.group_by[0]
    proj = db.catalog.projections[plan.projection]
    dom = _domain_estimate(db, proj, group)
    if dom is None or dom > plan.dense_domain_limit:
        return None
    total = np.zeros(dom, np.int64)
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        if store.wos.n_rows:
            return None
        for c in store.containers:
            if store.delete_vectors.get(c.id) or (c.epochs > as_of).any():
                return None
            if c.columns[group].encoding != Encoding.RLE:
                return None
            counts = ops.groupby_rle(c.columns[group],
                                     c.smas[group].counts, dom)
            # subtract tail-block padding (pad value = last value)
            total += np.asarray(counts["group_count"])
            pad = c.columns[group].n_blocks * \
                c.columns[group].block_rows - c.n_rows
            if pad and c.n_rows:
                last = int(c.decode_column(group)[-1])
                total[last] -= pad
    sel = total > 0
    out = {group: np.flatnonzero(sel), "group_count": total[sel]}
    for name, _, kind in q.aggs:
        if kind == "count":
            out[name] = total[sel]
    return out


# ---------------------------------------------------------------------------
# generic GroupBy over (possibly composite) keys
# ---------------------------------------------------------------------------

def _run_groupby(q: LogicalQuery, plan, cols, valid, stats
                 ) -> Dict[str, np.ndarray]:
    aggs = tuple(q.aggs)
    values = {c: cols[c] for _, c, kind in aggs
              if kind != "count" and c != "*"}
    if not q.group_by:
        # scalar aggregate: single group
        keys = jnp.zeros(valid.shape[0], jnp.int32)
        res = ops.groupby_dense(keys, valid, values, 1, aggs)
        return {name: np.asarray(v)[:1] for name, v in res.items()}

    if not bool(valid.any()):
        out = {g: np.zeros(0, np.int64) for g in q.group_by}
        out["group_count"] = np.zeros(0, np.int64)
        for name, _, _ in aggs:
            out[name] = np.zeros(0)
        return out

    algo = plan.groupby_algorithm
    if algo == "rle":
        algo = "sort"

    key_cols = [cols[g] for g in q.group_by]
    packed, lows, domains = key_cols[0], None, None
    if len(key_cols) > 1 or algo == "dense":
        # observed per-key bounds for packing / the dense domain (tighter
        # than SMA estimates; one host sync each -- this is the cold
        # path).  A single-key sort GroupBy needs none of this.
        lows, domains = [], []
        for k in key_cols:
            big = int(jnp.iinfo(k.dtype).max) if k.dtype.kind == "i" \
                else 2**30
            lo = int(jnp.where(valid, k, big).min())
            hi = int(jnp.where(valid, k, -big).max())
            lows.append(min(lo, 0))
            domains.append(hi - lows[-1] + 1)
        total = 1
        for d in domains:
            total *= d
        if total >= _PACK_LIMIT:
            # packed keys would overflow device int32: host fallback
            stats.groupby_algorithm = "host-unique (domain overflow)"
            return _groupby_host(q, cols, valid, values, aggs)
        if algo == "dense" and total > plan.dense_domain_limit:
            algo = "sort"   # runtime switch (§6.1)
            stats.groupby_algorithm = "sort (runtime switch)"
        if len(key_cols) > 1 or lows[0] != 0:
            packed = ops.pack_keys(key_cols, domains, lows)
        else:
            lows = domains = None    # raw single key: no unpack needed

    if algo == "dense":
        res = ops.groupby_dense(packed.astype(jnp.int32), valid, values,
                                total, aggs)
        counts = np.asarray(res["group_count"])
        sel = counts > 0
        gkeys = np.flatnonzero(sel)
        out = {"group_count": counts[sel]}
        for name, _, _ in aggs:
            out[name] = np.asarray(res[name])[sel]
    else:
        res = ops.groupby_sort(packed, valid, values, plan.max_groups, aggs)
        n = int(res["n_groups"])
        if n > plan.max_groups:
            # more distinct groups than the sort cap: groupby_sort would
            # silently merge the tail -- host fallback keeps it exact
            stats.groupby_algorithm = "host-unique (group overflow)"
            return _groupby_host(q, cols, valid, values, aggs)
        gkeys = np.asarray(res["group_keys"])[:n]
        out = {"group_count": np.asarray(res["group_count"])[:n]}
        for name, _, _ in aggs:
            out[name] = np.asarray(res[name])[:n]
    unpacked = [gkeys] if domains is None \
        else ops.unpack_keys(gkeys, domains, lows)
    for g, kv in zip(q.group_by, unpacked):
        out[g] = kv
    return out


def _groupby_host(q: LogicalQuery, cols, valid, values, aggs
                  ) -> Dict[str, np.ndarray]:
    """numpy unique-based GroupBy for key domains too wide to pack into
    the device integer width.  Small-result assumption holds (grouped
    outputs are aggregated), only the scan stays device-side."""
    mask = np.asarray(valid)
    keys2d = np.stack([np.asarray(cols[g])[mask] for g in q.group_by], 1)
    uniq, inv = np.unique(keys2d, axis=0, return_inverse=True)
    n_groups = len(uniq)
    counts = np.bincount(inv, minlength=n_groups)
    out = {g: uniq[:, i] for i, g in enumerate(q.group_by)}
    out["group_count"] = counts
    for name, c, kind in aggs:
        if kind == "count":
            out[name] = counts
            continue
        v = np.asarray(values[c])[mask]
        if kind in ("sum", "avg"):
            acc = np.bincount(inv, weights=v, minlength=n_groups)
            out[name] = acc / np.maximum(counts, 1) if kind == "avg" \
                else acc
        elif kind == "min":
            acc = np.full(n_groups, np.inf)
            np.minimum.at(acc, inv, v)
            out[name] = acc
        else:
            acc = np.full(n_groups, -np.inf)
            np.maximum.at(acc, inv, v)
            out[name] = acc
    return out
