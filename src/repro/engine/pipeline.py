"""Query pipeline: logical query -> chosen plan -> execution (paper §6).

A Query is the logical algebra (scan/filter/join/groupby/sort/limit); the
planner (planner/planner.py) picks the projection, join strategy, SIP
filters and GroupBy algorithm; this module runs the physical plan over a
VerticaDB's live nodes and returns numpy results.

Runtime algorithm switching (§6.1): the GroupBy starts on the planner's
choice but falls back from dense-hash to sort-based when the observed key
domain exceeds the table budget -- the paper's hash->sort-merge switch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.database import VerticaDB
from ..core.encodings import Encoding
from .expr import Col, Expr
from . import executor as fused_exec
from . import operators as ops
from .sip import sip_filter


@dataclasses.dataclass(frozen=True)
class JoinSpec:
    dim_table: str
    fact_key: str
    dim_key: str
    dim_columns: Tuple[str, ...] = ()
    dim_predicate: Optional[Expr] = None
    how: str = "inner"


@dataclasses.dataclass(frozen=True)
class Query:
    table: str
    columns: Tuple[str, ...] = ()
    predicate: Optional[Expr] = None
    join: Optional[JoinSpec] = None
    group_by: Optional[str] = None
    aggs: Tuple[Tuple[str, str, str], ...] = ()   # (out, col, kind)
    order_by: Optional[str] = None
    descending: bool = False
    limit: Optional[int] = None

    def needed_columns(self) -> set:
        need = set(self.columns)
        if self.predicate is not None:
            need |= self.predicate.columns()
        if self.group_by:
            need.add(self.group_by)
        for _, c, kind in self.aggs:
            if kind != "count":
                need.add(c)
        if self.join:
            need.add(self.join.fact_key)
        if self.order_by and self.order_by not in {a[0] for a in self.aggs}:
            need.add(self.order_by)
        return need


@dataclasses.dataclass
class ExecStats:
    projection: str = ""
    groupby_algorithm: str = ""
    join_strategy: str = ""
    containers_scanned: int = 0
    blocks_pruned: int = 0
    blocks_total: int = 0
    rows_scanned: int = 0
    sip_applied: bool = False
    wall_s: float = 0.0
    # warm-path telemetry (engine/executor.py)
    fused: bool = False
    plan_cache: str = ""            # "hit" / "miss" / "" (not attempted)
    block_cache_hits: int = 0
    block_cache_misses: int = 0


def execute(db: VerticaDB, q: Query, *, as_of: Optional[int] = None,
            plan=None) -> Tuple[Dict[str, np.ndarray], ExecStats]:
    """Run a query. ``plan`` (from planner.plan_query) may be supplied;
    otherwise the planner is invoked."""
    from ..planner.planner import plan_query

    t0 = time.time()
    plan = plan or plan_query(db, q)
    stats = ExecStats(projection=plan.projection,
                      groupby_algorithm=plan.groupby_algorithm,
                      join_strategy=plan.join_strategy)
    as_of = as_of if as_of is not None else db.epochs.latest_queryable()
    bc = db.block_cache.stats
    bc_h0, bc_m0 = bc.hits, bc.misses

    def _finish(out):
        stats.block_cache_hits = bc.hits - bc_h0
        stats.block_cache_misses = bc.misses - bc_m0
        stats.wall_s = time.time() - t0
        return out, stats

    # --- scalar COUNT directly on RLE runs (predicate on sort leader) ---
    if plan.scalar_rle:
        res = _rle_scalar_count(db, q, plan, as_of)
        if res is not None:
            stats.groupby_algorithm = "rle-scalar"
            return _finish(res)

    # --- RLE-direct fast path: aggregate on encoded data, zero decode ---
    if plan.groupby_algorithm == "rle" and q.join is None \
            and q.predicate is None:
        res = _rle_groupby(db, q, plan, as_of)
        if res is not None:
            return _finish(res)
        stats.groupby_algorithm = "sort (rle fallback)"
        plan = dataclasses.replace(plan, groupby_algorithm="sort")

    # --- warm path: cached fused scan->predicate->aggregate program ---
    res = fused_exec.execute_fused(db, q, plan, as_of, stats)
    if res is not None:
        stats.fused = True
        return _finish(res)

    # --- build side + SIP (§6.1) ---
    sip = None
    build = None
    if q.join is not None:
        dim_rows = db.read_table(q.join.dim_table, as_of=as_of)
        if q.join.dim_predicate is not None:
            m = np.asarray(q.join.dim_predicate(dim_rows), bool)
            dim_rows = {c: v[m] for c, v in dim_rows.items()}
        build = {c: jnp.asarray(dim_rows[c])
                 for c in (q.join.dim_key,) + tuple(q.join.dim_columns)}
        if plan.use_sip:
            sip = sip_filter(build[q.join.dim_key], q.join.fact_key)
            stats.sip_applied = True

    # --- scan (SMA pruning + predicate + SIP pushed down) ---
    need = q.needed_columns() | ({q.join.fact_key} if q.join else set())
    proj = db.catalog.projections[plan.projection]
    need &= set(proj.columns)
    scans = []
    # ROS containers: one batched device-cached scan over every source
    # (engine/executor.py), replacing the per-container Python loop
    ros = fused_exec.scan_stores_batched(db, plan, sorted(need),
                                         q.predicate, sip, as_of, stats)
    if ros is not None:
        scans.append(ros)
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        # WOS rows participate too (unencoded scan)
        data, eps, _ = store.wos.snapshot()
        if len(eps):
            dels = (np.concatenate(store.wos_delete_epochs)
                    if store.wos_delete_epochs
                    else np.zeros(len(eps), np.int64))
            vis = (eps <= as_of) & ~((dels > 0) & (dels <= as_of))
            cols = {c: jnp.asarray(data[c]) for c in need}
            valid = jnp.asarray(vis)
            if q.predicate is not None:
                valid = valid & jnp.asarray(q.predicate(cols), bool)
            if sip is not None:
                valid = valid & sip(cols)
            scans.append(ops.ScanResult(cols, valid))
    merged = ops.concat_scans(scans)
    if merged is None:
        # fully pruned / empty: return a structured empty result
        out = {c: np.zeros(0, np.int64) for c in q.columns}
        if q.group_by:
            out[q.group_by] = np.zeros(0, np.int64)
            out["group_count"] = np.zeros(0, np.int64)
        for name, _, kind in q.aggs:
            out[name] = (np.zeros(1) if q.group_by is None
                         else np.zeros(0))
        return _finish(out)
    stats.blocks_pruned = merged.pruned_blocks
    stats.blocks_total = merged.total_blocks
    cols, valid = dict(merged.columns), merged.valid
    stats.rows_scanned = int(cols[next(iter(cols))].shape[0])

    # --- join ---
    if q.join is not None:
        cols, valid = ops.hash_join(build, q.join.dim_key, cols,
                                    q.join.fact_key, valid, how=q.join.how)

    # --- groupby / aggregate ---
    if q.group_by is not None or q.aggs:
        out = _run_groupby(q, plan, cols, valid, stats)
    else:
        mask = np.asarray(valid)
        out = {c: np.asarray(v)[mask] for c, v in cols.items()
               if c in q.columns or not q.columns}
        if q.order_by:
            order = np.argsort(out[q.order_by])
            if q.descending:
                order = order[::-1]
            out = {c: v[order] for c, v in out.items()}
        if q.limit:
            out = {c: v[: q.limit] for c, v in out.items()}
    return _finish(out)


def _rle_scalar_count(db: VerticaDB, q: Query, plan, as_of: int
                      ) -> Optional[Dict[str, np.ndarray]]:
    """COUNT(*) with a range predicate on the RLE-encoded sort leader:
    sum run lengths whose value passes -- O(runs), no decode (§6.1; the
    Pallas twin is kernels/rle_scan_agg.py)."""
    from .expr import exact_int_interval

    proj = db.catalog.projections[plan.projection]
    leader = proj.sort_order[0]
    if q.predicate is not None:
        iv = exact_int_interval(q.predicate)
        if iv is None or iv[0] != leader:
            return None
        _, lo, hi = iv
    else:
        lo = hi = None
    lo = -np.inf if lo is None else lo
    hi = np.inf if hi is None else hi
    total = 0
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        if store.wos.n_rows:
            return None
        for c in store.containers:
            if store.delete_vectors.get(c.id) or (c.epochs > as_of).any():
                return None
            colenc = c.columns[leader]
            if colenc.encoding != Encoding.RLE:
                return None
            rv = colenc.arrays["run_values"].reshape(-1)
            rl = colenc.arrays["run_lengths"].reshape(-1)
            m = (rv >= lo) & (rv <= hi) & (rl > 0)
            cnt = int(rl[m].sum())
            pad = colenc.n_blocks * colenc.block_rows - c.n_rows
            if pad and c.n_rows:
                last = rv[np.flatnonzero(rl)[-1]]
                if lo <= last <= hi:
                    cnt -= pad
            total += cnt
    out = {}
    for name, _, _ in q.aggs:
        out[name] = np.asarray([total])
    return out


def _rle_groupby(db: VerticaDB, q: Query, plan, as_of: int
                 ) -> Optional[Dict[str, np.ndarray]]:
    """COUNT GROUP BY key straight off RLE runs (§6.1 'operate directly on
    encoded data'). Requires no pending deletes and fully-committed
    containers; otherwise returns None and the caller decodes."""
    from ..planner.planner import _domain_estimate

    proj = db.catalog.projections[plan.projection]
    dom = _domain_estimate(db, proj, q.group_by)
    if dom is None or dom > plan.dense_domain_limit:
        return None
    total = np.zeros(dom, np.int64)
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        if store.wos.n_rows:
            return None
        for c in store.containers:
            if store.delete_vectors.get(c.id) or (c.epochs > as_of).any():
                return None
            if c.columns[q.group_by].encoding != Encoding.RLE:
                return None
            counts = ops.groupby_rle(c.columns[q.group_by],
                                     c.smas[q.group_by].counts, dom)
            # subtract tail-block padding (pad value = last value)
            total += np.asarray(counts["group_count"])
            pad = c.columns[q.group_by].n_blocks * \
                c.columns[q.group_by].block_rows - c.n_rows
            if pad and c.n_rows:
                last = int(c.decode_column(q.group_by)[-1])
                total[last] -= pad
    sel = total > 0
    out = {q.group_by: np.flatnonzero(sel), "group_count": total[sel]}
    for name, _, kind in q.aggs:
        if kind == "count":
            out[name] = total[sel]
    return out


def _run_groupby(q: Query, plan, cols, valid, stats) -> Dict[str, np.ndarray]:
    aggs = tuple(q.aggs)
    values = {c: cols[c] for _, c, kind in aggs if kind != "count"
              for c in [c]}
    if q.group_by is None:
        # scalar aggregate: single group
        keys = jnp.zeros(valid.shape[0], jnp.int32)
        res = ops.groupby_dense(keys, valid, values, 1, aggs)
        return {name: np.asarray(v)[:1] for name, v in res.items()}

    keys = cols[q.group_by]
    algo = plan.groupby_algorithm
    if algo == "rle":
        algo = "sort"
    if not bool(valid.any()):
        out = {q.group_by: np.zeros(0, np.int64),
               "group_count": np.zeros(0, np.int64)}
        for name, _, _ in aggs:
            out[name] = np.zeros(0)
        return out
    if algo == "dense":
        big = int(jnp.iinfo(keys.dtype).max) if keys.dtype.kind == "i" \
            else 2**30
        kmin = int(jnp.where(valid, keys, big).min()) if valid.shape[0] \
            else 0
        kmax = int(jnp.where(valid, keys, -big).max()) if valid.shape[0] \
            else 0
        domain = kmax - min(kmin, 0) + 1
        if domain > plan.dense_domain_limit:
            algo = "sort"   # runtime switch (§6.1)
            stats.groupby_algorithm = "sort (runtime switch)"
    if algo == "dense":
        res = ops.groupby_dense(keys.astype(jnp.int32), valid, values,
                                int(domain), aggs)
        counts = np.asarray(res["group_count"])
        sel = counts > 0
        out = {q.group_by: np.flatnonzero(sel),
               "group_count": counts[sel]}
        for name, _, _ in aggs:
            out[name] = np.asarray(res[name])[sel]
    else:
        res = ops.groupby_sort(keys, valid, values, plan.max_groups, aggs)
        n = int(res["n_groups"])
        out = {q.group_by: np.asarray(res["group_keys"])[:n],
               "group_count": np.asarray(res["group_count"])[:n]}
        for name, _, _ in aggs:
            out[name] = np.asarray(res[name])[:n]
    if q.order_by:
        key = out.get(q.order_by, out.get(q.group_by))
        order = np.argsort(key)
        if q.descending:
            order = order[::-1]
        out = {c: v[order] for c, v in out.items()}
    if q.limit:
        out = {c: v[: q.limit] for c, v in out.items()}
    return out
