"""Compressed-domain execution: code-space predicates + late materialization.

The paper's EE "operates directly on encoded data" (§6.1): predicates on
dictionary-encoded columns are evaluated against the *codes*, GROUP BY keys
stay in code space, and only the rows that survive are ever decoded.  This
module is our analog for the fused aggregate path (engine/executor.py):

1.  **Plan-time rewrite** -- ``plan_compressed_scan`` decomposes the scan
    predicate into per-column integer intervals (expr.interval_decompose).
    For a BLOCK_DICT column the interval [lo, hi] becomes a per-block code
    range via binary search of the block dictionary: codes are assigned in
    sorted value order, so ``searchsorted(dict, lo/hi)`` brackets exactly
    the codes whose values fall inside the interval.  No value
    materialization happens to evaluate the predicate.

2.  **Code-domain GROUP BY** -- when every container encodes a group-by
    column as BLOCK_DICT, its container-global dictionaries are unioned
    and the per-block ``code_map`` composed into a block-code -> union-code
    remap.  The fused program then groups on union codes directly (a dense
    domain of exactly ``len(union)``), and the finish step translates codes
    back to values with one host-side take.  Because the union is sorted,
    code order == value order and the result rows come out byte-identical
    to the value-domain plan.

3.  **Late materialization** -- non-predicate payload columns are gathered
    for *surviving rows only*: randomly-accessible encodings (PLAIN,
    DELTA_VALUE, BLOCK_DICT, FLOAT_SCALED over those) gather straight out
    of the packed device payload (``gather_decode_jnp`` /
    ``gather_unpack``); sequential encodings (RLE, DELTA_RANGE,
    COMMON_DELTA) decode their SMA-surviving blocks into per-query
    temporaries that die with the scan -- the block cache only ever holds
    the packed payloads, which is what makes a constrained cache budget go
    2x+ further (BENCH_cstore.json "compression" row).

Eligibility is strict because the differential guarantee is byte-identity,
not allclose: integer intervals on INT columns only, conjunctions only;
anything else falls back to the decoded scan.  ``db.exec_mode`` picks the
policy ("auto" uses the compressed scan only when the decoded working set
is neither device-resident nor able to fit the cache budget comfortably,
so unconstrained workloads keep the exact legacy fast path -- cold and
warm, same plan signature).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.block_cache import KIND_DECODED, KIND_ENCODED
from ..core.encodings import (Encoding, EncodedColumn, _packed_width,
                              decode_jnp, device_bytes, gather_decode_jnp,
                              random_access_jnp, upload_jnp)
from ..core.types import SQLType
from ..kernels import ops as kops
from . import operators as ops
from .expr import Expr, interval_decompose

Interval = Tuple[Optional[int], Optional[int]]

# Jitted-closure cache for the scan's device programs.  The eager path
# costs ~1k python dispatches per query (decode + gather + mask ops per
# container), which dwarfs the actual device work; each (site, container,
# column) pair compiles once per shape instead.  Containers are immutable
# and their ids never reused, so closure staleness cannot occur; entries
# for retired containers are just dead weight (bounded by container
# count, tiny vs the arrays they produced).
_JIT_CACHE: Dict[tuple, object] = {}


def _jitted(key: tuple, fn):
    cached = _JIT_CACHE.get(key)
    if cached is None:
        cached = _JIT_CACHE[key] = jax.jit(fn)
    return cached


@dataclasses.dataclass
class CompressedScanPlan:
    """A plan-time rewrite of one fused scan into the code domain."""

    intervals: Dict[str, Interval]          # col -> inclusive int bounds
    containers: List[tuple]                 # [(store, ROSContainer), ...]
    need: List[str]                         # scan columns, sorted
    # group col -> sorted union dictionary (values); present only when the
    # column groups in code space
    group_dicts: Dict[str, np.ndarray]
    # (container id, group col) -> (n_blocks, dict_size) block-code ->
    # union-code remap
    union_maps: Dict[Tuple[int, str], np.ndarray]
    as_of: int
    # plan-cache identity: symbol widths of every packed stream touched +
    # union dictionary sizes (dictionary growth must miss the plan cache)
    sig_suffix: tuple

    # ------------------------------------------------------------ params --

    def key_domains(self, q, plan) -> Optional[Tuple[Optional[int], ...]]:
        """Per-key domains with dict-grouped columns overridden by their
        union dictionary size (codes are a dense [0, len(union)) domain)."""
        if not q.group_by:
            return None
        base = plan.key_domains or (None,) * len(q.group_by)
        return tuple(len(self.group_dicts[g]) if g in self.group_dicts
                     else base[i] for i, g in enumerate(q.group_by))

    # -------------------------------------------------------------- scan --

    def scan(self, db, predicate: Optional[Expr], sip,
             stats) -> Optional[ops.ScanResult]:
        """Code-domain scan: predicate in code/value space over packed
        payloads, ONE host sync for the survivor set, then late-materialize
        ``need`` columns for survivors only."""
        cache = getattr(db, "block_cache", None)

        def enc_of(c, name):
            col = c.columns[name]
            if cache is None:
                return col, upload_jnp(col)
            return col, cache.get_or_put(c.id, name, KIND_ENCODED,
                                         lambda: upload_jnp(col),
                                         device_bytes)

        from .executor import cached_valid

        pruned = total = 0
        # (container, kept_idx, device mask, block_rows, encs, tmps)
        segs = []
        for store, c in self.containers:
            first = c.columns[self.need[0]]
            nb, br = first.n_blocks, first.block_rows
            total += nb
            # identical SMA pruning to scan_stores_batched (stats parity)
            keep = np.ones(nb, dtype=bool)
            if predicate is not None:
                for colname, (lo, hi) in predicate.bounds().items():
                    if colname in c.smas:
                        keep &= c.smas[colname].prune_blocks(lo, hi)
            kept = np.flatnonzero(keep)
            pruned += nb - kept.size
            if kept.size == 0:
                continue
            stats.containers_scanned += 1
            counts = c.smas[self.need[0]].counts
            vblocks = cached_valid(cache, store, c, self.as_of, counts)

            # ONE jitted mask program per container: validity slice plus
            # every interval predicate (code range or decoded temporary)
            encs = {name: enc_of(c, name)[1] for name in self.need}
            meta = {name: c.columns[name] for name in self.need}
            bounds: Dict[str, object] = {}
            shape_key = []
            for name, (lo, hi) in sorted(self.intervals.items()):
                col = meta[name]
                if col.encoding == Encoding.BLOCK_DICT \
                        and "codes_packed" in col.arrays:
                    clo, chi = _code_range(col, lo, hi)
                    bounds[name] = jnp.asarray(
                        np.stack([clo[kept], chi[kept]]))
                    shape_key.append((name, "dict"))
                else:
                    # literals ride in as device scalars so a new literal
                    # reuses the compiled program
                    bounds[name] = tuple(
                        None if b is None else jnp.asarray(b)
                        for b in (lo, hi))
                    shape_key.append((name, lo is None, hi is None))
            fn = _jitted(("cmask", c.id, tuple(shape_key)),
                         _make_mask_fn(meta, dict(self.intervals)))
            mask, tmps = fn(vblocks, jnp.asarray(kept), encs, bounds)
            segs.append((c, kept, mask, br, encs, tmps))
        stats.blocks_pruned, stats.blocks_total = pruned, total
        if not segs:
            return None

        flat = segs[0][2].reshape(-1) if len(segs) == 1 else \
            jnp.concatenate([m.reshape(-1) for _, _, m, _, _, _ in segs])
        # the single host sync of the scan: survivor positions
        surv = np.flatnonzero(np.asarray(flat))
        n = int(surv.size)
        stats.rows_scanned = int(flat.shape[0])
        stats.rows_materialized = n
        # pad to the next pow2 so survivor-count jitter reuses programs
        bucket = max(1, 1 << (n - 1).bit_length()) if n else 1

        parts: Dict[str, List[jax.Array]] = {name: [] for name in self.need}
        off = 0
        for c, kept, mask, br, encs, tmps in segs:
            lo_off, off = off, off + kept.size * br
            s = surv[(surv >= lo_off) & (surv < off)] - lo_off
            if s.size == 0:
                continue
            lb, r = np.divmod(s, br)             # local kept-block, row
            # one upload: (global block, local kept-block, row) rows
            idx = jnp.asarray(np.stack([kept[lb], lb, r]))
            meta = {name: c.columns[name] for name in self.need}
            umaps = {g: self.union_maps[(c.id, g)]
                     for g in self.group_dicts if g in self.need}
            # ONE jitted gather program per container: every need column
            # (union codes / random-access gather / temp fancy-index)
            fn = _jitted(("cgat", c.id, tuple(self.need),
                          tuple(sorted(umaps)), tuple(sorted(tmps))),
                         _make_gather_fn(meta, umaps, tuple(self.need)))
            out = fn(encs, tmps, idx)
            for name in self.need:
                parts[name].append(out[name])

        cols: Dict[str, jax.Array] = {}
        any_c = self.containers[0][1]
        for name in self.need:
            ps = parts[name]
            if not ps:                           # zero survivors
                dt = self._empty_dtype(name)
                cols[name] = jnp.zeros(bucket, dt)
            else:
                # concat + dtype canonicalization (the exact dtypes the
                # decoded scan would produce) + zero-pad to the bucket
                fin = _jitted(("fin", len(ps), bucket),
                              _make_finish_fn(bucket))
                cols[name] = fin(tuple(ps))
            col = any_c.columns[name]
            if col.encoding == Encoding.FLOAT_SCALED \
                    and name not in self.group_dicts:
                # the gather program returned the INNER integer lanes;
                # apply the scale division eagerly so its rounding is
                # bit-identical to the eager decode_jnp path
                cols[name] = cols[name].astype(jnp.float32) / col.scale
        valid = jnp.arange(bucket) < n
        if sip is not None:
            valid = valid & sip(cols)
        return ops.ScanResult(cols, valid, pruned, total)

    def _empty_dtype(self, name):
        if name in self.group_dicts:
            return jnp.int32
        col = self.containers[0][1].columns[name]
        return jnp.float64 if col.sql_type == SQLType.FLOAT else jnp.int64

    # ------------------------------------------------------------ finish --

    def translate(self, out: Optional[Dict[str, np.ndarray]]
                  ) -> Optional[Dict[str, np.ndarray]]:
        """Union codes -> values on the (small) host-side result."""
        if out is None:
            return None
        for g, union in self.group_dicts.items():
            if g in out:
                out[g] = union[np.asarray(out[g], dtype=np.int64)]
        return out


def _make_mask_fn(meta: Dict[str, EncodedColumn],
                  intervals: Dict[str, Interval]):
    """Build the per-container mask program (traced once per shape set):
    validity slice + every interval predicate.  Dict columns compare
    unpacked codes against per-block code ranges; other columns decode to
    a temporary (returned for reuse by the gather program)."""
    def fn(vblocks, kept, encs, bounds):
        mask = vblocks[kept]
        tmps = {}
        for name in sorted(intervals):
            col = meta[name]
            if col.encoding == Encoding.BLOCK_DICT \
                    and "codes_packed" in col.arrays:
                w = _packed_width(col.arrays, "codes_packed",
                                  col.block_rows)
                codes = kops.bitunpack(encs[name]["codes_packed"][kept],
                                       w, col.block_rows)
                b = bounds[name]
                mask = mask & (codes >= b[0][:, None]) \
                    & (codes <= b[1][:, None])
            else:
                dec = decode_jnp(col, encs[name])[kept]
                tmps[name] = dec
                lo, hi = bounds[name]
                if lo is not None:
                    mask = mask & (dec >= lo)
                if hi is not None:
                    mask = mask & (dec <= hi)
        return mask, tmps
    return fn


def _make_gather_fn(meta: Dict[str, EncodedColumn],
                    umaps: Dict[str, np.ndarray], need: tuple):
    """Build the per-container late-materialization program: every need
    column gathered for survivor rows only.  ``idx`` rows are (global
    block, local kept-block, row-in-block)."""
    from ..kernels.bitunpack import gather_unpack

    def fn(encs, tmps, idx):
        b, lb, r = idx[0], idx[1], idx[2]
        out = {}
        for name in need:
            col = meta[name]
            # FLOAT_SCALED: gather the INNER integer lanes here and leave
            # the `/scale` division to the eager finish step -- inside jit
            # XLA rewrites division-by-constant into multiply-by-
            # reciprocal (1 ULP off), which would break byte-identity with
            # the eagerly-decoded scan
            if col.encoding == Encoding.FLOAT_SCALED:
                col = col.inner
            if name in umaps:
                # group col: gather union CODES, never the values
                w = _packed_width(col.arrays, "codes_packed",
                                  col.block_rows)
                codes = gather_unpack(encs[name]["codes_packed"], w, b, r)
                out[name] = jnp.asarray(umaps[name])[b, codes]
            elif name in tmps:
                # already decoded (kept-sliced) by the mask program
                out[name] = tmps[name][lb, r]
            elif random_access_jnp(col):
                out[name] = gather_decode_jnp(col, encs[name], b, r)
            else:
                # sequential encoding: decode, then fancy-index survivors
                out[name] = decode_jnp(col, encs[name])[b, r]
        return out
    return fn


def _make_finish_fn(bucket: int):
    """Concat survivor parts, canonicalize dtype exactly like the decoded
    scan, zero-pad to the pow2 bucket."""
    def fn(ps):
        v = ps[0] if len(ps) == 1 else jnp.concatenate(ps)
        v = v.astype(jnp.float64 if v.dtype.kind == "f" else jnp.int64)
        if bucket > v.shape[0]:
            v = jnp.concatenate([v, jnp.zeros(bucket - v.shape[0],
                                              v.dtype)])
        return v
    return fn


def _code_range(col: EncodedColumn, lo: Optional[int], hi: Optional[int]
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-block inclusive code range [clo, chi] matching value interval
    [lo, hi].  Blocks with no matching value get clo > chi (empty)."""
    dv, dn = col.arrays["dict_values"], col.arrays["dict_n"]
    nb = dv.shape[0]
    clo = np.zeros(nb, np.int64)
    chi = np.zeros(nb, np.int64)
    for i in range(nb):
        u = dv[i, : int(dn[i])]
        clo[i] = 0 if lo is None else np.searchsorted(u, lo, side="left")
        chi[i] = (int(dn[i]) if hi is None
                  else int(np.searchsorted(u, hi, side="right"))) - 1
    return clo.astype(np.int32), chi.astype(np.int32)


def plan_compressed_scan(db, q, plan, need, scan_pred: Optional[Expr],
                         as_of: int) -> Optional[CompressedScanPlan]:
    """Rewrite an eligible fused scan into the code domain, or None.

    Eligible: exec_mode allows it, the scan predicate decomposes into
    per-column integer intervals, and every interval column is INT-typed in
    every container (interval semantics are exact only for integers).  In
    "auto" mode the rewrite additionally requires that the decoded working
    set is NOT already device-resident and does NOT comfortably fit the
    cache budget -- a warm decoded scan is strictly faster than
    re-gathering, so unconstrained workloads keep the exact legacy path
    (same plan signature, cold and warm) and the compressed scan engages
    only when decoded residency is unattainable."""
    mode = getattr(db, "exec_mode", "auto")
    if mode == "decoded" or scan_pred is None:
        return None
    intervals = interval_decompose(scan_pred)
    if not intervals:
        return None
    need = sorted(set(need) | set(intervals))

    pairs = []
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        for c in store.containers:
            pairs.append((store, c))
    if not pairs:
        return None
    for name in intervals:
        for _, c in pairs:
            col = c.columns.get(name)
            if col is None or col.sql_type != SQLType.INT:
                return None
    if mode != "compressed":
        cache = getattr(db, "block_cache", None)
        if cache is None:
            return None
        if all((c.id, name, KIND_DECODED) in cache
               for _, c in pairs for name in need):
            return None
        # budget comfortably fits the decoded working set: let the legacy
        # path decode-and-cache (identical plan signature cold and warm,
        # so repeats stay plan-cache hits); compressed is for budgets
        # where decoded residency is unattainable
        dec_bytes = sum(c.columns[nm].n_blocks * c.columns[nm].block_rows
                        * 4 for _, c in pairs for nm in need
                        if nm in c.columns)
        if cache.budget_bytes >= 2 * dec_bytes:
            return None

    # code-domain GROUP BY: a group col groups on union codes only when it
    # carries no other role in the program (agg input, join key, derived
    # input) -- those need the real values inside the fused program
    used_as_value = {c for _, c, kind in q.aggs
                     if kind != "count" and c != "*"}
    for j in q.joins:
        used_as_value.add(j.fact_key)
    for _, e in q.derived:
        used_as_value |= e.columns()
    group_dicts: Dict[str, np.ndarray] = {}
    union_maps: Dict[Tuple[int, str], np.ndarray] = {}
    for g in q.group_by:
        if g in used_as_value:
            continue
        encs = [c.columns.get(g) for _, c in pairs]
        if not all(e is not None and e.encoding == Encoding.BLOCK_DICT
                   and "codes_packed" in e.arrays for e in encs):
            continue
        union = np.unique(np.concatenate([e.arrays["global_dict"]
                                          for e in encs]))
        for (_, c), e in zip(pairs, encs):
            umap = np.searchsorted(union, e.arrays["global_dict"]) \
                .astype(np.int32)[e.arrays["code_map"]]
            union_maps[(c.id, g)] = np.ascontiguousarray(umap)
        group_dicts[g] = union

    sig_suffix = (
        "cdom",
        tuple(sorted((c.id, name) + c.columns[name].width_signature()
                     for _, c in pairs for name in need
                     if name in c.columns)),
        tuple(sorted((g, len(u)) for g, u in group_dicts.items())),
    )
    return CompressedScanPlan(dict(intervals), pairs, list(need),
                              group_dicts, union_maps, as_of, sig_suffix)
