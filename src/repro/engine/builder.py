"""Fluent query builder: the session-level front-end over the logical IR.

    out = (db.query("lineitem")
             .where(col("l_shipdate") > 60)
             .join("orders", on=("l_orderkey", "o_orderkey"),
                   cols=("o_custkey",))
             .join("region", on=("o_custkey", "r_custkey"),
                   cols=("r_name",))
             .group_by("o_custkey", "r_name")
             .agg(revenue=("l_extprice", "sum"), n=("*", "count"))
             .having(col("revenue") > 0)
             .order_by("-revenue")
             .limit(10)
             .collect())

Each method returns a *new* builder (copy-on-write), so a partially built
pipeline can be reused as a template.  ``to_ir()`` lowers to the canonical
``LogicalQuery`` (engine/logical.py); ``collect()`` executes and returns
the result columns, stashing the run's ``ExecStats`` on ``.stats``;
``execute()`` returns ``(results, stats)`` like engine.execute.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .expr import Expr
from .logical import AGG_KINDS, LogicalJoin, LogicalQuery


def _parse_on(on) -> Tuple[str, str]:
    """Accept on="key" (same name both sides), on="fact=dim", or
    on=("fact", "dim")."""
    if isinstance(on, str):
        if "=" in on:
            f, d = on.split("=", 1)
            return f.strip(), d.strip()
        return on, on
    f, d = on
    return f, d


def _parse_order(cols, desc: bool) -> Tuple[Tuple[str, bool], ...]:
    out = []
    for c in cols:
        if c.startswith("-"):
            out.append((c[1:], True))
        else:
            out.append((c, desc))
    return tuple(out)


@dataclasses.dataclass(eq=False)
class QueryBuilder:
    db: object
    table: str
    _columns: Tuple[str, ...] = ()
    _derived: Tuple[Tuple[str, Expr], ...] = ()
    _predicate: Optional[Expr] = None
    _joins: Tuple[LogicalJoin, ...] = ()
    _group_by: Tuple[str, ...] = ()
    _aggs: Tuple[Tuple[str, str, str], ...] = ()
    _having: Optional[Expr] = None
    _order_by: Tuple[Tuple[str, bool], ...] = ()
    _limit: Optional[int] = None
    stats: object = None               # ExecStats of the last collect()

    def _with(self, **kw) -> "QueryBuilder":
        return dataclasses.replace(self, stats=None, **kw)

    # -------------------------------------------------------- clauses --

    def select(self, *cols: str, **derived: Expr) -> "QueryBuilder":
        """Output columns; keyword args define derived expressions
        (``margin=col("price") - col("cost")``) usable in later clauses."""
        return self._with(
            _columns=self._columns + cols,
            _derived=self._derived + tuple(derived.items()))

    def where(self, predicate: Expr) -> "QueryBuilder":
        """Fact-side filter; repeated calls AND together."""
        p = predicate if self._predicate is None \
            else self._predicate & predicate
        return self._with(_predicate=p)

    def join(self, dim_table: str, on, cols: Tuple[str, ...] = (),
             where: Optional[Expr] = None,
             how: str = "inner") -> "QueryBuilder":
        """Join a dimension table.  ``on`` is the key pair (see _parse_on);
        ``cols`` are the dimension columns carried into the output;
        ``where`` filters the dimension before the join (and arms SIP)."""
        fact_key, dim_key = _parse_on(on)
        cols = (cols,) if isinstance(cols, str) else tuple(cols)
        spec = LogicalJoin(dim_table, fact_key, dim_key, cols,
                           where, how)
        return self._with(_joins=self._joins + (spec,))

    def group_by(self, *cols: str) -> "QueryBuilder":
        return self._with(_group_by=self._group_by + cols)

    def agg(self, **named) -> "QueryBuilder":
        """Named aggregates: ``total=("price", "sum"), n=("*", "count")``.
        A bare column string means count: ``n="*"``."""
        specs = []
        for out, spec in named.items():
            if isinstance(spec, str):
                spec = (spec, "count")
            c, kind = spec
            if kind not in AGG_KINDS:
                raise ValueError(f"unknown aggregate {kind!r} "
                                 f"(one of {AGG_KINDS})")
            specs.append((out, c, kind))
        return self._with(_aggs=self._aggs + tuple(specs))

    def having(self, predicate: Expr) -> "QueryBuilder":
        h = predicate if self._having is None \
            else self._having & predicate
        return self._with(_having=h)

    def order_by(self, *cols: str, desc: bool = False) -> "QueryBuilder":
        """Sort keys in major-to-minor order; prefix "-" for descending
        per key (or desc=True for all)."""
        return self._with(_order_by=self._order_by
                          + _parse_order(cols, desc))

    def limit(self, n: int) -> "QueryBuilder":
        return self._with(_limit=int(n))

    # ------------------------------------------------------- lowering --

    def to_ir(self) -> LogicalQuery:
        return LogicalQuery(
            table=self.table, columns=self._columns,
            derived=self._derived, predicate=self._predicate,
            joins=self._joins, group_by=self._group_by, aggs=self._aggs,
            having=self._having, order_by=self._order_by,
            limit=self._limit).validate()

    def explain(self) -> str:
        """Logical tree plus the planner's physical choices."""
        from ..planner.planner import plan_query
        ir = self.to_ir()
        plan = plan_query(self.db, ir)
        return ir.explain() + "\n-- physical --\n" + "\n".join(plan.explain)

    # ------------------------------------------------------ execution --

    def execute(self, *, as_of: Optional[int] = None):
        from .pipeline import execute
        return execute(self.db, self.to_ir(), as_of=as_of)

    def collect(self, *, as_of: Optional[int] = None
                ) -> Dict[str, np.ndarray]:
        out, stats = self.execute(as_of=as_of)
        self.stats = stats
        return out
