from .builder import QueryBuilder
from .executor import PLAN_CACHE, PlanCache
from .expr import Col, Expr, Lit, col, lit
from .logical import (Aggregate, Filter, Join, Limit, LogicalJoin,
                      LogicalQuery, Project, Scan, Sort, as_ir, lower)
from .pipeline import ExecStats, JoinSpec, Query, execute
from .segmented import execute_segmented
from .serving import (QueryService, ServiceStats, ServingStats, Session,
                      Ticket)

__all__ = ["Aggregate", "Col", "ExecStats", "Expr", "Filter", "Join",
           "JoinSpec", "Limit", "Lit", "LogicalJoin", "LogicalQuery",
           "PLAN_CACHE", "PlanCache", "Project", "Query", "QueryBuilder",
           "QueryService", "Scan", "ServiceStats", "ServingStats",
           "Session", "Sort", "Ticket", "as_ir", "col", "execute",
           "execute_segmented", "lit", "lower"]
