from .executor import PLAN_CACHE, PlanCache
from .expr import Col, Expr, Lit, col, lit
from .pipeline import ExecStats, JoinSpec, Query, execute

__all__ = ["Col", "ExecStats", "Expr", "JoinSpec", "Lit", "PLAN_CACHE",
           "PlanCache", "Query", "col", "execute", "lit"]
