from .expr import Col, Expr, Lit, col, lit
from .pipeline import ExecStats, JoinSpec, Query, execute

__all__ = ["Col", "ExecStats", "Expr", "JoinSpec", "Lit", "Query", "col",
           "execute", "lit"]
