"""Segmented multi-node query execution on a jax device mesh.

This is the scale-out half of the paper made real in the engine: a
projection's ``SegmentationSpec`` (§3.6) decides which *device shard* owns
each tuple, Send/Recv (§6.1) runs as ``exchange.resegment`` /
``exchange.broadcast_build_side`` collectives, and buddy projections
(§5.2) keep every segment scannable when a node is down -- the planner's
``plan.sources`` routing already walks buddies, so ``fail_node()``
failover is transparent here too.

Execution shape (one query):

  0. **RLE-direct routes**: a count-only GroupBy on the RLE-encoded sort
     leader (or a scalar COUNT with a sort-leader range predicate)
     aggregates straight off each node's encoded runs -- per-node
     metadata work with a trivial host merge (§6.1 "operate on encoded
     data"); no slab, no collective.
  1. **Device slab build** (cold only, cached ``KIND_SEG``): the decoded
     device blocks of every source container are concatenated on device
     (``executor.snapshot_scan_device``), ring-hashed with the device
     twins ``hash_columns_jnp`` / ``shard_of_jnp``, moved to their owning
     shard by one ``exchange.resegment`` all_to_all sized from an exact
     on-device destination histogram, then compacted (valid rows first)
     and annotated with per-512-row-block min/max/count SMAs -- the
     columns never round-trip through the host.  Trickle-loaded WOS rows
     live in separate per-store device buffers (``KIND_WOS``) built at
     commit time (``prewarm_wos_buffer``) and keyed by ``WOS.version``;
     a query only uploads the per-row visibility mask for its epoch and
     appends them shard-locally.
  2. **Slab-block pruning** (per query, device gather): predicate bounds
     against the slab's block SMAs select the surviving 512-row blocks;
     each shard gathers just those blocks into a power-of-two-sized view.
     Conservative and exact: pruned rows cannot pass the predicate, and
     inner joins only ever drop rows.
  3. **Fused stage programs** (one shard_map'd jitted executable per
     resegment stage): ``exchange.resegment_local`` (Send/Recv) fused
     with the stage's hash joins, and -- in the final stage -- derived
     exprs, the deferred predicate, mixed-radix key packing and the
     shard-local pre-aggregation (``kernels.seg_preagg``: Pallas scatter
     on TPU, XLA scatter elsewhere; sort-based partials past the dense
     limit).  Exchange overflow reports are collected and checked once
     after the final dispatch, so no host sync splits a stage chain.
  4. **Final merge** (host, small): partial counts/sums add, min/max
     combine, avg = merged sum / merged count; packed keys unpack.

The plan-cache signature includes the mesh identity, the projection's
segmentation, the per-join exchange ops and the pack radices -- two mesh
shapes (or a re-segmented projection) can never share an executable.
Static exchange capacities are memoized INSIDE each cached entry (a
factory keyed by the per-stage slot counts), so data growth retraces
without invalidating the plan.

Falls back to the single-node pipeline (returns None) for shapes outside
the segmented subset: plain selects, non-inner joins, derived group keys,
group domains past the device integer width, or an empty snapshot.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.block_cache import KIND_SEG, KIND_WOS
from ..core.database import VerticaDB
from ..core.faults import fire_with_retries
from ..core.segmentation import (hash_columns, hash_columns_jnp, shard_of,
                                 shard_of_jnp)
from ..kernels.seg_preagg import seg_preagg
from ..planner import cost as cost_mod
from . import exchange
from . import executor as fused_exec
from . import operators as ops
from .executor import PLAN_CACHE
from .logical import LogicalQuery

_PACK_LIMIT = 1 << 31         # packed keys live in device int32
_PAD_MULTIPLE = 8
_SLAB_BLOCK = 512             # rows per slab SMA block (pruning granule)


def _round_up(n: int, m: int = _PAD_MULTIPLE) -> int:
    return -(-max(int(n), 1) // m) * m


def _pow2_at_least(n: int) -> int:
    k = 1
    while k < n:
        k <<= 1
    return k


# ---------------------------------------------------------------------------
# 1. Partitioned scan slabs (device-built, cached)
# ---------------------------------------------------------------------------

def _canon_np(v: np.ndarray) -> np.ndarray:
    """Match the single-node path's device canonicalization (jax default
    32-bit runtime) so both execution models aggregate identical dtypes."""
    if jax.config.jax_enable_x64:
        return v
    if v.dtype.kind in "iu" and v.dtype.itemsize > 4:
        return v.astype(np.int32)
    if v.dtype.kind == "f" and v.dtype.itemsize > 4:
        return v.astype(np.float32)
    return v


def _source_sig(db: VerticaDB, plan, need, reseg_keys, eff: int,
                mesh, axis: str) -> tuple:
    """Identity of a cached ROS slab: *effective* snapshot epoch (the
    query's as-of clamped to the sources' ROS epoch ceiling -- trickle
    commits that only touched the WOS advance the cluster epoch without
    changing ROS visibility, so warm slabs survive them), mesh identity,
    needed columns, resegment keys, and the exact physical container set
    (the tuple mover retires containers by replacing ids, so a mergeout
    or moveout naturally misses -- and ``ProjectionStore.
    invalidate_seg_slabs`` evicts precisely those entries)."""
    items = []
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        items.append((host, owner,
                      tuple(c.id for c in store.containers)))
    return (tuple(items), tuple(need), tuple(reseg_keys), int(eff),
            _mesh_sig(mesh, axis))


def _slab_positions(shard: np.ndarray, n_shards: int):
    """Stable within-shard slot assignment shared by row and build-side
    packing: returns (order, sorted_shard, pos, counts) such that source
    row ``order[i]`` belongs in slab slot ``[sorted_shard[i], pos[i]]``."""
    counts = np.bincount(shard, minlength=n_shards)
    order = np.argsort(shard, kind="stable")
    ss = shard[order]
    starts = np.zeros(n_shards, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    pos = np.arange(len(shard)) - starts[ss]
    return order, ss, pos, counts


# own-shard index columns for exchange pad slots, cached per (mesh, width)
# so warm resegment queries skip the host build + upload
_SHARD_IDX_CACHE: Dict[tuple, jax.Array] = {}
_SHARD_IDX_CAP = 64


def _shard_index_col(mesh, axis: str, n_shards: int,
                     per_local: int) -> jax.Array:
    key = (_mesh_sig(mesh, axis), per_local)
    v = _SHARD_IDX_CACHE.get(key)
    if v is None:
        # evict oldest-first down to the cap (dict preserves insertion
        # order); wholesale clearing would also throw away the hot
        # (mesh, width) pairs of every OTHER live query shape
        while len(_SHARD_IDX_CACHE) >= _SHARD_IDX_CAP:
            _SHARD_IDX_CACHE.pop(next(iter(_SHARD_IDX_CACHE)))
        v = jax.device_put(
            np.repeat(np.arange(n_shards, dtype=np.int32), per_local),
            NamedSharding(mesh, P(axis)))
        _SHARD_IDX_CACHE[key] = v
    return v


def _slab_bytes(slab: dict) -> int:
    n = 0
    for v in slab["cols"].values():
        n += int(v.size) * v.dtype.itemsize
    for v in slab["dests"].values():
        n += int(v.size) * v.dtype.itemsize
    n += int(slab["valid"].size)
    return n


def _shard_assignment(proj, cols_np: Dict[str, np.ndarray], n: int,
                      n_shards: int, ring: Optional[np.ndarray] = None,
                      base: int = 0) -> np.ndarray:
    """Device shard per row: ring hash of the segmentation columns,
    OFFSET-FREE (core/segmentation.shard_of) -- the same logical row must
    land on the same shard whether the primary or the ring-offset buddy
    store served it.  Trickle-loaded WOS rows arrive with their ring
    value already stamped at commit (``ring``), so no re-hash.
    Replicated projections have no ring: spread rows round-robin."""
    seg = proj.segmentation
    if seg.replicated:
        return ((base + np.arange(n, dtype=np.int64))
                % n_shards).astype(np.int32)
    if ring is None:
        ring = hash_columns(*[cols_np[c] for c in seg.columns])
    return shard_of(ring, n_shards)


def _partition_to_slab(cols_np: Dict[str, np.ndarray], shard: np.ndarray,
                       reseg_keys: Sequence[str], n_shards: int, mesh,
                       axis: str, keep_layout: bool = False
                       ) -> Optional[dict]:
    """Pack host rows (already canonicalized) into a static
    ``(n_shards, per)`` device slab from each row's shard assignment.
    Used for the commit-time WOS buffers (the ROS slab builds on device,
    ``_build_ros_slab_device``).  Zero rows return the empty-slab
    sentinel ``None`` -- computing ``v.min()`` bounds on an empty column
    used to raise out of the whole segmented path.  ``keep_layout``
    additionally records the (order, shard, slot) map so a caller can
    scatter per-row host data (e.g. an epoch visibility mask) into slab
    slots later without repartitioning."""
    n = len(shard)
    if n == 0:
        return None
    dests = {k: shard_of(hash_columns(cols_np[k]), n_shards)
             for k in reseg_keys}

    # observed per-column bounds: static pack radices for the shard
    # program (exact, tighter than SMA estimates)
    bounds = {}
    for c, v in cols_np.items():
        bounds[c] = (int(v.min()), int(v.max())) \
            if v.dtype.kind in "iub" else None

    order, ss, pos, counts = _slab_positions(shard, n_shards)
    per = _round_up(counts.max())

    sharding = NamedSharding(mesh, P(axis))
    out_cols = {}
    for c, v in cols_np.items():
        buf = np.zeros((n_shards, per), v.dtype)
        buf[ss, pos] = v[order]
        out_cols[c] = jax.device_put(buf.reshape(-1), sharding)
    vbuf = np.zeros((n_shards, per), bool)
    vbuf[ss, pos] = True
    out_valid = jax.device_put(vbuf.reshape(-1), sharding)
    out_dests = {}
    for k, d in dests.items():
        # pad slots point at their own shard so an exchange leaves them
        # in place instead of piling them all onto shard 0
        dbuf = np.repeat(np.arange(n_shards, dtype=np.int32)[:, None],
                         per, axis=1)
        dbuf[ss, pos] = d[order]
        out_dests[k] = jax.device_put(dbuf.reshape(-1), sharding)

    out = {"cols": out_cols, "valid": out_valid, "per": int(per),
           "n_rows": n, "dests": out_dests,
           "real": {k: np.bincount(d, minlength=n_shards)
                    for k, d in dests.items()},
           "r0": counts, "bounds": bounds}
    if keep_layout:
        out["layout"] = (order, ss, pos)
    return out


# ------------------------------------------------- device ROS slab build --

def _build_dest_program(mesh, axis: str, n_shards: int,
                        seg_cols: Tuple[str, ...],
                        reseg_keys: Tuple[str, ...], replicated: bool):
    """Build phase B1: per-row shard ownership and resegment destinations
    from the DEVICE hash twins, plus the exact histograms that size the
    build exchange -- per-source bucket counts over ALL rows (invalid
    rows stay on their own shard, so they can never overflow a bucket),
    and global per-destination counts of the valid rows."""

    def local_fn(valid_l, segd, resegd):
        me = jax.lax.axis_index(axis).astype(jnp.int32)
        n_local = valid_l.shape[0]
        if replicated:
            dest_v = ((me * n_local
                       + jnp.arange(n_local, dtype=jnp.int32))
                      % n_shards)
        else:
            ring = hash_columns_jnp(*[segd[c] for c in seg_cols])
            dest_v = shard_of_jnp(ring, n_shards)
        dest0 = jnp.where(valid_l, dest_v, me).astype(jnp.int32)
        oh = jax.nn.one_hot(dest0, n_shards, dtype=jnp.int32)
        bucket = oh.sum(axis=0)                    # ALL rows, this source
        vi = valid_l.astype(jnp.int32)
        r0 = jax.lax.psum((oh * vi[:, None]).sum(axis=0), axis)
        dests, reals = {}, {}
        for k in reseg_keys:
            dk = shard_of_jnp(hash_columns_jnp(resegd[k]), n_shards)
            dests[k] = dk
            ohk = jax.nn.one_hot(dk, n_shards, dtype=jnp.int32)
            reals[k] = jax.lax.psum((ohk * vi[:, None]).sum(axis=0), axis)
        return dest0, dests, bucket, r0, reals

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=(P(axis), P(axis), P(axis), P(), P()))
    return jax.jit(fn)


def _build_compact_program(mesh, axis: str, names: Tuple[str, ...],
                           dkeys: Tuple[str, ...], per_out: int, sb: int):
    """Build phase B2: per shard, move valid rows to the front (stable,
    preserving source container order -- at one shard the slab keeps the
    exact single-node scan order, so its block SMAs prune at least as
    tightly), slice to the padded row budget, and compute per-block
    min/max/count SMAs over the surviving layout."""
    nb = per_out // sb

    def local_fn(cols, valid, dests):
        n_local = valid.shape[0]
        # stable valid-first order without argsort-kind kwargs: invalid
        # rows rank after every valid row, ties broken by position
        rank = (jnp.where(valid, 0, 1) * n_local
                + jnp.arange(n_local, dtype=jnp.int32))
        take = jnp.argsort(rank)[:per_out]
        out_cols = {c: v[take] for c, v in cols.items()}
        valid_c = valid[take]
        out_dests = {k: d[take] for k, d in dests.items()}
        v2 = valid_c.reshape(nb, sb)
        bcount = v2.sum(axis=1).astype(jnp.int32)
        bmins, bmaxs = {}, {}
        for c in names:
            arr = out_cols[c].reshape(nb, sb)
            if arr.dtype.kind == "f":
                hi, lo = jnp.inf, -jnp.inf
            else:
                hi = jnp.iinfo(arr.dtype).max
                lo = jnp.iinfo(arr.dtype).min
            bmins[c] = jnp.where(v2, arr, hi).min(axis=1)
            bmaxs[c] = jnp.where(v2, arr, lo).max(axis=1)
        return out_cols, valid_c, out_dests, bcount, bmins, bmaxs

    fn = shard_map(local_fn, mesh=mesh, in_specs=(P(axis),) * 3,
                   out_specs=(P(axis),) * 6)
    return jax.jit(fn)


def _build_ros_slab_device(db: VerticaDB, proj, plan, need: Sequence[str],
                           reseg_keys: Sequence[str], eff: int, mesh,
                           axis: str, n_shards: int, stats
                           ) -> Optional[dict]:
    """Device-side ROS slab build: already-cached decoded device blocks ->
    ring hash + destination histograms (B1) -> one all_to_all resegment ->
    compaction + block SMAs (B2).  The only host traffic is the
    visibility mask going up and the small histograms/SMA stats coming
    back -- never the columns."""
    got = fused_exec.snapshot_scan_device(db, plan, need, eff, stats)
    if got is None:
        return None
    cols_dev, valid_np = got
    if not bool(valid_np.any()):
        return None
    n_total = int(valid_np.shape[0])
    n_vis = int(valid_np.sum())
    per_src = -(-n_total // n_shards)
    pad = n_shards * per_src - n_total
    sharding = NamedSharding(mesh, P(axis))
    cols_p = {}
    for c in need:
        v = cols_dev[c]
        if pad:
            v = jnp.pad(v, (0, pad))
        cols_p[c] = jax.device_put(v, sharding)
    vp = np.pad(valid_np, (0, pad)) if pad else valid_np
    valid_p = jax.device_put(np.ascontiguousarray(vp), sharding)

    seg = proj.segmentation
    seg_cols = () if seg.replicated else tuple(seg.columns)
    reseg_keys = tuple(reseg_keys)
    fn, _ = PLAN_CACHE.get_or_build(
        ("seg-dest", _mesh_sig(mesh, axis), seg_cols, reseg_keys,
         seg.replicated, n_shards),
        lambda: _build_dest_program(mesh, axis, n_shards, seg_cols,
                                    reseg_keys, seg.replicated))
    dest0, dests_raw, bucket, r0, reals = fn(
        valid_p, {c: cols_p[c] for c in seg_cols},
        {k: cols_p[k] for k in reseg_keys})
    bucket_np = np.asarray(bucket).reshape(n_shards, n_shards)
    r0_np = np.asarray(r0).astype(np.int64)
    real_np = {k: np.asarray(v).astype(np.int64)
               for k, v in reals.items()}

    # capacity from the exact per-source histogram: overflow-free by
    # construction.  Block-multiple so the compacted layout reshapes.
    per_b = _round_up(int(bucket_np.max()), _SLAB_BLOCK)
    payload = dict(cols_p)
    payload["__v"] = valid_p.astype(jnp.int8)   # bools ride as bytes
    for k in reseg_keys:
        payload["__d:" + k] = dests_raw[k]
    moved, slot_valid, overflow = exchange.resegment(
        mesh, axis, payload, dest0, per_b * n_shards)
    if int(np.asarray(overflow).sum()):
        return None                             # defensive; cannot happen
    valid2 = (moved["__v"] != 0) & slot_valid
    # invalid slots (pads AND rows deleted at this epoch) must point at
    # their own shard so every later exchange leaves them in place --
    # that invariant is what makes the staged capacity math exact
    shard_idx = _shard_index_col(mesh, axis, n_shards, n_shards * per_b)
    dests2 = {k: jnp.where(valid2, moved["__d:" + k], shard_idx)
              for k in reseg_keys}

    per_out = _round_up(max(int(r0_np.max()), 1), _SLAB_BLOCK)
    names = tuple(sorted(need))
    fn2, _ = PLAN_CACHE.get_or_build(
        ("seg-compact", _mesh_sig(mesh, axis), names, reseg_keys,
         per_out, _SLAB_BLOCK),
        lambda: _build_compact_program(mesh, axis, names, reseg_keys,
                                       per_out, _SLAB_BLOCK))
    cols_c, valid_c, dests_c, bcount, bmins, bmaxs = fn2(
        {c: moved[c] for c in need}, valid2, dests2)

    nb = per_out // _SLAB_BLOCK
    bcount_np = np.asarray(bcount).reshape(n_shards, nb)
    bmins_np = {c: np.asarray(v).reshape(n_shards, nb)
                for c, v in bmins.items()}
    bmaxs_np = {c: np.asarray(v).reshape(n_shards, nb)
                for c, v in bmaxs.items()}
    bounds = {}
    for c in need:
        if cols_p[c].dtype.kind in "iub":
            sel = bcount_np > 0
            bounds[c] = (int(bmins_np[c][sel].min()),
                         int(bmaxs_np[c][sel].max()))
        else:
            bounds[c] = None
    return {"cols": cols_c, "valid": valid_c, "dests": dests_c,
            "per": per_out, "n_rows": n_vis, "r0": r0_np,
            "real": real_np, "bounds": bounds, "sb": _SLAB_BLOCK,
            "bstats": (bcount_np, bmins_np, bmaxs_np)}


# -------------------------------------------- commit-time WOS buffers --

def _wos_buffer_key(store, mesh, axis: str) -> tuple:
    return ("wos", store.wos.version, _mesh_sig(mesh, axis))


def _build_wos_buffer(store, n_shards: int, mesh, axis: str
                      ) -> Optional[dict]:
    """Per-store device WOS buffer: EVERY projection column (plus a
    resegment-destination column per column), partitioned by the
    commit-stamped ring values.  Query-shape independent, so it can be
    built eagerly at commit time; a query subsets the columns it needs
    and uploads only its epoch's visibility mask."""
    proj = store.proj
    data, eps, _segs = store.wos.snapshot()
    n = len(eps)
    if n == 0:
        return None
    cols_np = {c: _canon_np(np.asarray(data[c])) for c in proj.columns}
    ring = store.wos.ring_snapshot()
    shard = _shard_assignment(proj, cols_np, n, n_shards, ring=ring)
    return _partition_to_slab(cols_np, shard, tuple(proj.columns),
                              n_shards, mesh, axis, keep_layout=True)


def _get_wos_buffer(db: VerticaDB, host: int, owner: str, mesh, axis: str,
                    n_shards: int) -> Optional[dict]:
    store = db.nodes[host].stores[owner]
    if store.wos.n_rows == 0:
        return None
    cache = getattr(db, "block_cache", None)
    if cache is None:
        return _build_wos_buffer(store, n_shards, mesh, axis)
    primary = store.proj.buddy_of or store.proj.name
    return cache.get_or_put(
        f"seg:{primary}", (_wos_buffer_key(store, mesh, axis), host, owner),
        KIND_WOS, lambda: _build_wos_buffer(store, n_shards, mesh, axis),
        _slab_bytes)


def prewarm_wos_buffer(db: VerticaDB, host: int, owner: str) -> None:
    """Commit-time hook (core/database.commit): stream the just-appended
    WOS batch into its per-shard device buffer while the commit is still
    holding the rows hot, so the next query's trickle delta is already
    resident.  Keyed by ``WOS.version`` -- a later append/delete/clear
    simply strands this entry for the LRU."""
    mesh = getattr(db, "mesh", None)
    axis = getattr(db, "mesh_axis", None)
    if mesh is None or getattr(db, "block_cache", None) is None:
        return
    node = db.nodes[host]
    if not node.up or owner not in node.stores:
        return
    _get_wos_buffer(db, host, owner, mesh, axis, int(mesh.shape[axis]))


def _wos_parts(db: VerticaDB, plan, need: Sequence[str],
               reseg_keys: Sequence[str], as_of: int, mesh, axis: str,
               n_shards: int) -> List[dict]:
    """Per-source WOS slab views at this query's snapshot: the cached
    device buffer's columns subset to ``need``, with ONLY the epoch
    visibility mask built host-side and uploaded (one small bool array).
    Capacity accounting (``r0``/``real``) counts ALL buffered rows --
    rows invisible at this epoch still occupy slots whose destinations
    are their real ring targets, so undercounting them could overflow a
    later exchange."""
    parts = []
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        buf = _get_wos_buffer(db, host, owner, mesh, axis, n_shards)
        if buf is None:
            continue
        w = fused_exec.wos_visible(store, as_of)
        if w is None:
            continue
        vis = np.asarray(w[1], bool)
        if not vis.any():
            continue
        order, ss, pos = buf["layout"]
        vbuf = np.zeros((n_shards, buf["per"]), bool)
        vbuf[ss, pos] = vis[order]
        valid = jax.device_put(vbuf.reshape(-1),
                               NamedSharding(mesh, P(axis)))
        parts.append({
            "cols": {c: buf["cols"][c] for c in need},
            "valid": valid,
            "dests": {k: buf["dests"][k] for k in reseg_keys},
            "per": buf["per"], "n_rows": int(vis.sum()),
            "r0": buf["r0"],
            "real": {k: buf["real"][k] for k in reseg_keys},
            "bounds": {c: buf["bounds"][c] for c in need}})
    return parts


# ------------------------------------------------- slab concatenation --

def _build_concat_program(mesh, axis: str):
    """Append one slab to another shard-locally (both are already
    partitioned by the same ring map, so this is pure local
    concatenation -- no collective)."""

    def local_fn(a_cols, a_valid, a_dests, b_cols, b_valid, b_dests):
        cols = {c: jnp.concatenate([a_cols[c], b_cols[c]])
                for c in a_cols}
        valid = jnp.concatenate([a_valid, b_valid])
        dests = {k: jnp.concatenate([a_dests[k], b_dests[k]])
                 for k in a_dests}
        return cols, valid, dests

    fn = shard_map(local_fn, mesh=mesh, in_specs=(P(axis),) * 6,
                   out_specs=(P(axis),) * 3)
    return jax.jit(fn)


def _merge_bounds(a: Optional[tuple], b: Optional[tuple]
                  ) -> Optional[tuple]:
    if a is None or b is None:
        return None
    return (min(a[0], b[0]), max(a[1], b[1]))


def _concat_slabs(ros: dict, wos: dict, mesh, axis: str) -> dict:
    fn, _ = PLAN_CACHE.get_or_build(
        ("seg-concat", _mesh_sig(mesh, axis),
         tuple(sorted(ros["cols"])), tuple(sorted(ros["dests"]))),
        lambda: _build_concat_program(mesh, axis))
    cols, valid, dests = fn(ros["cols"], ros["valid"], ros["dests"],
                            wos["cols"], wos["valid"], wos["dests"])
    return {"cols": cols, "valid": valid, "dests": dests,
            "per": ros["per"] + wos["per"],
            "n_rows": ros["n_rows"] + wos["n_rows"],
            "real": {k: ros["real"][k] + wos["real"][k]
                     for k in ros["real"]},
            "r0": ros["r0"] + wos["r0"],
            "bounds": {c: _merge_bounds(ros["bounds"][c],
                                        wos["bounds"][c])
                       for c in ros["bounds"]}}


# ------------------------------------------------ slab-block pruning --

def _build_prune_program(mesh, axis: str, n_shards: int,
                         names: Tuple[str, ...], dkeys: Tuple[str, ...],
                         per_in: int, k2: int, sb: int):
    nb = per_in // sb

    def local_fn(cols, valid, dests, idx, live):
        me = jax.lax.axis_index(axis).astype(jnp.int32)
        liv = jnp.repeat(live, sb)
        out_cols = {c: v.reshape(nb, sb)[idx].reshape(-1)
                    for c, v in cols.items()}
        valid_g = valid.reshape(nb, sb)[idx].reshape(-1) & liv
        # gathered pad blocks replay block 0's destinations: re-point
        # them at their own shard or they would travel on the next
        # exchange and break the capacity accounting
        out_dests = {k: jnp.where(liv,
                                  d.reshape(nb, sb)[idx].reshape(-1), me)
                     for k, d in dests.items()}
        # EXACT per-destination histograms over the surviving rows: the
        # staged capacity proof needs ``real`` to count precisely the
        # rows occupying slots (a pre-prune overestimate could undersize
        # a SECOND resegment stage's own-shard pad accounting)
        vi = valid_g.astype(jnp.int32)
        reals = {k: jax.lax.psum(
            (jax.nn.one_hot(d, n_shards, dtype=jnp.int32)
             * vi[:, None]).sum(axis=0), axis)
            for k, d in out_dests.items()}
        return out_cols, valid_g, out_dests, reals

    fn = shard_map(local_fn, mesh=mesh, in_specs=(P(axis),) * 5,
                   out_specs=(P(axis), P(axis), P(axis), P()))
    return jax.jit(fn)


def _prune_slab(q: LogicalQuery, slab: dict, mesh, axis: str,
                n_shards: int, stats) -> dict:
    """Per-query slab-block pruning: the predicate's column bounds
    against the slab's per-block SMAs (device-computed at build time)
    select the surviving ``sb``-row blocks; each shard gathers just
    those.  Conservative by construction -- a pruned block contains no
    row satisfying the predicate, and the segmented subset only runs
    inner joins, which never resurrect rows."""
    if "bstats" not in slab:
        return slab
    bcounts, bmins, bmaxs = slab["bstats"]
    total = int(bcounts.size)
    stats.blocks_total += total
    if q.predicate is None:
        return slab
    pbounds = q.predicate.bounds()
    keep = bcounts > 0
    applied = False
    for c, (lo, hi) in pbounds.items():
        if c not in bmins:
            continue
        lo = -np.inf if lo is None else lo
        hi = np.inf if hi is None else hi
        keep &= (bmaxs[c] >= lo) & (bmins[c] <= hi)
        applied = True
    if not applied:
        return slab
    kept = int(keep.sum())
    stats.blocks_pruned += total - kept
    if kept == total:
        return slab
    sb = slab["sb"]
    nb = slab["per"] // sb
    # static gather width: max surviving blocks on any shard, bucketed
    # to a power of two so repeat queries reuse a handful of traces.
    # kept == 0 keeps one all-dead block -- the program runs with every
    # row invalid and yields exactly the empty aggregation a predicate
    # matching nothing produces
    k2 = min(_pow2_at_least(max(int(keep.sum(axis=1).max()), 1)), nb)
    idx = np.zeros((n_shards, k2), np.int32)
    live = np.zeros((n_shards, k2), bool)
    for s in range(n_shards):
        ki = np.flatnonzero(keep[s])[:k2]
        idx[s, :len(ki)] = ki
        live[s, :len(ki)] = True
    sharding = NamedSharding(mesh, P(axis))
    idx_d = jax.device_put(idx.reshape(-1), sharding)
    live_d = jax.device_put(live.reshape(-1), sharding)
    names = tuple(sorted(slab["cols"]))
    dkeys = tuple(sorted(slab["dests"]))
    fn, _ = PLAN_CACHE.get_or_build(
        ("seg-prune", _mesh_sig(mesh, axis), names, dkeys,
         slab["per"], k2, sb),
        lambda: _build_prune_program(mesh, axis, n_shards, names, dkeys,
                                     slab["per"], k2, sb))
    cols, valid, dests, reals = fn(slab["cols"], slab["valid"],
                                   slab["dests"], idx_d, live_d)
    r0_kept = np.array([int(bcounts[s][keep[s]].sum())
                        for s in range(n_shards)], np.int64)
    out = dict(slab)
    out.update(cols=cols, valid=valid, dests=dests, per=k2 * sb,
               r0=r0_kept,
               real={k: np.asarray(v).astype(np.int64)
                     for k, v in reals.items()})
    out.pop("bstats", None)
    return out


def _sharded_scan(db: VerticaDB, proj, plan, q: LogicalQuery, need,
                  reseg_keys, as_of: int, mesh, axis: str, n_shards: int,
                  stats) -> Optional[dict]:
    """Partitioned scan: the device-built ROS slab is cached (keyed by
    the effective epoch + exact container set, invalidated precisely by
    the tuple mover), pruned per query against its block SMAs, then the
    per-store WOS buffer views are appended shard-locally -- a
    trickle-load commit therefore costs one small WOS visibility upload,
    never a whole-projection repartition."""
    # injection points: one per source store feeding the slab.  A crash
    # here fails the host node and escalates to query-level failover (the
    # retry replans onto buddy stores); transients retry in place.
    for host, owner in plan.sources:
        point = "segmented.buddy_read" \
            if db.catalog.projections[owner].buddy_of is not None \
            else "segmented.slab_build"
        fire_with_retries(db, point, stats=stats, node=host,
                          projection=owner)
    cache = getattr(db, "block_cache", None)
    ros = None
    if cache is None:
        ros = _build_ros_slab_device(db, proj, plan, need, reseg_keys,
                                     as_of, mesh, axis, n_shards, stats)
        stats.seg_slab = "nocache"
    else:
        ceil = max((db.nodes[h].stores[o].epoch_ceiling(include_wos=False)
                    for h, o in plan.sources), default=0)
        eff = min(as_of, ceil)
        sig = _source_sig(db, plan, need, reseg_keys, eff, mesh, axis)
        ids = frozenset(i for item in sig[0] for i in item[2])
        key = ("slab", ids, sig)
        cid = f"seg:{plan.projection}"
        ros = cache.get(cid, key, KIND_SEG)
        stats.seg_slab = "hit" if ros is not None else "miss"
        if ros is None:
            ros = _build_ros_slab_device(db, proj, plan, need, reseg_keys,
                                         eff, mesh, axis, n_shards, stats)
            if ros is not None:
                cache.put(cid, key, KIND_SEG, ros, _slab_bytes(ros))
    wos_parts = _wos_parts(db, plan, need, reseg_keys, as_of, mesh, axis,
                           n_shards)
    if wos_parts:
        stats.seg_slab += "+wos"
    if ros is None and not wos_parts:
        return None
    stats.rows_scanned = (0 if ros is None else ros["n_rows"]) \
        + sum(p["n_rows"] for p in wos_parts)
    if ros is not None:
        ros = _prune_slab(q, ros, mesh, axis, n_shards, stats)
    parts = ([] if ros is None else [ros]) + wos_parts
    slab = parts[0]
    for p in parts[1:]:
        slab = _concat_slabs(slab, p, mesh, axis)
    return slab


# ---------------------------------------------------------------------------
# 2. Build-side placement per exchange strategy
# ---------------------------------------------------------------------------

def _partition_build(bnp: Dict[str, np.ndarray], shard: np.ndarray,
                     n_shards: int, mesh, axis: str
                     ) -> Dict[str, jax.Array]:
    """Place dimension rows onto shards by hash(dim_key), padded per shard
    with copies of row 0.  A pad copy is harmless: a probe key equal to
    the pad's key hashes to the pad's home shard, so on any other shard no
    probe row can match it, and on its home shard the duplicate carries
    identical values."""
    sharding = NamedSharding(mesh, P(axis))
    n = len(shard)
    if n == 0:
        return {c: jax.device_put(np.zeros(0, _canon_np(v).dtype), sharding)
                for c, v in bnp.items()}
    order, ss, pos, counts = _slab_positions(shard, n_shards)
    per = max(int(counts.max()), 1)
    out = {}
    for c, v in bnp.items():
        v = _canon_np(v)
        buf = np.full((n_shards, per), v[0], v.dtype)
        buf[ss, pos] = v[order]
        out[c] = jax.device_put(buf.reshape(-1), sharding)
    return out


def _broadcast_build(bnp: Dict[str, np.ndarray], n_shards: int, mesh,
                     axis: str) -> Dict[str, jax.Array]:
    """Split the build side contiguously across shards, then replicate it
    with a real all_gather (exchange.broadcast_build_side)."""
    sharding = NamedSharding(mesh, P(axis))
    n = len(next(iter(bnp.values())))
    per = -(-n // n_shards) if n else 0
    cols = {}
    for c, v in bnp.items():
        v = _canon_np(v)
        if n == 0:
            buf = np.zeros(0, v.dtype)
        else:
            buf = np.full(n_shards * per, v[0], v.dtype)
            buf[:n] = v
        cols[c] = jax.device_put(buf, sharding)
    if n == 0:
        return cols               # nothing to gather
    return exchange.broadcast_build_side(mesh, axis, cols)


def _place_one_build(db: VerticaDB, spec, exch: str,
                     build: Dict[str, jax.Array], mesh, axis: str,
                     n_shards: int, replicated_dim: bool
                     ) -> Tuple[Dict[str, jax.Array], Dict]:
    """(placed device arrays, per-column host bounds) for one join."""
    bnp = {c: np.asarray(v) for c, v in build.items()}
    bounds = {}
    for c, v in bnp.items():
        if not v.size:
            bounds[c] = (0, 0)
        elif v.dtype.kind in "iub":
            bounds[c] = (int(v.min()), int(v.max()))
        else:
            bounds[c] = None
    if exch == "broadcast":
        return _broadcast_build(bnp, n_shards, mesh, axis), bounds
    if exch == "local" and replicated_dim:
        return {c: jax.device_put(
            jnp.asarray(_canon_np(v)), NamedSharding(mesh, P()))
            for c, v in bnp.items()}, bounds
    # co-located (probe placed by the join key) or the dim side of a
    # resegment: place rows by hash(dim_key) on the same offset-free
    # ring map the probe side uses
    shard = shard_of(hash_columns(bnp[spec.dim_key]), n_shards)
    return _partition_build(bnp, shard, n_shards, mesh, axis), bounds


def _place_builds(db: VerticaDB, q: LogicalQuery, plan, as_of: int, mesh,
                  axis: str, n_shards: int, stats=None
                  ) -> Tuple[List[Dict[str, jax.Array]], List, List[Dict]]:
    """Returns (placed build dicts, per-join shard_map specs, per-join
    dim-column bounds).  Placed builds are cached device-side keyed by
    (dim table, join signature, exchange op, mesh identity, snapshot
    epoch) -- MVCC makes the fixed-epoch read immutable, so a warm
    repeat skips the host round-trip, re-partition AND (for broadcast
    joins) the all_gather; drop_partition invalidates the dim's entries."""
    builds_dev = fused_exec.build_join_sides(db, q, as_of)
    cache = getattr(db, "block_cache", None)
    mh = hash(_mesh_sig(mesh, axis)) & 0xFFFFFFFFFFFFFFFF
    placed, specs, bounds = [], [], []
    for spec, exch, build in zip(q.joins, plan.join_exchanges, builds_dev):
        replicated_dim = db.catalog.super_of(
            spec.dim_table).segmentation.replicated
        specs.append(P() if exch == "broadcast"
                     or (exch == "local" and replicated_dim) else P(axis))
        if exch == "broadcast":
            # the all_gather of the small build side is a collective too:
            # a crash/transient here follows the same taxonomy
            fire_with_retries(db, "exchange.broadcast", stats=stats,
                              join=spec.dim_table)

        def make(spec=spec, exch=exch, build=build,
                 replicated_dim=replicated_dim):
            return _place_one_build(db, spec, exch, build, mesh, axis,
                                    n_shards, replicated_dim)
        if cache is None:
            pb = make()
        else:
            pb = cache.get_or_put(
                f"dim:{spec.dim_table}",
                f"seg|{spec.signature()}|{exch}|{mh:016x}@{as_of}",
                fused_exec.KIND_BUILD, make,
                lambda v: sum(int(a.size) * a.dtype.itemsize
                              for a in v[0].values()))
        placed.append(pb[0])
        bounds.append(pb[1])
    return placed, specs, bounds


# ---------------------------------------------------------------------------
# 3. Fused stage programs (plan-cached factories)
# ---------------------------------------------------------------------------

def _mesh_sig(mesh, axis: str) -> tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat), axis)


def _build_stage_factory(mesh, axis: str, n_shards: int, specs: Sequence,
                         build_specs: Sequence,
                         reseg_key: Optional[str], final_cfg):
    """One exchange->join(->pre-agg) stage as a SINGLE shard_map'd jitted
    program: ``exchange.resegment_local`` (when the stage opens with a
    Send/Recv), the stage's hash joins, and -- for the final stage --
    derived exprs, deferred predicate, key packing and the shard-local
    pre-aggregation.  The per-shard exchange OVERFLOW report is returned
    as an output instead of being checked inline, so a multi-stage query
    dispatches its whole chain without a host sync in the middle.

    Returns a factory memoizing the jitted program per static
    (input slots, exchange capacity) pair: the plan cache keys the
    factory by plan/mesh signature alone, and data-size changes retrace
    inside the entry without demoting it to a miss."""
    reseg = reseg_key is not None

    if final_cfg is not None:
        (ir, algo, domains, lows, domain, local_aggs, values_cols,
         packed) = final_cfg

    def build(per_new: int):
        def local_fn(cols, valid, dests, shard_idx, builds):
            cols = dict(cols)
            dests = dict(dests)
            if reseg:
                dest_l = dests.pop(reseg_key)
                names = sorted(cols)
                dkeys = sorted(dests)
                vals = (tuple(cols[c] for c in names)
                        + tuple(dests[k] for k in dkeys)
                        + (valid.astype(jnp.int8),))
                outs, vr, overflow = exchange.resegment_local(
                    axis, n_shards, per_new, dest_l, vals)
                nn = len(names)
                cols = dict(zip(names, outs[:nn]))
                # empty slots point at their own shard so the NEXT
                # exchange leaves them in place; occupied slots keep
                # their moved destination (a join-invalidated row's
                # destination is still counted by the build histogram)
                dests = {k: jnp.where(vr, outs[nn + i], shard_idx)
                         for i, k in enumerate(dkeys)}
                valid = (outs[-1] != 0) & vr
            else:
                overflow = jnp.zeros((n_shards,), jnp.int32)
            for spec, bld in zip(specs, builds):
                cols, valid = ops.hash_join(bld, spec.dim_key, cols,
                                            spec.fact_key, valid,
                                            how=spec.how)
            if final_cfg is None:
                out = dict(cols)
                out["__valid"] = valid
                for k, d in dests.items():
                    out["__d:" + k] = d
                return out, overflow
            for name, e in ir.derived:
                cols[name] = e(cols)
            if ir.predicate is not None:
                valid = valid & jnp.asarray(ir.predicate(cols), bool)
            values = {c: cols[c] for c in values_cols}
            if not ir.group_by:
                keys = jnp.zeros(valid.shape[0], jnp.int32)
                out = seg_preagg(keys, valid, values, 1, local_aggs)
                return ({k: v.reshape(-1) for k, v in out.items()},
                        overflow)
            keys = ops.pack_keys([cols[g] for g in ir.group_by],
                                 domains, lows) \
                if packed else cols[ir.group_by[0]]
            if algo == "dense":
                out = seg_preagg(keys.astype(jnp.int32), valid, values,
                                 domain, local_aggs)
            else:
                out = ops.groupby_sort(keys, valid, values, domain,
                                       local_aggs)
            return ({k: jnp.reshape(v, (-1,)) for k, v in out.items()},
                    overflow)

        fn = shard_map(local_fn, mesh=mesh,
                       in_specs=(P(axis), P(axis), P(axis), P(axis),
                                 tuple(build_specs)),
                       out_specs=(P(axis), P()))
        return jax.jit(fn)

    progs: Dict[int, object] = {}

    def get(per_new: int):
        fn = progs.get(per_new)
        if fn is None:
            fn = progs[per_new] = build(per_new)
        return fn

    return get


# ---------------------------------------------------------------------------
# 4. Final merge (host-side, over small partials)
# ---------------------------------------------------------------------------

def _merge_scalar(aggs, res, n_shards: int) -> Dict[str, np.ndarray]:
    counts = np.asarray(res["group_count"]).reshape(n_shards)
    total = int(counts.sum())
    out = {"group_count": np.asarray([total])}
    for name, _, kind in aggs:
        v = np.asarray(res[name]).reshape(n_shards)
        if kind in ("sum", "count"):
            out[name] = np.asarray([v.sum()])
        elif kind == "avg":
            out[name] = np.asarray([v.sum() / max(total, 1)])
        elif kind == "min":
            out[name] = np.asarray([v.min()])
        else:
            out[name] = np.asarray([v.max()])
    return out


def _merge_dense(aggs, res, n_shards: int, domain: int
                 ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    counts = np.asarray(res["group_count"]).reshape(n_shards, domain)
    counts = counts.sum(0)
    sel = counts > 0
    gkeys = np.flatnonzero(sel)
    out = {"group_count": counts[sel]}
    for name, _, kind in aggs:
        v = np.asarray(res[name]).reshape(n_shards, domain)
        if kind in ("sum", "count"):
            m = v.sum(0)
        elif kind == "avg":
            m = v.sum(0) / np.maximum(counts, 1)
        elif kind == "min":
            m = v.min(0)
        else:
            m = v.max(0)
        out[name] = m[sel]
    return gkeys, out


def _merge_sorted(aggs, res, n_shards: int, max_groups: int
                  ) -> Optional[Tuple[np.ndarray, Dict[str, np.ndarray]]]:
    ngs = np.asarray(res["n_groups"]).reshape(n_shards)
    if (ngs > max_groups).any():
        return None               # local sort cap exceeded: fall back
    gk = np.asarray(res["group_keys"]).reshape(n_shards, max_groups)
    gc = np.asarray(res["group_count"]).reshape(n_shards, max_groups)
    keys = np.concatenate([gk[s, :ngs[s]] for s in range(n_shards)])
    cnts = np.concatenate([gc[s, :ngs[s]] for s in range(n_shards)])
    if keys.size == 0:
        return np.zeros(0, np.int64), {
            "group_count": np.zeros(0, np.int64),
            **{name: np.zeros(0) for name, _, _ in aggs}}
    uniq, inv = np.unique(keys, return_inverse=True)
    ng = len(uniq)
    counts = np.bincount(inv, weights=cnts, minlength=ng).astype(np.int64)
    out = {"group_count": counts}
    for name, _, kind in aggs:
        pv = np.asarray(res[name])
        v = np.concatenate([pv.reshape(
            n_shards, max_groups)[s, :ngs[s]] for s in range(n_shards)])
        if kind in ("sum", "count", "avg"):
            acc = np.bincount(inv, weights=v, minlength=ng)
            if kind == "avg":
                acc = acc / np.maximum(counts, 1)
        elif kind == "min":
            acc = np.full(ng, np.inf)
            np.minimum.at(acc, inv, v)
        else:
            acc = np.full(ng, -np.inf)
            np.maximum.at(acc, inv, v)
        # integer partials stay integral (the single-node path returns
        # int sums/mins/maxes for int columns; only avg is a ratio)
        if kind != "avg" and pv.dtype.kind in "iub":
            acc = acc.astype(np.int64)
        out[name] = acc
    return uniq, out


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def execute_segmented(db: VerticaDB, q: LogicalQuery, plan, as_of: int,
                      mesh, axis: str, stats
                      ) -> Optional[Dict[str, np.ndarray]]:
    """Run an aggregate query segmented across the mesh.  Returns the
    merged (pre-HAVING/ORDER/LIMIT) result columns, or None to fall back
    to the single-node pipeline."""
    if not (q.aggs or q.group_by):
        return None               # plain selects stay single-node
    if any(j.how != "inner" for j in q.joins):
        return None
    derived_names = {n for n, _ in q.derived}
    if any(g in derived_names for g in q.group_by):
        return None               # no static pack bounds for derived keys

    n_shards = int(mesh.shape[axis])

    # ---- RLE-direct routes: aggregate each node's encoded runs on the
    # host and merge -- the paper's "operate directly on encoded data"
    # beats shipping 2M decoded rows through slabs for count-only
    # GroupBys on the sort leader (no predicate/joins/WOS/deletes; the
    # helpers return None otherwise and the slab path runs) ----
    from . import pipeline as _pipe
    if plan.scalar_rle:
        res = _pipe._rle_scalar_count(db, q, plan, as_of)
        if res is not None:
            stats.segmented = True
            stats.n_shards = n_shards
            stats.exchange = ";".join(plan.join_exchanges)
            stats.groupby_algorithm = "rle-scalar (segmented)"
            return res
    if _pipe.rle_direct_eligible(q, plan):
        res = _pipe._rle_groupby(db, q, plan, as_of)
        if res is not None:
            stats.segmented = True
            stats.n_shards = n_shards
            stats.exchange = ";".join(plan.join_exchanges)
            stats.groupby_algorithm = "rle (segmented)"
            return res

    proj = db.catalog.projections[plan.projection]
    reseg_keys = tuple(spec.fact_key for spec, e
                       in zip(q.joins, plan.join_exchanges)
                       if e == "resegment")
    need = set(q.scan_columns(proj))
    if not proj.segmentation.replicated:
        need |= set(proj.segmentation.columns)
    need |= set(reseg_keys)
    need = sorted(need & set(proj.columns))

    # per-stage wall clocks (ExecStats.stage_ms): opt-in because honest
    # stage boundaries need a device sync, which the pipelined normal
    # path must not pay.  The cstore bench's mesh8 tier flips this on.
    timing = bool(getattr(db, "collect_stage_timing", False))

    def _tick(label: str, t0: float, out) -> float:
        if timing:
            jax.block_until_ready(jax.tree.leaves(out))
            t1 = time.perf_counter()
            stats.stage_ms[label] = stats.stage_ms.get(label, 0.0) \
                + (t1 - t0) * 1e3
            return t1
        return t0

    t0 = time.perf_counter() if timing else 0.0
    slab = _sharded_scan(db, proj, plan, q, need, reseg_keys, as_of, mesh,
                         axis, n_shards, stats)
    if slab is None:
        return None               # empty snapshot: pipeline shapes it
    _tick("slab_build", t0, (slab["cols"], slab["valid"]))

    builds, build_specs, build_bounds = _place_builds(
        db, q, plan, as_of, mesh, axis, n_shards, stats)

    # ---- static pack radices for the group keys (exact host bounds) ----
    aggs = tuple(q.aggs)
    lows: Tuple[int, ...] = ()
    domains: Tuple[int, ...] = ()
    algo, domain = "dense", 1
    if q.group_by:
        los, doms = [], []
        for g in q.group_by:
            b = slab["bounds"].get(g)
            if b is None:
                for spec, bnds in zip(q.joins, build_bounds):
                    if g in spec.dim_columns:
                        b = bnds.get(g)
                        break
            if b is None:
                return None       # non-integral / unlocatable group key
            lo, hi = b
            lo = min(lo, 0)
            los.append(lo)
            doms.append(hi - lo + 1)
        total = 1
        for d in doms:
            total *= d
        if total >= _PACK_LIMIT:
            return None           # packed key overflows device int32
        lows, domains = tuple(los), tuple(doms)
        algo = "dense" if total <= plan.dense_domain_limit else "sort"
        domain = total if algo == "dense" else plan.max_groups

    values_cols = tuple(sorted({c for _, c, kind in aggs
                                if kind != "count" and c != "*"}))
    local_aggs = tuple((name, c, "sum" if kind == "avg" else kind)
                       for name, c, kind in aggs)
    packed = len(q.group_by) > 1 or (bool(lows) and lows[0] != 0)
    final_cfg = (q, algo, domains, lows, domain, local_aggs, values_cols,
                 packed)

    # ---- staged execution: joins run in plan order, with a resegment
    # exchange (Send/Recv) opening the stage of the join that needs it --
    # an up-front exchange would destroy the placement an earlier
    # co-located join depends on.  Each stage is ONE fused program ----
    stage_joins: List[List[int]] = [[]]
    for ji, exch in enumerate(plan.join_exchanges):
        if exch == "resegment":
            stage_joins.append([])
        stage_joins[-1].append(ji)

    mesh_sig = _mesh_sig(mesh, axis)
    hit_all = True

    def run_stages(mult: int):
        nonlocal hit_all
        cols, valid = dict(slab["cols"]), slab["valid"]
        dest_cols = dict(slab["dests"])
        per_prev, real_prev = slab["per"], slab["r0"]
        overflows = []
        res = None
        ts = time.perf_counter() if timing else 0.0
        for si, stage in enumerate(stage_joins):
            final = si == len(stage_joins) - 1
            reseg_key = None
            per_new = 0
            if si > 0:
                spec0 = q.joins[stage[0]]
                reseg_key = spec0.fact_key
                if reseg_key not in dest_cols:
                    return None   # no destination column: fall back
                real_k = slab["real"][reseg_key]
                # exact destination occupancy: arriving rows + slots
                # that stay (pads and earlier arrivals not moving again)
                filled = real_k + per_prev - real_prev
                per_new = cost_mod.resegment_capacity(
                    filled, n_shards) // n_shards * mult
                fire_with_retries(db, "exchange.resegment", stats=stats,
                                  join=spec0.dim_table)
            elif not final and not stage:
                continue          # leading resegment: nothing local yet
            specs = tuple(q.joins[ji] for ji in stage)
            sb = tuple(builds[ji] for ji in stage)
            sbs = tuple(build_specs[ji] for ji in stage)
            if final:
                sig = ("seg2", q.exec_signature(), plan.projection,
                       proj.segmentation.kind,
                       tuple(proj.segmentation.columns), mesh_sig,
                       plan.join_exchanges,
                       tuple(bs == P() for bs in build_specs),
                       algo, int(domain), domains, lows, reseg_key)
                cfg = final_cfg
            else:
                sig = ("seg-stage2",
                       tuple(s.signature() for s in specs),
                       tuple(bs == P() for bs in sbs), mesh_sig,
                       reseg_key)
                cfg = None
            factory, hit = PLAN_CACHE.get_or_build(
                sig, lambda: _build_stage_factory(mesh, axis, n_shards,
                                                  specs, sbs, reseg_key,
                                                  cfg))
            hit_all &= hit
            fn = factory(per_new)
            sidx = _shard_index_col(
                mesh, axis, n_shards,
                n_shards * per_new if reseg_key else 1)
            out, overflow = fn(cols, valid, dest_cols, sidx, sb)
            if reseg_key is not None:
                overflows.append(overflow)
                per_prev, real_prev = n_shards * per_new, real_k
            if final:
                # the final fused stage ends in the shard-local scatter
                # pre-aggregation (kernels/seg_preagg)
                ts = _tick("preagg", ts, out)
                res = out
            else:
                ts = _tick("exchange_join", ts, out)
                valid = out.pop("__valid")
                dest_cols = {k[4:]: v for k, v in out.items()
                             if k.startswith("__d:")}
                cols = {c: v for c, v in out.items()
                        if not c.startswith("__")}
        return res, overflows

    # overflow is checked ONCE, after the final dispatch: capacities come
    # from exact histograms so a nonzero report is defensive -- record,
    # double every stage's capacity, retry the whole chain, then fall back
    res = None
    for mult in (1, 2):
        r = run_stages(mult)
        if r is None:
            return None
        res0, overflows = r
        ov = sum(int(np.asarray(o).sum()) for o in overflows)
        if ov == 0:
            res = res0
            break
        stats.reseg_overflow += ov
    if res is None:
        return None
    stats.plan_cache = "hit" if hit_all else "miss"

    # ---- final merge ----
    t0 = time.perf_counter() if timing else 0.0
    if not q.group_by:
        out = _merge_scalar(aggs, res, n_shards)
    else:
        merged = _merge_dense(aggs, res, n_shards, domain) \
            if algo == "dense" else _merge_sorted(aggs, res, n_shards,
                                                  domain)
        if merged is None:
            return None
        gkeys, out = merged
        key_cols = ops.unpack_keys(gkeys, domains, lows) if packed \
            else [np.asarray(gkeys).astype(np.int64)]
        for g, kv in zip(q.group_by, key_cols):
            out[g] = kv
    _tick("final_merge", t0, ())
    stats.segmented = True
    stats.n_shards = n_shards
    stats.exchange = ";".join(plan.join_exchanges)
    stats.groupby_algorithm = f"{algo} (segmented)"
    return out
