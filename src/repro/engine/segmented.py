"""Segmented multi-node query execution on a jax device mesh.

This is the scale-out half of the paper made real in the engine: a
projection's ``SegmentationSpec`` (§3.6) decides which *device shard* owns
each tuple, Send/Recv (§6.1) runs as ``exchange.resegment`` /
``exchange.broadcast_build_side`` collectives, and buddy projections
(§5.2) keep every segment scannable when a node is down -- the planner's
``plan.sources`` routing already walks buddies, so ``fail_node()``
failover is transparent here too.

Execution shape (one query):

  1. **Gather + partition** (host): snapshot the projection's visible rows
     from every live source store (ROS decode goes through the device
     block cache), hash the segmentation columns onto the ring, and pack
     each shard's rows into a static ``(n_shards, per)`` slab that is
     ``device_put`` sharded over the mesh axis.  The partitioned slab is
     itself cached (``KIND_SEG``) keyed by snapshot epoch, mesh width and
     the exact container set, so warm repeats skip the host pass.
  2. **Exchange** (device collectives): per join, the planner's
     ``plan.join_exchanges`` decision runs -- ``local`` (co-located;
     dimension rows placed by hash(dim_key), zero network),
     ``broadcast`` (all_gather of the small build side), or
     ``resegment`` (all_to_all of the probe side to hash(fact_key)
     ownership, with the reported per-shard overflow checked).
  3. **Shard-local program** (one shard_map'd jitted executable, memoized
     in the plan cache): local hash joins, derived projections, deferred
     predicate, mixed-radix key packing, and a shard-local pre-aggregation
     (dense scatter over the packed domain, or sort-based partials).
  4. **Final merge** (host, small): partial counts/sums add, min/max
     combine, avg = merged sum / merged count; packed keys unpack.

The plan-cache signature includes the mesh identity, the projection's
segmentation, the per-join exchange ops and the pack radices -- two mesh
shapes (or a re-segmented projection) can never share an executable.

Falls back to the single-node pipeline (returns None) for shapes outside
the segmented subset: plain selects, non-inner joins, derived group keys,
or group domains past the device integer width.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.block_cache import KIND_SEG
from ..core.database import VerticaDB
from ..core.faults import fire_with_retries, with_retries
from ..core.segmentation import hash_columns, shard_of
from ..planner import cost as cost_mod
from . import exchange
from . import executor as fused_exec
from . import operators as ops
from .executor import PLAN_CACHE
from .logical import LogicalQuery

_PACK_LIMIT = 1 << 31         # packed keys live in device int32
_PAD_MULTIPLE = 8


def _round_up(n: int, m: int = _PAD_MULTIPLE) -> int:
    return -(-max(int(n), 1) // m) * m


# ---------------------------------------------------------------------------
# 1. Gather + partition: host rows -> per-shard slabs (cached)
# ---------------------------------------------------------------------------

def _canon_np(v: np.ndarray) -> np.ndarray:
    """Match the single-node path's device canonicalization (jax default
    32-bit runtime) so both execution models aggregate identical dtypes."""
    if jax.config.jax_enable_x64:
        return v
    if v.dtype.kind in "iu" and v.dtype.itemsize > 4:
        return v.astype(np.int32)
    if v.dtype.kind == "f" and v.dtype.itemsize > 4:
        return v.astype(np.float32)
    return v


def _source_sig(db: VerticaDB, plan, need, reseg_keys, eff: int,
                mesh, axis: str) -> tuple:
    """Identity of a cached ROS slab: *effective* snapshot epoch (the
    query's as-of clamped to the sources' ROS epoch ceiling -- trickle
    commits that only touched the WOS advance the cluster epoch without
    changing ROS visibility, so warm slabs survive them), mesh identity,
    needed columns, resegment keys, and the exact physical container set
    (the tuple mover retires containers by replacing ids, so a mergeout
    or moveout naturally misses -- and ``ProjectionStore.
    invalidate_seg_slabs`` evicts precisely those entries)."""
    items = []
    for host, owner in plan.sources:
        store = db.nodes[host].stores[owner]
        items.append((host, owner,
                      tuple(c.id for c in store.containers)))
    return (tuple(items), tuple(need), tuple(reseg_keys), int(eff),
            _mesh_sig(mesh, axis))


def _slab_positions(shard: np.ndarray, n_shards: int):
    """Stable within-shard slot assignment shared by row and build-side
    packing: returns (order, sorted_shard, pos, counts) such that source
    row ``order[i]`` belongs in slab slot ``[sorted_shard[i], pos[i]]``."""
    counts = np.bincount(shard, minlength=n_shards)
    order = np.argsort(shard, kind="stable")
    ss = shard[order]
    starts = np.zeros(n_shards, np.int64)
    starts[1:] = np.cumsum(counts)[:-1]
    pos = np.arange(len(shard)) - starts[ss]
    return order, ss, pos, counts


# own-shard index columns for exchange pad slots, cached per (mesh, width)
# so warm resegment queries skip the host build + upload
_SHARD_IDX_CACHE: Dict[tuple, jax.Array] = {}


def _shard_index_col(mesh, axis: str, n_shards: int,
                     per_local: int) -> jax.Array:
    key = (_mesh_sig(mesh, axis), per_local)
    v = _SHARD_IDX_CACHE.get(key)
    if v is None:
        if len(_SHARD_IDX_CACHE) > 64:
            _SHARD_IDX_CACHE.clear()
        v = jax.device_put(
            np.repeat(np.arange(n_shards, dtype=np.int32), per_local),
            NamedSharding(mesh, P(axis)))
        _SHARD_IDX_CACHE[key] = v
    return v


def _slab_bytes(slab: dict) -> int:
    n = 0
    for v in slab["cols"].values():
        n += int(v.size) * v.dtype.itemsize
    for v in slab["dests"].values():
        n += int(v.size) * v.dtype.itemsize
    n += int(slab["valid"].size)
    return n


def _shard_assignment(proj, cols_np: Dict[str, np.ndarray], n: int,
                      n_shards: int, ring: Optional[np.ndarray] = None,
                      base: int = 0) -> np.ndarray:
    """Device shard per row: ring hash of the segmentation columns,
    OFFSET-FREE (core/segmentation.shard_of) -- the same logical row must
    land on the same shard whether the primary or the ring-offset buddy
    store served it.  Trickle-loaded WOS rows arrive with their ring
    value already stamped at commit (``ring``), so no re-hash.
    Replicated projections have no ring: spread rows round-robin."""
    seg = proj.segmentation
    if seg.replicated:
        return ((base + np.arange(n, dtype=np.int64))
                % n_shards).astype(np.int32)
    if ring is None:
        ring = hash_columns(*[cols_np[c] for c in seg.columns])
    return shard_of(ring, n_shards)


def _partition_to_slab(cols_np: Dict[str, np.ndarray], shard: np.ndarray,
                       reseg_keys: Sequence[str], n_shards: int, mesh,
                       axis: str) -> dict:
    """Pack host rows (already masked + canonicalized) into a static
    ``(n_shards, per)`` device slab from each row's shard assignment."""
    n = len(shard)
    # resegment destinations (hash of each future join key) are computed
    # here, on the host rows, because a snowflake key that only exists
    # after a join was already demoted to broadcast by the planner
    dests = {k: shard_of(hash_columns(cols_np[k]), n_shards)
             for k in reseg_keys}

    # observed per-column bounds: static pack radices for the shard
    # program (exact, tighter than SMA estimates)
    bounds = {}
    for c, v in cols_np.items():
        bounds[c] = (int(v.min()), int(v.max())) \
            if v.dtype.kind in "iub" else None

    order, ss, pos, counts = _slab_positions(shard, n_shards)
    per = _round_up(counts.max())

    sharding = NamedSharding(mesh, P(axis))
    out_cols = {}
    for c, v in cols_np.items():
        buf = np.zeros((n_shards, per), v.dtype)
        buf[ss, pos] = v[order]
        out_cols[c] = jax.device_put(buf.reshape(-1), sharding)
    vbuf = np.zeros((n_shards, per), bool)
    vbuf[ss, pos] = True
    out_valid = jax.device_put(vbuf.reshape(-1), sharding)
    out_dests = {}
    for k, d in dests.items():
        # pad slots point at their own shard so an exchange leaves them
        # in place instead of piling them all onto shard 0
        dbuf = np.repeat(np.arange(n_shards, dtype=np.int32)[:, None],
                         per, axis=1)
        dbuf[ss, pos] = d[order]
        out_dests[k] = jax.device_put(dbuf.reshape(-1), sharding)

    return {"cols": out_cols, "valid": out_valid, "per": int(per),
            "n_rows": n, "dests": out_dests,
            "real": {k: np.bincount(d, minlength=n_shards)
                     for k, d in dests.items()},
            "r0": counts, "bounds": bounds}


def _gather_ros(db: VerticaDB, proj, plan, need: Sequence[str],
                reseg_keys: Sequence[str], eff: int, mesh,
                axis: str, n_shards: int, stats) -> Optional[dict]:
    host = fused_exec.snapshot_scan_host(db, plan, need, eff, stats,
                                         include_wos=False)
    if host is None:
        return None
    cols_np, valid_np = host
    mask = np.asarray(valid_np, bool)
    if not mask.any():
        return None
    cols_np = {c: _canon_np(np.asarray(v)[mask])
               for c, v in cols_np.items()}
    n = int(mask.sum())
    shard = _shard_assignment(proj, cols_np, n, n_shards)
    return _partition_to_slab(cols_np, shard, reseg_keys, n_shards, mesh,
                              axis)


def _gather_wos(db: VerticaDB, proj, plan, need: Sequence[str],
                reseg_keys: Sequence[str], as_of: int, mesh, axis: str,
                n_shards: int, ros_rows: int) -> Optional[dict]:
    """The trickle-load delta: pending WOS rows slabbed per shard from
    their commit-time ring tags.  Never cached -- every commit changes it
    -- but it is small by construction (the tuple mover drains saturated
    WOS), so re-slabbing it per query is the cheap half of the split."""
    wos = fused_exec.wos_scan_host(db, plan, need, as_of)
    if wos is None:
        return None
    cols_np, vis, ring = wos
    mask = np.asarray(vis, bool)
    if not mask.any():
        return None
    cols_np = {c: _canon_np(np.asarray(v)[mask])
               for c, v in cols_np.items()}
    n = int(mask.sum())
    shard = _shard_assignment(proj, cols_np, n, n_shards,
                              ring=None if ring is None else ring[mask],
                              base=ros_rows)
    return _partition_to_slab(cols_np, shard, reseg_keys, n_shards, mesh,
                              axis)


def _build_concat_program(mesh, axis: str):
    """Append the WOS delta slab to the ROS slab shard-locally (both are
    already partitioned by the same ring map, so this is pure local
    concatenation -- no collective)."""

    def local_fn(a_cols, a_valid, a_dests, b_cols, b_valid, b_dests):
        cols = {c: jnp.concatenate([a_cols[c], b_cols[c]])
                for c in a_cols}
        valid = jnp.concatenate([a_valid, b_valid])
        dests = {k: jnp.concatenate([a_dests[k], b_dests[k]])
                 for k in a_dests}
        return cols, valid, dests

    fn = shard_map(local_fn, mesh=mesh, in_specs=(P(axis),) * 6,
                   out_specs=(P(axis),) * 3)
    return jax.jit(fn)


def _merge_bounds(a: Optional[tuple], b: Optional[tuple]
                  ) -> Optional[tuple]:
    if a is None or b is None:
        return None
    return (min(a[0], b[0]), max(a[1], b[1]))


def _concat_slabs(ros: dict, wos: dict, mesh, axis: str) -> dict:
    fn, _ = PLAN_CACHE.get_or_build(
        ("seg-concat", _mesh_sig(mesh, axis),
         tuple(sorted(ros["cols"])), tuple(sorted(ros["dests"]))),
        lambda: _build_concat_program(mesh, axis))
    cols, valid, dests = fn(ros["cols"], ros["valid"], ros["dests"],
                            wos["cols"], wos["valid"], wos["dests"])
    return {"cols": cols, "valid": valid, "dests": dests,
            "per": ros["per"] + wos["per"],
            "n_rows": ros["n_rows"] + wos["n_rows"],
            "real": {k: ros["real"][k] + wos["real"][k]
                     for k in ros["real"]},
            "r0": ros["r0"] + wos["r0"],
            "bounds": {c: _merge_bounds(ros["bounds"][c],
                                        wos["bounds"][c])
                       for c in ros["bounds"]}}


def _sharded_scan(db: VerticaDB, proj, plan, need, reseg_keys, as_of: int,
                  mesh, axis: str, n_shards: int, stats) -> Optional[dict]:
    """Two-part partitioned scan: the ROS slab is cached (keyed by the
    effective epoch + exact container set, invalidated precisely by the
    tuple mover) while pending WOS rows are slabbed fresh per query and
    appended shard-locally -- a trickle-load commit therefore costs one
    small WOS re-slab, never a whole-projection repartition."""
    # injection points: one per source store feeding the slab.  A crash
    # here fails the host node and escalates to query-level failover (the
    # retry replans onto buddy stores); transients retry in place.
    for host, owner in plan.sources:
        point = "segmented.buddy_read" \
            if db.catalog.projections[owner].buddy_of is not None \
            else "segmented.slab_build"
        fire_with_retries(db, point, stats=stats, node=host,
                          projection=owner)
    cache = getattr(db, "block_cache", None)
    ros = None
    if cache is None:
        ros = _gather_ros(db, proj, plan, need, reseg_keys, as_of, mesh,
                          axis, n_shards, stats)
        stats.seg_slab = "nocache"
    else:
        ceil = max((db.nodes[h].stores[o].epoch_ceiling(include_wos=False)
                    for h, o in plan.sources), default=0)
        eff = min(as_of, ceil)
        sig = _source_sig(db, plan, need, reseg_keys, eff, mesh, axis)
        ids = frozenset(i for item in sig[0] for i in item[2])
        key = ("slab", ids, sig)
        cid = f"seg:{plan.projection}"
        ros = cache.get(cid, key, KIND_SEG)
        stats.seg_slab = "hit" if ros is not None else "miss"
        if ros is None:
            ros = _gather_ros(db, proj, plan, need, reseg_keys, eff, mesh,
                              axis, n_shards, stats)
            if ros is not None:
                cache.put(cid, key, KIND_SEG, ros, _slab_bytes(ros))
    wos = _gather_wos(db, proj, plan, need, reseg_keys, as_of, mesh, axis,
                      n_shards, 0 if ros is None else ros["n_rows"])
    if wos is not None:
        stats.seg_slab += "+wos"
    if ros is None:
        return wos
    if wos is None:
        return ros
    return _concat_slabs(ros, wos, mesh, axis)


# ---------------------------------------------------------------------------
# 2. Build-side placement per exchange strategy
# ---------------------------------------------------------------------------

def _partition_build(bnp: Dict[str, np.ndarray], shard: np.ndarray,
                     n_shards: int, mesh, axis: str
                     ) -> Dict[str, jax.Array]:
    """Place dimension rows onto shards by hash(dim_key), padded per shard
    with copies of row 0.  A pad copy is harmless: a probe key equal to
    the pad's key hashes to the pad's home shard, so on any other shard no
    probe row can match it, and on its home shard the duplicate carries
    identical values."""
    sharding = NamedSharding(mesh, P(axis))
    n = len(shard)
    if n == 0:
        return {c: jax.device_put(np.zeros(0, _canon_np(v).dtype), sharding)
                for c, v in bnp.items()}
    order, ss, pos, counts = _slab_positions(shard, n_shards)
    per = max(int(counts.max()), 1)
    out = {}
    for c, v in bnp.items():
        v = _canon_np(v)
        buf = np.full((n_shards, per), v[0], v.dtype)
        buf[ss, pos] = v[order]
        out[c] = jax.device_put(buf.reshape(-1), sharding)
    return out


def _broadcast_build(bnp: Dict[str, np.ndarray], n_shards: int, mesh,
                     axis: str) -> Dict[str, jax.Array]:
    """Split the build side contiguously across shards, then replicate it
    with a real all_gather (exchange.broadcast_build_side)."""
    sharding = NamedSharding(mesh, P(axis))
    n = len(next(iter(bnp.values())))
    per = -(-n // n_shards) if n else 0
    cols = {}
    for c, v in bnp.items():
        v = _canon_np(v)
        if n == 0:
            buf = np.zeros(0, v.dtype)
        else:
            buf = np.full(n_shards * per, v[0], v.dtype)
            buf[:n] = v
        cols[c] = jax.device_put(buf, sharding)
    if n == 0:
        return cols               # nothing to gather
    return exchange.broadcast_build_side(mesh, axis, cols)


def _place_one_build(db: VerticaDB, spec, exch: str,
                     build: Dict[str, jax.Array], mesh, axis: str,
                     n_shards: int, replicated_dim: bool
                     ) -> Tuple[Dict[str, jax.Array], Dict]:
    """(placed device arrays, per-column host bounds) for one join."""
    bnp = {c: np.asarray(v) for c, v in build.items()}
    bounds = {}
    for c, v in bnp.items():
        if not v.size:
            bounds[c] = (0, 0)
        elif v.dtype.kind in "iub":
            bounds[c] = (int(v.min()), int(v.max()))
        else:
            bounds[c] = None
    if exch == "broadcast":
        return _broadcast_build(bnp, n_shards, mesh, axis), bounds
    if exch == "local" and replicated_dim:
        return {c: jax.device_put(
            jnp.asarray(_canon_np(v)), NamedSharding(mesh, P()))
            for c, v in bnp.items()}, bounds
    # co-located (probe placed by the join key) or the dim side of a
    # resegment: place rows by hash(dim_key) on the same offset-free
    # ring map the probe side uses
    shard = shard_of(hash_columns(bnp[spec.dim_key]), n_shards)
    return _partition_build(bnp, shard, n_shards, mesh, axis), bounds


def _place_builds(db: VerticaDB, q: LogicalQuery, plan, as_of: int, mesh,
                  axis: str, n_shards: int, stats=None
                  ) -> Tuple[List[Dict[str, jax.Array]], List, List[Dict]]:
    """Returns (placed build dicts, per-join shard_map specs, per-join
    dim-column bounds).  Placed builds are cached device-side keyed by
    (dim table, join signature, exchange op, mesh identity, snapshot
    epoch) -- MVCC makes the fixed-epoch read immutable, so a warm
    repeat skips the host round-trip, re-partition AND (for broadcast
    joins) the all_gather; drop_partition invalidates the dim's entries."""
    builds_dev = fused_exec.build_join_sides(db, q, as_of)
    cache = getattr(db, "block_cache", None)
    mh = hash(_mesh_sig(mesh, axis)) & 0xFFFFFFFFFFFFFFFF
    placed, specs, bounds = [], [], []
    for spec, exch, build in zip(q.joins, plan.join_exchanges, builds_dev):
        replicated_dim = db.catalog.super_of(
            spec.dim_table).segmentation.replicated
        specs.append(P() if exch == "broadcast"
                     or (exch == "local" and replicated_dim) else P(axis))
        if exch == "broadcast":
            # the all_gather of the small build side is a collective too:
            # a crash/transient here follows the same taxonomy
            fire_with_retries(db, "exchange.broadcast", stats=stats,
                              join=spec.dim_table)

        def make(spec=spec, exch=exch, build=build,
                 replicated_dim=replicated_dim):
            return _place_one_build(db, spec, exch, build, mesh, axis,
                                    n_shards, replicated_dim)
        if cache is None:
            pb = make()
        else:
            pb = cache.get_or_put(
                f"dim:{spec.dim_table}",
                f"seg|{spec.signature()}|{exch}|{mh:016x}@{as_of}",
                fused_exec.KIND_BUILD, make,
                lambda v: sum(int(a.size) * a.dtype.itemsize
                              for a in v[0].values()))
        placed.append(pb[0])
        bounds.append(pb[1])
    return placed, specs, bounds


# ---------------------------------------------------------------------------
# 3. Shard-local program (plan-cached)
# ---------------------------------------------------------------------------

def _mesh_sig(mesh, axis: str) -> tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat), axis)


def _build_stage_program(mesh, axis: str, specs: Sequence,
                         build_specs: Sequence):
    """Intermediate stage: apply a run of placement-compatible joins and
    pass every column (plus the valid mask, as ``__valid``) through.
    Joins are row-wise, so row<->shard alignment of any carried side data
    (e.g. pending resegment destinations) is preserved."""

    def local_fn(cols, valid, builds):
        cols = dict(cols)
        for spec, build in zip(specs, builds):
            cols, valid = ops.hash_join(build, spec.dim_key, cols,
                                        spec.fact_key, valid, how=spec.how)
        cols["__valid"] = valid
        return cols

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(axis), P(axis), tuple(build_specs)),
                   out_specs=P(axis))
    return jax.jit(fn)


def _build_seg_program(mesh, axis: str, ir: LogicalQuery,
                       specs: Sequence, build_specs: Sequence, algo: str,
                       domains: Tuple[int, ...], lows: Tuple[int, ...],
                       domain: int,
                       aggs: Tuple[Tuple[str, str, str], ...]):
    """Final stage, one shard_map'd XLA program per shard: the remaining
    local joins -> derived -> deferred predicate -> mixed-radix pack ->
    local partial GroupBy.  avg partials aggregate as SUM (the merge
    divides by merged counts)."""
    values_cols = tuple(sorted({c for _, c, kind in aggs
                                if kind != "count" and c != "*"}))
    group_by = ir.group_by
    local_aggs = tuple((name, c, "sum" if kind == "avg" else kind)
                       for name, c, kind in aggs)
    packed = len(group_by) > 1 or (bool(lows) and lows[0] != 0)

    def local_fn(cols, valid, builds):
        cols = dict(cols)
        for spec, build in zip(specs, builds):
            cols, valid = ops.hash_join(build, spec.dim_key, cols,
                                        spec.fact_key, valid, how=spec.how)
        for name, e in ir.derived:
            cols[name] = e(cols)
        if ir.predicate is not None:
            valid = valid & jnp.asarray(ir.predicate(cols), bool)
        values = {c: cols[c] for c in values_cols}
        if not group_by:
            keys = jnp.zeros(valid.shape[0], jnp.int32)
            out = ops.groupby_dense(keys, valid, values, 1, local_aggs)
            return {k: v.reshape(-1) for k, v in out.items()}
        keys = ops.pack_keys([cols[g] for g in group_by], domains, lows) \
            if packed else cols[group_by[0]]
        if algo == "dense":
            out = ops.groupby_dense(keys.astype(jnp.int32), valid, values,
                                    domain, local_aggs)
        else:
            out = ops.groupby_sort(keys, valid, values, domain, local_aggs)
        return {k: jnp.reshape(v, (-1,)) for k, v in out.items()}

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=(P(axis), P(axis), tuple(build_specs)),
                   out_specs=P(axis))
    return jax.jit(fn)


# ---------------------------------------------------------------------------
# 4. Final merge (host-side, over small partials)
# ---------------------------------------------------------------------------

def _merge_scalar(aggs, res, n_shards: int) -> Dict[str, np.ndarray]:
    counts = np.asarray(res["group_count"]).reshape(n_shards)
    total = int(counts.sum())
    out = {"group_count": np.asarray([total])}
    for name, _, kind in aggs:
        v = np.asarray(res[name]).reshape(n_shards)
        if kind in ("sum", "count"):
            out[name] = np.asarray([v.sum()])
        elif kind == "avg":
            out[name] = np.asarray([v.sum() / max(total, 1)])
        elif kind == "min":
            out[name] = np.asarray([v.min()])
        else:
            out[name] = np.asarray([v.max()])
    return out


def _merge_dense(aggs, res, n_shards: int, domain: int
                 ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    counts = np.asarray(res["group_count"]).reshape(n_shards, domain)
    counts = counts.sum(0)
    sel = counts > 0
    gkeys = np.flatnonzero(sel)
    out = {"group_count": counts[sel]}
    for name, _, kind in aggs:
        v = np.asarray(res[name]).reshape(n_shards, domain)
        if kind in ("sum", "count"):
            m = v.sum(0)
        elif kind == "avg":
            m = v.sum(0) / np.maximum(counts, 1)
        elif kind == "min":
            m = v.min(0)
        else:
            m = v.max(0)
        out[name] = m[sel]
    return gkeys, out


def _merge_sorted(aggs, res, n_shards: int, max_groups: int
                  ) -> Optional[Tuple[np.ndarray, Dict[str, np.ndarray]]]:
    ngs = np.asarray(res["n_groups"]).reshape(n_shards)
    if (ngs > max_groups).any():
        return None               # local sort cap exceeded: fall back
    gk = np.asarray(res["group_keys"]).reshape(n_shards, max_groups)
    gc = np.asarray(res["group_count"]).reshape(n_shards, max_groups)
    keys = np.concatenate([gk[s, :ngs[s]] for s in range(n_shards)])
    cnts = np.concatenate([gc[s, :ngs[s]] for s in range(n_shards)])
    if keys.size == 0:
        return np.zeros(0, np.int64), {
            "group_count": np.zeros(0, np.int64),
            **{name: np.zeros(0) for name, _, _ in aggs}}
    uniq, inv = np.unique(keys, return_inverse=True)
    ng = len(uniq)
    counts = np.bincount(inv, weights=cnts, minlength=ng).astype(np.int64)
    out = {"group_count": counts}
    for name, _, kind in aggs:
        pv = np.asarray(res[name])
        v = np.concatenate([pv.reshape(
            n_shards, max_groups)[s, :ngs[s]] for s in range(n_shards)])
        if kind in ("sum", "count", "avg"):
            acc = np.bincount(inv, weights=v, minlength=ng)
            if kind == "avg":
                acc = acc / np.maximum(counts, 1)
        elif kind == "min":
            acc = np.full(ng, np.inf)
            np.minimum.at(acc, inv, v)
        else:
            acc = np.full(ng, -np.inf)
            np.maximum.at(acc, inv, v)
        # integer partials stay integral (the single-node path returns
        # int sums/mins/maxes for int columns; only avg is a ratio)
        if kind != "avg" and pv.dtype.kind in "iub":
            acc = acc.astype(np.int64)
        out[name] = acc
    return uniq, out


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def execute_segmented(db: VerticaDB, q: LogicalQuery, plan, as_of: int,
                      mesh, axis: str, stats
                      ) -> Optional[Dict[str, np.ndarray]]:
    """Run an aggregate query segmented across the mesh.  Returns the
    merged (pre-HAVING/ORDER/LIMIT) result columns, or None to fall back
    to the single-node pipeline."""
    if not (q.aggs or q.group_by):
        return None               # plain selects stay single-node
    if any(j.how != "inner" for j in q.joins):
        return None
    derived_names = {n for n, _ in q.derived}
    if any(g in derived_names for g in q.group_by):
        return None               # no static pack bounds for derived keys

    n_shards = int(mesh.shape[axis])
    proj = db.catalog.projections[plan.projection]
    reseg_keys = tuple(spec.fact_key for spec, e
                       in zip(q.joins, plan.join_exchanges)
                       if e == "resegment")
    need = set(q.scan_columns(proj))
    if not proj.segmentation.replicated:
        need |= set(proj.segmentation.columns)
    need |= set(reseg_keys)
    need = sorted(need & set(proj.columns))

    slab = _sharded_scan(db, proj, plan, need, reseg_keys, as_of, mesh,
                         axis, n_shards, stats)
    if slab is None:
        return None               # empty snapshot: pipeline shapes it
    stats.rows_scanned = slab["n_rows"]

    builds, build_specs, build_bounds = _place_builds(
        db, q, plan, as_of, mesh, axis, n_shards, stats)

    # ---- static pack radices for the group keys (exact host bounds) ----
    aggs = tuple(q.aggs)
    lows: Tuple[int, ...] = ()
    domains: Tuple[int, ...] = ()
    algo, domain = "dense", 1
    if q.group_by:
        los, doms = [], []
        for g in q.group_by:
            b = slab["bounds"].get(g)
            if b is None:
                for spec, bnds in zip(q.joins, build_bounds):
                    if g in spec.dim_columns:
                        b = bnds.get(g)
                        break
            if b is None:
                return None       # non-integral / unlocatable group key
            lo, hi = b
            lo = min(lo, 0)
            los.append(lo)
            doms.append(hi - lo + 1)
        total = 1
        for d in doms:
            total *= d
        if total >= _PACK_LIMIT:
            return None           # packed key overflows device int32
        lows, domains = tuple(los), tuple(doms)
        algo = "dense" if total <= plan.dense_domain_limit else "sort"
        domain = total if algo == "dense" else plan.max_groups

    # ---- staged execution: joins run in plan order, with a resegment
    # exchange (Send/Recv) immediately BEFORE the join that needs it --
    # an up-front exchange would destroy the placement an earlier
    # co-located join depends on ----
    stage_joins: List[List[int]] = [[]]
    for ji, exch in enumerate(plan.join_exchanges):
        if exch == "resegment":
            stage_joins.append([])
        stage_joins[-1].append(ji)

    cols, valid = dict(slab["cols"]), slab["valid"]
    dest_cols = dict(slab["dests"])
    per_prev, real_prev = slab["per"], slab["r0"]
    mesh_sig = _mesh_sig(mesh, axis)
    hit_all = True
    res = None
    for si, stage in enumerate(stage_joins):
        if si > 0:
            # resegment by the first join of this stage
            spec = q.joins[stage[0]]
            k = spec.fact_key
            dest = dest_cols.pop(k, None)
            if dest is None:
                return None       # no destination column: fall back
            real_k = slab["real"][k]
            # exact destination occupancy: arriving rows + slots that
            # stay (pads and earlier arrivals that are not moving again)
            filled = real_k + per_prev - real_prev
            per_new = cost_mod.resegment_capacity(filled,
                                                  n_shards) // n_shards
            payload = dict(cols)
            payload["__v"] = valid.astype(jnp.int8)  # bools ride as bytes
            for k2, d2 in dest_cols.items():
                payload[f"__d:{k2}"] = d2
            moved = slot_valid = None
            for _attempt in range(2):
                moved, slot_valid, overflow = with_retries(
                    db, "exchange.resegment",
                    lambda: exchange.resegment(mesh, axis, payload, dest,
                                               per_new * n_shards),
                    stats=stats, join=spec.dim_table)
                ov = int(np.asarray(overflow).sum())
                if ov == 0:
                    break
                # capacity was sized from the exact histogram, so this
                # is defensive: record, double, retry once
                stats.reseg_overflow += ov
                per_new *= 2
            else:
                return None       # still overflowing: fall back
            valid = (moved["__v"] != 0) & slot_valid
            # each shard now holds n_shards*per_new slots (one per_new
            # block per source); empty slots must point at their own
            # shard so the NEXT exchange leaves them in place
            shard_idx = _shard_index_col(mesh, axis, n_shards,
                                         n_shards * per_new)
            dest_cols = {k2: jnp.where(slot_valid, moved[f"__d:{k2}"],
                                       shard_idx) for k2 in dest_cols}
            cols = {c: moved[c] for c in cols}
            per_prev, real_prev = per_new * n_shards, real_k

        specs = tuple(q.joins[ji] for ji in stage)
        sb = tuple(builds[ji] for ji in stage)
        sbs = tuple(build_specs[ji] for ji in stage)
        if si < len(stage_joins) - 1:
            if not stage:
                continue          # leading resegment: nothing to join yet
            ssig = ("seg-stage", tuple(s.signature() for s in specs),
                    tuple(bs == P() for bs in sbs), mesh_sig)
            fn, hit = PLAN_CACHE.get_or_build(
                ssig, lambda: _build_stage_program(mesh, axis, specs, sbs))
            hit_all &= hit
            out_cols = fn(cols, valid, sb)
            valid = out_cols.pop("__valid")
            cols = out_cols
        else:
            # ---- final shard-local program (memoized by signature).
            # Build placement (replicated vs sharded) must be part of
            # the key: two same-named dims with different segmentation
            # would otherwise share an executable with wrong in_specs ----
            sig = ("seg", q.exec_signature(), plan.projection,
                   proj.segmentation.kind,
                   tuple(proj.segmentation.columns), mesh_sig,
                   plan.join_exchanges,
                   tuple(bs == P() for bs in build_specs),
                   algo, int(domain), domains, lows)
            fn, hit = PLAN_CACHE.get_or_build(
                sig, lambda: _build_seg_program(mesh, axis, q, specs, sbs,
                                                algo, domains, lows,
                                                domain, aggs))
            hit_all &= hit
            res = fn(cols, valid, sb)
    stats.plan_cache = "hit" if hit_all else "miss"

    # ---- final merge ----
    if not q.group_by:
        out = _merge_scalar(aggs, res, n_shards)
    else:
        merged = _merge_dense(aggs, res, n_shards, domain) \
            if algo == "dense" else _merge_sorted(aggs, res, n_shards,
                                                  domain)
        if merged is None:
            return None
        gkeys, out = merged
        packed = len(q.group_by) > 1 or (lows and lows[0] != 0)
        key_cols = ops.unpack_keys(gkeys, domains, lows) if packed \
            else [np.asarray(gkeys).astype(np.int64)]
        for g, kv in zip(q.group_by, key_cols):
            out[g] = kv
    stats.segmented = True
    stats.n_shards = n_shards
    stats.exchange = ";".join(plan.join_exchanges)
    stats.groupby_algorithm = f"{algo} (segmented)"
    return out
