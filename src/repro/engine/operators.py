"""Vectorized execution-engine operators (paper §6.1), jnp-based.

Adaptation (DESIGN.md): the pull-model multi-threaded pipeline becomes XLA
programs over block-structured columns; intra-node thread parallelism
becomes SPMD/grid parallelism. The operator *algebra* is the paper's:

  Scan (SMA pruning + predicate + SIP), GroupBy (dense-hash / sort /
  pipelined-on-sorted / RLE-direct / prepass), Join (lookup a.k.a. hash,
  merge on sorted), Sort, TopK, Analytic, ExprEval.

'Operate directly on encoded data': groupby_rle aggregates straight from
(run_value, run_length) pairs without decoding -- the flagship C-Store
move; kernels/rle_scan_agg.py is its Pallas twin for real TPUs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.encodings import EncodedColumn, Encoding, decode_jnp
from ..core.sma import ColumnSMA
from ..core.storage import ROSContainer
from .expr import Expr

AGGS = ("sum", "count", "min", "max", "avg")


# ---------------------------------------------------------------------------
# Scan: container -> (columns dict, valid mask), with SMA pruning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScanResult:
    columns: Dict[str, jax.Array]   # flat (n,) device arrays
    valid: jax.Array                # (n,) bool
    pruned_blocks: int = 0
    total_blocks: int = 0


def scan_container(c: ROSContainer, columns: Sequence[str],
                   predicate: Optional[Expr] = None,
                   deleted: Optional[np.ndarray] = None,
                   sip: Optional[Callable] = None) -> Optional[ScanResult]:
    """Scan one ROS container: SMA-prune blocks, decode survivors on
    device, apply the predicate (and any SIP filter) as a mask."""
    need = set(columns) | (predicate.columns() if predicate else set())
    first = c.columns[next(iter(need))]
    nb, br = first.n_blocks, first.block_rows

    # --- container/block pruning from predicate bounds (paper §3.5) ---
    keep = np.ones(nb, dtype=bool)
    if predicate is not None:
        for colname, (lo, hi) in predicate.bounds().items():
            if colname in c.smas:
                keep &= c.smas[colname].prune_blocks(lo, hi)
    if not keep.any():
        return None
    kept_idx = np.flatnonzero(keep)

    cols = {}
    for name in need:
        blocks = decode_jnp(c.columns[name])            # (nb, br)
        cols[name] = blocks[kept_idx].reshape(-1)
    n = kept_idx.size * br
    # row validity: inside n_rows, not deleted
    counts = c.smas[next(iter(need))].counts
    pos_in_block = np.arange(br)[None, :]
    valid_np = pos_in_block < counts[kept_idx][:, None]
    if deleted is not None:
        # deleted is positional over the container; spread over padded blocks
        flat = np.zeros(nb * br, bool)
        flat[np.flatnonzero(deleted)] = True
        valid_np &= ~flat.reshape(nb, br)[kept_idx]
    valid = jnp.asarray(valid_np.reshape(-1))
    if predicate is not None:
        valid = valid & jnp.asarray(predicate(cols), bool)
    if sip is not None:
        valid = valid & sip(cols)
    return ScanResult({k: v for k, v in cols.items() if k in columns},
                      valid, int(nb - kept_idx.size), int(nb))


def concat_scans(results: List[ScanResult]) -> Optional[ScanResult]:
    results = [r for r in results if r is not None]
    if not results:
        return None
    cols = {k: jnp.concatenate([r.columns[k] for r in results])
            for k in results[0].columns}
    valid = jnp.concatenate([r.valid for r in results])
    return ScanResult(cols, valid,
                      sum(r.pruned_blocks for r in results),
                      sum(r.total_blocks for r in results))


# ---------------------------------------------------------------------------
# GroupBy
# ---------------------------------------------------------------------------

# Composite group-by keys (logical IR: group_by is a tuple of columns) are
# key-packed into one dense non-negative domain -- mixed-radix, last column
# fastest -- so every single-key path below (dense scatter, sort-based,
# the fused executor program, and on TPU the Pallas rle_grouped_agg /
# onehot kernels) applies unchanged to multi-column grouping.

def pack_keys(key_cols: Sequence[jax.Array],
              domains: Sequence[int],
              lows: Optional[Sequence[int]] = None) -> jax.Array:
    """Mix-radix pack: keys k_i in [lo_i, lo_i + d_i) -> one int key in
    [0, prod(d_i)).  Values outside their domain are clipped (callers
    guarantee domains via SMAs or a runtime min/max pass)."""
    lows = lows or (0,) * len(domains)
    packed = None
    for k, d, lo in zip(key_cols, domains, lows):
        k = jnp.clip(k.astype(_int_dtype()) - lo, 0, d - 1)
        packed = k if packed is None else packed * d + k
    return packed


def unpack_keys(packed: np.ndarray, domains: Sequence[int],
                lows: Optional[Sequence[int]] = None) -> List[np.ndarray]:
    """Host-side inverse of pack_keys over the (small) group-key output."""
    lows = lows or (0,) * len(domains)
    packed = np.asarray(packed).astype(np.int64)
    out: List[np.ndarray] = []
    for d, lo in zip(reversed(domains), reversed(lows)):
        out.append(packed % d + lo)
        packed = packed // d
    out.reverse()
    return out

# device dtypes: jax runs 32-bit by default; counts/sums accumulate in
# i32/f32 on device (benchmark-scale exact for counts; sums compared with
# tolerance), 64-bit when the caller enables jax_enable_x64.
def _int_dtype():
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def _float_dtype():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def _sentinel(dt, hi: bool):
    if jnp.issubdtype(dt, jnp.integer):
        info = jnp.iinfo(dt)
        return info.max if hi else info.min
    return jnp.inf if hi else -jnp.inf


def _prep_agg(values: jax.Array, valid: jax.Array, agg: str):
    v = values.astype(_float_dtype()) if values.dtype.kind == "f" \
        else values.astype(_int_dtype())
    if agg == "count":
        return valid.astype(_int_dtype())
    if agg == "min":
        return jnp.where(valid, v, _sentinel(v.dtype, True))
    if agg == "max":
        return jnp.where(valid, v, _sentinel(v.dtype, False))
    return jnp.where(valid, v, 0)   # sum / avg


_COMBINE = {"sum": "add", "count": "add", "avg": "add",
            "min": "min", "max": "max"}


@partial(jax.jit, static_argnames=("domain", "aggs"))
def groupby_dense(keys: jax.Array, valid: jax.Array,
                  values: Dict[str, jax.Array],
                  domain: int, aggs: Tuple[Tuple[str, str, str], ...]):
    """Dense-hash GroupBy: keys are small non-negative ints (the paper's
    'few-valued' case / dictionary-encoded); one scatter per aggregate.

    aggs: (out_name, in_col, agg_kind). Returns dict with per-key results
    over [0, domain) plus 'group_count'."""
    k = jnp.clip(keys, 0, domain - 1)
    out = {}
    counts = jnp.zeros(domain, _int_dtype()).at[k].add(
        valid.astype(_int_dtype()))
    out["group_count"] = counts
    for name, col_, agg in aggs:
        src = _prep_agg(values[col_] if agg != "count" else keys,
                        valid, agg)
        if _COMBINE[agg] == "add":
            acc = jnp.zeros(domain, src.dtype).at[k].add(src)
        elif _COMBINE[agg] == "min":
            acc = jnp.full(domain, _sentinel(src.dtype, True),
                           src.dtype).at[k].min(src)
        else:
            acc = jnp.full(domain, _sentinel(src.dtype, False),
                           src.dtype).at[k].max(src)
        if agg == "avg":
            acc = acc / jnp.maximum(counts, 1)
        out[name] = acc
    return out


@partial(jax.jit, static_argnames=("max_groups", "aggs"))
def groupby_sort(keys: jax.Array, valid: jax.Array,
                 values: Dict[str, jax.Array],
                 max_groups: int, aggs: Tuple[Tuple[str, str, str], ...]):
    """Sort-based GroupBy for arbitrary int keys (the paper's runtime
    fallback when the hash table would not fit). Returns padded
    (keys, aggs, n_groups)."""
    big = jnp.asarray(jnp.iinfo(_int_dtype()).max, _int_dtype())
    k = jnp.where(valid, keys.astype(_int_dtype()), big)
    order = jnp.argsort(k)
    ks = k[order]
    is_new = jnp.concatenate([jnp.ones(1, bool), ks[1:] != ks[:-1]])
    is_new &= ks != big
    gid = jnp.cumsum(is_new) - 1                      # (n,) group index
    gid = jnp.where(ks == big, max_groups - 1, jnp.clip(gid, 0,
                                                        max_groups - 1))
    n_groups = is_new.sum()
    uniq = jnp.full(max_groups, big).at[gid].min(ks)
    out = {"group_keys": uniq, "n_groups": n_groups}
    vsort = {c: v[order] for c, v in values.items()}
    valid_s = valid[order]
    counts = jnp.zeros(max_groups, _int_dtype()).at[gid].add(
        valid_s.astype(_int_dtype()))
    out["group_count"] = counts
    for name, col_, agg in aggs:
        src = _prep_agg(vsort[col_] if agg != "count" else ks, valid_s, agg)
        if _COMBINE[agg] == "add":
            acc = jnp.zeros(max_groups, src.dtype).at[gid].add(src)
        elif _COMBINE[agg] == "min":
            acc = jnp.full(max_groups, _sentinel(src.dtype, True),
                           src.dtype).at[gid].min(src)
        else:
            acc = jnp.full(max_groups, _sentinel(src.dtype, False),
                           src.dtype).at[gid].max(src)
        if agg == "avg":
            acc = acc / jnp.maximum(counts, 1)
        out[name] = acc
    return out


def groupby_rle(key_col: EncodedColumn, valid_counts: np.ndarray,
                domain: int) -> Dict[str, jax.Array]:
    """COUNT(*) GROUP BY key directly on RLE-encoded data: each run
    contributes (value, length) without decoding a single row. This is the
    §6.1 'operate directly on encoded data' fast path (Pallas twin:
    kernels/rle_scan_agg.py)."""
    assert key_col.encoding == Encoding.RLE
    if jax.default_backend() == "tpu":
        # fused Pallas path: per-key count straight off the runs (grouped
        # twin of the scalar kernel; CPU stays on the XLA scatter below
        # because interpret-mode Pallas is row-at-a-time Python)
        from ..kernels import ops as kops
        out = kops.rle_grouped_agg(
            jnp.asarray(key_col.arrays["run_values"]),
            jnp.asarray(key_col.arrays["run_lengths"]), domain=domain)
        return {"group_count": out[0].astype(_int_dtype())}
    rv = jnp.asarray(key_col.arrays["run_values"]).reshape(-1)
    rl = jnp.asarray(key_col.arrays["run_lengths"]).reshape(-1)
    # clamp tail-block padding runs: total rows cap
    k = jnp.clip(rv, 0, domain - 1).astype(jnp.int32)
    counts = jnp.zeros(domain, _int_dtype()).at[k].add(
        rl.astype(_int_dtype()))
    return {"group_count": counts}


def groupby_prepass(keys: jax.Array, valid: jax.Array,
                    values: Dict[str, jax.Array], domain: int,
                    aggs: Tuple[Tuple[str, str, str], ...],
                    block: int = 4096):
    """Two-stage GroupBy mirroring the paper's prepass operators: partial
    per-block aggregation (the 'L1-sized hash table', VMEM-sized on TPU),
    then a final combine. Numerically identical to groupby_dense."""
    n = keys.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    kp = jnp.pad(keys, (0, pad))
    vp = jnp.pad(valid, (0, pad))
    vals = {c: jnp.pad(v, (0, pad)) for c, v in values.items()}
    kb = kp.reshape(nb, block)
    vb = vp.reshape(nb, block)

    # avg does not distribute over blocks: aggregate partial SUMs instead
    # and divide by the combined counts at the end.
    part_aggs = tuple((name, col_, "sum" if agg == "avg" else agg)
                      for name, col_, agg in aggs)

    def per_block(kb1, vb1, vals1):
        return groupby_dense(kb1, vb1, vals1, domain, part_aggs)

    partials = jax.vmap(per_block)(kb, vb,
                                   {c: v.reshape(nb, block)
                                    for c, v in vals.items()})
    out = {}
    for name, v in partials.items():
        if name == "group_count" or _COMBINE.get(
                _agg_kind(name, part_aggs), "add") == "add":
            out[name] = v.sum(axis=0)
        elif _COMBINE[_agg_kind(name, part_aggs)] == "min":
            out[name] = v.min(axis=0)
        else:
            out[name] = v.max(axis=0)
    for name, col_, agg in aggs:
        if agg == "avg":
            out[name] = out[name] / jnp.maximum(out["group_count"], 1)
    return out


def _agg_kind(name, aggs):
    for n, _, a in aggs:
        if n == name:
            return a
    return "sum"


# ---------------------------------------------------------------------------
# Join (N:1 lookup = hash join; same primitive is a merge join on sorted)
# ---------------------------------------------------------------------------

@jax.jit
def join_lookup(build_keys: jax.Array, probe_keys: jax.Array):
    """Returns (idx, matched): for each probe key, the position of the
    matching build key (build keys unique, pre-sorted by caller)."""
    idx = jnp.searchsorted(build_keys, probe_keys)
    idx = jnp.clip(idx, 0, build_keys.shape[0] - 1)
    matched = build_keys[idx] == probe_keys
    return idx, matched


def hash_join(build: Dict[str, jax.Array], build_key: str,
              probe: Dict[str, jax.Array], probe_key: str,
              probe_valid: jax.Array,
              how: str = "inner") -> Tuple[Dict[str, jax.Array], jax.Array]:
    """N:1 join: probe each fact row against the (small) build side.
    Build side is sorted once ('building the hash table'); the probe is one
    vectorized lookup. Returns (joined columns, valid mask)."""
    if build[build_key].shape[0] == 0:
        # empty build side (dim predicate filtered everything, or the
        # dimension was truncated): no probe row can match
        n = probe[probe_key].shape[0]
        out = dict(probe)
        for c, v in build.items():
            if c != build_key:
                out[c] = jnp.full((n,) + v.shape[1:], -1, v.dtype)
        matched = jnp.zeros(n, bool)
        if how == "inner":
            return out, probe_valid & matched
        if how == "left":
            out["_matched"] = matched
            return out, probe_valid
        raise ValueError(how)
    order = jnp.argsort(build[build_key])
    bk = build[build_key][order]
    idx, matched = join_lookup(bk, probe[probe_key])
    out = dict(probe)
    for c, v in build.items():
        if c == build_key:
            continue
        joined = v[order][idx]
        if how == "left":
            # unmatched rows carry the NULL sentinel (-1), the engine's
            # NULL analog, instead of an arbitrary clipped build row
            joined = jnp.where(matched, joined,
                               jnp.asarray(-1, joined.dtype))
        out[f"{c}"] = joined
    if how == "inner":
        valid = probe_valid & matched
    elif how == "left":
        valid = probe_valid
        out["_matched"] = matched
    else:
        raise ValueError(how)
    return out, valid


# ---------------------------------------------------------------------------
# Sort / TopK / Analytic
# ---------------------------------------------------------------------------

def sort_rows(cols: Dict[str, jax.Array], valid: jax.Array,
              by: Sequence[str], descending: bool = False):
    key = cols[by[0]].astype(jnp.float64)
    big = jnp.inf if not descending else -jnp.inf
    key = jnp.where(valid, key, big)
    order = jnp.argsort(-key if descending else key)
    return {c: v[order] for c, v in cols.items()}, valid[order]


def top_k(cols: Dict[str, jax.Array], valid: jax.Array, by: str, k: int):
    key = jnp.where(valid, cols[by].astype(jnp.float32), -jnp.inf)
    _, idx = jax.lax.top_k(key, k)
    return {c: v[idx] for c, v in cols.items()}


@jax.jit
def analytic_running_sum(values: jax.Array, partition_ids: jax.Array):
    """SQL-99 windowed SUM() OVER (PARTITION BY p ORDER BY input order):
    segmented cumulative sum (input pre-sorted by partition)."""
    n = values.shape[0]
    csum = jnp.cumsum(values)
    is_new = jnp.concatenate([jnp.ones(1, bool),
                              partition_ids[1:] != partition_ids[:-1]])
    gid = jnp.cumsum(is_new) - 1
    # each group has exactly one start; record csum-before-start per group
    base_per_gid = jnp.zeros(n, csum.dtype).at[gid].add(
        jnp.where(is_new, csum - values, 0))
    return csum - base_per_gid[gid]
