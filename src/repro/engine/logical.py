"""Logical-plan IR: the relational front-end's single source of truth.

The paper's interface claim (§1, §6) is that Vertica looks like a classical
relational database while executing on a columnar, compressed, distributed
engine.  This module is that interface layer for the repro: a small
relational algebra

    Scan -> Filter -> Join* -> Project -> Aggregate[HAVING] -> Sort -> Limit

with two equivalent representations:

* **Node tree** (`Scan`, `Filter`, `Join`, `Project`, `Aggregate`, `Sort`,
  `Limit`): the syntax-level plan, one node per operator, composable by
  hand or by the fluent builder (engine/builder.py).  `lower()` folds a
  tree into the canonical form below, merging stacked Filters conjunctively
  and classifying a post-Aggregate Filter as HAVING.
* **`LogicalQuery`**: the canonical flat form every downstream layer
  consumes -- the planner (planner/planner.py) chooses projection, join
  order/strategy, SIP and groupby algorithm from it; the executor
  (engine/pipeline.py, engine/executor.py) runs it; and its
  ``signature()`` is the *hashable canonical key* the plan cache memoizes
  fused programs under, so "same query shape" is defined once, here.

Generalizations over the legacy ``Query`` dataclass (kept as a shim in
engine/pipeline.py): a *list* of join specs instead of at most one, a
*tuple* of group-by columns instead of at most one, derived-expression
projections (``revenue = price * qty``), HAVING, and multi-key ORDER BY.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .expr import Expr

AGG_KINDS = ("sum", "count", "min", "max", "avg")


def _sig(e: Optional[Expr]) -> str:
    return "" if e is None else e.signature()


@dataclasses.dataclass(frozen=True, eq=False)
class LogicalJoin:
    """One N:1 (fact -> dimension) join edge.

    ``fact_key`` names a column of the probe side *at the point the join
    runs* -- for snowflake chains it may be a column emitted by an earlier
    join rather than a physical fact column."""
    dim_table: str
    fact_key: str
    dim_key: str
    dim_columns: Tuple[str, ...] = ()
    dim_predicate: Optional[Expr] = None
    how: str = "inner"

    def signature(self) -> tuple:
        return ("join", self.dim_table, self.fact_key, self.dim_key,
                tuple(self.dim_columns), _sig(self.dim_predicate), self.how)


@dataclasses.dataclass(frozen=True, eq=False)
class LogicalQuery:
    """Canonical flat IR.  Field order mirrors execution order."""
    table: str
    columns: Tuple[str, ...] = ()
    derived: Tuple[Tuple[str, Expr], ...] = ()      # (name, expr)
    predicate: Optional[Expr] = None                # fact-side WHERE
    joins: Tuple[LogicalJoin, ...] = ()
    group_by: Tuple[str, ...] = ()
    aggs: Tuple[Tuple[str, str, str], ...] = ()     # (out, col|"*", kind)
    having: Optional[Expr] = None                   # over agg outputs
    order_by: Tuple[Tuple[str, bool], ...] = ()     # (col, descending)
    limit: Optional[int] = None

    # ------------------------------------------------------------ info --

    def validate(self) -> "LogicalQuery":
        agg_out = {a[0] for a in self.aggs}
        derived_names = {n for n, _ in self.derived}
        for out, c, kind in self.aggs:
            if kind not in AGG_KINDS:
                raise ValueError(f"unknown aggregate {kind!r}")
            if c == "*" and kind != "count":
                raise ValueError(f"{kind}(*) is not defined; "
                                 "only count(*)")
        for j in self.joins:
            if j.how not in ("inner", "left"):
                raise ValueError(f"unsupported join type {j.how!r}")
        if self.having is not None:
            bad = self.having.columns() - agg_out - set(self.group_by) \
                - {"group_count"}
            if bad:
                raise ValueError(
                    f"HAVING references {sorted(bad)}, not produced by "
                    f"group keys {self.group_by} or aggs {sorted(agg_out)}")
        if (self.aggs or self.group_by) and self.columns:
            extra = set(self.columns) - set(self.group_by) - agg_out \
                - derived_names
            if extra:
                raise ValueError(
                    f"selected columns {sorted(extra)} are neither group "
                    "keys nor aggregates")
        if self.order_by:
            # sort keys must exist in the output row set (statically
            # checkable except for select-all queries)
            if self.aggs or self.group_by:
                avail = set(self.group_by) | agg_out | {"group_count"}
            elif self.columns or self.derived:
                avail = set(self.columns) | derived_names
            else:
                avail = None          # select * : resolved at runtime
            if avail is not None:
                bad = [c for c, _ in self.order_by if c not in avail]
                if bad:
                    raise ValueError(
                        f"ORDER BY {bad} not in the output columns "
                        f"{sorted(avail)}")
        return self

    def needed_columns(self) -> set:
        """Input columns required before aggregation (fact or dim side;
        the planner subtracts join-provided and derived names to get the
        scan set)."""
        derived_names = {n for n, _ in self.derived}
        agg_out = {a[0] for a in self.aggs}
        need = set(self.columns) - derived_names - agg_out
        for _, e in self.derived:
            need |= e.columns()
        if self.predicate is not None:
            need |= self.predicate.columns()
        need |= set(self.group_by) - derived_names
        for _, c, kind in self.aggs:
            if kind != "count" and c != "*" and c not in derived_names:
                need.add(c)
        for j in self.joins:
            need.add(j.fact_key)
        for c, _ in self.order_by:
            if c not in agg_out and c not in derived_names \
                    and c != "group_count":
                need.add(c)
        return need

    def signature(self) -> tuple:
        """Canonical hashable identity of the full query, host-side
        shaping included."""
        return ("lq", self.table, tuple(self.columns),
                tuple((n, e.signature()) for n, e in self.derived),
                _sig(self.predicate),
                tuple(j.signature() for j in self.joins),
                tuple(self.group_by), tuple(self.aggs),
                _sig(self.having), tuple(self.order_by), self.limit)

    def scan_predicate(self, proj_columns) -> Optional[Expr]:
        """The WHERE predicate iff it is fully evaluable on scanned fact
        columns (push-down); None means it defers until after joins and
        derived projections.  Single definition keeps the fused executor
        and the general pipeline (and the plan-cache signature's
        determinism argument) in sync."""
        if self.predicate is not None \
                and self.predicate.columns() <= set(proj_columns):
            return self.predicate
        return None

    def scan_columns(self, proj) -> set:
        """Physical columns the scan must produce from a projection.
        Never empty for an aggregate query: count(*) with no predicate
        still needs one column to carry row validity -- the sort leader,
        whose RLE encoding makes it the cheapest to decode."""
        need = self.needed_columns() & set(proj.columns)
        if not need and (self.aggs or self.group_by):
            need = {proj.sort_order[0] if proj.sort_order
                    else proj.columns[0]}
        return need

    def exec_signature(self) -> tuple:
        """Identity of the *device program* only: HAVING / ORDER BY /
        LIMIT (and the output column list) are applied host-side in
        pipeline._finalize and never enter the traced program, so two
        queries differing only there share one fused executable.  This is
        the plan-cache key (engine/executor.py adds the physical choices
        on top)."""
        return ("lq-exec", self.table,
                tuple((n, e.signature()) for n, e in self.derived),
                _sig(self.predicate),
                tuple(j.signature() for j in self.joins),
                tuple(self.group_by), tuple(self.aggs))

    # ------------------------------------------------------- tree view --

    def to_tree(self) -> "Node":
        node: Node = Scan(self.table, tuple(sorted(self.needed_columns())))
        if self.predicate is not None:
            node = Filter(node, self.predicate)
        for j in self.joins:
            node = Join(node, j)
        if self.derived or (self.columns and not self.aggs
                            and not self.group_by):
            node = Project(node, self.columns, self.derived)
        if self.aggs or self.group_by:
            node = Aggregate(node, self.group_by, self.aggs)
            if self.having is not None:
                node = Filter(node, self.having)
        if self.order_by:
            node = Sort(node, self.order_by)
        if self.limit is not None:
            node = Limit(node, self.limit)
        return node

    def explain(self) -> str:
        lines = []
        node: Optional[Node] = self.to_tree()
        depth = 0
        chain = []
        while node is not None:
            chain.append(node)
            node = getattr(node, "child", None)
        for node in reversed(chain):
            lines.append("  " * depth + node.describe())
            depth += 1
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Node tree (syntax level)
# ---------------------------------------------------------------------------

class Node:
    """Base of the syntax tree; every node but Scan holds a ``child``."""

    def describe(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(eq=False)
class Scan(Node):
    table: str
    columns: Tuple[str, ...] = ()
    child: None = None

    def describe(self):
        return f"Scan {self.table} {list(self.columns)}"


@dataclasses.dataclass(eq=False)
class Filter(Node):
    child: Node
    predicate: Expr

    def describe(self):
        return f"Filter {self.predicate.signature()}"


@dataclasses.dataclass(eq=False)
class Join(Node):
    child: Node
    spec: LogicalJoin

    def describe(self):
        s = self.spec
        pred = f" where {_sig(s.dim_predicate)}" if s.dim_predicate \
            is not None else ""
        return (f"Join {s.how} {s.dim_table} on "
                f"{s.fact_key}={s.dim_key} +{list(s.dim_columns)}{pred}")


@dataclasses.dataclass(eq=False)
class Project(Node):
    child: Node
    columns: Tuple[str, ...] = ()
    derived: Tuple[Tuple[str, Expr], ...] = ()

    def describe(self):
        d = [f"{n}={e.signature()}" for n, e in self.derived]
        return f"Project {list(self.columns) + d}"


@dataclasses.dataclass(eq=False)
class Aggregate(Node):
    child: Node
    group_by: Tuple[str, ...] = ()
    aggs: Tuple[Tuple[str, str, str], ...] = ()

    def describe(self):
        a = [f"{o}={k}({c})" for o, c, k in self.aggs]
        return f"Aggregate by {list(self.group_by)} {a}"


@dataclasses.dataclass(eq=False)
class Sort(Node):
    child: Node
    keys: Tuple[Tuple[str, bool], ...]

    def describe(self):
        return "Sort " + ", ".join(f"{c}{' desc' if d else ''}"
                                   for c, d in self.keys)


@dataclasses.dataclass(eq=False)
class Limit(Node):
    child: Node
    n: int

    def describe(self):
        return f"Limit {self.n}"


def lower(root: Node) -> LogicalQuery:
    """Fold a node tree into the canonical LogicalQuery.  Stacked Filters
    merge conjunctively; a Filter above an Aggregate becomes HAVING;
    operator order is validated (joins/filters below aggregation, sort and
    limit above it)."""
    chain = []
    node: Optional[Node] = root
    while node is not None:
        chain.append(node)
        node = node.child
    chain.reverse()                       # Scan first
    if not chain or not isinstance(chain[0], Scan):
        raise ValueError("plan must be rooted at a Scan")
    scan = chain[0]
    q = dict(table=scan.table, columns=(), derived=(), predicate=None,
             joins=(), group_by=(), aggs=(), having=None, order_by=(),
             limit=None)
    seen_agg = False
    for node in chain[1:]:
        if isinstance(node, Filter):
            if seen_agg:
                q["having"] = node.predicate if q["having"] is None \
                    else q["having"] & node.predicate
            else:
                q["predicate"] = node.predicate if q["predicate"] is None \
                    else q["predicate"] & node.predicate
        elif isinstance(node, Join):
            if seen_agg:
                raise ValueError("Join above Aggregate is unsupported")
            q["joins"] = q["joins"] + (node.spec,)
        elif isinstance(node, Project):
            q["columns"] = tuple(node.columns)
            q["derived"] = q["derived"] + tuple(node.derived)
        elif isinstance(node, Aggregate):
            if seen_agg:
                raise ValueError("only one Aggregate per query")
            seen_agg = True
            q["group_by"] = tuple(node.group_by)
            q["aggs"] = tuple(node.aggs)
        elif isinstance(node, Sort):
            q["order_by"] = tuple(node.keys)
        elif isinstance(node, Limit):
            q["limit"] = node.n
        else:
            raise ValueError(f"unexpected node {type(node).__name__}")
    return LogicalQuery(**q).validate()


def as_ir(q) -> LogicalQuery:
    """Accept any front-end shape: LogicalQuery (identity), a node tree,
    or anything exposing ``to_ir()`` (the legacy Query shim, the fluent
    builder)."""
    if isinstance(q, LogicalQuery):
        return q
    if isinstance(q, Node):
        return lower(q)
    to_ir = getattr(q, "to_ir", None)
    if to_ir is not None:
        return to_ir()
    raise TypeError(f"not a logical plan: {type(q).__name__}")
