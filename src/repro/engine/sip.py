"""Sideways Information Passing (paper §6.1): semi-join filters built from a
hash join's build side, pushed into the probe-side Scan so non-joining rows
never flow up the plan.

Filter = a Bloom-style bit array over the build keys; the Scan ANDs the
probe membership test into its row mask. kernels/sip_bloom.py is the Pallas
twin (fused probe inside the scan kernel).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

# 32-bit mixers (jax default runtime is 32-bit; Knuth/xxhash-style salts)
_SALTS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D)


def _hash(keys: jax.Array, salt: int, bits: int) -> jax.Array:
    h = keys.astype(jnp.uint32) * jnp.uint32(salt)
    h = h ^ (h >> jnp.uint32(15))
    h = h * jnp.uint32(0x27D4EB2F)
    h = h ^ (h >> jnp.uint32(13))
    return (h % jnp.uint32(bits)).astype(jnp.int32)


@partial(jax.jit, static_argnames=("bits", "k"))
def bloom_build(keys: jax.Array, bits: int = 1 << 16, k: int = 2):
    bitarr = jnp.zeros(bits, jnp.bool_)
    for i in range(k):
        bitarr = bitarr.at[_hash(keys, _SALTS[i], bits)].set(True)
    return bitarr


@partial(jax.jit, static_argnames=("k",))
def bloom_probe(bitarr: jax.Array, keys: jax.Array, k: int = 2):
    bits = bitarr.shape[0]
    ok = jnp.ones(keys.shape, jnp.bool_)
    for i in range(k):
        ok &= bitarr[_hash(keys, _SALTS[i], bits)]
    return ok


def sip_filter(build_keys: jax.Array, probe_column: str,
               bits: int = 1 << 16) -> Callable[[Dict], jax.Array]:
    """Build a SIP filter closure for Scan (probe col -> row mask)."""
    bitarr = bloom_build(build_keys, bits)

    def apply(cols: Dict) -> jax.Array:
        return bloom_probe(bitarr, cols[probe_column])

    return apply
