"""Send/Recv (paper §6.1): inter-node data movement as jax collectives.

The paper's Send operator 'segments data such that all alike values are
sent to the same node, so each node computes full results independently' --
that is exactly an all_to_all resegmentation under shard_map. Broadcast
(replicating a small build side) is an all_gather. The optimizer picks
between co-located (no exchange), resegment, and broadcast (planner/cost).

These run on whatever mesh the caller provides -- tests use an 8-device CPU
mesh; the training stack reuses the same pattern for MoE expert dispatch
(models/moe.py 'a2a' mode).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def resegment(mesh: Mesh, axis: str, cols: Dict[str, jax.Array],
              dest: jax.Array, capacity: int
              ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Move each row to the shard ``dest[i]`` (hash-segmentation target).

    Returns (columns, valid) with per-shard static capacity; overflow
    drops (callers size capacity via the planner's stats). One all_to_all
    per column -- each tuple crosses the wire exactly once."""
    n_shards = mesh.shape[axis]

    def local(dest_l, *vals):
        # dest_l: (n_local,) destination shard per local row
        n_local = dest_l.shape[0]
        per = capacity // n_shards
        # slot of each row within its destination bucket
        onehot = jax.nn.one_hot(dest_l, n_shards, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)[
            jnp.arange(n_local), dest_l]
        keep = pos < per
        out_valid = jnp.zeros((n_shards, per), jnp.bool_)
        out_valid = out_valid.at[dest_l, jnp.where(keep, pos, per - 1)].set(
            keep)
        outs = []
        for v in vals:
            buf = jnp.zeros((n_shards, per), v.dtype)
            buf = buf.at[dest_l, jnp.where(keep, pos, per - 1)].set(
                jnp.where(keep, v, 0))
            outs.append(jax.lax.all_to_all(buf, axis, 0, 0, tiled=False))
        vr = jax.lax.all_to_all(out_valid, axis, 0, 0, tiled=False)
        return tuple(o.reshape(-1) for o in outs) + (vr.reshape(-1),)

    names = list(cols)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis),) * (1 + len(names)),
                   out_specs=(P(axis),) * (len(names) + 1))
    res = fn(dest, *[cols[c] for c in names])
    out = dict(zip(names, res[:-1]))
    return out, res[-1]


def broadcast_build_side(mesh: Mesh, axis: str,
                         cols: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Replicate a (small) build side to every shard: all_gather."""
    def local(*vals):
        return tuple(jax.lax.all_gather(v, axis, tiled=True) for v in vals)

    names = list(cols)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis),) * len(names),
                   out_specs=(P(),) * len(names))
    return dict(zip(names, fn(*[cols[c] for c in names])))
