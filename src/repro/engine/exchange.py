"""Send/Recv (paper §6.1): inter-node data movement as jax collectives.

The paper's Send operator 'segments data such that all alike values are
sent to the same node, so each node computes full results independently' --
that is exactly an all_to_all resegmentation under shard_map. Broadcast
(replicating a small build side) is an all_gather. The optimizer picks
between co-located (no exchange), resegment, and broadcast (planner/cost).

These run on whatever mesh the caller provides -- tests use an 8-device CPU
mesh; the training stack reuses the same pattern for MoE expert dispatch
(models/moe.py 'a2a' mode).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def resegment_local(axis: str, n_shards: int, per: int, dest_l: jax.Array,
                    vals: Tuple[jax.Array, ...]
                    ) -> Tuple[Tuple[jax.Array, ...], jax.Array, jax.Array]:
    """Per-shard body of :func:`resegment`, callable from INSIDE another
    ``shard_map``'d program (the segmented executor fuses this with the
    join + pre-aggregation stage so a multi-join query dispatches one
    program per stage instead of blocking on a host get between the
    exchange and the join).  ``dest_l`` is the (n_local,) destination
    shard per local row; returns (moved value tuple, valid, overflow),
    each moved value flat with ``n_shards * per`` slots."""
    n_local = dest_l.shape[0]
    # slot of each row within its destination bucket
    onehot = jax.nn.one_hot(dest_l, n_shards, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(n_local), dest_l]
    keep = pos < per
    # rows this source shard wanted to send to each destination but
    # could not fit; global per-destination overflow is the psum
    dropped = (onehot * (~keep)[:, None].astype(jnp.int32)).sum(axis=0)
    overflow = jax.lax.psum(dropped, axis)
    # overflowing rows write to a scratch column (per) that is sliced
    # off -- writing them to per-1 would clobber the legitimate last
    # slot and silently drop one MORE tuple than reported
    slot = jnp.where(keep, pos, per)
    out_valid = jnp.zeros((n_shards, per + 1), jnp.bool_)
    out_valid = out_valid.at[dest_l, slot].set(keep)[:, :per]
    outs = []
    for v in vals:
        buf = jnp.zeros((n_shards, per + 1), v.dtype)
        buf = buf.at[dest_l, slot].set(
            jnp.where(keep, v, 0))[:, :per]
        outs.append(jax.lax.all_to_all(buf, axis, 0, 0, tiled=False))
    vr = jax.lax.all_to_all(out_valid, axis, 0, 0, tiled=False)
    return (tuple(o.reshape(-1) for o in outs), vr.reshape(-1), overflow)


def resegment(mesh: Mesh, axis: str, cols: Dict[str, jax.Array],
              dest: jax.Array, capacity: int
              ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Move each row to the shard ``dest[i]`` (hash-segmentation target).

    Returns (columns, valid, overflow) with per-shard static capacity.
    ``overflow`` is an (n_shards,) int32 count of tuples destined to each
    shard that did NOT fit in ``capacity // n_shards`` slots and were
    dropped -- callers MUST check it (``overflow.sum() == 0``) and either
    retry with a larger capacity or fail loudly; silent truncation is a
    wrong answer, not a slow one.  One all_to_all per column -- each tuple
    crosses the wire exactly once."""
    n_shards = mesh.shape[axis]

    def local(dest_l, *vals):
        outs, vr, overflow = resegment_local(
            axis, n_shards, capacity // n_shards, dest_l, vals)
        return outs + (vr, overflow)

    names = list(cols)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis),) * (1 + len(names)),
                   out_specs=(P(axis),) * (len(names) + 1) + (P(),))
    res = fn(dest, *[cols[c] for c in names])
    out = dict(zip(names, res[:-2]))
    return out, res[-2], res[-1]


def broadcast_build_side(mesh: Mesh, axis: str,
                         cols: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Replicate a (small) build side to every shard: all_gather."""
    def local(*vals):
        return tuple(jax.lax.all_gather(v, axis, tiled=True) for v in vals)

    names = list(cols)
    # check_rep=False: all_gather(tiled) output IS replicated, but the
    # static replication checker cannot infer it on every jax version
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis),) * len(names),
                   out_specs=(P(),) * len(names), check_rep=False)
    return dict(zip(names, fn(*[cols[c] for c in names])))
