"""Logical-axis -> mesh-axis sharding rules (the framework's GSPMD policy).

The paper's segmentation insight maps directly: a *deterministic value->node
assignment* (here: batch element -> ('pod','data') chip row; attention-head /
expert / vocab shard -> 'model' chip column) is what makes operations local
and keeps the interconnect off the roofline's critical path. Co-located
compute = Vertica's co-located join; resegmentation = all_to_all.

Rule tables are plain dicts so hillclimbing can swap them per (arch x shape)
without touching model code. See EXPERIMENTS.md §Perf for the iterations.

Head layout for tensor parallelism
----------------------------------
Attention q/o weights are stored in a ``(kv_eff, group_eff, head_dim)``
layout (see models/attention.py: HeadLayout). ``kv_eff`` is always a multiple
of the model-axis size, so head sharding is even for every assigned arch --
including starcoder2 (36 heads) and hymba (25 heads) which do not divide 16.
Surplus slots are *dead* (zero-init, hard-masked) and kv heads needing
replication are repeated **in the weight graph** (a few-MB collective on
weights, instead of per-token activation collectives). The compute waste of
dead slots is visible in the roofline MODEL_FLOPS/HLO_FLOPS ratio.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Baseline rules (paper-faithful segmentation analogue).
# Params store their embed/vocab-ish dims sharded over 'data' (ZeRO-3/FSDP
# storage; all-gathered per layer by GSPMD) and their head/mlp/expert dims
# over 'model' (Megatron TP / expert parallelism).
# ---------------------------------------------------------------------------

BASE_RULES: Dict[str, Any] = {
    # --- activations ---
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,          # decode long-context override shards this
    "embed_act": None,
    "vocab_act": "model",
    # --- params ---
    "embed": "data",         # param storage sharding (FSDP-style)
    "vocab": "model",
    "heads": "model",        # q/o in (kv_eff, group) layout: kv_eff dim
    "kv_heads": None,        # raw kv weights stay replicated on model;
                             # the in-graph repeat produces kv_eff sharded
    "kv_heads_eff": "model",
    "q_group": None,
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_in": "data",     # within-expert storage sharding
    "ssm_heads": "model",
    "ssm_state": None,
    "layers": None,          # scanned-layer leading dim
    "conv": None,
    "frontend_seq": None,
}


def rules_for(arch, shape_kind: str, *, overrides: Optional[Dict[str, Any]]
              = None) -> Dict[str, Any]:
    """Resolve the rule table for an (arch x shape) cell.

    decode with global_batch < dp_size gets its KV-cache sequence dim
    sharded over 'data' instead (long_500k: batch=1), so the data axis
    contributes memory+compute instead of idling.
    """
    rules = dict(BASE_RULES)
    if shape_kind == "decode":
        rules["kv_seq"] = None  # default: batch carries 'data'
    if overrides:
        rules.update(overrides)
    return rules


def long_context_overrides() -> Dict[str, Any]:
    """batch=1 decode: shard the KV/state sequence dim over 'data'."""
    return {"batch": None, "kv_seq": "data"}


# ---------------------------------------------------------------------------
# Logical partition specs + activation sharding hints
# ---------------------------------------------------------------------------

def resolve_spec(axes: Tuple[Optional[str], ...], rules: Dict[str, Any],
                 mesh_axis_names: Tuple[str, ...]):
    """Map logical axis names to a PartitionSpec under ``rules``.

    Mesh axes absent from the current mesh are dropped; a mesh axis may
    appear at most once per spec (first dim wins)."""
    from jax.sharding import PartitionSpec

    used = set()
    out = []
    for ax in axes:
        entry = rules.get(ax) if ax is not None else None
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        names = [n for n in names if n in mesh_axis_names and n not in used]
        used.update(names)
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(tuple(names))
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


_HINTS = threading.local()


@contextlib.contextmanager
def activation_hints(rules: Dict[str, Any], mesh):
    """While active, shard_hint() pins activations via the given rules.
    Model code stays mesh-agnostic: it names logical axes only; tests and
    single-device runs see a no-op."""
    prev = getattr(_HINTS, "ctx", None)
    _HINTS.ctx = (rules, mesh)
    try:
        yield
    finally:
        _HINTS.ctx = prev


def shard_hint(x, *axes: Optional[str]):
    ctx = getattr(_HINTS, "ctx", None)
    if ctx is None:
        return x
    import jax
    from jax.sharding import NamedSharding

    rules, mesh = ctx
    spec = resolve_spec(axes, rules, mesh.axis_names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
