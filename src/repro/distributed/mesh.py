"""Mesh axis conventions.

Axes:
  pod   -- inter-pod data parallelism (DCI links); present on multi-pod mesh
  data  -- intra-pod data parallelism / FSDP param storage / segmentation
           (the Vertica 'segmentation' axis: tuple->node, batch->chip)
  model -- tensor/expert parallelism

The production meshes are built by launch/mesh.py (kept separate so that
importing this module never touches jax device state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

POD, DATA, MODEL = "pod", "data", "model"


def mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def dp_size(mesh) -> int:
    """Total data-parallel ways = pod * data."""
    return mesh_axis_size(mesh, POD) * mesh_axis_size(mesh, DATA)


def tp_size(mesh) -> int:
    return mesh_axis_size(mesh, MODEL)
