"""Mesh axis conventions.

Axes:
  pod   -- inter-pod data parallelism (DCI links); present on multi-pod mesh
  data  -- intra-pod data parallelism / FSDP param storage / segmentation
           (the Vertica 'segmentation' axis: tuple->node, batch->chip)
  model -- tensor/expert parallelism

The production meshes are built by launch/mesh.py (kept separate so that
importing this module never touches jax device state).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

POD, DATA, MODEL = "pod", "data", "model"


def make_query_mesh(n_shards: Optional[int] = None, axis: str = DATA):
    """1-D mesh for the segmented query executor (engine/segmented.py):
    every shard is one 'node' of the Vertica ring, tuples land on shards
    by segmentation hash.  Defaults to every visible device; built lazily
    so importing this module never initializes the jax backend."""
    import numpy as np

    n = n_shards if n_shards is not None else jax.device_count()
    devs = np.asarray(jax.devices()[:n])
    return jax.sharding.Mesh(devs, (axis,))


def mesh_axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def dp_size(mesh) -> int:
    """Total data-parallel ways = pod * data."""
    return mesh_axis_size(mesh, POD) * mesh_axis_size(mesh, DATA)


def tp_size(mesh) -> int:
    return mesh_axis_size(mesh, MODEL)
