from .mesh import DATA, MODEL, POD, dp_size, mesh_axis_size, tp_size
from .sharding import BASE_RULES, long_context_overrides, rules_for

__all__ = ["DATA", "MODEL", "POD", "dp_size", "mesh_axis_size", "tp_size",
           "BASE_RULES", "long_context_overrides", "rules_for"]
