"""Parameter declaration / init / partition-spec system.

Single source of truth per model: a nested dict of ``ParamDecl`` (shape +
logical axis names + init). From it we derive
  * concrete parameters       (``init_params``),
  * abstract ShapeDtypeStructs for the dry-run (``abstract_params``),
  * ``jax.sharding.PartitionSpec`` trees (``partition_specs``)
so weights, dry-run stand-ins and shardings can never drift apart.

Logical->mesh axis rules live in distributed/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = none)
    init: str = "normal"             # normal | zeros | ones | embed
    scale: Optional[float] = None    # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Decls = Dict[str, Any]  # nested dict: str -> ParamDecl | Decls


def _fan_in(shape: Tuple[int, ...]) -> int:
    # all dims except the last are treated as fan-in (weights are stored
    # (in_dims..., out_dims...) with out = last dim by convention here; for
    # multi-dim outputs the stddev difference is negligible for smoke tests)
    return max(1, int(np.prod(shape[:-1])))


def _init_one(decl: ParamDecl, key, dtype) -> jax.Array:
    if decl.init == "zeros":
        return jnp.zeros(decl.shape, dtype)
    if decl.init == "ones":
        return jnp.ones(decl.shape, dtype)
    if decl.init == "embed":
        std = decl.scale if decl.scale is not None else 0.02
        return (jax.random.normal(key, decl.shape, jnp.float32) * std
                ).astype(dtype)
    std = decl.scale if decl.scale is not None else _fan_in(decl.shape) ** -0.5
    return (jax.random.normal(key, decl.shape, jnp.float32) * std).astype(dtype)


def _map_decls(decls: Decls, fn: Callable[[str, ParamDecl], Any],
               prefix: str = "") -> Dict[str, Any]:
    out = {}
    for name, d in decls.items():
        path = f"{prefix}/{name}" if prefix else name
        if isinstance(d, ParamDecl):
            out[name] = fn(path, d)
        else:
            out[name] = _map_decls(d, fn, path)
    return out


def init_params(decls: Decls, key: jax.Array, dtype=jnp.float32):
    """Materialize parameters. Each leaf gets a key folded from its path so
    adding/removing parameters does not reshuffle others."""

    def one(path: str, d: ParamDecl):
        k = jax.random.fold_in(key, zlib_crc(path))
        return _init_one(d, k, dtype)

    return _map_decls(decls, one)


def zlib_crc(s: str) -> int:
    import zlib

    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def abstract_params(decls: Decls, dtype=jnp.float32):
    """ShapeDtypeStruct tree for .lower() without allocation (dry-run)."""
    return _map_decls(
        decls, lambda _, d: jax.ShapeDtypeStruct(d.shape, dtype))


def logical_axes(decls: Decls):
    return _map_decls(decls, lambda _, d: d.axes)


from ..distributed.sharding import resolve_spec  # noqa: E402 (re-export)


def partition_specs(decls: Decls, rules: Dict[str, Any],
                    mesh_axis_names: Tuple[str, ...]):
    return _map_decls(
        decls, lambda _, d: resolve_spec(d.axes, rules, mesh_axis_names))


def count_params(decls: Decls) -> int:
    total = 0

    def one(_, d: ParamDecl):
        nonlocal total
        total += int(np.prod(d.shape))

    _map_decls(decls, one)
    return total
