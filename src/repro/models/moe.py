"""Mixture-of-Experts layer with expert parallelism over the 'model' axis.

Two dispatch implementations (config: MoEConfig.dispatch):

* ``scatter`` (baseline): capacity-buffer dispatch expressed with gather /
  scatter under plain pjit; GSPMD inserts the cross-shard data movement.
* ``a2a`` (optimized): explicit expert-local dispatch under shard_map.
  We set out to build the Vertica Send/Recv resegmentation (all_to_all of
  tokens to expert shards) -- and discovered mid-implementation that the
  co-located-join-with-replicated-dimension plan (paper §6.2) is strictly
  cheaper here: TP already replicates activations over 'model', so expert
  dispatch is local and the only collective is the output psum. See
  moe_apply_expert_local and EXPERIMENTS.md §Perf for the measured
  collective-byte reduction.

Capacity policy: tokens beyond ``capacity_factor * N * top_k / E`` per expert
are dropped (standard Switch/GShard semantics); the residual stream carries
them unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import MoEConfig
from ..distributed.sharding import shard_hint
from .params import ParamDecl


def moe_decls(d: int, moe: MoEConfig) -> Dict[str, Any]:
    e, f = moe.num_experts, moe.d_ff_expert
    return {
        "router": ParamDecl((d, e), ("embed", "experts")),
        "wi_gate": ParamDecl((e, d, f), ("experts", "expert_in", "mlp")),
        "wi_up": ParamDecl((e, d, f), ("experts", "expert_in", "mlp")),
        "wo": ParamDecl((e, f, d), ("experts", "mlp", "expert_in")),
    }


def _route(p, x, moe: MoEConfig):
    """Router: returns (gates (N,k), experts (N,k), aux_loss)."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (N,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    e = moe.num_experts
    density = jnp.zeros((e,), jnp.float32).at[experts.reshape(-1)].add(
        1.0) / max(1, experts.size)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(density * mean_prob)
    return gates.astype(x.dtype), experts, aux


def _expert_ffn(p, h):
    """h: (E, C, d) -> (E, C, d); per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", h, p["wi_gate"].astype(h.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, p["wi_up"].astype(h.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      p["wo"].astype(h.dtype))


def moe_apply(p, x: jax.Array, moe: MoEConfig, *,
              mesh=None) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar). Dispatch mode from
    MoEConfig; 'a2a' requires an active activation_hints context (mesh)."""
    if moe.dispatch == "a2a":
        from ..distributed.sharding import _HINTS
        ctx = getattr(_HINTS, "ctx", None)
        if ctx is not None:
            return moe_apply_expert_local(p, x, moe, ctx[0], ctx[1])
    return _moe_apply_scatter(p, x, moe)


def _moe_apply_scatter(p, x: jax.Array, moe: MoEConfig
                       ) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    n = B * S
    flat = x.reshape(n, d)
    gates, experts, aux = _route(p, flat, moe)

    e = moe.num_experts
    cap = int(np.ceil(n * moe.top_k * moe.capacity_factor / e))
    cap = max(cap, 4)

    # position of each (token, k) within its expert, by arrival order
    flat_e = experts.reshape(-1)                              # (n*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)       # (n*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)          # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap

    # dispatch: scatter tokens into the (E, cap, d) buffer
    tok_idx = jnp.arange(n * moe.top_k) // moe.top_k
    buf = jnp.zeros((e, cap, d), flat.dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, cap - 1)].add(
        jnp.where(keep[:, None], flat[tok_idx], 0))
    buf = shard_hint(buf, "experts", "expert_cap", None)
    buf = _expert_ffn(p, buf)
    buf = shard_hint(buf, "experts", "expert_cap", None)

    # combine: gather expert outputs back and weight by gates
    out_tok = buf[flat_e, jnp.clip(pos, 0, cap - 1)]          # (n*k, d)
    out_tok = jnp.where(keep[:, None], out_tok, 0)
    out = (out_tok * gates.reshape(-1)[:, None]).reshape(n, moe.top_k, d)
    return out.sum(axis=1).reshape(B, S, d), aux * moe.router_aux_coef


# ---------------------------------------------------------------------------
# Expert-local dispatch under shard_map (the §Perf-optimized path)
# ---------------------------------------------------------------------------

def moe_apply_expert_local(p, x: jax.Array, moe: MoEConfig, rules, mesh
                           ) -> tuple[jax.Array, jax.Array]:
    """Explicit expert-parallel dispatch via shard_map.

    Hypothesis history (EXPERIMENTS.md §Perf): we set out to implement the
    Vertica Send/Recv resegmentation (all_to_all of tokens to their expert
    shard). Working it through exposed a cheaper plan the paper itself
    suggests (§6.2 'co-located joins with a replicated dimension'):
    activations are already REPLICATED over the 'model' axis under tensor
    parallelism, so every expert shard already holds every local token --
    dispatch is a purely LOCAL gather, and the only collective is one psum
    of the combined outputs (identical in shape to a dense TP MLP's
    all-reduce). Token->expert movement: zero bytes.

    Layout inside shard_map:
      x       : sharded over ('pod','data') on batch, replicated on 'model'
      router  : replicated (d x E is tiny)
      experts : expert dim sharded over 'model', d dim sharded over 'data'
                (FSDP storage) and all-gathered here, explicitly.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    tp = mesh.shape["model"] if "model" in names else 1
    e, k, f = moe.num_experts, moe.top_k, moe.d_ff_expert
    assert e % tp == 0, (e, tp)
    e_loc = e // tp
    B, S, d = x.shape

    def local(router, wig, wiu, wo, x_l):
        n = x_l.shape[0] * x_l.shape[1]
        flat = x_l.reshape(n, d)
        # FSDP: assemble full expert weights from their 'data' shards
        if dp_axes:
            wig = jax.lax.all_gather(wig, "data", axis=1, tiled=True)
            wiu = jax.lax.all_gather(wiu, "data", axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        logits = (flat @ router.astype(flat.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, experts = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        m_idx = jax.lax.axis_index("model") if tp > 1 else 0
        lo = m_idx * e_loc
        # local capacity dispatch: only (token, k) pairs routed to MY experts
        flat_e = experts.reshape(-1)                       # (n*k,)
        local_e = flat_e - lo
        mine = (local_e >= 0) & (local_e < e_loc)
        local_e = jnp.where(mine, local_e, 0)
        cap = max(4, int(np.ceil(n * k * moe.capacity_factor / e)))
        onehot = jax.nn.one_hot(local_e, e_loc, dtype=jnp.int32) * \
            mine[:, None].astype(jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)[
            jnp.arange(n * k), local_e]
        keep = mine & (pos < cap)
        tok_idx = jnp.arange(n * k, dtype=jnp.int32) // k
        # SLOT-INDEXED dispatch (§Perf LM-1 iter 3): scatter 4-byte token
        # indices into slots, then gather token data ONCE at slot
        # granularity -- the naive pair-wise gather+scatter materializes
        # (n*k, d) token copies, ~k/capacity_factor = ~6x more bytes
        # (measured 1.2 TB/dev of phantom traffic on olmoe train_4k).
        n_slots = e_loc * cap
        flat_slot = jnp.where(keep, local_e * cap + pos, n_slots)
        slot_tok = jnp.zeros(n_slots + 1, jnp.int32).at[flat_slot].set(
            tok_idx)
        slot_gate = jnp.zeros(n_slots + 1, jnp.float32).at[flat_slot].set(
            jnp.where(keep, gates.reshape(-1), 0.0))
        slot_live = jnp.zeros(n_slots + 1, jnp.bool_).at[flat_slot].set(
            keep)
        buf = flat[slot_tok[:n_slots]] * slot_live[:n_slots, None].astype(
            flat.dtype)
        buf = buf.reshape(e_loc, cap, d)
        # expert FFN on local experts
        g = jnp.einsum("ecd,edf->ecf", buf, wig.astype(buf.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wiu.astype(buf.dtype))
        hid = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                         wo.astype(buf.dtype))
        # combine: gate-weight each slot, scatter-add back to its token
        weighted = hid.reshape(n_slots, d) * \
            slot_gate[:n_slots, None].astype(hid.dtype)
        part = jnp.zeros((n, d), flat.dtype).at[slot_tok[:n_slots]].add(
            jnp.where(slot_live[:n_slots, None], weighted, 0))
        # combine partial expert outputs: ONE all-reduce, same shape as a
        # dense TP MLP's -- zero-byte token movement
        if tp > 1:
            part = jax.lax.psum(part, "model")
        # load-balance aux: the per-DP-shard estimator (density x mean-prob
        # computed over local tokens, then averaged) -- the standard choice
        # under data parallelism; it differs from a global-batch estimator
        # by O(1/shards) sampling noise
        density = jnp.zeros((e,), jnp.float32).at[flat_e].add(
            1.0) / max(1, n * k)
        aux = e * jnp.sum(density * probs.mean(axis=0))
        if dp_axes:
            aux = jax.lax.pmean(aux, dp_axes)
        return part.reshape(x_l.shape), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P("model", "data"), P("model", "data"),
                  P("model", None, "data"), P(dp_axes)),
        out_specs=(P(dp_axes), P()),
        check_rep=False)
    out, aux = fn(p["router"], p["wi_gate"], p["wi_up"], p["wo"], x)
    return out, aux * moe.router_aux_coef
