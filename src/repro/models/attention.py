"""Grouped-query attention with a TP-even head layout, KV caching, and
memory-bounded (chunked online-softmax) cores for long sequences.

HeadLayout
----------
The model axis of the production mesh is 16-way, but the assigned archs have
q-head counts {16, 25, 32, 36} and kv-head counts {4, 5, 8, 16, 32}. To keep
head sharding even (no GSPMD padding waste on the big q/o projections and no
per-token activation collectives), q/o weights are stored in a

    (kv_eff, g_eff, head_dim)  layout, with  kv_eff % tp == 0.

* Each kv_eff slot serves g_eff q slots whose keys/values it holds.
* kv weights are stored raw (d, n_kv, hd) -- replicated over 'model',
  sharded over 'data' on the embed dim -- and expanded to kv_eff slots with
  an in-graph static gather ``wk[:, kv_map, :]``. The gather is a *weight*
  op (a few MB), not an activation op: each model shard slices locally;
  gradients of the replicated copies are summed by GSPMD, exactly matching
  GQA semantics.
* Surplus slots are dead: zero-init q weights + a hard output mask so
  gradients cannot resurrect them; math is exactly the published arch.
* dead-slot compute waste shows up in the roofline MODEL_FLOPS/HLO ratio.

Examples at tp=16: qwen3 (32q,8kv) -> kv_eff=16, g_eff=2, 0 dead.
starcoder2 (36q,4kv) -> kv_eff=16 (4 kv x 3 copies + 4 dead), g_eff=3,
12 dead q slots of 48. hymba (25q,5kv) -> kv_eff=16 (5 kv x 3 copies +
1 dead), g_eff=2, 7 dead of 32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard_hint
from .layers import apply_rope, rmsnorm, rmsnorm_decl
from .params import ParamDecl

NEG_INF = -1e9
CHUNKED_THRESHOLD = 8192   # use chunked online-softmax core above this T
KV_CHUNK = 1024


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    n_q: int
    n_kv: int
    head_dim: int
    tp: int
    kv_eff: int
    g_eff: int
    kv_map: Tuple[int, ...]   # len kv_eff; original kv head (dead slots -> 0)
    q_map: Tuple[int, ...]    # len kv_eff*g_eff; original q head or -1
    alive: Tuple[int, ...]    # len kv_eff*g_eff; 1 if slot is a real q head

    @property
    def n_q_eff(self) -> int:
        return self.kv_eff * self.g_eff

    @property
    def n_dead(self) -> int:
        return self.n_q_eff - self.n_q

    def alive_mask(self) -> np.ndarray:
        return np.asarray(self.alive, np.float32).reshape(
            self.kv_eff, self.g_eff)


def resolve_head_layout(n_q: int, n_kv: int, head_dim: int,
                        tp: int) -> HeadLayout:
    assert n_q % n_kv == 0, (n_q, n_kv)
    group = n_q // n_kv
    if n_kv >= tp:
        kv_eff = -(-n_kv // tp) * tp
        g_eff = group
        kv_map, q_map = [], []
        for j in range(kv_eff):
            kv_map.append(j if j < n_kv else 0)
            for g in range(g_eff):
                q_map.append(j * group + g if j < n_kv else -1)
    else:
        g_eff = max(1, -(-n_q // tp))
        # grow g_eff until all (kv, q-chunk) pairs fit in tp slots
        while n_kv * (-(-group // g_eff)) > tp:
            g_eff += 1
        kv_map, q_map = [], []
        for k in range(n_kv):
            qs = list(range(k * group, (k + 1) * group))
            for c in range(0, group, g_eff):
                kv_map.append(k)
                chunk = qs[c: c + g_eff]
                chunk += [-1] * (g_eff - len(chunk))
                q_map.extend(chunk)
        while len(kv_map) < tp:
            kv_map.append(0)
            q_map.extend([-1] * g_eff)
        kv_eff = len(kv_map)
    alive = tuple(1 if q >= 0 else 0 for q in q_map)
    return HeadLayout(n_q, n_kv, head_dim, tp, kv_eff, g_eff,
                      tuple(kv_map), tuple(q_map), alive)


# ---------------------------------------------------------------------------
# Param decls
# ---------------------------------------------------------------------------

def attention_decls(d: int, layout: HeadLayout, qk_norm: bool,
                    cross: bool = False) -> Dict[str, Any]:
    hd = layout.head_dim
    decls = {
        "wq": ParamDecl((d, layout.kv_eff, layout.g_eff, hd),
                        ("embed", "kv_heads_eff", "q_group", "head_dim")),
        "wk": ParamDecl((d, layout.n_kv, hd),
                        ("embed", "kv_heads", "head_dim")),
        "wv": ParamDecl((d, layout.n_kv, hd),
                        ("embed", "kv_heads", "head_dim")),
        "wo": ParamDecl((layout.kv_eff, layout.g_eff, hd, d),
                        ("kv_heads_eff", "q_group", "head_dim", "embed")),
    }
    if qk_norm:
        decls["q_norm"] = rmsnorm_decl(hd)
        decls["k_norm"] = rmsnorm_decl(hd)
    if cross:
        decls["gate"] = ParamDecl((1,), (None,), init="zeros")
    return decls


def _expand_kv_weight(w: jax.Array, layout: HeadLayout) -> jax.Array:
    """(d, n_kv, hd) -> (d, kv_eff, hd). Static gather; each model shard
    slices its own copies locally (w is replicated over 'model')."""
    idx = jnp.asarray(layout.kv_map, jnp.int32)
    return jnp.take(w, idx, axis=1)


def project_qkv(p, x: jax.Array, layout: HeadLayout, *,
                positions: Optional[jax.Array], rope_theta: float,
                qk_norm: bool, kv_x: Optional[jax.Array] = None):
    """x: (B,S,d) -> q (B,S,kv_eff,g_eff,hd), k/v (B,T,kv_eff,hd).

    kv_x: source for k/v (cross attention); defaults to x.
    positions=None skips RoPE (cross attention / encoder option)."""
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"].astype(x.dtype))
    wk = _expand_kv_weight(p["wk"].astype(x.dtype), layout)
    wv = _expand_kv_weight(p["wv"].astype(x.dtype), layout)
    k = jnp.einsum("btd,dkh->btkh", src, wk)
    v = jnp.einsum("btd,dkh->btkh", src, wv)
    if qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if positions is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    q = shard_hint(q, "batch", "seq", "kv_heads_eff", "q_group", "head_dim")
    k = shard_hint(k, "batch", "seq", "kv_heads_eff", "head_dim")
    v = shard_hint(v, "batch", "seq", "kv_heads_eff", "head_dim")
    return q, k, v


def output_proj(p, ctx: jax.Array, layout: HeadLayout) -> jax.Array:
    """ctx (B,S,kv_eff,g_eff,hd) -> (B,S,d), dead slots hard-masked."""
    mask = jnp.asarray(layout.alive_mask(), ctx.dtype)
    ctx = ctx * mask[None, None, :, :, None]
    return jnp.einsum("bskgh,kghd->bsd", ctx, p["wo"].astype(ctx.dtype))


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(S,T) additive bias from absolute positions."""
    d = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def attend_full(q, k, v, q_pos, k_pos, *, causal: bool,
                window: Optional[int]) -> jax.Array:
    """Materialized-scores core. q (B,S,K,G,H), k/v (B,T,K,H)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k) / np.sqrt(hd)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    probs = jax.nn.softmax(scores.astype(jnp.float32) + bias, axis=-1)
    return jnp.einsum("bkgst,btkh->bskgh", probs.astype(q.dtype), v)


def attend_chunked(q, k, v, q_pos, k_pos, *, causal: bool,
                   window: Optional[int], chunk: int = KV_CHUNK) -> jax.Array:
    """Online-softmax over KV chunks: O(S*chunk) live memory instead of
    O(S*T). This is the XLA flash-attention analogue used on the dry-run
    path; the Pallas kernel (kernels/flash_attention.py) implements the same
    contraction with explicit VMEM tiling for real TPUs."""
    B, S, K, G, H = q.shape
    T = k.shape[1]
    n_chunks = -(-T // chunk)
    Tp = n_chunks * chunk
    pad = Tp - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-10**9)
    k = k.reshape(B, n_chunks, chunk, K, H).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, n_chunks, chunk, K, H).transpose(1, 0, 2, 3, 4)
    k_pos = k_pos.reshape(n_chunks, chunk)
    scale = 1.0 / np.sqrt(H)

    def step(carry, inp):
        acc, m, l = carry                         # (B,S,K,G,H) f32, (B,K,G,S)
        kc, vc, kp = inp
        s = jnp.einsum("bskgh,btkh->bkgst", q, kc).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos, kp, causal, window)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkh->bskgh", p.astype(q.dtype), vc)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + \
            pv.astype(jnp.float32)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, S, K, G, H), jnp.float32)
    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(step, (acc0, m0, l0), (k, v, k_pos))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.astype(q.dtype)


def attend(q, k, v, q_pos, k_pos, *, causal: bool = True,
           window: Optional[int] = None) -> jax.Array:
    if k.shape[1] > CHUNKED_THRESHOLD:
        return attend_chunked(q, k, v, q_pos, k_pos, causal=causal,
                              window=window)
    return attend_full(q, k, v, q_pos, k_pos, causal=causal, window=window)


# ---------------------------------------------------------------------------
# KV cache (decode) -- optionally int8-quantized (per-token-per-head scale):
# decode is cache-read-bandwidth bound, so halving bytes vs bf16 halves the
# memory-roofline term (EXPERIMENTS.md §Perf, qwen3 decode_32k).
# ---------------------------------------------------------------------------


def quantize_kv(x: jax.Array):
    """(B,T,K,H) -> (int8 codes, f32 scale (B,T,K,1)); symmetric."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s = jnp.maximum(s / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s), -127, 127
                 ).astype(jnp.int8)
    return q, s


def dequantize_kv(q: jax.Array, s: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * s).astype(dtype)

def cache_decl_shapes(batch: int, max_len: int, layout: HeadLayout,
                      window: Optional[int]):
    """Shape/axes for one layer's KV cache. Window layers use a ring buffer
    of the window size; global layers hold the full context."""
    T = min(max_len, window) if window else max_len
    shape = (batch, T, layout.kv_eff, layout.head_dim)
    axes = ("batch", "kv_seq", "kv_heads_eff", "head_dim")
    return shape, axes


def cache_update(cache_k, cache_v, k_new, v_new, pos: jax.Array,
                 window: Optional[int]):
    """Insert one step's k/v at absolute position ``pos`` (ring for SWA)."""
    T = cache_k.shape[1]
    idx = (pos % T) if window else pos
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                      (0, idx, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                      (0, idx, 0, 0))
    return ck, cv


def cache_positions(pos: jax.Array, T: int, window: Optional[int]):
    """Absolute positions of each cache slot given current write pos."""
    slots = jnp.arange(T)
    if not window:
        # slot i holds absolute position i; unwritten slots masked by > pos
        return jnp.where(slots <= pos, slots, -10**9)
    # ring: slot i holds the largest p <= pos with p % T == i
    cur = pos % T
    p = pos - ((cur - slots) % T)
    return jnp.where(p >= 0, p, -10**9)


def attend_decode(q, cache_k, cache_v, pos: jax.Array,
                  window: Optional[int]) -> jax.Array:
    """q (B,1,K,G,H) against the cache (B,T,K,H); pos = current abs pos."""
    T = cache_k.shape[1]
    k_pos = cache_positions(pos, T, window)
    q_pos = pos[None] if pos.ndim == 0 else pos
    return attend_full(q, cache_k, cache_v, jnp.atleast_1d(q_pos), k_pos,
                       causal=True, window=window)
