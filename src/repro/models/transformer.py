"""Decoder-only LM assembly: dense, MoE, SSM (mamba2) and hybrid (hymba)
families share one generic block; layers run under jax.lax.scan with
configurable remat so the HLO stays one-block-sized (fast compiles, the
production-idiomatic structure for 1000+ node jobs).

Layer segmentation: archs with heterogeneous layers (hymba's 3 global-
attention layers among sliding-window layers) are split into *segments* --
unscanned singles and scanned stacks -- so every scan body is homogeneous.

Modes:
  train   -- full sequence, loss-ready logits, MoE aux losses accumulated
  prefill -- full sequence, last-position logits + KV/SSM cache out
  decode  -- one token against the cache
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import attention as attn
from . import ssm as ssm_mod
from .layers import (embed_decls, embed_lookup, logits_fn, mlp_apply,
                     mlp_decls, rmsnorm, rmsnorm_decl)
from .moe import moe_apply, moe_decls
from .params import Decls, ParamDecl

CACHE_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Segment:
    name: str
    n_layers: int          # 1 for singles
    scanned: bool
    window: Optional[int]  # None = full attention


def segments(cfg: ArchConfig) -> List[Segment]:
    if not cfg.global_layers or cfg.window is None:
        return [Segment("layers", cfg.n_layers, cfg.n_layers > 1, cfg.window)]
    segs: List[Segment] = []
    prev = 0
    for i, g in enumerate(sorted(cfg.global_layers)):
        if g > prev:
            segs.append(Segment(f"swa_{i}", g - prev, g - prev > 1,
                                cfg.window))
        segs.append(Segment(f"global_{i}", 1, False, None))
        prev = g + 1
    if prev < cfg.n_layers:
        segs.append(Segment(f"swa_tail", cfg.n_layers - prev,
                            cfg.n_layers - prev > 1, cfg.window))
    assert sum(s.n_layers for s in segs) == cfg.n_layers
    return segs


def _stack_decls(decls: Decls, n: int) -> Decls:
    """Prepend a scanned 'layers' dim to every leaf."""
    out = {}
    for k, v in decls.items():
        if isinstance(v, ParamDecl):
            out[k] = ParamDecl((n,) + v.shape, ("layers",) + v.axes,
                               v.init, v.scale)
        else:
            out[k] = _stack_decls(v, n)
    return out


# ---------------------------------------------------------------------------
# Generic block
# ---------------------------------------------------------------------------

def block_decls(cfg: ArchConfig, tp: int, *, cross: bool = False) -> Decls:
    d = cfg.d_model
    decls: Decls = {}
    if cfg.n_heads:
        layout = attn.resolve_head_layout(cfg.n_heads, cfg.n_kv_heads,
                                          cfg.resolved_head_dim, tp)
        decls["ln1"] = rmsnorm_decl(d)
        decls["attn"] = attn.attention_decls(d, layout, cfg.qk_norm)
    if cfg.ssm is not None:
        lo = ssm_mod.resolve_ssm_layout(d, cfg.ssm, tp)
        decls["ln_ssm"] = rmsnorm_decl(d)
        decls["ssm"] = ssm_mod.ssm_decls(d, lo)
        if cfg.family == "hybrid":
            # per-branch learned output scales (Hymba's branch fusion)
            decls["attn_scale"] = ParamDecl((d,), (None,), init="ones")
            decls["ssm_scale"] = ParamDecl((d,), (None,), init="ones")
    if cross:
        layout = attn.resolve_head_layout(cfg.n_heads, cfg.n_kv_heads,
                                          cfg.resolved_head_dim, tp)
        decls["ln_cross"] = rmsnorm_decl(d)
        decls["cross"] = attn.attention_decls(d, layout, False, cross=True)
    if cfg.moe is not None:
        decls["ln2"] = rmsnorm_decl(d)
        decls["moe"] = moe_decls(d, cfg.moe)
    elif cfg.d_ff:
        decls["ln2"] = rmsnorm_decl(d)
        decls["mlp"] = mlp_decls(d, cfg.d_ff, cfg.mlp)
    return decls


def _attn_branch(cfg, layout, p, h, *, mode, window, positions, cache, pos,
                 causal: bool = True, max_len: Optional[int] = None,
                 kv_quant: bool = False):
    """Self-attention on pre-normed h; returns (out, cache_out)."""
    if mode == "decode":
        q, k, v = attn.project_qkv(p, h, layout, positions=positions,
                                   rope_theta=cfg.rope_theta,
                                   qk_norm=cfg.qk_norm)
        if kv_quant:
            kq, ks = attn.quantize_kv(k)
            vq, vs = attn.quantize_kv(v)
            ckq, cvq = attn.cache_update(cache["k"]["q"], cache["v"]["q"],
                                         kq, vq, pos, window)
            cks, cvs = attn.cache_update(cache["k"]["s"], cache["v"]["s"],
                                         ks, vs, pos, window)
            ck = attn.dequantize_kv(ckq, cks, q.dtype)
            cv = attn.dequantize_kv(cvq, cvs, q.dtype)
            ctx = attn.attend_decode(q, ck, cv, pos, window)
            return ctx, {"k": {"q": ckq, "s": cks},
                         "v": {"q": cvq, "s": cvs}}
        ck, cv = attn.cache_update(cache["k"], cache["v"], k, v, pos, window)
        ctx = attn.attend_decode(q, ck, cv, pos, window)
        return ctx, {"k": ck, "v": cv}
    q, k, v = attn.project_qkv(p, h, layout, positions=positions,
                               rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm)
    pos1d = positions[0]
    ctx = attn.attend(q, k, v, pos1d, pos1d, causal=causal, window=window)
    cache_out = None
    if mode == "prefill":
        S = k.shape[1]
        cap = max_len or S
        if window:
            # ring buffer of W slots; token p lives at slot p % W
            W = min(S, window)
            kw, vw = k[:, S - W:], v[:, S - W:]
            if W < window:
                kw = jnp.pad(kw, ((0, 0), (0, window - W), (0, 0), (0, 0)))
                vw = jnp.pad(vw, ((0, 0), (0, window - W), (0, 0), (0, 0)))
            shift = (S - W) % window if W == window else (S - W)
            kw = jnp.roll(kw, shift, axis=1)
            vw = jnp.roll(vw, shift, axis=1)
            kc, vc = kw, vw
        else:
            pad = cap - S
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
        if kv_quant:
            kq, ks = attn.quantize_kv(kc)
            vq, vs = attn.quantize_kv(vc)
            cache_out = {"k": {"q": kq, "s": ks}, "v": {"q": vq, "s": vs}}
        else:
            cache_out = {"k": kc.astype(CACHE_DTYPE),
                         "v": vc.astype(CACHE_DTYPE)}
    return ctx, cache_out


def _cross_branch(cfg, tp, p, x, *, mode, memory, cache):
    """Cross-attention to a (B,T,d) memory (encoder output / image embeds).
    K/V are projected per layer from the memory (train/prefill) or read from
    the cache (decode). Gated (tanh, zero-init) like Llama-3.2's image
    layers; the gate trains open."""
    layout = attn.resolve_head_layout(cfg.n_heads, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, tp)
    h = rmsnorm(p["ln_cross"], x)
    q = jnp.einsum("bsd,dkgh->bskgh", h, p["cross"]["wq"].astype(h.dtype))
    cache_out = None
    if mode == "decode":
        ik, iv = cache["k"], cache["v"]
        cache_out = cache
    else:
        wk = attn._expand_kv_weight(p["cross"]["wk"].astype(h.dtype), layout)
        wv = attn._expand_kv_weight(p["cross"]["wv"].astype(h.dtype), layout)
        ik = jnp.einsum("btd,dkh->btkh", memory.astype(h.dtype), wk)
        iv = jnp.einsum("btd,dkh->btkh", memory.astype(h.dtype), wv)
        if mode == "prefill":
            cache_out = {"k": ik.astype(CACHE_DTYPE),
                         "v": iv.astype(CACHE_DTYPE)}
    S, T = q.shape[1], ik.shape[1]
    qp = jnp.zeros((S,), jnp.int32)
    kp = jnp.zeros((T,), jnp.int32)
    if S == 1 or T <= attn.CHUNKED_THRESHOLD:
        ctx = attn.attend_full(q, ik.astype(h.dtype), iv.astype(h.dtype),
                               qp, kp, causal=False, window=None)
    else:
        ctx = attn.attend_chunked(q, ik.astype(h.dtype), iv.astype(h.dtype),
                                  qp, kp, causal=False, window=None)
    gate = jnp.tanh(p["cross"]["gate"].astype(h.dtype))
    return gate * attn.output_proj(p["cross"], ctx, layout), cache_out


def block_apply(cfg: ArchConfig, tp: int, p: Dict[str, Any], x: jax.Array, *,
                mode: str, window: Optional[int],
                positions: Optional[jax.Array],
                cache: Optional[Dict[str, Any]] = None,
                pos: Optional[jax.Array] = None,
                memory: Optional[jax.Array] = None,
                causal: bool = True,
                max_len: Optional[int] = None,
                kv_quant: bool = False,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """One decoder block. Returns (x, cache_out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    cache = cache or {}
    cache_out: Dict[str, Any] = {}
    d = cfg.d_model

    if cfg.n_heads and "attn" in p:
        layout = attn.resolve_head_layout(cfg.n_heads, cfg.n_kv_heads,
                                          cfg.resolved_head_dim, tp)
        h = rmsnorm(p["ln1"], x)
        if cfg.family == "hybrid":
            # parallel attention + SSM branches on the same input (Hymba)
            ctx, c_attn = _attn_branch(cfg, layout, p["attn"], h, mode=mode,
                                       window=window, positions=positions,
                                       cache=cache.get("attn"), pos=pos,
                                       max_len=max_len, kv_quant=kv_quant)
            a_out = attn.output_proj(p["attn"], ctx, layout)
            lo = ssm_mod.resolve_ssm_layout(d, cfg.ssm, tp)
            if mode == "decode":
                s_out, c_ssm = ssm_mod.ssm_decode_step(
                    p["ssm"], cache["ssm"], h, lo)
            elif mode == "prefill":
                s_out, s_state = ssm_mod.ssd_apply(
                    p["ssm"], h, lo, cfg.ssm.chunk, return_state=True)
                c_ssm = _ssm_prefill_cache(p, h, lo, s_state)
            else:
                s_out = ssm_mod.ssd_apply(p["ssm"], h, lo, cfg.ssm.chunk)
                c_ssm = None
            fused = 0.5 * (a_out * p["attn_scale"].astype(a_out.dtype)
                           + s_out * p["ssm_scale"].astype(s_out.dtype))
            x = x + fused
            if mode != "train":
                cache_out = {"attn": c_attn, "ssm": c_ssm}
        else:
            ctx, c_attn = _attn_branch(cfg, layout, p["attn"], h, mode=mode,
                                       window=window, positions=positions,
                                       cache=cache.get("attn"), pos=pos,
                                       causal=causal, max_len=max_len,
                                       kv_quant=kv_quant)
            x = x + attn.output_proj(p["attn"], ctx, layout)
            if mode != "train":
                cache_out["attn"] = c_attn
    elif cfg.ssm is not None:
        # pure SSM family (mamba2): norm -> SSD -> residual
        lo = ssm_mod.resolve_ssm_layout(d, cfg.ssm, tp)
        h = rmsnorm(p["ln_ssm"], x)
        if mode == "decode":
            s_out, c_ssm = ssm_mod.ssm_decode_step(p["ssm"], cache["ssm"],
                                                   h, lo)
            cache_out["ssm"] = c_ssm
        elif mode == "prefill":
            s_out, s_state = ssm_mod.ssd_apply(p["ssm"], h, lo,
                                               cfg.ssm.chunk,
                                               return_state=True)
            cache_out["ssm"] = _ssm_prefill_cache(p, h, lo, s_state)
        else:
            s_out = ssm_mod.ssd_apply(p["ssm"], h, lo, cfg.ssm.chunk)
        x = x + s_out

    if "cross" in p and (memory is not None or "cross" in cache):
        c_out, c_cache = _cross_branch(cfg, tp, p, x, mode=mode,
                                       memory=memory,
                                       cache=cache.get("cross"))
        x = x + c_out
        if mode != "train":
            cache_out["cross"] = c_cache

    if cfg.moe is not None:
        h = rmsnorm(p["ln2"], x)
        mo, moe_aux = moe_apply(p["moe"], h, cfg.moe)
        x = x + mo
        if mode == "train":
            aux = aux + moe_aux
    elif cfg.d_ff:
        h = rmsnorm(p["ln2"], x)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp)

    return x, (cache_out or None), aux


def _ssm_prefill_cache(p, h, lo, s_state):
    """Conv tail (last d_conv inputs of each conv stream) + final state.
    Only the last d_conv positions are projected (cheap)."""
    K = lo.d_conv
    tail = h[:, -K:]
    _, xs, Bm, Cm, _ = ssm_mod._project(p["ssm"], tail, lo)
    return {"state": s_state,
            "conv_x": xs.astype(CACHE_DTYPE),
            "conv_B": Bm.astype(CACHE_DTYPE),
            "conv_C": Cm.astype(CACHE_DTYPE)}


# ---------------------------------------------------------------------------
# Whole-model decls / apply
# ---------------------------------------------------------------------------

def decoder_decls(cfg: ArchConfig, tp: int) -> Decls:
    decls: Decls = dict(embed_decls(cfg.vocab_size, cfg.d_model,
                                    cfg.tie_embeddings))
    for seg in segments(cfg):
        b = block_decls(cfg, tp)
        decls[seg.name] = _stack_decls(b, seg.n_layers) if seg.scanned else b
    decls["ln_f"] = rmsnorm_decl(cfg.d_model)
    return decls


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # minimal: save only block boundaries


def run_decoder(cfg: ArchConfig, tp: int, params: Dict[str, Any],
                x: jax.Array, *, mode: str,
                positions: Optional[jax.Array] = None,
                caches: Optional[Dict[str, Any]] = None,
                pos: Optional[jax.Array] = None,
                memory=None, causal: bool = True,
                max_len: Optional[int] = None, kv_quant: bool = False,
                remat_policy: str = "minimal"):
    """Run all segments. Returns (x, caches_out, aux)."""
    caches = caches or {}
    caches_out: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)

    for seg in segments(cfg):
        p_seg = params[seg.name]
        c_seg = caches.get(seg.name)
        if not seg.scanned:
            fn = partial(block_apply, cfg, tp, mode=mode, window=seg.window,
                         positions=positions, pos=pos, memory=memory,
                         causal=causal, max_len=max_len, kv_quant=kv_quant)
            if mode == "train":
                def train_fn(p, h, _fn=fn):
                    out, _, aux = _fn(p, h)
                    return out, aux
                x, aux = _remat(train_fn, remat_policy)(p_seg, x)
                aux_total = aux_total + aux
            else:
                x, c_out, _ = fn(p_seg, x, cache=c_seg)
                caches_out[seg.name] = c_out
            continue

        def body(carry, xs, _w=seg.window):
            h, aux_acc = carry
            p_l, c_l = xs
            h, c_out, aux = block_apply(
                cfg, tp, p_l, h, mode=mode, window=_w,
                positions=positions, cache=c_l, pos=pos, memory=memory,
                causal=causal, max_len=max_len, kv_quant=kv_quant)
            return (h, aux_acc + aux), c_out

        if mode == "train":
            body2 = _remat(lambda c, p_l: (body(c, (p_l, None))[0], None),
                           remat_policy)
            (x, aux_total), _ = jax.lax.scan(body2, (x, aux_total), p_seg)
        else:
            (x, aux_total), c_outs = jax.lax.scan(
                body, (x, aux_total), (p_seg, c_seg))
            caches_out[seg.name] = c_outs

    return x, (caches_out or None), aux_total
