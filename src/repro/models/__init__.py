from .model import Model, build_model, cache_partition_axes
from .params import (abstract_params, count_params, init_params,
                     logical_axes, partition_specs, resolve_spec)

__all__ = ["Model", "build_model", "cache_partition_axes", "abstract_params",
           "count_params", "init_params", "logical_axes", "partition_specs",
           "resolve_spec"]
