"""Shared model layers: norms, RoPE, MLPs, embeddings, losses.

Dtype policy: parameters are held in ``param_dtype`` (fp32 master for
training, bf16 for serving); activations run in ``compute_dtype`` (bf16);
softmax/norm statistics accumulate in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_hint
from .params import ParamDecl

VOCAB_ALIGN = 256  # pad vocab to a multiple of (data*model) so the embedding
                   # shards evenly on both mesh axes; padded logits are masked.


def pad_vocab(v: int, align: int = VOCAB_ALIGN) -> int:
    return -(-v // align) * align


@dataclasses.dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16

    def cast(self, x):
        return jax.tree.map(lambda a: a.astype(self.compute_dtype), x)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_decl(d: int) -> ParamDecl:
    return ParamDecl((d,), (None,), init="ones")


def rmsnorm(scale, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, ..., head_dim) with positions (B, S) broadcastable to the
    leading batch/seq dims. We require layout (B, S, *heads, head_dim)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,hd/2)
    # broadcast over any interior head axes
    extra = x.ndim - angles.ndim - 0
    shape = angles.shape[:2] + (1,) * (x.ndim - 3) + angles.shape[-1:]
    angles = angles.reshape(shape)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def mlp_decls(d: int, d_ff: int, kind: str):
    if kind == "swiglu":
        return {
            "wi_gate": ParamDecl((d, d_ff), ("embed", "mlp")),
            "wi_up": ParamDecl((d, d_ff), ("embed", "mlp")),
            "wo": ParamDecl((d_ff, d), ("mlp", "embed")),
        }
    return {  # gelu
        "wi": ParamDecl((d, d_ff), ("embed", "mlp")),
        "wo": ParamDecl((d_ff, d), ("mlp", "embed")),
    }


def mlp_apply(p, x, kind: str):
    if kind == "swiglu":
        g = x @ p["wi_gate"].astype(x.dtype)
        u = x @ p["wi_up"].astype(x.dtype)
        return (jax.nn.silu(g) * u) @ p["wo"].astype(x.dtype)
    return jax.nn.gelu(x @ p["wi"].astype(x.dtype),
                       approximate=True) @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding (padded vocab)
# ---------------------------------------------------------------------------

def embed_decls(vocab: int, d: int, tie: bool):
    vp = pad_vocab(vocab)
    decls = {"embedding": ParamDecl((vp, d), ("vocab", "embed"),
                                    init="embed")}
    if not tie:
        decls["unembed"] = ParamDecl((d, vp), ("embed", "vocab"))
    return decls


def embed_lookup(p, tokens: jax.Array, compute_dtype) -> jax.Array:
    x = jnp.take(p["embedding"].astype(compute_dtype), tokens, axis=0)
    return shard_hint(x, "batch", "seq", "embed_act")


def logits_fn(p, x: jax.Array, vocab: int, tie: bool) -> jax.Array:
    """(B,S,d) -> (B,S,vocab_padded) fp32 logits with padded slots masked."""
    if tie:
        w = p["embedding"].astype(x.dtype).T
    else:
        w = p["unembed"].astype(x.dtype)
    logits = (x @ w).astype(jnp.float32)
    logits = shard_hint(logits, "batch", "seq", "vocab_act")
    vp = logits.shape[-1]
    if vp != vocab:
        mask = jnp.arange(vp) < vocab
        logits = jnp.where(mask, logits, -1e9)
    return logits


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy in fp32. labels: (B,S) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
