"""Mamba2 SSD (state-space duality) mixer.

Train/prefill use the chunked block-matmul dual form (MXU-friendly: the
inner loops are (L x L) and (N x P) matmuls per chunk); decode uses the O(1)
recurrent form with a conv ring buffer + (H, N, P) state.

TP layout: SSD heads are padded to a multiple of the model axis
(24 -> 32 at tp=16) with dead heads zero-init and hard-masked, mirroring
attention's HeadLayout policy. B/C projections are per-group (G=1 for the
assigned archs) and replicated over 'model'.

Numerics: all decay terms are exp of non-positive cumulative sums (A < 0),
so nothing overflows; accumulation is fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import SSMConfig
from ..distributed.sharding import shard_hint
from .layers import rmsnorm_decl
from .params import ParamDecl


@dataclasses.dataclass(frozen=True)
class SSMLayout:
    n_heads: int      # real heads = d_inner // head_dim
    h_eff: int        # padded to multiple of tp
    head_dim: int     # P
    d_state: int      # N
    n_groups: int     # G (1 for assigned archs)
    d_conv: int

    def alive_mask(self) -> np.ndarray:
        m = np.zeros(self.h_eff, np.float32)
        m[: self.n_heads] = 1
        return m


def resolve_ssm_layout(d_model: int, ssm: SSMConfig, tp: int) -> SSMLayout:
    d_inner = ssm.expand * d_model
    h = d_inner // ssm.head_dim
    h_eff = -(-h // tp) * tp
    return SSMLayout(h, h_eff, ssm.head_dim, ssm.d_state, 1, ssm.d_conv)


def ssm_decls(d: int, lo: SSMLayout) -> Dict[str, Any]:
    H, P, N, G, K = lo.h_eff, lo.head_dim, lo.d_state, lo.n_groups, lo.d_conv
    return {
        "wz": ParamDecl((d, H, P), ("embed", "ssm_heads", "head_dim")),
        "wx": ParamDecl((d, H, P), ("embed", "ssm_heads", "head_dim")),
        "wB": ParamDecl((d, G, N), ("embed", None, "ssm_state")),
        "wC": ParamDecl((d, G, N), ("embed", None, "ssm_state")),
        "wdt": ParamDecl((d, H), ("embed", "ssm_heads")),
        "dt_bias": ParamDecl((H,), ("ssm_heads",), init="zeros"),
        "A_log": ParamDecl((H,), ("ssm_heads",), init="ones"),
        "D": ParamDecl((H,), ("ssm_heads",), init="ones"),
        "conv_x": ParamDecl((K, H, P), ("conv", "ssm_heads", "head_dim")),
        "conv_B": ParamDecl((K, G, N), ("conv", None, "ssm_state")),
        "conv_C": ParamDecl((K, G, N), ("conv", None, "ssm_state")),
        "norm": ParamDecl((H, P), ("ssm_heads", "head_dim"), init="ones"),
        "wo": ParamDecl((H, P, d), ("ssm_heads", "head_dim", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along axis 1. x (B,S,...), w (K, ...)."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(K - 1):
        shift = K - 1 - i
        xi = jnp.pad(x, [(0, 0), (shift, 0)] + [(0, 0)] * (x.ndim - 2)
                     )[:, : x.shape[1]]
        out = out + xi * w[i]
    return out


def _conv_step(state: jax.Array, x_new: jax.Array, w: jax.Array):
    """Decode-time conv: state (B,K,...) ring holding the last K inputs."""
    state = jnp.concatenate([state[:, 1:], x_new[:, None]], axis=1)
    out = jnp.einsum("bk...,k...->b...", state, w)
    return state, out


def _project(p, u: jax.Array, lo: SSMLayout):
    """u (B,S,d) -> z,x (B,S,H,P), B,C (B,S,G,N), dt (B,S,H) (pre-conv)."""
    dt = u @ p["wdt"].astype(u.dtype)
    z = jnp.einsum("bsd,dhp->bshp", u, p["wz"].astype(u.dtype))
    x = jnp.einsum("bsd,dhp->bshp", u, p["wx"].astype(u.dtype))
    Bm = jnp.einsum("bsd,dgn->bsgn", u, p["wB"].astype(u.dtype))
    Cm = jnp.einsum("bsd,dgn->bsgn", u, p["wC"].astype(u.dtype))
    return z, x, Bm, Cm, dt


def _finish(p, y: jax.Array, x: jax.Array, z: jax.Array,
            lo: SSMLayout) -> jax.Array:
    """y,x,z (B,S,H,P) -> (B,S,d): +Dx, gated RMSNorm, dead-head mask, out."""
    y = y + p["D"].astype(y.dtype)[:, None] * x
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6)).astype(y.dtype) * \
        p["norm"].astype(y.dtype)
    mask = jnp.asarray(lo.alive_mask(), y.dtype)
    y = y * mask[:, None]
    return jnp.einsum("bshp,hpd->bsd", y, p["wo"].astype(y.dtype))


def _head_groups(lo: SSMLayout) -> jax.Array:
    """Real head h -> group h*G//n_heads; dead heads -> group 0."""
    g = np.zeros(lo.h_eff, np.int32)
    for h in range(lo.n_heads):
        g[h] = h * lo.n_groups // lo.n_heads
    return jnp.asarray(g)


def ssd_apply(p, u: jax.Array, lo: SSMLayout, chunk: int,
              initial_state: Optional[jax.Array] = None,
              return_state: bool = False):
    """Chunked SSD over a full sequence. u (B,S,d) -> (B,S,d).

    S is padded internally to a multiple of ``chunk``; padded positions get
    dt=0 (identity decay, zero input) so the returned final state is exactly
    the state after the S real tokens."""
    B, S0, d = u.shape
    L = chunk
    S = -(-S0 // L) * L
    if S != S0:
        u = jnp.pad(u, ((0, 0), (0, S - S0), (0, 0)))
    nc = S // L
    z, x, Bm, Cm, dt = _project(p, u, lo)
    x = _causal_conv(x, p["conv_x"].astype(x.dtype))
    Bm = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype))
    Cm = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype))
    x, Bm, Cm = (jax.nn.silu(t.astype(jnp.float32)).astype(t.dtype)
                 for t in (x, Bm, Cm))

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))      # (B,S,H)
    if S != S0:
        valid = (jnp.arange(S) < S0)[None, :, None]
        dt = dt * valid
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,) < 0
    dA = dt * A                                                  # <= 0

    gidx = _head_groups(lo)
    # chunked views
    c = lambda t: t.reshape((B, nc, L) + t.shape[2:])
    xc, Bc, Cc, dtc, dAc = c(x), c(Bm), c(Cm), c(dt), c(dA)
    cum = jnp.cumsum(dAc, axis=2)                                # (B,nc,L,H)

    # ---- intra-chunk (dual / quadratic-within-chunk form) ----
    # The (L x L) per-head decay matrix is the big intermediate; pin its
    # head dim to the model axis or GSPMD replicates it (measured: 34GB/dev
    # on mamba2 train_4k before this hint; see EXPERIMENTS.md §Perf).
    CB = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc)                # (B,nc,G,L,L)
    CBh = CB[:, :, gidx]                                         # (B,nc,H,L,L)
    CBh = shard_hint(CBh, "batch", None, "ssm_heads", None, None)
    cumh = cum.transpose(0, 1, 3, 2)                             # (B,nc,H,L)
    seg = cumh[..., :, None] - cumh[..., None, :]                # cum_i-cum_j
    tri = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: seg is positive above the diagonal and exp overflows
    # there; exp(inf)*0 => NaN in the backward (d(exp)=exp). exp(-inf)=0
    # has a clean zero gradient.
    seg = jnp.where(tri, seg, -jnp.inf)
    M = jnp.exp(seg) * \
        CBh.astype(jnp.float32) * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    M = shard_hint(M, "batch", None, "ssm_heads", None, None)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", M.astype(u.dtype), xc)

    # ---- chunk states ----
    w = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                   # (B,nc,L,H)
    Bh = Bc[:, :, :, gidx]                                       # (B,nc,L,H,N)
    Bh = shard_hint(Bh, "batch", None, None, "ssm_heads", "ssm_state")
    states = jnp.einsum("bclh,bclhn,bclhp->bchnp",
                        w.astype(u.dtype), Bh, xc)               # (B,nc,H,N,P)
    states = shard_hint(states, "batch", None, "ssm_heads", "ssm_state",
                        "head_dim")

    # ---- inter-chunk recurrence over nc ----
    decay = jnp.exp(cum[:, :, -1, :])                            # (B,nc,H)

    def step(s_prev, inp):
        dcy, st = inp                                            # (B,H),(B,H,N,P)
        s = s_prev * dcy[..., None, None].astype(s_prev.dtype) + \
            st.astype(s_prev.dtype)
        return s, s_prev

    s0 = (initial_state if initial_state is not None
          else jnp.zeros((B, lo.h_eff, lo.d_state, lo.head_dim), jnp.float32))
    s_final, s_prevs = jax.lax.scan(
        step, s0, (decay.transpose(1, 0, 2),
                   states.transpose(1, 0, 2, 3, 4).astype(jnp.float32)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)                   # (B,nc,H,N,P)

    Ch = Cc[:, :, :, gidx]                                       # (B,nc,L,H,N)
    Ch = shard_hint(Ch, "batch", None, None, "ssm_heads", "ssm_state")
    y_inter = jnp.einsum("bclhn,bchnp,bclh->bclhp", Ch,
                         s_prevs.astype(u.dtype),
                         jnp.exp(cum).astype(u.dtype))
    y = (y_intra + y_inter).reshape(B, S, lo.h_eff, lo.head_dim)
    out = _finish(p, y, x, z, lo)
    if S != S0:
        out = out[:, :S0]
    if return_state:
        return out, s_final
    return out


def ssd_reference(p, u: jax.Array, lo: SSMLayout):
    """Sequential (per-token recurrent) oracle for tests."""
    B, S, d = u.shape
    z, x, Bm, Cm, dt = _project(p, u, lo)
    x = _causal_conv(x, p["conv_x"].astype(x.dtype))
    Bm = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype))
    Cm = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype))
    x, Bm, Cm = (jax.nn.silu(t.astype(jnp.float32)).astype(t.dtype)
                 for t in (x, Bm, Cm))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    gidx = _head_groups(lo)

    def step(s, inp):
        xt, bt, ct, dtt = inp                      # (B,H,P),(B,G,N)x2,(B,H)
        da = jnp.exp(dtt * A)                      # (B,H)
        bh, ch = bt[:, gidx], ct[:, gidx]          # (B,H,N)
        s = s * da[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dtt, bh.astype(jnp.float32),
            xt.astype(jnp.float32))
        y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), s)
        return s, y

    s0 = jnp.zeros((B, lo.h_eff, lo.d_state, lo.head_dim), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (x.transpose(1, 0, 2, 3),
                                    Bm.transpose(1, 0, 2, 3),
                                    Cm.transpose(1, 0, 2, 3),
                                    dt.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3).astype(u.dtype)   # (B,S,H,P)
    return _finish(p, y, x, z, lo)


# ---------------------------------------------------------------------------
# Decode (recurrent) path
# ---------------------------------------------------------------------------

def ssm_cache_shapes(batch: int, lo: SSMLayout):
    H, P, N, G, K = lo.h_eff, lo.head_dim, lo.d_state, lo.n_groups, lo.d_conv
    return {
        "state": ((batch, H, N, P),
                  ("batch", "ssm_heads", "ssm_state", "head_dim")),
        "conv_x": ((batch, K, H, P),
                   ("batch", "conv", "ssm_heads", "head_dim")),
        "conv_B": ((batch, K, G, N), ("batch", "conv", None, "ssm_state")),
        "conv_C": ((batch, K, G, N), ("batch", "conv", None, "ssm_state")),
    }


def ssm_decode_step(p, cache: Dict[str, jax.Array], u: jax.Array,
                    lo: SSMLayout):
    """u (B,1,d) one token -> (out (B,1,d), new cache)."""
    z, x, Bm, Cm, dt = _project(p, u, lo)
    sq = lambda t: t[:, 0]
    cx, xo = _conv_step(cache["conv_x"], sq(x), p["conv_x"].astype(x.dtype))
    cb, bo = _conv_step(cache["conv_B"], sq(Bm), p["conv_B"].astype(x.dtype))
    cc, co = _conv_step(cache["conv_C"], sq(Cm), p["conv_C"].astype(x.dtype))
    xo, bo, co = (jax.nn.silu(t.astype(jnp.float32)).astype(t.dtype)
                  for t in (xo, bo, co))

    dtt = jax.nn.softplus(dt.astype(jnp.float32)[:, 0] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    gidx = _head_groups(lo)
    da = jnp.exp(dtt * A)                                        # (B,H)
    bh, ch = bo[:, gidx], co[:, gidx]                            # (B,H,N)
    s = cache["state"] * da[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtt, bh.astype(jnp.float32),
        xo.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", ch.astype(jnp.float32), s)
    out = _finish(p, y[:, None].astype(u.dtype), xo[:, None], z, lo)
    return out, {"state": s, "conv_x": cx, "conv_B": cb, "conv_C": cc}
