"""build_model(cfg, tp): the single entry point used by the trainer, the
serving engine, smoke tests and the dry-run.

A Model bundles:
  decls          -- parameter declarations (shapes + logical axes)
  loss           -- (params, batch) -> scalar   [train]
  prefill        -- (params, batch) -> (last_logits, cache)
  decode_step    -- (params, cache, tokens, pos, [memory_cacheable]) ->
                    (logits, cache)
  cache_decls    -- (batch, max_len) -> pytree of (shape, axes, dtype)
  input_specs    -- ShapeConfig -> kwargs pytree of ShapeDtypeStruct
                    (the dry-run stand-ins; no allocation)

Family routing: dense / moe / ssm / hybrid share the decoder-only path;
audio = enc-dec with a stubbed frame-embedding frontend; vlm = decoder with
interleaved gated cross-attention groups.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from . import attention as attn
from . import ssm as ssm_mod
from .layers import (embed_lookup, logits_fn, pad_vocab, rmsnorm,
                     rmsnorm_decl, softmax_xent)
from .params import Decls, ParamDecl, count_params
from .transformer import (_stack_decls, block_apply, block_decls,
                          decoder_decls, run_decoder, segments)

from .transformer import CACHE_DTYPE  # noqa: F401 (single source)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    tp: int
    decls: Decls
    loss: Callable
    prefill: Callable
    decode_step: Callable
    cache_decls: Callable
    input_specs: Callable

    @property
    def n_params(self) -> int:
        return count_params(self.decls)


# ---------------------------------------------------------------------------
# Cache declaration mirrors (must match block_apply cache structure exactly)
# ---------------------------------------------------------------------------

def _attn_cache(cfg, tp, batch, max_len, window, kv_quant=False):
    layout = attn.resolve_head_layout(cfg.n_heads, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, tp)
    shape, axes = attn.cache_decl_shapes(batch, max_len, layout, window)
    if kv_quant:
        sshape = shape[:-1] + (1,)
        entry = {"q": (shape, axes, jnp.int8),
                 "s": (sshape, axes, jnp.float32)}
        return {"k": entry, "v": dict(entry)}
    return {"k": (shape, axes, CACHE_DTYPE), "v": (shape, axes, CACHE_DTYPE)}


def _ssm_cache(cfg, tp, batch):
    lo = ssm_mod.resolve_ssm_layout(cfg.d_model, cfg.ssm, tp)
    shapes = ssm_mod.ssm_cache_shapes(batch, lo)
    out = {}
    for k, (shape, axes) in shapes.items():
        dt = jnp.float32 if k == "state" else CACHE_DTYPE
        out[k] = (shape, axes, dt)
    return out


def _block_cache(cfg, tp, batch, max_len, window, *, cross_len=None,
                 kv_quant=False):
    entry: Dict[str, Any] = {}
    if cfg.n_heads:
        entry["attn"] = _attn_cache(cfg, tp, batch, max_len, window,
                                    kv_quant)
    if cfg.ssm is not None:
        entry["ssm"] = _ssm_cache(cfg, tp, batch)
    if cross_len is not None:
        layout = attn.resolve_head_layout(cfg.n_heads, cfg.n_kv_heads,
                                          cfg.resolved_head_dim, tp)
        shape = (batch, cross_len, layout.kv_eff, layout.head_dim)
        axes = ("batch", "frontend_seq", "kv_heads_eff", "head_dim")
        entry["cross"] = {"k": (shape, axes, CACHE_DTYPE),
                          "v": (shape, axes, CACHE_DTYPE)}
    return entry


def _stack_cache(entry, n):
    def f(leaf):
        shape, axes, dt = leaf
        return ((n,) + shape, ("layers",) + axes, dt)
    return jax.tree.map(f, entry, is_leaf=lambda x: isinstance(x, tuple)
                        and len(x) == 3 and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# Decoder-only family
# ---------------------------------------------------------------------------

def _positions(B, S):
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def _build_decoder_only(cfg: ArchConfig, tp: int, remat: str,
                        kv_quant: bool = False) -> Model:
    decls = decoder_decls(cfg, tp)

    def loss(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        x, _, aux = run_decoder(cfg, tp, params, x, mode="train",
                                positions=_positions(B, S),
                                remat_policy=remat)
        x = rmsnorm(params["ln_f"], x)
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return softmax_xent(logits, labels) + aux

    def prefill(params, batch, max_len=None):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        x, caches, _ = run_decoder(cfg, tp, params, x, mode="prefill",
                                   positions=_positions(B, S),
                                   max_len=max_len, kv_quant=kv_quant,
                                   remat_policy=remat)
        x = rmsnorm(params["ln_f"], x[:, -1:])
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return logits, caches

    def decode_step(params, cache, tokens, pos):
        B = tokens.shape[0]
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, caches, _ = run_decoder(cfg, tp, params, x, mode="decode",
                                   positions=positions, caches=cache,
                                   pos=pos, kv_quant=kv_quant)
        x = rmsnorm(params["ln_f"], x)
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return logits, caches

    def cache_decls(batch, max_len):
        out = {}
        for seg in segments(cfg):
            entry = _block_cache(cfg, tp, batch, max_len, seg.window,
                                 kv_quant=kv_quant)
            out[seg.name] = _stack_cache(entry, seg.n_layers) \
                if seg.scanned else entry
        return out

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            return {"batch": {"tokens": tok, "labels": tok}}
        if shape.kind == "prefill":
            return {"batch": {"tokens": tok}}
        cache = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l[0], l[2]),
            cache_decls(B, S), is_leaf=_is_cache_leaf)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    return Model(cfg, tp, decls, loss, prefill, decode_step, cache_decls,
                 input_specs)


def _is_cache_leaf(x):
    return (isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# Enc-dec family (seamless: audio frontend stub -> encoder -> decoder)
# ---------------------------------------------------------------------------

def _build_encdec(cfg: ArchConfig, tp: int, remat: str) -> Model:
    d = cfg.d_model
    enc_block = block_decls(cfg, tp)
    dec_block = block_decls(cfg, tp, cross=True)
    decls: Decls = dict(decoder_decls(cfg, tp))  # embed + layers + ln_f
    decls["layers"] = _stack_decls(dec_block, cfg.n_layers)
    decls["frontend_proj"] = ParamDecl((d, d), ("embed", None))
    decls["encoder"] = _stack_decls(enc_block, cfg.n_encoder_layers)
    decls["ln_enc"] = rmsnorm_decl(d)

    def encode(params, frames):
        B, S, _ = frames.shape
        x = frames.astype(CACHE_DTYPE) @ params["frontend_proj"].astype(
            CACHE_DTYPE)
        positions = _positions(B, S)

        def body(carry, p_l):
            h, = carry
            h, _, _ = block_apply(cfg, tp, p_l, h, mode="train", window=None,
                                  positions=positions, causal=False)
            return (h,), None

        (x,), _ = jax.lax.scan(jax.checkpoint(body), (x,), params["encoder"])
        return rmsnorm(params["ln_enc"], x)

    def loss(params, batch):
        enc_out = encode(params, batch["frames"])
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        x, _, aux = run_decoder(cfg, tp, params, x, mode="train",
                                positions=_positions(B, S), memory=enc_out,
                                remat_policy=remat)
        x = rmsnorm(params["ln_f"], x)
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return softmax_xent(logits, labels) + aux

    def prefill(params, batch, max_len=None):
        enc_out = encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        x, caches, _ = run_decoder(cfg, tp, params, x, mode="prefill",
                                   positions=_positions(B, S),
                                   memory=enc_out, max_len=max_len,
                                   remat_policy=remat)
        x = rmsnorm(params["ln_f"], x[:, -1:])
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return logits, caches

    def decode_step(params, cache, tokens, pos):
        B = tokens.shape[0]
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, caches, _ = run_decoder(cfg, tp, params, x, mode="decode",
                                   positions=positions, caches=cache,
                                   pos=pos)
        x = rmsnorm(params["ln_f"], x)
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return logits, caches

    def cache_decls(batch, max_len, enc_len=None):
        entry = _block_cache(cfg, tp, batch, max_len, None,
                             cross_len=enc_len or max_len)
        return {"layers": _stack_cache(entry, cfg.n_layers)}

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        frames = jax.ShapeDtypeStruct((B, S, d), jnp.bfloat16)
        if shape.kind == "train":
            return {"batch": {"frames": frames, "tokens": tok,
                              "labels": tok}}
        if shape.kind == "prefill":
            return {"batch": {"frames": frames, "tokens": tok}}
        cache = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l[0], l[2]),
            cache_decls(B, S), is_leaf=_is_cache_leaf)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    return Model(cfg, tp, decls, loss, prefill, decode_step, cache_decls,
                 input_specs)


# ---------------------------------------------------------------------------
# VLM family (groups of self layers + one gated cross-attn layer)
# ---------------------------------------------------------------------------

def _build_vlm(cfg: ArchConfig, tp: int, remat: str) -> Model:
    every = cfg.cross_attn_every
    n_groups = cfg.n_layers // every
    n_self = every - 1
    self_block = block_decls(cfg, tp)
    cross_block = block_decls(cfg, tp, cross=True)
    # cross layers replace self-attention (Llama-3.2 style image layers)
    cross_block = {k: v for k, v in cross_block.items()
                   if k not in ("ln1", "attn")}
    decls: Decls = dict(decoder_decls(cfg, tp))
    del decls["layers"]
    decls["groups_self"] = _stack_decls(_stack_decls(self_block, n_self),
                                        n_groups)
    decls["groups_cross"] = _stack_decls(cross_block, n_groups)

    def _run(params, x, *, mode, positions, caches=None, pos=None,
             memory=None, max_len=None):
        caches = caches or {}
        aux0 = jnp.zeros((), jnp.float32)

        def group_body(carry, xs):
            h, aux_acc = carry
            p_self, p_cross, c_self, c_cross = xs

            def inner(c2, xs2):
                hh, aa = c2
                p_l, c_l = xs2
                hh, c_out, aux = block_apply(cfg, tp, p_l, hh, mode=mode,
                                             window=None,
                                             positions=positions,
                                             cache=c_l, pos=pos,
                                             max_len=max_len)
                return (hh, aa + aux), c_out

            (h, aux_acc), c_self_out = jax.lax.scan(
                inner, (h, aux_acc), (p_self, c_self))
            h, c_cross_out, aux = block_apply(cfg, tp, p_cross, h, mode=mode,
                                              window=None,
                                              positions=positions,
                                              cache=c_cross, pos=pos,
                                              memory=memory, max_len=max_len)
            return (h, aux_acc + aux), (c_self_out, c_cross_out)

        xs = (params["groups_self"], params["groups_cross"],
              caches.get("groups_self"), caches.get("groups_cross"))
        if mode == "train":
            body = jax.checkpoint(
                lambda c, x_: (group_body(c, x_)[0], None))
            (x, aux), _ = jax.lax.scan(body, (x, aux0), xs)
            return x, None, aux
        (x, aux), (c_self, c_cross) = jax.lax.scan(group_body, (x, aux0), xs)
        return x, {"groups_self": c_self, "groups_cross": c_cross}, aux

    def loss(params, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        x, _, aux = _run(params, x, mode="train", positions=_positions(B, S),
                         memory=batch["image_embeds"])
        x = rmsnorm(params["ln_f"], x)
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return softmax_xent(logits, labels) + aux

    def prefill(params, batch, max_len=None):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        x, caches, _ = _run(params, x, mode="prefill",
                            positions=_positions(B, S),
                            memory=batch["image_embeds"], max_len=max_len)
        x = rmsnorm(params["ln_f"], x[:, -1:])
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return logits, caches

    def decode_step(params, cache, tokens, pos):
        B = tokens.shape[0]
        x = embed_lookup(params, tokens, CACHE_DTYPE)
        positions = jnp.full((B, 1), pos, jnp.int32)
        x, caches, _ = _run(params, x, mode="decode", positions=positions,
                            caches=cache, pos=pos)
        x = rmsnorm(params["ln_f"], x)
        logits = logits_fn(params, x, cfg.vocab_size, cfg.tie_embeddings)
        return logits, caches

    def cache_decls(batch, max_len):
        self_entry = _block_cache(cfg, tp, batch, max_len, None)
        cross_entry = _block_cache(
            dataclasses.replace(cfg, ssm=None), tp, batch, max_len, None,
            cross_len=cfg.n_frontend_tokens)
        cross_entry = {"cross": cross_entry["cross"]}
        return {
            "groups_self": _stack_cache(_stack_cache(self_entry, n_self),
                                        n_groups),
            "groups_cross": _stack_cache(cross_entry, n_groups),
        }

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        img = jax.ShapeDtypeStruct((B, cfg.n_frontend_tokens, cfg.d_model),
                                   jnp.bfloat16)
        if shape.kind == "train":
            return {"batch": {"tokens": tok, "labels": tok,
                              "image_embeds": img}}
        if shape.kind == "prefill":
            return {"batch": {"tokens": tok, "image_embeds": img}}
        cache = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l[0], l[2]),
            cache_decls(B, S), is_leaf=_is_cache_leaf)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    return Model(cfg, tp, decls, loss, prefill, decode_step, cache_decls,
                 input_specs)


# ---------------------------------------------------------------------------

def build_model(cfg: ArchConfig, tp: int = 1, remat: str = "minimal",
                kv_quant: bool = False) -> Model:
    """kv_quant: int8 KV cache (decoder-only families; the §Perf serving
    optimization -- decode is cache-bandwidth bound)."""
    if cfg.family == "audio":
        return _build_encdec(cfg, tp, remat)
    if cfg.family == "vlm":
        return _build_vlm(cfg, tp, remat)
    return _build_decoder_only(cfg, tp, remat, kv_quant=kv_quant)


def cache_partition_axes(model: Model, batch: int, max_len: int):
    """Logical axes tree for the cache (dry-run in_shardings)."""
    return jax.tree.map(lambda l: l[1], model.cache_decls(batch, max_len),
                        is_leaf=_is_cache_leaf)
