"""The Database Designer (paper §6.3): automatic physical design.

Two sequential phases, as published:
  1. Query optimization -- enumerate candidate projections from workload
     heuristics (predicate columns, group-by columns, aggregate columns,
     join keys), invoke the real optimizer/cost model per query with each
     candidate available, and keep the projections the optimizer actually
     picks.
  2. Storage optimization -- choose encodings *empirically*: encode a data
     sample with every legal scheme and keep the smallest (this is
     encodings.encode(AUTO); the DBD records the choice per column).

Design policies trade query speed against storage/load cost by capping how
many non-super projections are proposed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.database import VerticaDB
from ..core.encodings import Encoding, encode
from ..core.projection import ProjectionDef, SegmentationSpec
from ..core.types import SQLType
from ..engine.logical import LogicalQuery, as_ir
from . import cost as cost_mod

POLICIES = {"load-optimized": 0, "balanced": 2, "query-optimized": 4}


@dataclasses.dataclass
class DesignReport:
    proposed: List[ProjectionDef]
    encoding_choices: Dict[str, Dict[str, str]]
    per_query: List[Tuple[str, float, float]]   # (desc, before_s, after_s)


def _candidates_for_query(db: VerticaDB, q: LogicalQuery
                          ) -> List[ProjectionDef]:
    """Heuristic candidate enumeration (paper phase 1)."""
    table = db.catalog.tables[q.table].schema
    need = sorted(q.needed_columns() & set(table.column_names()))
    cands = []
    sort_firsts = []
    if q.predicate is not None:
        sort_firsts += sorted(q.predicate.bounds())
    sort_firsts += list(q.group_by)
    sort_firsts += [j.fact_key for j in q.joins]
    seen = set()
    for first in sort_firsts:
        if first in seen or first not in need:
            continue
        seen.add(first)
        rest = [c for c in need if c != first]
        seg_cols = (q.joins[0].fact_key,) if q.joins else \
            ((first,) if not q.group_by else q.group_by)
        cands.append(ProjectionDef(
            name=f"{q.table}_dbd_{first}",
            anchor=q.table, columns=tuple([first] + rest),
            sort_order=(first,) + tuple(rest[:1]),
            segmentation=SegmentationSpec("hash", tuple(
                c for c in seg_cols if c in need) or (first,))))
    return cands


def design(db: VerticaDB, workload: Sequence, *,
           policy: str = "balanced",
           deploy: bool = False) -> DesignReport:
    from .planner import plan_query

    workload = [as_ir(q) for q in workload]
    budget = POLICIES[policy]
    # baseline costs with the current design
    before = []
    for q in workload:
        plan = plan_query(db, q)
        before.append(plan.estimated.total if plan.estimated else 0.0)

    # phase 1: propose, deploy tentatively, re-plan, keep what gets used
    proposals: Dict[str, ProjectionDef] = {}
    for q in workload:
        for cand in _candidates_for_query(db, q):
            if cand.name not in proposals \
                    and cand.name not in db.catalog.projections:
                proposals[cand.name] = cand
    chosen: List[ProjectionDef] = []
    per_query = []
    if proposals and budget > 0:
        for cand in list(proposals.values()):
            db.create_projection(cand, populate=True)
        for q, b in zip(workload, before):
            plan = plan_query(db, q)
            a = plan.estimated.total if plan.estimated else 0.0
            per_query.append((repr(q.table) + "/" +
                              (",".join(q.group_by) or "scan"), b, a))
            picked = db.catalog.projections.get(plan.projection)
            if picked is not None and picked.name in proposals and \
                    picked not in chosen:
                chosen.append(picked)
        chosen = chosen[:budget]
        # tear down unused proposals (and everything if not deploying)
        for cand in list(proposals.values()):
            keep = deploy and cand in chosen
            if not keep:
                _drop_projection(db, cand.name)
                _drop_projection(db, cand.name + "_b1")
    else:
        for q, b in zip(workload, before):
            per_query.append((repr(q.table), b, b))

    # phase 2: empirical encoding choice on a sample (AUTO == the
    # experiment; we record what it picked)
    enc_report: Dict[str, Dict[str, str]] = {}
    for proj in ([p for p in chosen] if deploy else
                 list(db.catalog.projections.values())):
        choice = {}
        rows = db.read_projection(proj.name) if deploy else \
            db.read_table(proj.anchor)
        for c in proj.columns:
            if c not in rows or len(rows[c]) == 0:
                continue
            sample = rows[c][:100_000]
            enc = encode(np.asarray(sample), SQLType.INT)
            choice[c] = enc.encoding.value
        enc_report[proj.name] = choice
    return DesignReport(chosen, enc_report, per_query)


def _drop_projection(db: VerticaDB, name: str):
    if name not in db.catalog.projections:
        return
    del db.catalog.projections[name]
    for node in db.nodes:
        node.stores.pop(name, None)
