"""The Database Designer (paper §6.3): automatic physical design.

Two sequential phases, as published:
  1. Query optimization -- enumerate candidate projections from workload
     heuristics (predicate columns, group-by columns, aggregate columns,
     join keys), invoke the real optimizer/cost model per query with each
     candidate available, and keep the projections the optimizer actually
     picks.
  2. Storage optimization -- choose encodings *empirically*: encode a data
     sample with every legal scheme and keep the smallest (this is
     encodings.encode(AUTO); the DBD records the choice per column).

Design policies trade query speed against storage/load cost by capping how
many non-super projections are proposed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.database import VerticaDB
from ..core.encodings import Encoding, encode
from ..core.projection import ProjectionDef, SegmentationSpec
from ..core.types import SQLType
from ..engine.logical import LogicalQuery, as_ir
from . import cost as cost_mod

POLICIES = {"load-optimized": 0, "balanced": 2, "query-optimized": 4}


@dataclasses.dataclass
class DesignReport:
    proposed: List[ProjectionDef]
    encoding_choices: Dict[str, Dict[str, str]]
    per_query: List[Tuple[str, float, float]]   # (desc, before_s, after_s)
    sort_choices: Dict[str, Tuple[str, ...]] = \
        dataclasses.field(default_factory=dict)


SORT_SAMPLE_ROWS = 20_000


def _sort_key_score(sample: Dict[str, np.ndarray],
                    order: Tuple[str, ...], need: Sequence[str],
                    types: Dict[str, SQLType],
                    groupby_sets: Sequence[frozenset]
                    ) -> Tuple[int, float]:
    """Score one candidate sort key (paper §6.3).  Lower is better.

    Primary term: how many workload group-by sets the key covers as a
    sort-order prefix -- those queries aggregate sorted runs in one pass
    instead of rebuilding a hash table.  Secondary term: actual encoded
    bytes of a data sample laid out in that order (the phase-2 storage
    experiment reused as a tie-breaker; better-clustered sort keys
    RLE/delta-compress smaller).
    """
    if not sample or any(c not in sample for c in order):
        return (0, float("inf"))
    idx = np.lexsort(tuple(np.asarray(sample[c])
                           for c in reversed(order)))
    nbytes = 0.0
    for c in need:
        if c not in sample:
            continue
        enc = encode(np.asarray(sample[c])[idx],
                     types.get(c, SQLType.INT))
        nbytes += enc.storage_bytes
    covered = sum(1 for g in groupby_sets if g <= set(order[:len(g)]))
    return (-covered, nbytes)


def _candidates_for_query(db: VerticaDB, q: LogicalQuery,
                          groupby_sets: Sequence[frozenset] = (),
                          sample: Optional[Dict[str, np.ndarray]] = None
                          ) -> List[ProjectionDef]:
    """Heuristic candidate enumeration (paper phase 1)."""
    table = db.catalog.tables[q.table].schema
    need = sorted(q.needed_columns() & set(table.column_names()))
    types = {c.name: c.sql_type for c in table.columns}
    gb_cols = set().union(*groupby_sets) if groupby_sets else set()
    cands = []
    sort_firsts = []
    if q.predicate is not None:
        sort_firsts += sorted(q.predicate.bounds())
    sort_firsts += list(q.group_by)
    sort_firsts += [j.fact_key for j in q.joins]
    seen = set()
    for first in sort_firsts:
        if first in seen or first not in need:
            continue
        seen.add(first)
        rest = [c for c in need if c != first]
        # candidate 2-column sort keys: the second column comes from the
        # workload's group-by sets (falling back to the first remaining
        # column); each is scored against the whole workload
        seconds = [c for c in rest if c in gb_cols] or rest[:1]
        orders = [(first, s) for s in seconds] or [(first,)]
        if sample is not None and len(orders) > 1:
            order = min(orders, key=lambda o: _sort_key_score(
                sample, o, need, types, groupby_sets))
        else:
            order = orders[0]
        seg_cols = (q.joins[0].fact_key,) if q.joins else \
            ((first,) if not q.group_by else q.group_by)
        cands.append(ProjectionDef(
            name=f"{q.table}_dbd_{first}",
            anchor=q.table, columns=tuple([first] + rest),
            sort_order=order,
            segmentation=SegmentationSpec("hash", tuple(
                c for c in seg_cols if c in need) or (first,))))
    return cands


def design(db: VerticaDB, workload: Sequence, *,
           policy: str = "balanced",
           deploy: bool = False) -> DesignReport:
    from .planner import plan_query

    workload = [as_ir(q) for q in workload]
    budget = POLICIES[policy]
    # baseline costs with the current design
    before = []
    for q in workload:
        plan = plan_query(db, q)
        before.append(plan.estimated.total if plan.estimated else 0.0)

    # workload-wide group-by sets + per-table samples drive 2-column
    # sort-key scoring (paper §6.3)
    groupby_sets = [frozenset(q.group_by) for q in workload if q.group_by]
    samples: Dict[str, Dict[str, np.ndarray]] = {}
    for q in workload:
        if q.table not in samples:
            rows = db.read_table(q.table)
            samples[q.table] = {c: np.asarray(v)[:SORT_SAMPLE_ROWS]
                                for c, v in rows.items()}

    # phase 1: propose, deploy tentatively, re-plan, keep what gets used
    proposals: Dict[str, ProjectionDef] = {}
    for q in workload:
        for cand in _candidates_for_query(db, q, groupby_sets,
                                          samples.get(q.table)):
            if cand.name not in proposals \
                    and cand.name not in db.catalog.projections:
                proposals[cand.name] = cand
    chosen: List[ProjectionDef] = []
    per_query = []
    if proposals and budget > 0:
        for cand in list(proposals.values()):
            db.create_projection(cand, populate=True)
        for q, b in zip(workload, before):
            plan = plan_query(db, q)
            a = plan.estimated.total if plan.estimated else 0.0
            per_query.append((repr(q.table) + "/" +
                              (",".join(q.group_by) or "scan"), b, a))
            picked = db.catalog.projections.get(plan.projection)
            if picked is not None and picked.name in proposals and \
                    picked not in chosen:
                chosen.append(picked)
        chosen = chosen[:budget]
        # tear down unused proposals (and everything if not deploying)
        for cand in list(proposals.values()):
            keep = deploy and cand in chosen
            if not keep:
                _drop_projection(db, cand.name)
                _drop_projection(db, cand.name + "_b1")
    else:
        for q, b in zip(workload, before):
            per_query.append((repr(q.table), b, b))

    # phase 2: empirical encoding choice on a sample (AUTO == the
    # experiment; we record what it picked)
    enc_report: Dict[str, Dict[str, str]] = {}
    for proj in ([p for p in chosen] if deploy else
                 list(db.catalog.projections.values())):
        choice = {}
        rows = db.read_projection(proj.name) if deploy else \
            db.read_table(proj.anchor)
        for c in proj.columns:
            if c not in rows or len(rows[c]) == 0:
                continue
            sample = rows[c][:100_000]
            enc = encode(np.asarray(sample), SQLType.INT)
            choice[c] = enc.encoding.value
        enc_report[proj.name] = choice
    return DesignReport(chosen, enc_report, per_query,
                        {p.name: p.sort_order
                         for p in proposals.values()})


def _drop_projection(db: VerticaDB, name: str):
    if name not in db.catalog.projections:
        return
    del db.catalog.projections[name]
    for node in db.nodes:
        node.stores.pop(name, None)
