"""Compression-aware cost model (paper §6.2: 'compression aware I/O, CPU
and Network transfer costs').

Costs are in abstract seconds built from the same hardware constants the
roofline uses: I/O = *encoded* bytes touched after SMA pruning (compression
directly buys scan speed -- the paper's central costing change), CPU = rows
processed, NET = bytes exchanged for non-co-located joins/groupbys.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.database import VerticaDB
from ..core.projection import ProjectionDef
from ..engine.expr import Expr

IO_BW = 819e9       # bytes/s (HBM on the TPU adaptation)
CPU_RATE = 2e9      # rows/s per node
NET_BW = 50e9       # bytes/s (ICI)


@dataclasses.dataclass
class CostEstimate:
    io_s: float = 0.0
    cpu_s: float = 0.0
    net_s: float = 0.0
    rows: int = 0
    bytes_scanned: float = 0.0

    @property
    def total(self) -> float:
        return self.io_s + self.cpu_s + self.net_s


def scan_cost(db: VerticaDB, proj: ProjectionDef,
              predicate: Optional[Expr], columns) -> CostEstimate:
    """Encoded bytes surviving SMA pruning, for the needed columns only
    (columnar: untouched columns cost nothing)."""
    bounds = predicate.bounds() if predicate is not None else {}
    est = CostEstimate()
    for node in db.nodes:
        if not node.serving():      # recovering stores are incomplete
            continue
        store = node.stores.get(proj.name)
        if store is None:
            continue
        for c in store.containers:
            frac = 1.0
            for colname, (lo, hi) in bounds.items():
                if colname in c.smas:
                    keep = c.smas[colname].prune_blocks(lo, hi)
                    frac = min(frac, keep.mean() if keep.size else 0.0)
            for colname in columns:
                if colname in c.columns:
                    est.bytes_scanned += c.columns[colname].storage_bytes() \
                        * frac
            est.rows += int(c.n_rows * frac)
    est.io_s = est.bytes_scanned / IO_BW
    est.cpu_s = est.rows / CPU_RATE
    return est


def selectivity(db: VerticaDB, proj: ProjectionDef,
                predicate: Optional[Expr]) -> float:
    """Fraction of rows expected to pass (SMA-based histogram proxy)."""
    if predicate is None:
        return 1.0
    bounds = predicate.bounds()
    if not bounds:
        return 0.5
    frac = 1.0
    for node in db.nodes:
        if not node.serving():
            continue
        store = node.stores.get(proj.name)
        if not store or not store.containers:
            continue
        for colname, (lo, hi) in bounds.items():
            kept = total = 0
            for c in store.containers:
                if colname in c.smas:
                    k = c.smas[colname].prune_blocks(lo, hi)
                    kept += int(k.sum())
                    total += k.size
            if total:
                frac = min(frac, kept / total)
        break
    return max(frac, 1e-4)


def join_distribution(db: VerticaDB, fact_proj: ProjectionDef,
                      fact_key: str, dim_table: str,
                      dim_rows: int, dim_key: str = "",
                      placement: Optional[Tuple[str, ...]] = None
                      ) -> Tuple[str, float]:
    """Pick co-located / broadcast / resegment and its NET cost (paper
    §6.2: 'optimizing queries to favor co-located joins where possible').

    * co-located: both sides segmented on the join key (or dim replicated)
      -> zero network.
    * broadcast: small dim -> all_gather of the build side.
    * resegment: both large -> all_to_all of the probe side.

    ``placement`` is the probe side's *current* hash-segmentation columns
    at the point this join runs -- the planner threads it through a join
    chain because an earlier resegment changes it (a resegment on k1 makes
    a later 'co-located on k2' claim false even when the stored projection
    is segmented by k2); None means 'use the projection's stored
    segmentation'.
    """
    dim_super = db.catalog.super_of(dim_table)
    fact_seg = fact_proj.segmentation
    if placement is None:
        placement = None if fact_seg.replicated else tuple(fact_seg.columns)
    if dim_super.segmentation.replicated:
        return "co-located (replicated dim)", 0.0
    if (placement == (fact_key,) and dim_key
            and dim_super.segmentation.columns == (dim_key,)):
        return "co-located (matching segmentation)", 0.0
    bcast_bytes = dim_rows * 16.0 * db.catalog.n_nodes
    fact_rows = sum(
        st.ros_rows() for n in db.nodes if n.serving()
        for st in [n.stores[fact_proj.name]])
    reseg_bytes = fact_rows * 16.0
    if bcast_bytes <= reseg_bytes:
        return "broadcast", bcast_bytes / NET_BW
    return "resegment", reseg_bytes / NET_BW


def resegment_capacity(dest_counts: np.ndarray, n_shards: int,
                       pad_multiple: int = 8) -> int:
    """Per-exchange static capacity for exchange.resegment: enough slots
    on the fullest destination shard (rounded up), times n_shards.  Exact
    when ``dest_counts`` comes from the actual destination histogram; the
    caller still checks the reported overflow."""
    per = int(max(int(np.max(dest_counts)) if len(dest_counts) else 0, 1))
    per = -(-per // pad_multiple) * pad_multiple
    return per * n_shards
