"""The query planner (paper §6.2, a compact V2Opt).

Physical-property driven: for each candidate projection we check
  * column coverage (can it answer the query at all),
  * sort-order match against predicate / group-by columns (pruning and
    pipelined aggregation),
  * segmentation vs join keys (co-located vs broadcast vs resegment),
then cost the survivors with the compression-aware model and keep the
cheapest. GroupBy algorithm choice (dense-hash / sort / RLE-direct) is part
of the physical plan; SIP filters are planned whenever a selective dim
predicate exists.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.database import VerticaDB
from ..core.encodings import Encoding
from ..engine.pipeline import Query
from . import cost as cost_mod


@dataclasses.dataclass
class PhysicalPlan:
    projection: str
    sources: List[Tuple[int, str]]          # (host node, projection) pairs
    groupby_algorithm: str = "sort"
    scalar_rle: bool = False           # COUNT on RLE runs, zero decode
    join_strategy: str = ""
    use_sip: bool = False
    dense_domain_limit: int = 1 << 20
    max_groups: int = 1 << 16
    estimated: Optional[cost_mod.CostEstimate] = None
    explain: List[str] = dataclasses.field(default_factory=list)


def _fact_columns(q: Query) -> set:
    """Columns the fact-side projection must supply (join output columns
    come from the dimension build side, not the scan)."""
    need = q.needed_columns()
    if q.join is not None:
        need -= set(q.join.dim_columns) | {q.join.dim_key}
    return need


def candidate_projections(db: VerticaDB, q: Query):
    need = _fact_columns(q)
    out = []
    for p in db.catalog.projections_of(q.table):
        if p.buddy_of is not None:
            continue
        if need <= set(p.columns):
            out.append(p)
    return out


def plan_query(db: VerticaDB, q: Query) -> PhysicalPlan:
    cands = candidate_projections(db, q)
    if not cands:
        raise ValueError(f"no projection covers {sorted(_fact_columns(q))}")
    need = _fact_columns(q)
    best = None
    for p in cands:
        est = cost_mod.scan_cost(db, p, q.predicate, need)
        bonus = 1.0
        # sort-order match: leading sort column in the predicate => pruning
        # actually bites; on the group-by key => pipelined aggregation
        bounds = q.predicate.bounds() if q.predicate is not None else {}
        if p.sort_order and p.sort_order[0] in bounds:
            bonus *= 0.5
        if q.group_by and p.sort_order and p.sort_order[0] == q.group_by:
            bonus *= 0.8
        score = est.total * bonus
        if best is None or score < best[0]:
            best = (score, p, est)
    _, proj, est = best

    plan = PhysicalPlan(projection=proj.name, sources=[], estimated=est)
    plan.explain.append(
        f"projection {proj.name} (sort {proj.sort_order}, "
        f"~{est.bytes_scanned/1e6:.2f}MB scanned, est {est.total*1e3:.3f}ms)")

    # source routing (buddy failover; one host may serve two segments)
    if proj.segmentation.replicated:
        first_up = next(n.id for n in db.nodes if n.up)
        plan.sources = [(first_up, proj.name)]
    else:
        owners = db.segment_owners(proj)
        for seg_node, owner_proj in owners.items():
            host = seg_node
            if owner_proj != proj.name:
                host = (seg_node + db.catalog.projections[
                    owner_proj].segmentation.offset) % db.catalog.n_nodes
            if (host, owner_proj) not in plan.sources:
                plan.sources.append((host, owner_proj))

    # join strategy + SIP
    if q.join is not None:
        dim_rows = len(db.read_table(q.join.dim_table)[q.join.dim_key])
        strat, net_s = cost_mod.join_distribution(
            db, proj, q.join.fact_key, q.join.dim_table, dim_rows,
            dim_key=q.join.dim_key)
        plan.join_strategy = strat
        est.net_s += net_s
        # SIP only pays when the build side actually filters (the paper's
        # predictability lesson: drop special cases that sometimes lose);
        # without a dim predicate every fact row joins and the filter is
        # pure overhead.
        plan.use_sip = q.join.dim_predicate is not None
        plan.explain.append(f"join {strat}, SIP={plan.use_sip}")

    # scalar COUNT with an EXACT integer interval on the RLE sort leader:
    # run-level math only (bounds() is pruning-conservative; counting needs
    # exact_int_interval -- see engine/expr.py)
    if q.group_by is None and q.aggs and q.join is None \
            and all(a[2] == "count" for a in q.aggs):
        from ..engine.expr import exact_int_interval
        leader = proj.sort_order[0] if proj.sort_order else None
        iv = exact_int_interval(q.predicate) \
            if q.predicate is not None else (leader, None, None)
        if iv is not None and iv[0] == leader \
                and _is_rle_sorted(db, proj, leader):
            plan.scalar_rle = True
            plan.explain.append("scalar COUNT on RLE runs (no decode)")

    # groupby algorithm: dense for small domains (dict-encoded /
    # low-cardinality), else sort-based; RLE-direct noted when available
    if q.group_by is not None:
        if q.join is not None and q.group_by in q.join.dim_columns:
            # grouping on a dimension attribute: its domain comes from the
            # dim projection's SMAs (the fact side never stores it)
            dom = _domain_estimate(
                db, db.catalog.super_of(q.join.dim_table), q.group_by)
        else:
            dom = _domain_estimate(db, proj, q.group_by)
        if dom is not None and 0 <= dom <= plan.dense_domain_limit:
            plan.groupby_algorithm = "dense"
        else:
            plan.groupby_algorithm = "sort"
        if _is_rle_sorted(db, proj, q.group_by) and not q.predicate \
                and q.join is None and all(a[2] == "count" for a in q.aggs):
            plan.groupby_algorithm = "rle"
        plan.explain.append(
            f"groupby {plan.groupby_algorithm} (domain~{dom})")
    return plan


def _domain_estimate(db: VerticaDB, proj, col: str) -> Optional[int]:
    lo = hi = None
    for node in db.nodes:
        if not node.up:
            continue
        for c in node.stores[proj.name].containers:
            if col not in c.smas or c.n_rows == 0:
                continue
            cmin, cmax = int(c.smas[col].container_min()), \
                int(c.smas[col].container_max())
            lo = cmin if lo is None else min(lo, cmin)
            hi = cmax if hi is None else max(hi, cmax)
    if lo is None:
        return None
    if lo < 0:
        return None
    return hi + 1


def _is_rle_sorted(db: VerticaDB, proj, col: str) -> bool:
    if not proj.sort_order or proj.sort_order[0] != col:
        return False
    for node in db.nodes:
        if not node.up:
            continue
        for c in node.stores[proj.name].containers:
            if c.columns[col].encoding != Encoding.RLE:
                return False
    return True
