"""The query planner (paper §6.2, a compact V2Opt), over the logical IR.

Physical-property driven: for each candidate projection we check
  * column coverage (can it answer the query at all),
  * sort-order match against predicate / group-by columns (pruning and
    pipelined aggregation),
  * segmentation vs join keys (co-located vs broadcast vs resegment),
then cost the survivors with the compression-aware model and keep the
cheapest.  Each join in the IR's join list gets its own distribution
strategy and SIP decision; composite group-by keys get per-column domain
estimates (from container SMAs) that drive both the dense/sort algorithm
choice and the executor's static key packing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.database import VerticaDB
from ..engine.logical import LogicalQuery, as_ir
from ..core.encodings import Encoding
from . import cost as cost_mod


@dataclasses.dataclass
class PhysicalPlan:
    projection: str
    sources: List[Tuple[int, str]]          # (host node, projection) pairs
    groupby_algorithm: str = "sort"
    scalar_rle: bool = False           # COUNT on RLE runs, zero decode
    join_strategy: str = ""            # "; "-joined per-join strategies
    join_strategies: Tuple[str, ...] = ()
    # per-join exchange operator for the segmented executor
    # (engine/segmented.py): "local" | "broadcast" | "resegment"
    join_exchanges: Tuple[str, ...] = ()
    use_sip: bool = False              # any join armed with SIP
    sip_joins: Tuple[bool, ...] = ()   # per-join SIP decision
    # per-group-column dense domain estimates (None = unknown); the
    # executor packs composite keys with these as static radices
    key_domains: Optional[Tuple[Optional[int], ...]] = None
    dense_domain_limit: int = 1 << 20
    max_groups: int = 1 << 16
    estimated: Optional[cost_mod.CostEstimate] = None
    explain: List[str] = dataclasses.field(default_factory=list)


def _fact_columns(q: LogicalQuery) -> set:
    """Columns the fact-side projection must supply (join output columns
    come from the dimension build sides, derived columns are computed)."""
    need = q.needed_columns()
    for j in q.joins:
        need -= set(j.dim_columns) | {j.dim_key}
    need -= {n for n, _ in q.derived}
    return need


def candidate_projections(db: VerticaDB, q: LogicalQuery):
    need = _fact_columns(q)
    out = []
    for p in db.catalog.projections_of(q.table):
        if p.buddy_of is not None:
            continue
        if need <= set(p.columns):
            out.append(p)
    return out


def plan_query(db: VerticaDB, q) -> PhysicalPlan:
    q = as_ir(q)
    cands = candidate_projections(db, q)
    if not cands:
        raise ValueError(f"no projection covers {sorted(_fact_columns(q))}")
    need = _fact_columns(q)
    best = None
    for p in cands:
        est = cost_mod.scan_cost(db, p, q.predicate, need)
        bonus = 1.0
        # sort-order match: leading sort column in the predicate => pruning
        # actually bites; on the leading group-by key => pipelined agg
        bounds = q.predicate.bounds() if q.predicate is not None else {}
        if p.sort_order and p.sort_order[0] in bounds:
            bonus *= 0.5
        if q.group_by and p.sort_order \
                and p.sort_order[0] == q.group_by[0]:
            bonus *= 0.8
        score = est.total * bonus
        if best is None or score < best[0]:
            best = (score, p, est)
    _, proj, est = best

    plan = PhysicalPlan(projection=proj.name, sources=[], estimated=est)
    plan.explain.append(
        f"projection {proj.name} (sort {proj.sort_order}, "
        f"~{est.bytes_scanned/1e6:.2f}MB scanned, est {est.total*1e3:.3f}ms)")

    # source routing (buddy failover; one host may serve two segments).
    # ``serving()`` excludes recovering shards: a rejoined node receives
    # commits but must not serve scans until recover_node() completes
    if proj.segmentation.replicated:
        first_up = next((n.id for n in db.nodes if n.serving()), None)
        if first_up is None:
            from ..core.database import AvailabilityError
            raise AvailabilityError(f"no serving replica of {proj.name}")
        plan.sources = [(first_up, proj.name)]
    else:
        owners = db.segment_owners(proj)
        for seg_node, owner_proj in owners.items():
            host = seg_node
            if owner_proj != proj.name:
                host = (seg_node + db.catalog.projections[
                    owner_proj].segmentation.offset) % db.catalog.n_nodes
            if (host, owner_proj) not in plan.sources:
                plan.sources.append((host, owner_proj))
        n_buddy = sum(1 for _, o in plan.sources
                      if db.catalog.projections[o].buddy_of is not None)
        if n_buddy:
            plan.explain.append(
                f"failover routing: {n_buddy}/{len(plan.sources)} "
                f"source(s) served by buddy projections (K-safety)")

    # join strategy + SIP + exchange op, one decision per join edge.  The
    # probe side's *placement* (which columns its rows are currently
    # hash-distributed by) starts at the projection's segmentation and is
    # rewritten by every resegment, so a later join's co-location claim is
    # judged against where the rows actually are, not where storage put
    # them (paper §6.2 'favor co-located joins where possible').
    placement = None if proj.segmentation.replicated \
        else tuple(proj.segmentation.columns)
    strategies, sips, exchanges = [], [], []
    for spec in q.joins:
        dim_rows = _dim_row_estimate(db, db.catalog.super_of(
            spec.dim_table))
        strat, net_s = cost_mod.join_distribution(
            db, proj, spec.fact_key, spec.dim_table, dim_rows,
            dim_key=spec.dim_key, placement=placement)
        if strat.startswith("co-located"):
            exch = "local"
        elif strat == "resegment":
            if placement == (spec.fact_key,):
                # an earlier resegment already placed the probe side by
                # this key; the build side is placed by hash(dim_key)
                # regardless of its stored segmentation, so the join is
                # local now -- re-exchanging would be pure waste
                exch = "local"
                strat = "co-located (placement)"
                net_s = 0.0
            elif spec.fact_key in proj.columns:
                exch = "resegment"
                placement = (spec.fact_key,)
            else:
                # snowflake key: it only materializes after an earlier
                # join, so the scan cannot compute its hash destination --
                # replicate the build side instead
                exch = "broadcast"
                strat = "broadcast (snowflake key)"
        else:
            exch = "broadcast"
        strategies.append(strat)
        exchanges.append(exch)
        est.net_s += net_s
        # SIP only pays when the build side actually filters (the paper's
        # predictability lesson: drop special cases that sometimes lose)
        # and the probe key is a physical fact column the scan can see --
        # snowflake keys materialize only after an earlier join.
        sips.append(spec.dim_predicate is not None
                    and spec.fact_key in proj.columns)
        plan.explain.append(
            f"join {spec.dim_table} on {spec.fact_key}: {strat} "
            f"(exchange {exch}), SIP={sips[-1]}")
    plan.join_strategies = tuple(strategies)
    plan.join_strategy = "; ".join(strategies)
    plan.join_exchanges = tuple(exchanges)
    plan.sip_joins = tuple(sips)
    plan.use_sip = any(sips)

    # scalar COUNT with an EXACT integer interval on the RLE sort leader:
    # run-level math only (bounds() is pruning-conservative; counting needs
    # exact_int_interval -- see engine/expr.py)
    if not q.group_by and q.aggs and not q.joins and not q.derived \
            and all(a[2] == "count" for a in q.aggs):
        from ..engine.expr import exact_int_interval
        leader = proj.sort_order[0] if proj.sort_order else None
        iv = exact_int_interval(q.predicate) \
            if q.predicate is not None else (leader, None, None)
        if iv is not None and iv[0] == leader \
                and _is_rle_sorted(db, proj, leader):
            plan.scalar_rle = True
            plan.explain.append("scalar COUNT on RLE runs (no decode)")

    # groupby algorithm: dense when the packed key domain (product of
    # per-column SMA domains) is small, else sort-based; RLE-direct for a
    # single already-sorted RLE key with count-only aggregates
    if q.group_by:
        derived_names = {n for n, _ in q.derived}
        doms: List[Optional[int]] = []
        for g in q.group_by:
            if g in derived_names:
                doms.append(None)
                continue
            src = proj
            for spec in q.joins:
                if g in spec.dim_columns:
                    # a dimension attribute: its domain comes from the dim
                    # projection's SMAs (the fact side never stores it)
                    src = db.catalog.super_of(spec.dim_table)
                    break
            doms.append(_domain_estimate(db, src, g))
        plan.key_domains = tuple(doms)
        if all(d is not None for d in doms):
            total = 1
            for d in doms:
                total *= d
            plan.groupby_algorithm = (
                "dense" if 0 <= total <= plan.dense_domain_limit
                else "sort")
        else:
            total = None
            plan.groupby_algorithm = "sort"
        if len(q.group_by) == 1 \
                and _is_rle_sorted(db, proj, q.group_by[0]) \
                and not q.predicate and not q.joins \
                and all(a[2] == "count" for a in q.aggs):
            plan.groupby_algorithm = "rle"
        plan.explain.append(
            f"groupby {plan.groupby_algorithm} "
            f"(domains {doms} -> {total})")
    return plan


def _dim_row_estimate(db: VerticaDB, proj) -> int:
    """Build-side cardinality from store metadata (no decode; delete
    vectors ignored -- an overcount is fine for a strategy decision)."""
    up = [n for n in db.nodes if n.serving()]
    if proj.segmentation.replicated:
        up = up[:1]
    return sum(st.ros_rows() + st.wos.n_rows
               for n in up for st in [n.stores[proj.name]])


def _domain_estimate(db: VerticaDB, proj, col: str) -> Optional[int]:
    lo = hi = None
    for node in db.nodes:
        if not node.serving():
            continue
        for c in node.stores[proj.name].containers:
            if col not in c.smas or c.n_rows == 0:
                continue
            cmin, cmax = int(c.smas[col].container_min()), \
                int(c.smas[col].container_max())
            lo = cmin if lo is None else min(lo, cmin)
            hi = cmax if hi is None else max(hi, cmax)
    if lo is None:
        return None
    if lo < 0:
        return None
    return hi + 1


def _is_rle_sorted(db: VerticaDB, proj, col: str) -> bool:
    if not proj.sort_order or proj.sort_order[0] != col:
        return False
    for node in db.nodes:
        if not node.serving():
            continue
        for c in node.stores[proj.name].containers:
            if c.columns[col].encoding != Encoding.RLE:
                return False
    return True
