from .cost import CostEstimate, join_distribution, scan_cost, selectivity
from .designer import DesignReport, design
from .planner import PhysicalPlan, candidate_projections, plan_query

__all__ = ["CostEstimate", "DesignReport", "PhysicalPlan",
           "candidate_projections", "design", "join_distribution",
           "plan_query", "scan_cost", "selectivity"]
