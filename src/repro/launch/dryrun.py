import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
backend initialization, and this process needs 512 placeholder CPU devices
to build the production meshes. (Smoke tests and benchmarks run in normal
1-device processes; only the dry-run sets this flag.)

Per cell we record into results/dryrun/<cell>.json:
  memory_analysis   -- proves the step fits per-device HBM
  cost_analysis     -- per-device HLO FLOPs / bytes (roofline inputs)
  collective bytes  -- parsed from the post-SPMD HLO text
  the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --sweep            # all cells, both meshes
  python -m repro.launch.dryrun --sweep --multi-pod-only
"""
import argparse
import json
import pathlib
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from .. import configs
from ..configs.base import SHAPES_BY_NAME, RunConfig, ShapeConfig
from ..distributed.sharding import (BASE_RULES, activation_hints,
                                    long_context_overrides, rules_for)
from ..models.model import build_model, cache_partition_axes
from ..models.params import logical_axes, resolve_spec
from ..train.train_step import (abstract_train_state, make_train_step,
                                train_state_axes)
from .hlo_cost import analyze_hlo
from .mesh import make_production_mesh
from .roofline_math import analyze, model_flops

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _axes_leaf(x) -> bool:
    """True for logical-axes tuples like ('embed','mlp') or () -- but not for
    NamedTuples (OptState) which must be traversed."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(isinstance(a, (str, type(None))) for a in x))

_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", "embed_act"),
    "image_embeds": ("batch", "frontend_seq", "embed_act"),
}


def _spec_tree_for_inputs(specs: Dict[str, Any], model, shape: ShapeConfig,
                          rules, mesh) -> Dict[str, Any]:
    """Build the in_shardings pytree matching model.input_specs output."""
    names = mesh.axis_names

    def batch_axes_tree(batch):
        return {k: resolve_spec(_BATCH_AXES[k], rules, names)
                for k in batch}

    out: Dict[str, Any] = {}
    if "batch" in specs:
        out["batch"] = batch_axes_tree(specs["batch"])
    if "cache" in specs:
        axes = cache_partition_axes(model, shape.global_batch, shape.seq_len)
        out["cache"] = jax.tree.map(
            lambda a: resolve_spec(a, rules, names), axes,
            is_leaf=_axes_leaf)
        out["tokens"] = resolve_spec(("batch", "seq"), rules, names)
        out["pos"] = PartitionSpec()
    return jax.tree.map(lambda s: NamedSharding(mesh, s), out,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules_overrides: Optional[Dict[str, Any]] = None,
             rc: Optional[RunConfig] = None, moe_dispatch: Optional[str]
             = None, kv_quant: bool = False, save: bool = True,
             tag: str = "baseline") -> Dict[str, Any]:
    import dataclasses as _dc

    cfg = configs.get(arch)
    if moe_dispatch and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                               dispatch=moe_dispatch))
    shape = SHAPES_BY_NAME[shape_name]
    ok, reason = cfg.supports_shape(shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "tag": tag,
        "multi_pod": multi_pod, "time": time.strftime("%F %T"),
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        _save(rec, save)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    rc = rc or RunConfig()
    model = build_model(cfg, tp=tp, remat=rc.remat_policy,
                        kv_quant=kv_quant)
    rules = rules_for(cfg, shape.kind, overrides=rules_overrides)
    if shape.name == "long_500k":
        rules.update(long_context_overrides())
        if rules_overrides:
            rules.update(rules_overrides)
    names = mesh.axis_names

    t0 = time.time()
    try:
      with activation_hints(rules, mesh):
        inputs = model.input_specs(shape)
        in_specs = _spec_tree_for_inputs(inputs, model, shape, rules, mesh)

        if shape.kind == "train":
            state = abstract_train_state(model)
            st_axes = train_state_axes(model)
            st_specs = jax.tree.map(
                lambda a: NamedSharding(mesh, resolve_spec(a, rules, names)),
                st_axes, is_leaf=_axes_leaf)
            step = make_train_step(model, rc)
            jitted = jax.jit(step,
                             in_shardings=(st_specs, in_specs["batch"]),
                             out_shardings=(st_specs, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, inputs["batch"])
        elif shape.kind == "prefill":
            p_axes = logical_axes(model.decls)
            p_specs = jax.tree.map(
                lambda a: NamedSharding(mesh, resolve_spec(a, rules, names)),
                p_axes, is_leaf=_axes_leaf)
            from ..models.params import abstract_params
            params = abstract_params(model.decls, jnp.bfloat16)
            jitted = jax.jit(model.prefill,
                             in_shardings=(p_specs, in_specs["batch"]))
            lowered = jitted.lower(params, inputs["batch"])
        else:  # decode
            p_axes = logical_axes(model.decls)
            p_specs = jax.tree.map(
                lambda a: NamedSharding(mesh, resolve_spec(a, rules, names)),
                p_axes, is_leaf=_axes_leaf)
            from ..models.params import abstract_params
            params = abstract_params(model.decls, jnp.bfloat16)
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(p_specs, in_specs["cache"],
                              in_specs["tokens"], in_specs["pos"]),
                out_shardings=(None, in_specs["cache"]),
                donate_argnums=(1,))
            lowered = jitted.lower(params, inputs["cache"],
                                   inputs["tokens"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        hc = analyze_hlo(hlo)  # loop-aware: xla cost_analysis counts each
        #                        while body once (see hlo_cost.py docstring)
        mflops = model_flops(cfg, shape)
        roof = analyze(hc, mflops, n_chips)

        mem_rec = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_rec[attr] = int(v)

        rec.update(
            status="ok", n_chips=n_chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            hlo_flops_per_device=roof.hlo_flops,
            hlo_bytes_per_device=roof.hlo_bytes,
            collective_bytes_per_device=roof.coll_bytes,
            collective_s_bf16wire=hc.coll_bf16_wire / 50e9,
            collectives={k: v for k, v in hc.coll.items() if v},
            unknown_trip_counts=hc.unknown_trip,
            model_flops=mflops,
            compute_s=roof.compute_s, memory_s=roof.memory_s,
            collective_s=roof.collective_s, dominant=roof.dominant,
            useful_flops_ratio=round(roof.useful_ratio, 4),
            roofline_fraction=round(roof.roofline_fraction, 4),
            memory_analysis=mem_rec,
            xla_cost_analysis={k: float(v) for k, v in cost.items()
                               if isinstance(v, (int, float))
                               and k in ("flops", "bytes accessed")}
            if cost else {},
        )
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'2x16x16' if multi_pod else '16x16'}): "
              f"dominant={roof.dominant} "
              f"frac={roof.roofline_fraction:.3f} "
              f"useful={roof.useful_ratio:.3f} "
              f"compile={t_compile:.0f}s", flush=True)
    except Exception as e:  # noqa: BLE001 -- record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] {arch} x {shape_name} FAILED: {e}", flush=True)
    _save(rec, save)
    return rec


def _save(rec: Dict[str, Any], save: bool):
    if not save:
        return
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    mesh_tag = "pod2" if rec["multi_pod"] else "pod1"
    name = f"{rec['arch']}--{rec['shape']}--{mesh_tag}--{rec['tag']}.json"
    (RESULTS_DIR / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--moe-dispatch", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.sweep:
        pods = [False, True]
        if args.multi_pod_only:
            pods = [True]
        if args.single_pod_only:
            pods = [False]
        for mp in pods:
            for arch in configs.ARCH_NAMES:
                for shape in ("train_4k", "prefill_32k", "decode_32k",
                              "long_500k"):
                    mesh_tag = "pod2" if mp else "pod1"
                    f = RESULTS_DIR / (f"{arch}--{shape}--{mesh_tag}--"
                                       f"{args.tag}.json")
                    if args.skip_existing and f.exists():
                        prev = json.loads(f.read_text())
                        if prev.get("status") in ("ok", "skipped"):
                            continue
                    run_cell(arch, shape, multi_pod=mp, tag=args.tag)
        return
    rc = RunConfig(remat_policy=args.remat) if args.remat else None
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod, tag=args.tag,
             moe_dispatch=args.moe_dispatch, kv_quant=args.kv_quant, rc=rc)


if __name__ == "__main__":
    main()
