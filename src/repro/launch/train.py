"""End-to-end training driver (CPU-scale; the same structure a pod job
would run -- see launch/dryrun.py for the production-mesh compile proof).

Pipeline: columnar token store (Vertica projection, data epoch pinned)
-> batches -> jitted train_step -> epoch-based K-safe checkpoints.
Failure injection (--fail-at-step) exercises buddy restore + deterministic
replay mid-run.

Usage:
  python -m repro.launch.train --arch qwen3-4b --reduced --steps 100
  python -m repro.launch.train --d-model 512 --layers 8 --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..configs.base import ArchConfig, RunConfig
from ..data import TokenStore, token_corpus
from ..models import build_model, init_params
from ..train.checkpoint import CheckpointStore, shard_state, unshard_state
from ..train.optim import init_opt_state
from ..train.train_step import init_train_state, make_train_step


def build_cfg(args) -> ArchConfig:
    if args.arch:
        cfg = configs.get(args.arch)
        return cfg.reduced() if args.reduced else cfg
    return ArchConfig(
        name=f"custom-{args.layers}L-{args.d_model}d",
        family="dense", n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=args.d_model // 64,
        d_ff=args.d_model * 4, vocab_size=args.vocab, head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--n-docs", type=int, default=256)
    ap.add_argument("--doc-len", type=int, default=512)
    args = ap.parse_args()

    cfg = build_cfg(args)
    rc = RunConfig(learning_rate=args.lr, total_steps=args.steps,
                   warmup_steps=max(1, args.steps // 10))
    model = build_model(cfg, tp=1)
    print(f"[train] arch={cfg.name} params={model.n_params:,}")

    # --- corpus through the columnar store (bulk ingest -> tuple mover) ---
    store = TokenStore.create(n_nodes=4)
    corpus = token_corpus(args.n_docs, args.doc_len, cfg.vocab_size)
    data_epoch = store.ingest(corpus)
    st = store.storage_stats()
    print(f"[train] corpus: {st['rows']:,} tokens in {st['containers']} "
          f"containers, compression {st['ratio']:.2f}x, "
          f"data epoch {data_epoch}")

    state = init_train_state(model, jax.random.key(0))
    step_fn = jax.jit(make_train_step(model, rc), donate_argnums=(0,))
    ckpt = CheckpointStore(pathlib.Path(args.ckpt_dir) / cfg.name,
                           n_shards=4)

    def stream():
        while True:
            yield from store.batches(args.batch, args.seq,
                                     as_of=data_epoch, seed=0)

    batches = stream()
    t0 = time.time()
    losses = []
    step = 0
    while step < args.steps:
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        step += 1
        if step % 10 == 0 or step == 1:
            dt = time.time() - t0
            tok_s = step * args.batch * args.seq / dt
            print(f"[train] step {step:4d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"{tok_s:,.0f} tok/s")
        if step % args.ckpt_every == 0 or step == args.steps:
            for shard in range(4):
                ckpt.save_shard(step, shard, shard_state(
                    jax.tree.map(np.asarray, state), shard, 4))
            ckpt.commit_epoch(step, {"loss": losses[-1]})
            print(f"[train] checkpoint @ step {step} (K-safe x2)")
        if args.fail_at_step and step == args.fail_at_step:
            print(f"[train] !!! injecting node-1 failure at step {step}")
            lge = ckpt.last_good_epoch()
            shards = [ckpt.restore_shard(lge, s, shard_state(
                jax.tree.map(np.asarray, state), s, 4),
                lost_nodes=(1,)) for s in range(4)]
            full = unshard_state(shards, jax.tree.map(np.asarray, state))
            state = jax.tree.map(jnp.asarray, full)
            # deterministic replay: rewind the stream to the LGE
            batches = stream()
            for _ in range(lge):
                next(batches)
            step = lge
            args.fail_at_step = None
            print(f"[train] recovered from LGE {lge}, replaying")
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"in {time.time()-t0:.1f}s")
    return losses


if __name__ == "__main__":
    main()
