"""Production mesh construction (assignment-prescribed shapes).

Kept as functions so importing this module never touches jax device state
(jax locks the device count at first backend init -- the dry-run must set
XLA_FLAGS before this runs).
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod; (pod=2, data=16, model=16) for 512."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        return jax.make_mesh(shape, axes)
    except (ValueError, TypeError):
        # jax.make_mesh requires len(devices) == prod(shape); when the
        # runtime exposes more placeholder devices than the mesh needs
        # (single-pod mesh on the 512-device dry-run process), take a slice.
        n = int(np.prod(shape))
        devs = np.asarray(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devs, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests/examples (uses however many devices exist)."""
    import jax

    n = data * model
    devs = np.asarray(jax.devices()[:n]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))
