"""Batched serving driver: prefill a batch of prompts, then decode with the
KV cache (CPU-scale demo of the same serve_step the dry-run lowers for the
decode_32k / long_500k cells).

Usage:
  python -m repro.launch.serve --arch mamba2-130m --reduced --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import build_model, init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, tp=1)
    params = init_params(model.decls, jax.random.key(0), jnp.float32)
    print(f"[serve] arch={cfg.name} params={model.n_params:,}")

    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len
    max_len = S + args.tokens
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16)

    t0 = time.time()
    logits, cache = model.prefill(params, batch, max_len=max_len)
    t_prefill = time.time() - t0
    print(f"[serve] prefill {B}x{S} in {t_prefill:.2f}s "
          f"({B*S/t_prefill:,.0f} tok/s)")

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [tokens]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, cache, tokens,
                               jnp.asarray(S + i, jnp.int32))
        tokens = jnp.argmax(logits[:, -1], axis=-1).astype(
            jnp.int32)[:, None]
        outs.append(tokens)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"[serve] decoded {args.tokens-1} steps x {B} seqs in {dt:.2f}s "
          f"({(args.tokens-1)*B/max(dt,1e-9):,.0f} tok/s)")
    print(f"[serve] sample generations (token ids):")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {gen[b][:16].tolist()}")


if __name__ == "__main__":
    main()
