"""Loop-aware cost extraction from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE -- a
``lax.scan`` over 40 layers reports one layer's FLOPs (verified empirically:
scan of 8 matmuls reports 2.1e9, not 1.7e10). Every model here scans over
layers, so the built-in numbers are useless for a roofline. This module
re-derives per-device costs by walking the HLO call graph and multiplying
``while`` bodies by their ``known_trip_count`` backend_config.

Cost model (per device):
  flops  -- 2 * prod(result dims) * prod(lhs contracting dims) per dot,
            accumulated through fusion-called computations.
  bytes  -- HBM traffic proxy: for each top-level op in an execution context
            (ENTRY / while bodies / called computations -- NOT fusion
            internals, which are register/VMEM-resident), charge result
            bytes (write) + resolvable operand bytes (reads).
            dynamic-update-slice is charged 2x the update slice (in-place).
  coll   -- collective result bytes by op kind (all-reduce charged 2x for
            the reduce+broadcast ring phases), trip-count multiplied.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
# NB: tuple shapes longer than 5 elements carry /*index=N*/ comments, so the
# tuple alternative must allow '=' inside the parens.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\([^)]*\)|[^,)]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\D{0,12}(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "after-all", "partition-id", "replica-id",
               "iota", "call", "conditional"}

# Top-level elementwise ops are a CPU-lowering artifact: TPU fuses them into
# neighboring dots/fusions whose operand/result bytes we already count.
# Charging their operands would overstate HBM traffic ~20x (measured).
_ELEMENTWISE = {
    "convert", "multiply", "add", "subtract", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "negate", "compare", "select",
    "and", "or", "not", "xor", "tanh", "power", "sqrt", "rsqrt", "log",
    "log-plus-one", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "clamp", "broadcast", "reshape", "pad", "sine", "cosine", "is-finite",
    "reduce-precision", "real", "imag", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "popcnt", "clz",
    "stochastic-convert", "erf", "expm1", "log1p", "logistic", "cbrt", "tan",
}

# Ops whose operand list must not be charged wholesale: they touch only a
# slice of (possibly huge, scan-carried) operands.
_SLICE_LIKE = {"dynamic-slice", "slice", "gather"}


def shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    line: str
    operands: List[str]
    is_root: bool = False


@dataclasses.dataclass
class Comp:
    name: str
    symbols: Dict[str, str]           # %name -> shape str (params + ops)
    ops: List[Op]
    param_order: List[str] = dataclasses.field(default_factory=list)

    def root(self) -> Optional[Op]:
        for op in reversed(self.ops):
            if op.is_root:
                return op
        return self.ops[-1] if self.ops else None


def parse_module(text: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry = None
    cur: Optional[Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        mc = _COMP_RE.match(line.strip()) if line.strip().endswith("{") else None
        if mc:
            cur = Comp(mc.group(2), {}, [])
            comps[cur.name] = cur
            if mc.group(1):
                entry = cur.name
            for pname, pshape in _PARAM_RE.findall(mc.group(3)):
                cur.symbols[pname] = pshape
                cur.param_order.append(pname)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, shape, kind = mo.group(1), mo.group(2), mo.group(3)
        rest = line[mo.end():]
        paren_end = rest.find(")")
        operands = _OPERAND_RE.findall(rest[:paren_end if paren_end >= 0
                                            else len(rest)])
        cur.symbols[name] = shape
        cur.ops.append(Op(name, shape, kind, line, operands,
                          is_root=line.lstrip().startswith("ROOT")))
    return comps, entry


def _dot_flops(comp: Comp, op: Op) -> float:
    dims = _shape_dims(op.shape)
    out = 1.0
    for d in dims:
        out *= d
    m = _LHS_CONTRACT_RE.search(op.line)
    contract = 1.0
    if m and op.operands:
        lhs_shape = comp.symbols.get(op.operands[0], "")
        lhs_dims = _shape_dims(lhs_shape)
        for i in (int(x) for x in m.group(1).split(",") if x.strip()):
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out * contract


def _fusion_bytes(comps: Dict[str, Comp], comp: Comp, op: Op,
                  calls_name: str) -> float:
    """HBM traffic of one fusion op, looking inside the called computation.

    Scan bodies pass whole stacked arrays into fusions that slice out one
    layer's piece (or DUS one piece back in). Charging the full operand
    would bill the entire stack once per loop iteration -- instead, charge
    the slice/update sizes the fusion actually touches."""
    called = comps.get(calls_name)
    if called is None:
        return _op_bytes(comp, op)
    # pure-elementwise fusions (wrapped converts/broadcasts) are CPU-lowering
    # artifacts; on TPU they fuse into their consumers -- charge nothing.
    if all(o.kind in _ELEMENTWISE or o.kind in _SKIP_BYTES
           for o in called.ops):
        return 0.0
    total = 0.0
    by_name = {o.name: o for o in called.ops}

    def resolve_through_elementwise(o: Optional[Op]) -> Optional[Op]:
        seen = 0
        while o is not None and o.kind in _ELEMENTWISE and o.operands \
                and seen < 8:
            o = by_name.get(o.operands[0])
            seen += 1
        return o

    # result side: DUS-rooted fusions (possibly through converts) update
    # in place
    root = called.root()
    root_ops = [root] if root else []
    if root and root.kind == "tuple":
        root_ops = [by_name.get(n) for n in root.operands]
        root_ops = [o for o in root_ops if o is not None]
    charged_result = 0.0
    for ro in root_ops:
        ro_shape = ro.shape
        eff = resolve_through_elementwise(ro)
        if eff is not None and eff.kind == "dynamic-update-slice" \
                and len(eff.operands) >= 2:
            charged_result += 2.0 * shape_bytes(
                called.symbols.get(eff.operands[1], ""))
        else:
            charged_result += shape_bytes(ro_shape)
    total += charged_result if root_ops else shape_bytes(op.shape)
    # operand side: per fusion parameter, find its transitive non-elementwise
    # consumers (converts in between are CPU artifacts)
    def terminal_consumers(name: str, depth=0) -> List[Op]:
        out = []
        for o in called.ops:
            if name in o.operands:
                if o.kind in _ELEMENTWISE and depth < 8:
                    out.extend(terminal_consumers(o.name, depth + 1))
                else:
                    out.append(o)
        return out

    for i, oname in enumerate(op.operands):
        if i >= len(called.param_order):
            break
        pname = called.param_order[i]
        consumers = terminal_consumers(pname)
        if consumers and all(
                o.kind in _SLICE_LIKE or
                (o.kind == "dynamic-update-slice" and
                 _feeds_target(called, by_name, pname, o))
                for o in consumers):
            total += sum(2.0 * shape_bytes(o.shape) for o in consumers
                         if o.kind in _SLICE_LIKE)
        else:
            total += shape_bytes(comp.symbols.get(oname, ""))
    return total


def _feeds_target(called: Comp, by_name: Dict[str, Op], pname: str,
                  dus: Op) -> bool:
    """True if pname reaches dus as its in-place TARGET (operand 0),
    possibly through elementwise ops."""
    if not dus.operands:
        return False
    cur = dus.operands[0]
    for _ in range(8):
        if cur == pname:
            return True
        o = by_name.get(cur)
        if o is None or o.kind not in _ELEMENTWISE or not o.operands:
            return False
        cur = o.operands[0]
    return False


def _op_bytes(comp: Comp, op: Op) -> float:
    res = shape_bytes(op.shape)
    if op.kind == "dynamic-update-slice" and len(op.operands) >= 2:
        upd = shape_bytes(comp.symbols.get(op.operands[1], ""))
        return 2.0 * upd                      # in-place: read+write the slice
    if op.kind in _SLICE_LIKE:
        return 2.0 * res                      # offset read + write
    if op.kind == "scatter" and len(op.operands) >= 3:
        upd = shape_bytes(comp.symbols.get(op.operands[2], ""))
        return 2.0 * upd + res
    reads = sum(shape_bytes(comp.symbols.get(o, "")) for o in op.operands)
    return res + reads


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_f32: float = 0.0      # payload bytes moved at f32 width
    n_dots: int = 0
    unknown_trip: int = 0

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())

    @property
    def coll_bf16_wire(self) -> float:
        """Collective bytes assuming f32 payloads travel at bf16 width.
        XLA-CPU upcasts bf16 dots to f32, so partial-sum all-reduces carry
        f32 on this runtime; a real TPU reduces the bf16 dot outputs. The
        roofline reports both (EXPERIMENTS.md notes the bias)."""
        return self.coll_total - 0.5 * self.coll_f32


def analyze_hlo(text: str) -> Costs:
    comps, entry = parse_module(text)
    costs = Costs()
    flops_memo: Dict[str, float] = {}

    def comp_flops(name: str) -> float:
        """dot flops of a computation incl. fusion-called ones (no loops)."""
        if name in flops_memo:
            return flops_memo[name]
        flops_memo[name] = 0.0  # cycle guard
        c = comps.get(name)
        if c is None:
            return 0.0
        total = 0.0
        for op in c.ops:
            if op.kind == "dot":
                total += _dot_flops(c, op)
            mcall = _CALLS_RE.search(op.line) or _TO_APPLY_RE.search(op.line)
            if mcall and op.kind in ("fusion", "call", "map", "reduce",
                                     "custom-call"):
                total += comp_flops(mcall.group(1))
        flops_memo[name] = total
        return total

    visited_exec: set = set()

    def walk(name: str, mult: float):
        c = comps.get(name)
        if c is None:
            return
        for op in c.ops:
            base_kind = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if op.kind == "while":
                mw = _WHILE_RE.search(op.line)
                mt = _TRIP_RE.search(op.line)
                trip = float(mt.group(1)) if mt else 1.0
                if not mt:
                    costs.unknown_trip += 1
                if mw:
                    walk(mw.group(2), mult * trip)   # body
                    walk(mw.group(1), mult * trip)   # condition
                continue
            if op.kind in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|branch_computations=\{)"
                                     r"%?([\w\.\-]+)", op.line):
                    walk(m.group(1), mult)
                continue
            if base_kind in COLLECTIVES:
                if op.kind.endswith("-done"):
                    continue
                b = shape_bytes(op.shape)
                factor = 2.0 if base_kind == "all-reduce" else 1.0
                costs.coll[base_kind] += b * factor * mult
                if op.shape.lstrip("(").startswith(("f32", "f64")):
                    costs.coll_f32 += b * factor * mult
                costs.bytes += _op_bytes(c, op) * mult
                continue
            if op.kind == "dot":
                costs.flops += _dot_flops(c, op) * mult
                costs.n_dots += 1
                costs.bytes += _op_bytes(c, op) * mult
                continue
            if op.kind == "fusion":
                mcall = _CALLS_RE.search(op.line)
                if mcall:
                    costs.flops += comp_flops(mcall.group(1)) * mult
                    costs.bytes += _fusion_bytes(comps, c, op,
                                                 mcall.group(1)) * mult
                else:
                    costs.bytes += _op_bytes(c, op) * mult
                continue
            if op.kind in _SKIP_BYTES or op.kind in _ELEMENTWISE:
                continue
            costs.bytes += _op_bytes(c, op) * mult

    if entry:
        walk(entry, 1.0)
    return costs
