"""Roofline arithmetic: hardware constants, MODEL_FLOPS estimates, and the
three-term analysis derived from a compiled dry-run artifact.

Hardware model (TPU v5e, per assignment):
  peak   197 TFLOP/s bf16 / chip
  HBM    819 GB/s / chip
  ICI    ~50 GB/s / link

MODEL_FLOPS is the *published-architecture* useful work (6*N_active*D for
training), so the HLO/MODEL ratio surfaces padding waste, remat recompute and
capacity-factor overhead -- exactly what §Perf iterates on.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from ..configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful work of the published arch; no padding, no remat)
# ---------------------------------------------------------------------------

def _layer_param_flops_per_token(cfg: ArchConfig) -> float:
    """2 * active params per layer (matmul fwd flops per token)."""
    d = cfg.d_model
    total = 0.0
    if cfg.n_heads:
        hd = cfg.resolved_head_dim
        attn_p = d * cfg.n_heads * hd * 2 + d * cfg.n_kv_heads * hd * 2
        total += attn_p
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * d
        h = d_inner // cfg.ssm.head_dim
        n, g = cfg.ssm.d_state, 1
        proj = d * (2 * d_inner + 2 * g * n + h) + d_inner * d
        total += proj
    if cfg.moe is not None:
        total += d * cfg.moe.num_experts  # router
        total += cfg.moe.top_k * 3 * d * cfg.moe.d_ff_expert
    elif cfg.d_ff:
        n_mats = 3 if cfg.mlp == "swiglu" else 2
        total += n_mats * d * cfg.d_ff
    return 2.0 * total


def _attn_core_flops_per_token(cfg: ArchConfig, ctx: float) -> float:
    """4 * Hq * hd * ctx (QK^T + AV) per attention layer."""
    if not cfg.n_heads:
        return 0.0
    return 4.0 * cfg.n_heads * cfg.resolved_head_dim * ctx


def _ssm_core_flops_per_token(cfg: ArchConfig) -> float:
    if cfg.ssm is None:
        return 0.0
    d_inner = cfg.ssm.expand * cfg.d_model
    h = d_inner // cfg.ssm.head_dim
    # state update (2*H*P*N mul-add pairs) + output contraction
    return 4.0 * h * cfg.ssm.head_dim * cfg.ssm.d_state


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Total useful FLOPs of one step of this (arch x shape) cell."""
    S, B = shape.seq_len, shape.global_batch
    d = cfg.d_model
    vocab_f = 2.0 * d * cfg.vocab_size  # unembed per token

    def layer_flops(ctx):
        per = _layer_param_flops_per_token(cfg)
        per += _attn_core_flops_per_token(cfg, ctx)
        per += _ssm_core_flops_per_token(cfg)
        return per

    if shape.kind == "train":
        ctx = _avg_ctx(cfg, S)
        fwd = B * S * (cfg.n_layers * layer_flops(ctx) + vocab_f)
        if cfg.is_encdec:
            enc_ctx = S / 2  # bidirectional, full
            fwd += B * S * cfg.n_encoder_layers * (
                _layer_param_flops_per_token(cfg)
                + _attn_core_flops_per_token(cfg, S))
        if cfg.family == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            fwd += B * S * n_cross * _attn_core_flops_per_token(
                cfg, cfg.n_frontend_tokens)
        return 3.0 * fwd  # fwd + 2x bwd
    if shape.kind == "prefill":
        ctx = _avg_ctx(cfg, S)
        fwd = B * S * cfg.n_layers * layer_flops(ctx) + B * vocab_f
        if cfg.is_encdec:
            fwd += B * S * cfg.n_encoder_layers * (
                _layer_param_flops_per_token(cfg)
                + _attn_core_flops_per_token(cfg, S))
        if cfg.family == "vlm":
            n_cross = cfg.n_layers // cfg.cross_attn_every
            fwd += B * S * n_cross * _attn_core_flops_per_token(
                cfg, cfg.n_frontend_tokens)
        return fwd
    # decode: one token against a cache of S
    ctx = min(S, cfg.window) if cfg.window else S
    per_tok = cfg.n_layers * layer_flops(ctx) + vocab_f
    if cfg.window and cfg.global_layers:
        # global layers see the full context
        per_tok += len(cfg.global_layers) * (
            _attn_core_flops_per_token(cfg, S)
            - _attn_core_flops_per_token(cfg, ctx))
    return B * per_tok


def _avg_ctx(cfg: ArchConfig, S: int) -> float:
    if cfg.window:
        n_glob = len(cfg.global_layers)
        w_frac = (cfg.n_layers - n_glob) / cfg.n_layers
        return w_frac * min(cfg.window, S) + (1 - w_frac) * S / 2
    return S / 2.0


# ---------------------------------------------------------------------------
# HLO parsing: collective bytes from the post-SPMD per-device module
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVE_OPS) + r")(-start)?\(")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by collectives, by op kind.

    We charge the *result* bytes of each collective (the received payload
    per device), with all-reduce counted twice (reduce + broadcast phases of
    a ring). '-done' halves of async pairs are skipped.
    """
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        out[op] += b * (2.0 if op == "all-reduce" else 1.0)
    out["total"] = sum(out.values())
    return out


# ---------------------------------------------------------------------------
# The three roofline terms
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    coll_bytes: float         # per device
    model_flops: float        # whole step, published arch
    n_chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips)."""
        tot = self.hlo_flops * self.n_chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of peak on the dominant-term model:
        (MODEL_FLOPS / chips / peak) / bound_s."""
        ideal = self.model_flops / self.n_chips / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s else 0.0


def analyze(hc, mflops: float, n_chips: int) -> Roofline:
    """hc: hlo_cost.Costs (loop-aware per-device totals)."""
    return Roofline(
        compute_s=hc.flops / PEAK_FLOPS,
        memory_s=hc.bytes / HBM_BW,
        collective_s=hc.coll_total / ICI_BW,
        hlo_flops=hc.flops, hlo_bytes=hc.bytes, coll_bytes=hc.coll_total,
        model_flops=mflops, n_chips=n_chips)
