"""SIP semi-join probe Pallas kernel (§6.1 Sideways Information Passing).

The join build side (small dimension keys, padded to a lane multiple) sits
in VMEM; each grid step tests one block of probe keys against all of it
with a broadcast compare + any-reduce. Exact (not Bloom): on TPU the
build side fits VMEM wholesale, so the approximate filter is unnecessary --
an intentional deviation recorded in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_BUILD = 4096   # keys; 16KB of VMEM


def _kernel(keys_ref, build_ref, out_ref):
    k = keys_ref[...]                                  # (1, B)
    b = build_ref[...]                                 # (1, S)
    eq = k.reshape(-1, 1) == b.reshape(1, -1)          # (B, S)
    out_ref[...] = eq.any(axis=1).reshape(1, -1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def semijoin_probe(keys: jax.Array, build: jax.Array, *,
                   interpret: bool = False) -> jax.Array:
    """keys (nb, B) int32, build (S,) int32 (pad with -1) -> bool (nb, B)."""
    nb, B = keys.shape
    S = build.shape[0]
    assert S <= MAX_BUILD, "chunk the build side upstream"
    pad = (-S) % 128
    if pad:
        build = jnp.pad(build, (0, pad), constant_values=-1)
        S += pad
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, S), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, B), jnp.bool_),
        interpret=interpret,
    )(keys.astype(jnp.int32), build.astype(jnp.int32).reshape(1, -1))
