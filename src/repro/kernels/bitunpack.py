"""Bit-unpack Pallas kernel: packed uint32 word streams -> int32 symbol lanes.

Storage packs w-bit symbols (w = 1..32) into little-endian uint32 words with
a group structure of 32 symbols per 32*w bits (core/encodings.py §9 format):
symbol s of a group starts at bit s*w, i.e. word (s*w)//32 bit (s*w)%32,
possibly straddling one word boundary.  Because a group is exactly w words,
every slot's (word, shift) pair is a compile-time constant per width -- the
kernel is 32 unrolled shift/mask lanes with static indices, no gather.

Three implementations, dispatched by kernels/ops.py like seg_preagg:

* ``bitunpack_pallas`` -- grid kernel, one (block, 512-row tile) per program,
  optionally fused with the per-block base-offset add of the delta
  reconstruction (DELTA_VALUE base / DELTA_RANGE delta_min).
* ``bitunpack_xla``    -- shift/mask reference path, byte-identical on CPU.
* ``gather_unpack``    -- random access: decode only (block, row) positions,
  the late-materialization gather for surviving rows.

TPU tiling note: the words tile's last dim is 16*w for a 512-row tile, a
multiple of 128 for w in {8, 16, 24, 32}; other widths rely on relayout (or
interpret mode off-TPU).  Symbols wider than 32 bits never reach here --
encodings fall back to byte-wide storage.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_TILE_ROWS = 512


def _mask32(width: int) -> int:
    return (1 << width) - 1 if width < 32 else 0xFFFFFFFF


def _slot_tables(width: int):
    """Static per-slot (of 32) word index / shift tables for one width."""
    slot = np.arange(32)
    bit = slot * width
    lo = bit // 32
    sh = bit % 32
    straddle = sh + width > 32
    hi = np.minimum(lo + 1, width - 1)   # clipped: only read when straddling
    hi_shift = (32 - sh) % 32
    return lo, sh, hi, hi_shift, straddle


def bitunpack_xla(words: jax.Array, width: int, block_rows: int,
                  base: Optional[jax.Array] = None) -> jax.Array:
    """XLA shift/mask unpack: words (nb, ng*width) uint32 ->
    (nb, block_rows) int32; ``base`` (nb,) is added per block when given."""
    nb, nw = words.shape
    ng = nw // width
    lo, sh, hi, hi_shift, straddle = _slot_tables(width)
    g = words.reshape(nb, ng, width)
    v = g[:, :, lo] >> jnp.asarray(sh, jnp.uint32)
    hi_part = jnp.where(jnp.asarray(straddle),
                        g[:, :, hi] << jnp.asarray(hi_shift, jnp.uint32),
                        jnp.uint32(0))
    v = (v | hi_part) & jnp.uint32(_mask32(width))
    out = v.reshape(nb, ng * 32)[:, :block_rows].astype(jnp.int32)
    if base is not None:
        out = out + base[:, None].astype(jnp.int32)
    return out


def _unpack_block(g: jax.Array, width: int) -> jax.Array:
    """(rows//32, width) uint32 words -> (rows,) uint32 symbols, unrolled."""
    lo, sh, hi, hi_shift, straddle = _slot_tables(width)
    mask = jnp.uint32(_mask32(width))
    cols = []
    for s in range(32):
        v = g[:, lo[s]] >> jnp.uint32(sh[s])
        if straddle[s]:
            v = v | (g[:, hi[s]] << jnp.uint32(hi_shift[s]))
        cols.append(v & mask)
    return jnp.stack(cols, axis=1).reshape(-1)


def _kernel(words_ref, out_ref, *, width, rows):
    g = words_ref[...].reshape(rows // 32, width)
    out_ref[...] = _unpack_block(g, width).astype(jnp.int32)[None, :]


def _kernel_base(base_ref, words_ref, out_ref, *, width, rows):
    g = words_ref[...].reshape(rows // 32, width)
    syms = _unpack_block(g, width).astype(jnp.int32)[None, :]
    out_ref[...] = syms + base_ref[...].astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("width", "block_rows", "interpret"))
def bitunpack_pallas(words: jax.Array, width: int, block_rows: int,
                     base: Optional[jax.Array] = None, *,
                     interpret: bool = False) -> jax.Array:
    """Pallas grid unpack, fused with the per-block base add when given."""
    nb, nw = words.shape
    ng = nw // width
    rows_padded = ng * 32
    tile = _TILE_ROWS if rows_padded % _TILE_ROWS == 0 else rows_padded
    nt = rows_padded // tile
    tile_words = (tile // 32) * width
    word_spec = pl.BlockSpec((1, tile_words), lambda i, j: (i, j))
    out_spec = pl.BlockSpec((1, tile), lambda i, j: (i, j))
    if base is None:
        out = pl.pallas_call(
            functools.partial(_kernel, width=width, rows=tile),
            grid=(nb, nt),
            in_specs=[word_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((nb, rows_padded), jnp.int32),
            interpret=interpret,
        )(words)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_base, width=width, rows=tile),
            grid=(nb, nt),
            in_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, 0)), word_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((nb, rows_padded), jnp.int32),
            interpret=interpret,
        )(base.reshape(nb, 1).astype(jnp.int32), words)
    return out[:, :block_rows]


def gather_unpack(words: jax.Array, width: int, b_idx: jax.Array,
                  r_idx: jax.Array) -> jax.Array:
    """Random-access unpack of symbols (b_idx[i], r_idx[i]) -> int32.

    The late-materialization path: per-element dynamic word index + shift,
    so survivor rows decode without touching the rest of the block."""
    nw = words.shape[1]
    r = r_idx.astype(jnp.uint32)
    s = r % 32
    bit = s * jnp.uint32(width)
    lo = (r // 32) * jnp.uint32(width) + bit // 32
    sh = bit % 32
    w_lo = words[b_idx, lo]
    w_hi = words[b_idx, jnp.minimum(lo + 1, jnp.uint32(nw - 1))]
    straddle = (sh + jnp.uint32(width)) > 32
    hi_shift = (jnp.uint32(32) - sh) % 32
    v = (w_lo >> sh) | jnp.where(straddle, w_hi << hi_shift, jnp.uint32(0))
    return (v & jnp.uint32(_mask32(width))).astype(jnp.int32)
