"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rle_filter_agg_ref(run_values: jax.Array, run_lengths: jax.Array,
                       lo: float, hi: float) -> jax.Array:
    """Per-block (count, sum, max) of rows with lo <= value <= hi, computed
    on RLE runs: a run contributes len rows and len*value sum.
    run_values/run_lengths: (nb, R). Returns (nb, 3) f32."""
    rv = run_values.astype(jnp.float32)
    rl = run_lengths.astype(jnp.float32)
    m = ((rv >= lo) & (rv <= hi) & (rl > 0)).astype(jnp.float32)
    cnt = (rl * m).sum(axis=1)
    s = (rv * rl * m).sum(axis=1)
    mx = jnp.where(m > 0, rv, -jnp.inf).max(axis=1)
    return jnp.stack([cnt, s, mx], axis=1)


def rle_grouped_agg_ref(run_values: jax.Array, run_lengths: jax.Array,
                        values: jax.Array, domain: int,
                        lo: float, hi: float) -> jax.Array:
    """Per-key (count, sum, min, max) over a dense domain, from RLE runs:
    a run of key k and length L contributes L rows of its value.  Runs
    with key outside [lo, hi] (or [0, domain)) or zero length drop out.
    Returns (4, domain) f32; empty keys: count 0, sum 0, min/max at the
    +-3.4e38 sentinels (matching the Pallas kernel)."""
    rv = run_values.astype(jnp.float32).reshape(-1)
    rl = run_lengths.astype(jnp.float32).reshape(-1)
    val = values.astype(jnp.float32).reshape(-1)
    m = (rv >= lo) & (rv <= hi) & (rl > 0) & (rv >= 0) & (rv < domain)
    k = jnp.clip(run_values.astype(jnp.int32).reshape(-1), 0, domain - 1)
    mf = m.astype(jnp.float32)
    cnt = jnp.zeros(domain, jnp.float32).at[k].add(rl * mf)
    s = jnp.zeros(domain, jnp.float32).at[k].add(val * rl * mf)
    pos, neg = jnp.float32(3.4e38), jnp.float32(-3.4e38)
    mn = jnp.full(domain, pos).at[k].min(jnp.where(m, val, pos))
    mx = jnp.full(domain, neg).at[k].max(jnp.where(m, val, neg))
    return jnp.stack([cnt, s, mn, mx], axis=0)


def seg_preagg_ref(keys: jax.Array, valid: jax.Array, values,
                   domain: int, aggs) -> dict:
    """Oracle for the segmented executor's packed-domain pre-aggregation
    scatter (kernels/seg_preagg.py): same contract as
    ``operators.groupby_dense`` -- keys clip into [0, domain) (negative
    keys merge into group 0 exactly like the scatter path), counts
    accumulate in int32, int sums in int32 (wrapping, exact), float sums
    in f32 (summation-order tolerance), min/max start from the dtype's
    sentinels.  ``aggs`` is the
    (out_name, in_col, kind) tuple the engine passes; ``values`` maps
    column name -> (n,) array."""
    k = jnp.clip(keys.astype(jnp.int32), 0, domain - 1)
    vi = valid.astype(jnp.int32)
    counts = jnp.zeros(domain, jnp.int32).at[k].add(vi)
    out = {"group_count": counts}
    for name, col, kind in aggs:
        if kind == "count":
            out[name] = counts
            continue
        v = values[col]
        v = v.astype(jnp.float32) if v.dtype.kind == "f" \
            else v.astype(jnp.int32)
        if kind in ("sum", "avg"):
            acc = jnp.zeros(domain, v.dtype).at[k].add(
                jnp.where(valid, v, 0))
            if kind == "avg":
                acc = acc / jnp.maximum(counts, 1)
        elif kind == "min":
            sent = jnp.iinfo(v.dtype).max if v.dtype.kind == "i" \
                else jnp.inf
            acc = jnp.full(domain, sent, v.dtype).at[k].min(
                jnp.where(valid, v, sent))
        else:
            sent = jnp.iinfo(v.dtype).min if v.dtype.kind == "i" \
                else -jnp.inf
            acc = jnp.full(domain, sent, v.dtype).at[k].max(
                jnp.where(valid, v, sent))
        out[name] = acc
    return out


def onehot_groupby_ref(keys: jax.Array, values: jax.Array,
                       domain: int) -> jax.Array:
    """Per-block dense partial GroupBy (count+sum) via one-hot contraction.
    keys (nb, B) int32, values (nb, B) f32 -> (nb, domain, 2) f32."""
    onehot = jax.nn.one_hot(keys, domain, dtype=jnp.float32)  # (nb,B,dom)
    cnt = onehot.sum(axis=1)
    s = jnp.einsum("nbd,nb->nd", onehot, values.astype(jnp.float32))
    return jnp.stack([cnt, s], axis=-1)


def bitunpack_ref(words, width: int, block_rows: int,
                  base=None) -> jax.Array:
    """Bit-by-bit oracle for the packed word-stream format
    (kernels/bitunpack.py): symbol j of a block lives in group j//32 slot
    j%32, starting at bit (j%32)*width of the group's width words.  Slow on
    purpose -- an independent reimplementation, not shared shift tables."""
    w = np.asarray(words, dtype=np.uint32)
    nb, nw = w.shape
    ng = nw // width
    out = np.zeros((nb, block_rows), dtype=np.int64)
    for b in range(nb):
        for j in range(min(block_rows, ng * 32)):
            g, s = divmod(j, 32)
            v = 0
            for i in range(width):
                bit = s * width + i
                word = int(w[b, g * width + bit // 32])
                v |= ((word >> (bit % 32)) & 1) << i
            out[b, j] = v
    if base is not None:
        out = out + np.asarray(base).astype(np.int64)[:, None]
    return jnp.asarray(out.astype(np.int32))


def delta_decode_ref(first: jax.Array, deltas: jax.Array) -> jax.Array:
    """DELTA_RANGE block decode: first (nb, 1), deltas (nb, B) ->
    values (nb, B) where v[0]=first, v[i]=v[i-1]+deltas[i]."""
    d = deltas.astype(jnp.float32)
    return first.astype(jnp.float32) + jnp.cumsum(d, axis=1) - d[:, :1]


def semijoin_probe_ref(keys: jax.Array, build: jax.Array) -> jax.Array:
    """Exact semi-join membership: keys (nb, B) int32 vs build (S,) int32
    (padded with -1) -> bool (nb, B)."""
    eq = keys[..., None] == build[None, None, :]
    return eq.any(axis=-1)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q (S, d), k/v (T, d) -> (S, d); fp32 softmax."""
    d = q.shape[-1]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / np.sqrt(d)
    if causal:
        S, T = s.shape
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
