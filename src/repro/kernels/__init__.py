"""Pallas TPU kernels for the perf-critical hot spots the paper optimizes:
encoded-data scans/aggregates (RLE, delta), the prepass GroupBy table, SIP
join filters -- plus blocked attention for the LM serving stack.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), validated against
ref.py oracles in interpret mode; ops.py is the dispatching public API.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
