"""DELTA_RANGE block decode Pallas kernel: in-VMEM prefix scan.

Decode is fused into consumers on real pipelines; standalone it shows the
structure: one block strip per grid step, cumsum along the 128-lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(first_ref, deltas_ref, out_ref):
    d = deltas_ref[...].astype(jnp.float32)            # (1, B)
    first = first_ref[...].astype(jnp.float32)         # (1, 1)
    out_ref[...] = first + jnp.cumsum(d, axis=1) - d[:, :1]


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_decode(first: jax.Array, deltas: jax.Array, *,
                 interpret: bool = False) -> jax.Array:
    """first (nb, 1), deltas (nb, B) -> values (nb, B) f32."""
    nb, B = deltas.shape
    return pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, B), jnp.float32),
        interpret=interpret,
    )(first, deltas)
