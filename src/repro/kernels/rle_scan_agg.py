"""Fused RLE scan->filter->aggregate Pallas kernel.

The paper's flagship 'operate directly on encoded data' (§6.1): a scan over
an RLE column evaluates the predicate per RUN and aggregates len-weighted
contributions -- O(runs) work and O(runs) HBM bytes instead of O(rows).
On TPU this turns the encoding ratio directly into memory-roofline headroom
(DESIGN.md hardware-adaptation table).

Tiling: grid over blocks; each step holds one block's (run_values,
run_lengths) strip in VMEM -- R is padded to a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rv_ref, rl_ref, out_ref, *, lo: float, hi: float):
    rv = rv_ref[...].astype(jnp.float32)          # (1, R)
    rl = rl_ref[...].astype(jnp.float32)
    m = ((rv >= lo) & (rv <= hi) & (rl > 0)).astype(jnp.float32)
    cnt = (rl * m).sum()
    s = (rv * rl * m).sum()
    mx = jnp.where(m > 0, rv, -jnp.inf).max()
    out_ref[0, 0] = cnt
    out_ref[0, 1] = s
    out_ref[0, 2] = mx


@functools.partial(jax.jit, static_argnames=("lo", "hi", "interpret"))
def rle_filter_agg(run_values: jax.Array, run_lengths: jax.Array, *,
                   lo: float, hi: float,
                   interpret: bool = False) -> jax.Array:
    """(nb, R) runs -> (nb, 3) [count, sum, max] of rows in [lo, hi]."""
    nb, R = run_values.shape
    pad = (-R) % 128
    if pad:
        run_values = jnp.pad(run_values, ((0, 0), (0, pad)))
        run_lengths = jnp.pad(run_lengths, ((0, 0), (0, pad)))
        R += pad
    return pl.pallas_call(
        functools.partial(_kernel, lo=lo, hi=hi),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, R), lambda i: (i, 0)),
            pl.BlockSpec((1, R), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 3), jnp.float32),
        interpret=interpret,
    )(run_values, run_lengths)
