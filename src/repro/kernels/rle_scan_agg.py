"""Fused RLE scan->filter->aggregate Pallas kernels.

The paper's flagship 'operate directly on encoded data' (§6.1): a scan over
an RLE column evaluates the predicate per RUN and aggregates len-weighted
contributions -- O(runs) work and O(runs) HBM bytes instead of O(rows).
On TPU this turns the encoding ratio directly into memory-roofline headroom
(DESIGN.md hardware-adaptation table).

Two kernels:

  * ``rle_filter_agg``  -- scalar [count, sum, max] of rows in [lo, hi]
                           (Q1-shaped: filtered scalar aggregate).
  * ``rle_grouped_agg`` -- per-key [count, sum, min, max] over a dense key
                           domain (Q2/Q3-shaped: filtered GROUP BY).  The
                           scatter is a one-hot contraction so the MXU does
                           the grouping; the (4, domain) accumulator stays
                           VMEM-resident across grid steps (the revisiting
                           output pattern: every step maps to block (0,0)).

Tiling: grid over blocks; each step holds one block's (run_values,
run_lengths) strip in VMEM -- R and domain are padded to multiples of 128
lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(rv_ref, rl_ref, out_ref, *, lo: float, hi: float):
    rv = rv_ref[...].astype(jnp.float32)          # (1, R)
    rl = rl_ref[...].astype(jnp.float32)
    m = ((rv >= lo) & (rv <= hi) & (rl > 0)).astype(jnp.float32)
    cnt = (rl * m).sum()
    s = (rv * rl * m).sum()
    mx = jnp.where(m > 0, rv, -jnp.inf).max()
    out_ref[0, 0] = cnt
    out_ref[0, 1] = s
    out_ref[0, 2] = mx


@functools.partial(jax.jit, static_argnames=("lo", "hi", "interpret"))
def rle_filter_agg(run_values: jax.Array, run_lengths: jax.Array, *,
                   lo: float, hi: float,
                   interpret: bool = False) -> jax.Array:
    """(nb, R) runs -> (nb, 3) [count, sum, max] of rows in [lo, hi]."""
    nb, R = run_values.shape
    pad = (-R) % 128
    if pad:
        run_values = jnp.pad(run_values, ((0, 0), (0, pad)))
        run_lengths = jnp.pad(run_lengths, ((0, 0), (0, pad)))
        R += pad
    return pl.pallas_call(
        functools.partial(_kernel, lo=lo, hi=hi),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, R), lambda i: (i, 0)),
            pl.BlockSpec((1, R), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 3), jnp.float32),
        interpret=interpret,
    )(run_values, run_lengths)


_NEG = -3.4e38   # min/max sentinels (finite: inf trips the VPU comparison
_POS = 3.4e38    # lowering on some targets); python floats so the kernel
                 # closes over static constants, not traced arrays


def _grouped_kernel(rv_ref, rl_ref, val_ref, out_ref, *, domain: int,
                    valid_domain: int, lo: float, hi: float):
    # the (4, domain) accumulator block revisits every grid step: zero it
    # once, then fold this block's runs in
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[0, :] = jnp.zeros((domain,), jnp.float32)
        out_ref[1, :] = jnp.zeros((domain,), jnp.float32)
        out_ref[2, :] = jnp.full((domain,), _POS, jnp.float32)
        out_ref[3, :] = jnp.full((domain,), _NEG, jnp.float32)

    rv = rv_ref[...].astype(jnp.float32)           # (1, R) key per run
    rl = rl_ref[...].astype(jnp.float32)           # (1, R) rows per run
    val = val_ref[...].astype(jnp.float32)         # (1, R) value per run
    R = rv.shape[1]
    m = (rv >= lo) & (rv <= hi) & (rl > 0)         # predicate per RUN
    m &= (rv >= 0) & (rv < valid_domain)           # keys must be in-domain
    k = jnp.clip(rv_ref[...].astype(jnp.int32), 0, domain - 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (R, domain), 1)
    hit = (k.reshape(R, 1) == cols) & m.reshape(R, 1)      # (R, domain)
    oh = hit.astype(jnp.float32)
    # count/sum land on the MXU as (1,R)@(R,domain) contractions
    cnt = (rl * m.astype(jnp.float32)) @ oh                # (1, domain)
    s = (val * rl * m.astype(jnp.float32)) @ oh
    mn = jnp.where(hit, val.reshape(R, 1), _POS).min(axis=0)
    mx = jnp.where(hit, val.reshape(R, 1), _NEG).max(axis=0)
    out_ref[0, :] += cnt[0]
    out_ref[1, :] += s[0]
    out_ref[2, :] = jnp.minimum(out_ref[2, :], mn)
    out_ref[3, :] = jnp.maximum(out_ref[3, :], mx)


@functools.partial(jax.jit, static_argnames=("domain", "lo", "hi",
                                             "interpret"))
def rle_grouped_agg(run_values: jax.Array, run_lengths: jax.Array,
                    values: jax.Array = None, *, domain: int,
                    lo: float = -3.0e38, hi: float = 3.0e38,
                    interpret: bool = False) -> jax.Array:
    """(nb, R) runs -> (4, domain) per-key [count, sum, min, max].

    ``run_values`` carries the (dense, non-negative) group key per run;
    ``values`` the per-run aggregate value (every row of a run contributes
    that value -- defaults to the key itself, the C-Store 'aggregate the
    sort leader' case).  Runs whose key falls outside [lo, hi] or with
    zero length are dropped, so padding runs never contribute.  Keys with
    no surviving rows report count 0, sum 0, min +BIG, max -BIG.
    """
    if values is None:
        values = run_values
    nb, R = run_values.shape
    padr = (-R) % 128
    if padr:
        run_values = jnp.pad(run_values, ((0, 0), (0, padr)))
        run_lengths = jnp.pad(run_lengths, ((0, 0), (0, padr)))
        values = jnp.pad(values, ((0, 0), (0, padr)))
        R += padr
    dpad = -(-domain // 128) * 128
    out = pl.pallas_call(
        functools.partial(_grouped_kernel, domain=dpad,
                          valid_domain=domain, lo=lo, hi=hi),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, R), lambda i: (i, 0)),
            pl.BlockSpec((1, R), lambda i: (i, 0)),
            pl.BlockSpec((1, R), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((4, dpad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((4, dpad), jnp.float32),
        interpret=interpret,
    )(run_values, run_lengths, values)
    return out[:, :domain]
