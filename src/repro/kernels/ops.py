"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy (honest about the runtime, per DESIGN.md):
  * on TPU           -> compiled Pallas kernels
  * on CPU (tests)   -> interpret=True (kernel body executed in Python,
                        validating the kernel logic itself)
  * ``force_ref=True`` -> the pure-jnp oracle (kernels/ref.py)

The dry-run lowers the XLA-path models (use_pallas=False) so the 512-device
CPU compile succeeds; on real TPU hardware the same ops.py calls flip to the
kernels with no model changes.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .bitunpack import bitunpack_pallas, bitunpack_xla
from .delta_decode import delta_decode as _delta_decode
from .flash_attention import flash_attention as _flash_attention
from .hash_groupby import onehot_groupby as _onehot_groupby
from .rle_scan_agg import (rle_filter_agg as _rle_filter_agg,
                           rle_grouped_agg as _rle_grouped_agg)
from .sip_probe import semijoin_probe as _semijoin_probe


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pallas_enabled(env: str) -> bool:
    """Single decode-kernel gate (mirrors kernels/seg_preagg.py): compiled
    Pallas on TPU, opt-in interpret mode via env var, XLA path otherwise."""
    return _on_tpu() or os.environ.get(env, "") == "pallas"


def rle_filter_agg(run_values, run_lengths, *, lo, hi, force_ref=False):
    if force_ref:
        return ref.rle_filter_agg_ref(run_values, run_lengths, lo, hi)
    return _rle_filter_agg(run_values, run_lengths, lo=lo, hi=hi,
                           interpret=not _on_tpu())


def rle_grouped_agg(run_values, run_lengths, values=None, *, domain,
                    lo=-3.0e38, hi=3.0e38, force_ref=False):
    """Per-key [count, sum, min, max] over a dense domain, straight from
    RLE runs (the §6.1 grouped 'operate on encoded data' path)."""
    if force_ref:
        return ref.rle_grouped_agg_ref(
            run_values, run_lengths,
            run_values if values is None else values, domain, lo, hi)
    return _rle_grouped_agg(run_values, run_lengths, values,
                            domain=domain, lo=lo, hi=hi,
                            interpret=not _on_tpu())


def onehot_groupby(keys, values, *, domain, force_ref=False):
    if force_ref:
        return ref.onehot_groupby_ref(keys, values, domain)
    return _onehot_groupby(keys, values, domain=domain,
                           interpret=not _on_tpu())


def bitunpack(words, width, block_rows, base=None, *, force_ref=False):
    """Unpack w-bit symbols from packed uint32 words -> (nb, block_rows)
    int32, optionally fused with a per-block base add (delta/dict
    reconstruction).  See kernels/bitunpack.py for the word format."""
    if force_ref:
        return ref.bitunpack_ref(words, width, block_rows, base)
    if _pallas_enabled("REPRO_BITUNPACK"):
        return bitunpack_pallas(words, width, block_rows, base,
                                interpret=not _on_tpu())
    return bitunpack_xla(words, width, block_rows, base)


def delta_decode(first, deltas, *, force_ref=False):
    if force_ref:
        return ref.delta_decode_ref(first, deltas)
    if _pallas_enabled("REPRO_DELTA_DECODE"):
        return _delta_decode(first, deltas, interpret=not _on_tpu())
    # XLA path (same math as the kernel body, byte-identical on CPU)
    d = deltas.astype(jnp.float32)
    return first.astype(jnp.float32) + jnp.cumsum(d, axis=1) - d[:, :1]


def semijoin_probe(keys, build, *, force_ref=False):
    if force_ref:
        return ref.semijoin_probe_ref(keys, build)
    return _semijoin_probe(keys, build, interpret=not _on_tpu())


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    force_ref=False):
    """Batched/multi-head wrapper: q (..., S, d), k/v (..., T, d)."""
    if force_ref:
        fn = functools.partial(ref.flash_attention_ref, causal=causal)
    else:
        fn = functools.partial(_flash_attention, causal=causal, bq=bq,
                               bk=bk, interpret=not _on_tpu())
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
