"""Blocked causal attention (flash) Pallas kernel -- the LM stack's prefill
hot spot.

Grid: (q_blocks,) outer; the kernel loops KV blocks with an online softmax
(running max / normalizer in fp32), touching O(Bq*Bk) VMEM instead of the
O(S*T) scores matrix. Causal blocks above the diagonal are skipped by
masking; dims are multiples of 128 for MXU alignment.

The XLA-path twin used by the dry-run is models/attention.py:
attend_chunked (same contraction order); this kernel swaps in through
kernels/ops.py on real TPUs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, causal: bool):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)                 # (bq, d)
    d = q.shape[-1]
    T = k_ref.shape[0]
    n_kv = T // bk
    scale = 1.0 / np.sqrt(d)

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)              # (bk, d)
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))
                    ).astype(jnp.float32)
        s = (q @ k.T) * scale                          # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    # causal: kv blocks beyond this q block's diagonal contribute nothing
    upper = (qi + 1) * bq if causal else T
    n_iter = (upper + bk - 1) // bk if causal else n_kv
    acc, m, l = jax.lax.fori_loop(0, n_iter, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 128, bk: int = 128, causal: bool = True,
                    interpret: bool = False) -> jax.Array:
    """Single-head attention: q (S, d), k/v (T, d) -> (S, d).
    Heads/batch are vmapped by the caller (ops.py)."""
    S, d = q.shape
    T = k.shape[0]
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal),
        grid=(S // bq,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((T, d), lambda i: (0, 0)),
            pl.BlockSpec((T, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
