"""Prepass GroupBy Pallas kernel: the paper's 'L1-cache-sized hash table'
pre-aggregation (§6.1), rethought for the MXU.

TPU adaptation (DESIGN.md): instead of a chasing-pointers hash table, the
VMEM-resident dense table is built by a ONE-HOT CONTRACTION -- the (B x
domain) one-hot of the keys hits the systolic array as a matmul, producing
per-block (count, sum) partials that a cheap tree-combine finishes. Domain
is capped so the table tiles VMEM (<= 1024 here), exactly mirroring the
paper's 'when the table fills, emit partials and start afresh'.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, vals_ref, out_ref, *, domain: int):
    k = keys_ref[...]                                  # (1, B) int32
    v = vals_ref[...].astype(jnp.float32)              # (1, B)
    B = k.shape[1]
    # one-hot via broadcasted iota compare: (B, domain)
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, domain), 1)
    onehot = (k.reshape(B, 1) == cols).astype(jnp.float32)
    cnt = jnp.ones((1, B), jnp.float32) @ onehot       # (1, domain)  MXU
    s = v @ onehot                                     # (1, domain)  MXU
    out_ref[0, :, 0] = cnt[0]
    out_ref[0, :, 1] = s[0]


@functools.partial(jax.jit, static_argnames=("domain", "interpret"))
def onehot_groupby(keys: jax.Array, values: jax.Array, *, domain: int,
                   interpret: bool = False) -> jax.Array:
    """keys/values (nb, B) -> per-block partials (nb, domain, 2)."""
    assert domain <= 1024, "prepass table must fit VMEM; combine upstream"
    nb, B = keys.shape
    return pl.pallas_call(
        functools.partial(_kernel, domain=domain),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, domain, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, domain, 2), jnp.float32),
        interpret=interpret,
    )(keys.astype(jnp.int32), values)
