"""Packed-domain pre-aggregation scatter as a Pallas grid kernel.

The segmented executor's shard-local GroupBy (engine/segmented.py) packs
the group keys into one dense domain and scatters each aggregate into a
per-shard partial vector -- ``operators.groupby_dense``.  On TPU the XLA
scatter serializes; this kernel re-expresses it as the one-hot /
reduction shape the MXU+VPU like (same trick as kernels/hash_groupby.py),
streaming row blocks through VMEM and accumulating every aggregate's
(1, domain) partial in place across grid steps.

Contract: matches ``operators.groupby_dense`` under the default 32-bit
runtime -- keys clip into [0, domain) (negative keys merge into group 0),
counts and int sums accumulate in int32 (wrapping, exact), float sums in
f32 (to summation-order tolerance), min/max start from the dtype's
sentinels.  The oracle is
``kernels.ref.seg_preagg_ref``; ``tests/test_kernels_seg_preagg.py``
checks kernel == oracle == groupby_dense.

Dispatch (``seg_preagg``): the kernel runs when compiled for TPU (or
forced via ``REPRO_SEG_PREAGG=pallas``, interpreted elsewhere) and the
packed domain fits the VMEM budget; every other shape keeps the XLA
scatter, so CPU differential tests exercise byte-identical code by
default.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_BLOCK = 256             # rows per grid step
_DOMAIN_CAP = 1024       # (B, domain) one-hot must sit in VMEM


def _use_kernel(domain: int, kinds: Tuple[str, ...]) -> bool:
    """Kernel eligibility: small packed domain, plain aggregates, 32-bit
    runtime, and a backend that wants it (TPU, or the env override)."""
    if domain > _DOMAIN_CAP or jax.config.jax_enable_x64:
        return False
    if not all(k in ("count", "sum", "min", "max") for k in kinds):
        return False
    if jax.default_backend() == "tpu":
        return True
    return os.environ.get("REPRO_SEG_PREAGG", "") == "pallas"


def _sentinel(dt, hi: bool):
    if jnp.issubdtype(dt, jnp.integer):
        info = jnp.iinfo(dt)
        return info.max if hi else info.min
    return jnp.inf if hi else -jnp.inf


def _make_kernel(domain: int, kinds: Tuple[str, ...], block: int):
    n_vals = len(kinds)

    def kernel(*refs):
        keys_ref, mask_ref = refs[0], refs[1]
        vrefs = refs[2:2 + n_vals]
        cref = refs[2 + n_vals]
        orefs = refs[3 + n_vals:]
        i = pl.program_id(0)

        def accumulate(oref, part, comb):
            @pl.when(i == 0)
            def _init():
                oref[0] = part

            @pl.when(i > 0)
            def _fold():
                oref[0] = comb(oref[0], part)

        k = jnp.clip(keys_ref[0], 0, domain - 1)
        m = mask_ref[0] != 0
        cols = jax.lax.broadcasted_iota(jnp.int32, (block, domain), 1)
        oh = (k[:, None] == cols) & m[:, None]          # (B, domain)
        cnt = oh.astype(jnp.int32).sum(axis=0)
        accumulate(cref, cnt, jnp.add)
        for j, kind in enumerate(kinds):
            if kind == "count":
                accumulate(orefs[j], cnt, jnp.add)
                continue
            v = vrefs[j][0]
            if kind == "sum":
                part = jnp.where(oh, v[:, None],
                                 jnp.zeros((), v.dtype)).sum(axis=0)
                accumulate(orefs[j], part, jnp.add)
            elif kind == "min":
                sent = _sentinel(v.dtype, True)
                part = jnp.where(oh, v[:, None], sent).min(axis=0)
                accumulate(orefs[j], part, jnp.minimum)
            else:
                sent = _sentinel(v.dtype, False)
                part = jnp.where(oh, v[:, None], sent).max(axis=0)
                accumulate(orefs[j], part, jnp.maximum)

    return kernel


@functools.partial(jax.jit, static_argnames=("domain", "kinds",
                                             "interpret"))
def _preagg_call(keys, mask, vals, domain: int, kinds: Tuple[str, ...],
                 interpret: bool):
    """keys/mask (n,) padded to a _BLOCK multiple by the caller; vals is
    one prepared (n,) array per aggregate, aligned with ``kinds``."""
    n = keys.shape[0]
    nb = n // _BLOCK
    keys2 = keys.reshape(nb, _BLOCK)
    mask2 = mask.reshape(nb, _BLOCK)
    vals2 = tuple(v.reshape(nb, _BLOCK) for v in vals)
    row_spec = pl.BlockSpec((1, _BLOCK), lambda i: (i, 0))
    acc_spec = pl.BlockSpec((1, domain), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((1, domain), jnp.int32)]
    for kind, v in zip(kinds, vals2):
        dt = jnp.int32 if kind == "count" else v.dtype
        out_shape.append(jax.ShapeDtypeStruct((1, domain), dt))
    outs = pl.pallas_call(
        _make_kernel(domain, kinds, _BLOCK),
        grid=(nb,),
        in_specs=[row_spec] * (2 + len(vals2)),
        out_specs=[acc_spec] * len(out_shape),
        out_shape=out_shape,
        interpret=interpret,
    )(keys2, mask2, *vals2)
    return tuple(o.reshape(domain) for o in outs)


def seg_preagg_pallas(keys, valid, values: Dict[str, jax.Array],
                      domain: int, aggs, *, interpret: bool = True):
    """Run the kernel unconditionally (tests drive this in interpret
    mode); same signature and outputs as ``operators.groupby_dense``."""
    kinds = tuple(a[2] for a in aggs)
    n = keys.shape[0]
    pad = (-n) % _BLOCK
    k = jnp.pad(keys.astype(jnp.int32), (0, pad))
    m = jnp.pad(valid.astype(jnp.int32), (0, pad))
    vals = []
    for _name, col, kind in aggs:
        if kind == "count":
            vals.append(k)          # placeholder, never read
            continue
        v = values[col]
        v = v.astype(jnp.float32) if v.dtype.kind == "f" \
            else v.astype(jnp.int32)
        vals.append(jnp.pad(v, (0, pad)))
    outs = _preagg_call(k, m, tuple(vals), int(domain), kinds,
                        bool(interpret))
    res = {"group_count": outs[0]}
    for (name, _col, _kind), o in zip(aggs, outs[1:]):
        res[name] = o
    return res


def seg_preagg(keys, valid, values: Dict[str, jax.Array], domain: int,
               aggs, *, force_ref: bool = False):
    """Drop-in for ``operators.groupby_dense`` inside the segmented
    executor's fused shard program: Pallas kernel when eligible
    (``_use_kernel``), XLA scatter otherwise, jnp oracle on demand."""
    if force_ref:
        return ref.seg_preagg_ref(keys, valid, values, domain, aggs)
    kinds = tuple(a[2] for a in aggs)
    if _use_kernel(int(domain), kinds):
        return seg_preagg_pallas(keys, valid, values, int(domain), aggs,
                                 interpret=jax.default_backend() != "tpu")
    from ..engine import operators as ops
    return ops.groupby_dense(keys.astype(jnp.int32), valid, values,
                             int(domain), aggs)
