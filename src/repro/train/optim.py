"""AdamW + cosine schedule + global-norm clipping, in plain jax.

Optimizer moments inherit the parameter sharding (params are already 2-D
sharded 'data' x 'model' on their embed/head dims, so m/v are ZeRO-sharded
for free -- see distributed/sharding.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return OptState(zeros, jax.tree.map(lambda p: jnp.zeros_like(p), params),
                    jnp.zeros((), jnp.int32))


def lr_schedule(rc: RunConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(rc.warmup_steps, 1), 1.0)
    t = jnp.clip((step - rc.warmup_steps) /
                 jnp.maximum(rc.total_steps - rc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return rc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(rc: RunConfig, params, grads,
                 opt: OptState) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, rc.grad_clip)
    step = opt.step + 1
    lr = lr_schedule(rc, step)
    b1, b2 = rc.beta1, rc.beta2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + rc.eps) + rc.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, opt.m, opt.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_m, new_v, step), \
        {"lr": lr, "grad_norm": gnorm}
