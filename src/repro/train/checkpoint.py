"""Epoch-based, K-safe checkpointing (the paper's §5 semantics applied to
training state -- DESIGN.md §3 integration).

* A checkpoint commit = an epoch. The Last Good Epoch is the newest
  checkpoint fully persisted on every shard; recovery resumes from it and
  replays the (deterministic, epoch-pinned) data stream since.
* K-safety: every state shard is written to its primary directory AND a
  ring-offset buddy directory; losing one location recovers from the other
  (restore_shard tries primary, falls back to buddy).
* AHM: checkpoints older than the Ancient History Mark are garbage
  collected; the AHM never advances past the cluster LGE.
* Saves are atomic (tmp + rename) and shard-parallel in a real deployment;
  data+epoch is the whole log -- no separate WAL, exactly the paper.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class CheckpointStore:
    root: pathlib.Path
    n_shards: int
    k_safety: int = 1

    def __post_init__(self):
        self.root = pathlib.Path(self.root)
        self.root.mkdir(parents=True, exist_ok=True)

    # ---------------------------------------------------------- layout --

    def _dir(self, epoch: int, shard: int, buddy: bool) -> pathlib.Path:
        kind = "buddy" if buddy else "primary"
        host = (shard + 1) % self.n_shards if buddy else shard
        return self.root / f"epoch_{epoch:08d}" / f"node_{host}" / \
            f"{kind}_shard_{shard}"

    # ------------------------------------------------------------ save --

    def save_shard(self, epoch: int, shard: int, state: Dict[str, Any]):
        """Persist one shard's pytree to primary + buddy locations."""
        flat, treedef = jax.tree.flatten(state)
        arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(flat)}
        for buddy in ([False, True] if self.k_safety >= 1 else [False]):
            d = self._dir(epoch, shard, buddy)
            d.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=d)
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, d / "state.npz")

    def commit_epoch(self, epoch: int, meta: Optional[Dict] = None):
        """Mark the epoch complete (the LGE advances to it)."""
        d = self.root / f"epoch_{epoch:08d}"
        (d / "COMMIT").write_text(json.dumps(
            {"epoch": epoch, **(meta or {})}))

    # --------------------------------------------------------- restore --

    def last_good_epoch(self) -> Optional[int]:
        epochs = sorted(
            int(p.name.split("_")[1]) for p in self.root.glob("epoch_*")
            if (p / "COMMIT").exists())
        return epochs[-1] if epochs else None

    def restore_shard(self, epoch: int, shard: int,
                      template: Dict[str, Any], *,
                      lost_nodes: Tuple[int, ...] = ()) -> Dict[str, Any]:
        """Load one shard, preferring the primary copy; if its node is
        'lost', read the buddy (paper §5.2 buddy recovery)."""
        for buddy in (False, True):
            d = self._dir(epoch, shard, buddy)
            host = int(d.parent.name.split("_")[1])
            if host in lost_nodes:
                continue
            f = d / "state.npz"
            if f.exists():
                data = np.load(f)
                flat, treedef = jax.tree.flatten(template)
                loaded = [data[f"leaf_{i}"] for i in range(len(flat))]
                return jax.tree.unflatten(treedef, loaded)
        raise FileNotFoundError(
            f"shard {shard} of epoch {epoch} unavailable "
            f"(lost nodes: {lost_nodes}) -- K-safety exceeded")

    # -------------------------------------------------------------- gc --

    def advance_ahm(self, ahm_epoch: int) -> List[int]:
        """Drop checkpoints strictly older than the AHM; never the newest
        committed one."""
        lge = self.last_good_epoch()
        dropped = []
        for p in sorted(self.root.glob("epoch_*")):
            e = int(p.name.split("_")[1])
            if e < min(ahm_epoch, lge if lge is not None else e + 1):
                shutil.rmtree(p)
                dropped.append(e)
        return dropped


def shard_state(state: Dict[str, Any], shard: int,
                n_shards: int) -> Dict[str, Any]:
    """Slice a replicated state pytree into shard ``shard`` along each
    leaf's largest divisible axis (the simulation's stand-in for the real
    sharded save where each host writes its addressable shards)."""
    def slc(x):
        x = np.asarray(x)
        for ax, size in enumerate(x.shape):
            if size % n_shards == 0 and size >= n_shards:
                w = size // n_shards
                sl = [slice(None)] * x.ndim
                sl[ax] = slice(shard * w, (shard + 1) * w)
                return x[tuple(sl)]
        return x if shard == 0 else np.zeros((0,), x.dtype)
    return jax.tree.map(slc, state)


def unshard_state(shards: List[Dict[str, Any]],
                  template: Dict[str, Any]) -> Dict[str, Any]:
    """Reassemble the full pytree from per-shard slices (leaf-wise, using
    the template to find the sliced axis)."""
    flat_t, treedef = jax.tree.flatten(template)
    flats = [jax.tree.flatten(s)[0] for s in shards]
    out = []
    for i, t in enumerate(flat_t):
        t = np.asarray(t)
        parts = [np.asarray(f[i]) for f in flats]
        if parts[0].shape == t.shape:
            out.append(parts[0])
            continue
        ax = next(a for a in range(t.ndim)
                  if parts[0].shape[a] != t.shape[a])
        out.append(np.concatenate(parts, axis=ax))
    return jax.tree.unflatten(treedef, out)
