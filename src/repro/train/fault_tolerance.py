"""Fault tolerance and distributed-optimization tricks for the training
loop, built on the Vertica mechanisms (DESIGN.md §3):

* node failure  -> restore the lost rank's state shard from its buddy
                   checkpoint copy + deterministic replay of the
                   epoch-pinned data stream since the LGE,
* elastic scale -> rebalance data shards wholesale (local segments) and
                   re-split the global batch over the new DP size,
* stragglers    -> quorum gradient commit: a step commits once a quorum of
                   DP ranks contributed; laggard contributions are dropped
                   (the paper's commit-on-quorum, no 2PC),
* gradient compression -> DELTA+narrow-int encoding of the DP all-reduce
                   payload (the §3.4 encodings applied to gradients).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Quorum gradient commit (straggler mitigation)
# ---------------------------------------------------------------------------

def quorum_combine(rank_grads: Sequence[Optional[Dict]], *,
                   quorum_frac: float = 0.5) -> Tuple[Dict, int]:
    """Average gradients from the ranks that reported (None = straggler /
    failed). Raises if fewer than a quorum contributed -- identical policy
    to the paper's cluster commit."""
    live = [g for g in rank_grads if g is not None]
    need = int(np.floor(len(rank_grads) * quorum_frac)) + 1
    if len(live) < need:
        raise RuntimeError(
            f"gradient quorum lost: {len(live)}/{len(rank_grads)} "
            f"(need {need})")
    scale = 1.0 / len(live)
    out = jax.tree.map(lambda *xs: sum(xs) * scale, *live)
    return out, len(live)


# ---------------------------------------------------------------------------
# Gradient compression (paper §3.4 encodings on the wire)
# ---------------------------------------------------------------------------

def compress_grads_int8(grads: Dict) -> Tuple[Dict, Dict]:
    """Per-leaf symmetric int8 quantization (the all-reduce payload shrinks
    4x vs f32; scales travel alongside, 8 bytes per leaf)."""
    payload, scales = {}, {}

    def enc(path, g):
        g = np.asarray(g, np.float32)
        s = float(np.max(np.abs(g))) / 127.0 if g.size else 1.0
        s = s or 1.0
        q = np.clip(np.round(g / s), -127, 127).astype(np.int8)
        return q, s

    flat, treedef = jax.tree.flatten(grads)
    qs, ss = [], []
    for g in flat:
        q, s = enc(None, g)
        qs.append(q)
        ss.append(s)
    return ({"q": qs, "tree": treedef}, {"s": ss})


def decompress_grads_int8(payload: Dict, scales: Dict) -> Dict:
    flat = [q.astype(np.float32) * s
            for q, s in zip(payload["q"], scales["s"])]
    return jax.tree.unflatten(payload["tree"], flat)


def compressed_allreduce(rank_grads: List[Dict]) -> Dict:
    """Simulated ring all-reduce with int8 payloads: each rank's
    contribution is quantized before the wire, accumulated in fp32."""
    acc = None
    for g in rank_grads:
        p, s = compress_grads_int8(g)
        d = decompress_grads_int8(p, s)
        acc = d if acc is None else jax.tree.map(np.add, acc, d)
    return jax.tree.map(lambda x: x / len(rank_grads), acc)


# ---------------------------------------------------------------------------
# Failure / elasticity simulation harness
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DPSimulator:
    """Simulated data-parallel group: per-rank state shards with buddy
    recovery and elastic resize, driving a real train_step."""

    world: int
    ranks_up: List[bool] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.ranks_up:
            self.ranks_up = [True] * self.world

    def fail(self, rank: int):
        self.ranks_up[rank] = False

    def recover(self, rank: int):
        self.ranks_up[rank] = True

    @property
    def n_up(self) -> int:
        return sum(self.ranks_up)

    def split_batch(self, batch: Dict[str, np.ndarray]
                    ) -> List[Optional[Dict[str, np.ndarray]]]:
        """Re-split the global batch over live ranks (elasticity: the
        global batch is invariant; per-rank share changes)."""
        up = [i for i, ok in enumerate(self.ranks_up) if ok]
        n = len(next(iter(batch.values())))
        per = n // len(up)
        out: List[Optional[Dict]] = [None] * self.world
        for j, r in enumerate(up):
            out[r] = {k: v[j * per: (j + 1) * per] for k, v in
                      batch.items()}
        return out
