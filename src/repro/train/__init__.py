from .optim import OptState, adamw_update, init_opt_state, lr_schedule
from .train_step import (abstract_train_state, init_train_state,
                         make_train_step, train_state_axes)

__all__ = ["OptState", "adamw_update", "init_opt_state", "lr_schedule",
           "abstract_train_state", "init_train_state", "make_train_step",
           "train_state_axes"]
