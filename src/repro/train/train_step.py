"""The jitted training step + its sharding specs.

``make_train_step(model, rc)`` returns (step_fn, state_specs, batch_specs)
where specs are logical-axis trees resolvable against any mesh via
distributed.sharding rules. Gradient accumulation (rc.microbatches > 1)
runs a lax.scan over microbatch slices, trading step latency for activation
memory -- one of the §Perf hillclimb levers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import RunConfig
from ..models.model import Model
from ..models.params import logical_axes
from .optim import OptState, adamw_update, init_opt_state


def init_train_state(model: Model, key) -> Dict[str, Any]:
    from ..models.params import init_params
    params = init_params(model.decls, key, jnp.float32)
    return {"params": params, "opt": init_opt_state(params)}


def abstract_train_state(model: Model) -> Dict[str, Any]:
    from ..models.params import abstract_params
    params = abstract_params(model.decls, jnp.float32)
    zeros = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                         params)
    return {"params": params,
            "opt": OptState(zeros, jax.tree.map(lambda s: s, zeros),
                            jax.ShapeDtypeStruct((), jnp.int32))}


def train_state_axes(model: Model):
    """Logical-axis tree matching the train state structure."""
    p_axes = logical_axes(model.decls)
    return {"params": p_axes,
            "opt": OptState(p_axes, jax.tree.map(lambda a: a, p_axes,
                                                 is_leaf=_is_axes),
                            ())}


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                        for a in x)


def make_train_step(model: Model, rc: RunConfig):
    nm = rc.microbatches

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step_fn(state, batch):
        params, opt = state["params"], state["opt"]
        if nm <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(grads_acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return jax.tree.map(jnp.add, grads_acc, g), l
            split = jax.tree.map(
                lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zeros, split)
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = losses.mean()
        new_params, new_opt, om = adamw_update(rc, params, grads, opt)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return step_fn
