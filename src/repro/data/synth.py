"""Synthetic data generators.

* token corpus -- Zipfian LM tokens in documents (for the columnar token
  store and the training examples)
* meter data  -- the paper's §8.2.2 schema (metric, meter, ts, value),
  regenerated with the published cardinalities/periodicities so Table 4's
  compression experiment is reproducible at any scale
* star schema -- LINEITEM/ORDERS-style fact+dim tables for the §8.1
  C-Store query harness
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(vocab, size=n, p=p).astype(np.int64)


def token_corpus(n_docs: int, doc_len: int, vocab: int,
                 seed: int = 0) -> Dict[str, np.ndarray]:
    """(doc_id, pos, token) rows -- the token store's logical table."""
    rng = np.random.default_rng(seed)
    n = n_docs * doc_len
    return {
        "doc_id": np.repeat(np.arange(n_docs, dtype=np.int64), doc_len),
        "pos": np.tile(np.arange(doc_len, dtype=np.int64), n_docs),
        "token": zipf_tokens(rng, n, vocab),
    }


def meter_data(n_rows: int, seed: int = 0, *, n_metrics: int = 300,
               n_meters: int = 2000) -> Dict[str, np.ndarray]:
    """Paper §8.2.2: 'a few hundred metrics, a couple of thousand meters,
    readings every 5/10/60 min, 64-bit float values with trends'."""
    rng = np.random.default_rng(seed)
    rows_per_series = max(1, n_rows // (n_metrics * n_meters))
    metric, meter, ts, value = [], [], [], []
    periods = np.array([300, 600, 3600])
    made = 0
    for m in range(n_metrics):
        period = periods[m % 3]
        n_m = min(n_meters, max(1, (n_rows - made) //
                                (rows_per_series * (n_metrics - m))
                                // max(rows_per_series, 1) + 1))
        for mt in range(n_meters):
            k = rows_per_series
            if made + k > n_rows:
                k = n_rows - made
            if k <= 0:
                break
            metric.append(np.full(k, m, np.int64))
            meter.append(np.full(k, mt, np.int64))
            ts.append(1_600_000_000 + period * np.arange(k, dtype=np.int64))
            kind = m % 3
            if kind == 0:      # mostly zeros (paper: 'lots of 0 values')
                v = np.where(rng.random(k) < 0.9, 0.0,
                             rng.normal(50, 5, k).round(1))
            elif kind == 1:    # gradual trend
                v = np.round(100 + 0.1 * np.arange(k) +
                             rng.normal(0, 0.05, k), 2)
            else:              # noisy (but quantized: meters report
                #                  fixed-precision readings)
                v = np.round(rng.normal(0, 100, k), 2)
            value.append(v)
            made += k
        if made >= n_rows:
            break
    return {"metric": np.concatenate(metric)[:n_rows],
            "meter": np.concatenate(meter)[:n_rows],
            "ts": np.concatenate(ts)[:n_rows],
            "value": np.concatenate(value)[:n_rows]}


def star_schema(n_fact: int, n_dim: int, seed: int = 0
                ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """LINEITEM-ish fact + ORDERS-ish dimension (C-Store §8.1 harness)."""
    rng = np.random.default_rng(seed)
    fact = {
        "l_orderkey": rng.integers(0, n_dim, n_fact).astype(np.int64),
        "l_suppkey": rng.integers(0, 100, n_fact).astype(np.int64),
        "l_shipdate": np.sort(rng.integers(0, 365, n_fact)).astype(np.int64),
        "l_qty": rng.integers(1, 50, n_fact).astype(np.int64),
        "l_extprice": np.round(rng.normal(1000, 200, n_fact), 2),
    }
    dim = {
        "o_orderkey": np.arange(n_dim, dtype=np.int64),
        "o_custkey": rng.integers(0, max(10, n_dim // 10),
                                  n_dim).astype(np.int64),
        "o_orderdate": rng.integers(0, 365, n_dim).astype(np.int64),
    }
    return fact, dim
