from .synth import meter_data, star_schema, token_corpus, zipf_tokens
from .tokenstore import TokenStore

__all__ = ["TokenStore", "meter_data", "star_schema", "token_corpus",
           "zipf_tokens"]
