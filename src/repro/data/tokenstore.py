"""Columnar token store: the training corpus as a Vertica projection.

Integration story (DESIGN.md §3): training data is a table
(doc_id, pos, token) with a super projection sorted by (doc_id, pos) and
segmented by HASH(doc_id) across the 'data' mesh axis, so

  * bulk ingest goes through WOS -> tuple mover (loading never blocks
    reading: I-lock semantics),
  * a *data epoch* pins an exactly-reproducible training stream (MVCC
    snapshot: re-reading epoch E yields identical batches after any amount
    of later ingest -- this is how restarts resume deterministically),
  * K-safe buddies + elastic rebalance come for free when data-parallel
    ranks fail or the cluster resizes,
  * the (doc_id, pos) sort makes 'token' delta/RLE-compressible and makes
    sequence reconstruction a positional read, not a shuffle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..core import (ColumnDef, SQLType, TableSchema, VerticaDB)


@dataclasses.dataclass
class TokenStore:
    db: VerticaDB
    table: str = "corpus"
    doc_len: int = 0

    @staticmethod
    def create(n_nodes: int = 4, *, block_rows: int = 4096,
               k_safety: int = 1) -> "TokenStore":
        db = VerticaDB(n_nodes=n_nodes, k_safety=k_safety,
                       block_rows=block_rows)
        schema = TableSchema("corpus", (
            ColumnDef("doc_id"), ColumnDef("pos"), ColumnDef("token")))
        db.create_table(schema, sort_order=("doc_id", "pos"),
                        segment_by=("doc_id",))
        return TokenStore(db)

    def ingest(self, rows: Dict[str, np.ndarray], *,
               direct_to_ros: bool = True) -> int:
        """Bulk load a batch of documents; returns the commit (data) epoch."""
        t = self.db.begin(direct_to_ros=direct_to_ros)
        self.db.insert(t, self.table, rows)
        epoch = self.db.commit(t)
        self.db.run_tuple_mover()
        if self.doc_len == 0:
            self.doc_len = int(rows["pos"].max()) + 1
        return epoch

    def n_tokens(self, as_of: Optional[int] = None) -> int:
        return len(self.db.read_table(self.table, as_of=as_of)["token"])

    def sequences(self, seq_len: int, *, as_of: Optional[int] = None
                  ) -> np.ndarray:
        """Materialize (n_seqs, seq_len) token matrix at a data epoch.

        Reads the projection in (doc_id, pos) order -- a positional
        reconstruction, no shuffle -- then packs documents into fixed
        training sequences."""
        rows = self.db.read_table(self.table, as_of=as_of)
        order = np.lexsort((rows["pos"], rows["doc_id"]))
        tokens = rows["token"][order]
        n = (len(tokens) // seq_len) * seq_len
        return tokens[:n].reshape(-1, seq_len)

    def batches(self, batch_size: int, seq_len: int, *,
                as_of: Optional[int] = None, seed: int = 0,
                drop_last: bool = True) -> Iterator[Dict[str, np.ndarray]]:
        """Deterministic epoch-pinned batch stream: (tokens, labels)."""
        seqs = self.sequences(seq_len + 1, as_of=as_of)
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(seqs))
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            take = seqs[idx[i: i + batch_size]]
            yield {"tokens": take[:, :-1].astype(np.int32),
                   "labels": take[:, 1:].astype(np.int32)}

    def shard_batches(self, rank: int, world: int, batch_size: int,
                      seq_len: int, **kw) -> Iterator[Dict[str, np.ndarray]]:
        """Per-data-parallel-rank stream: rank r takes every w-th batch
        (segment-aligned sharding would read only local segments on a real
        cluster; the simulation keeps the global-stream semantics)."""
        for i, b in enumerate(self.batches(batch_size, seq_len, **kw)):
            if i % world == rank:
                yield b

    def storage_stats(self) -> Dict[str, float]:
        return self.db.storage_report()[f"{self.table}_super"]
