"""A miniature ``hypothesis`` stand-in for environments without the real
package (tests/conftest.py installs it ONLY when ``import hypothesis``
fails, so an installed hypothesis always wins).

Implements just the surface our tests use -- ``given``, ``settings`` and
the strategies ``integers, floats, lists, tuples, just, sampled_from``
plus ``.map`` / ``.flatmap`` / ``.filter`` -- by drawing a deterministic
pseudo-random sample of ``max_examples`` inputs per test.  No adaptive
search, no shrinking: strictly weaker than hypothesis, but the properties
themselves still run (and the suite no longer fails at collection).
"""
from __future__ import annotations

import sys
import types
import zlib
from typing import Any, Callable, List, Optional, Sequence

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is just a seeded sampler: draw(rng) -> value."""

    def __init__(self, draw: Callable[["_Rng"], Any]):
        self._draw = draw

    def draw(self, rng: "_Rng") -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rng: f(self.draw(rng)))

    def flatmap(self, f: Callable[[Any], "_Strategy"]) -> "_Strategy":
        return _Strategy(lambda rng: f(self.draw(rng)).draw(rng))

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        def _draw(rng):
            for _ in range(1000):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return _Strategy(_draw)


class _Rng:
    """Tiny deterministic PRNG (xorshift64*), independent of numpy so the
    stub works even in a numpy-less interpreter."""

    def __init__(self, seed: int):
        self._s = (seed or 1) & 0xFFFFFFFFFFFFFFFF

    def _next(self) -> int:
        s = self._s
        s ^= (s >> 12) & 0xFFFFFFFFFFFFFFFF
        s ^= (s << 25) & 0xFFFFFFFFFFFFFFFF
        s ^= (s >> 27) & 0xFFFFFFFFFFFFFFFF
        self._s = s
        return (s * 0x2545F4914F6CDD1D) & 0xFFFFFFFFFFFFFFFF

    def randint(self, lo: int, hi: int) -> int:
        """Uniform int in [lo, hi] inclusive."""
        span = hi - lo + 1
        return lo + self._next() % span

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (self._next() / 2.0 ** 64) * (hi - lo)

    def choice(self, seq: Sequence) -> Any:
        return seq[self.randint(0, len(seq) - 1)]


# --------------------------------------------------------------- strategies

def integers(min_value: int = -(2 ** 31), max_value: int = 2 ** 31 - 1
             ) -> _Strategy:
    lo, hi = int(min_value), int(max_value)
    def _draw(rng: _Rng):
        # mix uniform draws with boundary values (hypothesis-ish bias)
        r = rng.randint(0, 9)
        if r == 0:
            return lo
        if r == 1:
            return hi
        if r == 2 and lo <= 0 <= hi:
            return 0
        return rng.randint(lo, hi)
    return _Strategy(_draw)


def floats(min_value: float = -1e9, max_value: float = 1e9,
           **_kw) -> _Strategy:
    lo, hi = float(min_value), float(max_value)
    def _draw(rng: _Rng):
        r = rng.randint(0, 9)
        if r == 0:
            return lo
        if r == 1:
            return hi
        if r == 2 and lo <= 0.0 <= hi:
            return 0.0
        return rng.uniform(lo, hi)
    return _Strategy(_draw)


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: Optional[int] = None, **_kw) -> _Strategy:
    hi = max_size if max_size is not None else min_size + 50
    def _draw(rng: _Rng):
        n = rng.randint(min_size, hi)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(_draw)


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def just(value: Any) -> _Strategy:
    return _Strategy(lambda rng: value)


def sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: rng.choice(items))


# ---------------------------------------------------------------- decorators

def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    def deco(fn):
        inner = fn

        # NB: deliberately *zero-arg* (and no functools.wraps, which would
        # re-expose the inner signature): pytest must not mistake the
        # strategy-filled parameters for fixtures
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples",
                        getattr(inner, "_stub_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(
                f"{inner.__module__}.{inner.__qualname__}".encode())
            rng = _Rng(seed)
            for i in range(n):
                drawn = [s.draw(rng) for s in arg_strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    inner(*drawn, **drawn_kw)
                except Exception:
                    print(f"[hypothesis-stub] falsifying example "
                          f"(#{i}): args={drawn!r} kwargs={drawn_kw!r}",
                          file=sys.stderr)
                    raise
        wrapper.__name__ = getattr(inner, "__name__", "wrapper")
        wrapper.__doc__ = inner.__doc__
        wrapper.__module__ = inner.__module__
        wrapper.__qualname__ = getattr(inner, "__qualname__",
                                       wrapper.__name__)
        wrapper.hypothesis_stub = True
        return wrapper
    return deco


class HealthCheck:
    all = staticmethod(lambda: [])


def install_hypothesis_stub() -> None:
    """Register stub 'hypothesis' and 'hypothesis.strategies' modules in
    sys.modules.  Call ONLY after a failed ``import hypothesis``."""
    if "hypothesis" in sys.modules:        # real package present: no-op
        return
    hyp = types.ModuleType("hypothesis")
    hyp.__doc__ = __doc__
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "tuples", "just",
                 "sampled_from"):
        setattr(st, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.HealthCheck = HealthCheck
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
