"""Dependency shims for packages the runtime image may lack.

The only guaranteed third-party stack is jax/numpy (the jax_pallas image);
everything else must degrade gracefully.  Currently: a miniature
property-testing shim standing in for ``hypothesis`` so the test suite
still collects and exercises its properties (over a fixed pseudo-random
sample rather than hypothesis' adaptive search + shrinking).
"""
from .hypothesis_stub import install_hypothesis_stub

__all__ = ["install_hypothesis_stub"]
