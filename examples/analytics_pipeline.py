"""The paper-native end-to-end scenario: continuous ingest (WOS -> tuple
mover) while serving batched analytic queries, with a mid-run node failure
and online recovery -- §4/§5 of the paper in one script.

Run: PYTHONPATH=src python examples/analytics_pipeline.py
"""
import time

import numpy as np

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.core.recovery import recover_node
from repro.engine import Query, col, execute

rng = np.random.default_rng(1)
db = VerticaDB(n_nodes=4, k_safety=1, block_rows=2048)
db.create_table(
    TableSchema("metrics", (ColumnDef("metric"), ColumnDef("meter"),
                            ColumnDef("ts"),
                            ColumnDef("value", SQLType.FLOAT))),
    sort_order=("metric", "meter", "ts"), segment_by=("meter",))

QUERIES = [
    Query("metrics", group_by="metric", aggs=(("n", "metric", "count"),)),
    Query("metrics", predicate=col("metric") == 3,
          aggs=(("n", "metric", "count"), ("avg", "value", "avg"))),
]

total = 0
for wave in range(8):
    # ingest wave (I-lock: loads run in parallel, reads take no locks)
    k = 20_000
    t = db.begin()
    db.insert(t, "metrics", {
        "metric": rng.integers(0, 10, k),
        "meter": rng.integers(0, 100, k),
        "ts": 10**6 * wave + np.sort(rng.integers(0, 10**6, k)),
        "value": np.round(rng.normal(50, 10, k), 2)})
    db.commit(t)
    total += k
    stats = db.run_tuple_mover(force_moveout=(wave % 2 == 1))
    # serve queries concurrently with the load
    out, st = execute(db, QUERIES[0])
    assert out["n"].sum() == total
    rep = db.storage_report()["metrics_super"]
    print(f"wave {wave}: {total:,} rows | containers "
          f"{rep['containers']:3d} | moveouts {stats['moveouts']} "
          f"mergeouts {stats['mergeouts']} | compression "
          f"{rep['ratio']:.1f}x | q0 {st.wall_s*1e3:.0f}ms")
    if wave == 4:
        print(">>> failing node 1 mid-ingest")
        db.fail_node(1)
    if wave == 6:
        replayed = recover_node(db, 1)
        print(f">>> node 1 recovered; replayed "
              f"{sum(replayed.values()):,} rows from buddies")

out, _ = execute(db, QUERIES[1])
print(f"final: metric=3 count {out['n'][0]:,}, avg {out['avg'][0]:.2f}")
