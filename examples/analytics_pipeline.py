"""The paper-native end-to-end scenario: continuous ingest (WOS -> tuple
mover) while serving batched analytic queries, with a mid-run node failure
and online recovery -- §4/§5 of the paper in one script.  Queries go
through the fluent builder (engine/builder.py), which lowers to the
logical-plan IR shared by planner and executor.

Run: PYTHONPATH=src python examples/analytics_pipeline.py
"""
import time

import numpy as np

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.core.recovery import recover_node
from repro.engine import col

rng = np.random.default_rng(1)
db = VerticaDB(n_nodes=4, k_safety=1, block_rows=2048)
db.create_table(
    TableSchema("metrics", (ColumnDef("metric"), ColumnDef("meter"),
                            ColumnDef("ts"),
                            ColumnDef("value", SQLType.FLOAT))),
    sort_order=("metric", "meter", "ts"), segment_by=("meter",))

# builder pipelines are reusable templates: build once, collect per wave
q_counts = db.query("metrics").group_by("metric").agg(n=("*", "count"))
q_metric3 = (db.query("metrics").where(col("metric") == 3)
             .agg(n=("*", "count"), avg=("value", "avg")))
q_per_meter = (db.query("metrics")
               .group_by("metric", "meter")
               .agg(n=("*", "count"), total=("value", "sum"))
               .order_by("-total").limit(3))

total = 0
for wave in range(8):
    # ingest wave (I-lock: loads run in parallel, reads take no locks)
    k = 20_000
    t = db.begin()
    db.insert(t, "metrics", {
        "metric": rng.integers(0, 10, k),
        "meter": rng.integers(0, 100, k),
        "ts": 10**6 * wave + np.sort(rng.integers(0, 10**6, k)),
        "value": np.round(rng.normal(50, 10, k), 2)})
    db.commit(t)
    total += k
    stats = db.run_tuple_mover(force_moveout=(wave % 2 == 1))
    # serve queries concurrently with the load
    out = q_counts.collect()
    st = q_counts.stats
    assert out["n"].sum() == total
    rep = db.storage_report()["metrics_super"]
    print(f"wave {wave}: {total:,} rows | containers "
          f"{rep['containers']:3d} | moveouts {stats['moveouts']} "
          f"mergeouts {stats['mergeouts']} | compression "
          f"{rep['ratio']:.1f}x | q0 {st.wall_s*1e3:.0f}ms "
          f"(plan_cache={st.plan_cache or 'n/a'})")
    if wave == 4:
        print(">>> failing node 1 mid-ingest")
        db.fail_node(1)
    if wave == 6:
        replayed = recover_node(db, 1)
        print(f">>> node 1 recovered; replayed "
              f"{sum(replayed.values()):,} rows from buddies")

out = q_metric3.collect()
print(f"final: metric=3 count {out['n'][0]:,}, avg {out['avg'][0]:.2f}")
hot = q_per_meter.collect()
print("hottest (metric, meter) by total value:",
      [(int(m), int(mt), round(float(v))) for m, mt, v in
       zip(hot["metric"], hot["meter"], hot["total"])])
