"""End-to-end LM training through the columnar token store: ~10M-param
model, a few hundred steps, K-safe checkpoints, failure injection + replay.

This is the small-scale twin of the production path the multi-pod dry-run
compiles (launch/dryrun.py); the step function and substrate are identical.

Run: PYTHONPATH=src python examples/train_lm.py            (fast demo)
     PYTHONPATH=src python examples/train_lm.py --full     (~100M params)
"""
import sys

sys.argv = [sys.argv[0]] + (
    ["--d-model", "512", "--layers", "8", "--vocab", "8192",
     "--steps", "300", "--batch", "8", "--seq", "256",
     "--n-docs", "512", "--doc-len", "512", "--ckpt-every", "100"]
    if "--full" in sys.argv else
    ["--d-model", "192", "--layers", "4", "--vocab", "2048",
     "--steps", "120", "--batch", "8", "--seq", "128",
     "--fail-at-step", "90", "--ckpt-every", "40"])

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main()
