"""Fault-tolerance mechanisms, end to end (DESIGN.md §3 integration):

  1. K-safe checkpoint -> lose a node -> restore its shard from the buddy
  2. gradient quorum commit with a straggler (paper's no-2PC quorum)
  3. int8-compressed gradient all-reduce (paper §3.4 encodings on the wire)
  4. elastic re-split of the global batch when a rank dies
  5. seeded fault injection on the analytic cluster: a node crash
     mid-query fails over onto buddy projections (DESIGN.md §15)

Run: PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, RunConfig
from repro.models import build_model
from repro.train.checkpoint import (CheckpointStore, shard_state,
                                    unshard_state)
from repro.train.fault_tolerance import (DPSimulator, compressed_allreduce,
                                         quorum_combine)
from repro.train.train_step import init_train_state, make_train_step

cfg = ArchConfig(name="demo", family="dense", n_layers=2, d_model=128,
                 n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=512,
                 head_dim=32)
model = build_model(cfg, tp=1)
state = init_train_state(model, jax.random.key(0))
step = jax.jit(make_train_step(model, RunConfig(total_steps=10,
                                                warmup_steps=1)))
rng = np.random.default_rng(0)
tok = jnp.asarray(rng.integers(0, 512, (8, 64)), jnp.int32)
batch = {"tokens": tok, "labels": tok}
state, m = step(state, batch)
print(f"[1] trained a step: loss {float(m['loss']):.3f}")

# --- K-safe checkpoint + buddy restore ---
with tempfile.TemporaryDirectory() as d:
    ck = CheckpointStore(d, n_shards=4)
    np_state = jax.tree.map(np.asarray, state)
    for s in range(4):
        ck.save_shard(1, s, shard_state(np_state, s, 4))
    ck.commit_epoch(1)
    shards = [ck.restore_shard(1, s, shard_state(np_state, s, 4),
                               lost_nodes=(2,)) for s in range(4)]
    restored = unshard_state(shards, np_state)
    ok = all(np.array_equal(a, b) for a, b in
             zip(jax.tree.leaves(restored), jax.tree.leaves(np_state)))
    print(f"[1] node 2 lost -> restored from buddy copies: exact={ok}")

# --- quorum gradients with a straggler ---
g = jax.tree.map(np.asarray, jax.grad(model.loss)(state["params"], batch))
combined, n_live = quorum_combine([g, g, None, g])
print(f"[2] gradient quorum: {n_live}/4 ranks contributed; step commits")

# --- compressed all-reduce ---
avg = compressed_allreduce([g, g, g, g])
err = max(float(np.abs(a - b).max() / (np.abs(b).max() + 1e-9))
          for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(g)))
print(f"[3] int8 gradient all-reduce: 4x fewer wire bytes, "
      f"max rel err {err:.4f}")

# --- elastic batch re-split ---
sim = DPSimulator(4)
parts = sim.split_batch({"x": np.arange(64)})
sim.fail(1)
parts2 = sim.split_batch({"x": np.arange(64)})
sizes = [len(p["x"]) if p else 0 for p in parts2]
print(f"[4] elastic: rank sizes after failure {sizes} "
      f"(global batch preserved: {sum(sizes)})")

# --- deterministic fault injection: mid-query crash -> buddy failover ---
from repro.core import ColumnDef, CrashNode, TableSchema, VerticaDB
from repro.engine import execute

db = VerticaDB(n_nodes=4, k_safety=1, block_rows=256)
db.create_table(TableSchema("t", (ColumnDef("k"), ColumnDef("v"))),
                sort_order=("k",), segment_by=("k",))
txn = db.begin()
db.insert(txn, "t", {"k": np.arange(4000, dtype=np.int64),
                     "v": np.arange(4000, dtype=np.int64) % 11})
db.commit(txn)
db.run_tuple_mover(force_moveout=True)
db.attach_mesh()
inj = db.enable_faults(seed=11)
inj.on("segmented.slab_build", CrashNode(), node=1, hit=1)
out, stats = execute(db, db.query("t").group_by("v")
                     .agg(n=("*", "count")).to_ir())
db.disable_faults()
db.detach_mesh()
print(f"[5] node 1 crashed mid-query -> {stats.failovers} failover(s), "
      f"answer exact: {int(np.asarray(out['n']).sum()) == 4000}")
