"""Batched serving demo: prefill + KV-cache decode (optionally int8 KV),
the small-scale twin of the decode_32k / long_500k dry-run cells.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch hymba-1.5b]
"""
import sys

if "--arch" not in " ".join(sys.argv):
    sys.argv += ["--arch", "qwen3-4b"]
sys.argv += ["--batch", "4", "--prompt-len", "64", "--tokens", "32"]

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    main()
