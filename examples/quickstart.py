"""Quickstart: the Vertica-in-JAX analytic core in ~60 lines.

Creates a 4-node cluster, loads a small star schema, and runs queries
through the fluent builder front-end (engine/builder.py -> logical IR)
-- the primary API; the pre-IR ``Query``/``JoinSpec`` dataclasses
survive only as deprecated shims (see engine/pipeline.py) -- showing
projections, encodings, SMA pruning, snapshot isolation, trickle loads
and K-safety with incremental recovery.

Run: PYTHONPATH=src python examples/quickstart.py
(README.md carries a doc-tested copy of this flow, kept green by
scripts/check_docs.py.)
"""
import numpy as np

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.core.recovery import recover_node
from repro.engine import col

rng = np.random.default_rng(0)
db = VerticaDB(n_nodes=4, k_safety=1, block_rows=1024)

db.create_table(
    TableSchema("sales", (ColumnDef("sale_id"), ColumnDef("cid"),
                          ColumnDef("date"),
                          ColumnDef("price", SQLType.FLOAT))),
    sort_order=("date",), segment_by=("sale_id",),
    partition_by=("date", "div_1000"))
db.create_table(
    TableSchema("customers", (ColumnDef("cust_id"),
                              ColumnDef("segment"))),
    sort_order=("cust_id",), segment_by=())

n = 100_000
t = db.begin(direct_to_ros=True)
db.insert(t, "sales", {
    "sale_id": np.arange(n), "cid": rng.integers(0, 500, n),
    "date": np.sort(rng.integers(0, 3000, n)),
    "price": np.round(rng.normal(100, 15, n), 2)})
db.insert(t, "customers", {
    "cust_id": np.arange(500), "segment": rng.integers(0, 4, 500)})
epoch = db.commit(t)
rep = db.storage_report()["sales_super"]
print(f"loaded {n:,} rows -> {rep['containers']} ROS containers, "
      f"compression {rep['ratio']:.1f}x (plus a K-safe buddy projection)")

# filtered aggregate: the scan prunes blocks via per-block min/max (SMA)
q = (db.query("sales")
     .where((col("date") >= 1000) & (col("date") < 1100))
     .group_by("cid")
     .agg(n=("*", "count"), total=("price", "sum")))
out = q.collect()
stats = q.stats
print(f"query: {len(out['cid'])} groups; pruned "
      f"{stats.blocks_pruned}/{stats.blocks_total} blocks; "
      f"groupby={stats.groupby_algorithm}; {stats.wall_s*1e3:.1f}ms")

# multi-join, multi-column GROUP BY with HAVING/ORDER/LIMIT: the logical
# IR carries a list of joins and a tuple of group keys
top = (db.query("sales")
       .where(col("date") < 1500)
       .join("customers", on=("cid", "cust_id"), cols=("segment",))
       .group_by("segment", "cid")
       .agg(revenue=("price", "sum"), n=("*", "count"))
       .having(col("n") > 20)
       .order_by("-revenue")
       .limit(5))
res = top.collect()
print("top (segment, cid) by revenue:",
      [(int(s), int(c), round(float(r))) for s, c, r in
       zip(res["segment"], res["cid"], res["revenue"])])
res = top.collect()   # repeat: the fused program is plan-cached
print(f"repeat run: plan_cache={top.stats.plan_cache} "
      f"({top.stats.wall_s*1e3:.1f}ms)")

# MVCC: deletes never block readers; old snapshots stay queryable
t = db.begin()
db.delete(t, "sales", lambda r: r["cid"] < 100)
e2 = db.commit(t)
now = len(db.read_table("sales")["cid"])
before = len(db.read_table("sales", as_of=e2 - 1)["cid"])
print(f"after delete: {now:,} rows; snapshot@{e2-1}: {before:,} rows")

# K-safety: take a node down; queries route through buddy projections
ref = q.collect()            # post-delete reference
db.fail_node(2)
out2 = q.collect()
assert np.array_equal(np.sort(ref["cid"]), np.sort(out2["cid"]))
print("node 2 down: identical results via buddy projection")

# incremental recovery: rejoin first (the node receives new commits but
# serves no reads), trickle-load meanwhile, then replay ONLY the epochs
# missed while down -- adopting segment-aligned buddy containers wholesale
db.rejoin_node(2)
t = db.begin()
db.insert(t, "sales", {"sale_id": np.arange(n, n + 100),
                       "cid": np.full(100, 3, np.int64),
                       "date": np.full(100, 2999, np.int64),
                       "price": np.ones(100)})
db.commit(t)                 # lands on node 2 live, no replay needed
recover_node(db, 2)
rec = db.nodes[2].last_recovery
print(f"node 2 recovered: replayed {rec['replayed_rows']} rows up to "
      f"epoch {rec['replay_hi']} ({rec['adopted_containers']} containers "
      f"adopted wholesale from buddies)")

# fast bulk delete: drop a whole partition (file unlink, no delete vectors)
db.run_tuple_mover(force_moveout=True)
db.drop_partition("sales", 0)
print(f"dropped partition 0: {len(db.read_table('sales')['cid']):,} rows "
      f"remain (min date {db.read_table('sales')['date'].min()})")
