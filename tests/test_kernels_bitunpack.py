"""Bit-unpack kernel oracle tests: XLA path, Pallas path (interpret on CPU),
and the random-access gather, all against the bit-by-bit ref.bitunpack_ref
-- byte-identical, not allclose.  Also the delta_decode oracle coverage the
kernel previously lacked (every ops.py decode dispatch now shares one gate).
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encodings import pack_words, unpack_words
from repro.kernels import ops, ref
from repro.kernels.bitunpack import bitunpack_pallas, bitunpack_xla, \
    gather_unpack

RNG = np.random.default_rng(3)


def _symbols(nb, br, width):
    return RNG.integers(0, 1 << width, (nb, br), dtype=np.uint64) \
        .astype(np.int64)


@pytest.mark.parametrize("width", [1, 2, 3, 5, 7, 8, 11, 16, 21, 31, 32])
@pytest.mark.parametrize("nb,br", [(1, 32), (3, 64), (2, 100)])
def test_xla_unpack_matches_bit_oracle(width, nb, br):
    syms = _symbols(nb, br, width)
    words = pack_words(syms, width)
    want = ref.bitunpack_ref(words, width, br)
    got = bitunpack_xla(jnp.asarray(words), width, br)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the oracle itself round-trips the host packer
    np.testing.assert_array_equal(
        np.asarray(want).astype(np.uint32).astype(np.int64),
        syms.astype(np.uint32).astype(np.int64))


@pytest.mark.parametrize("width", [1, 4, 6, 8, 13, 17, 24, 32])
@pytest.mark.parametrize("nb,br", [(2, 64), (1, 512), (3, 1024), (2, 96)])
def test_pallas_unpack_matches_bit_oracle(width, nb, br):
    syms = _symbols(nb, br, width)
    words = pack_words(syms, width)
    want = ref.bitunpack_ref(words, width, br)
    got = bitunpack_pallas(jnp.asarray(words), width, br, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("width", [5, 8, 19, 32])
def test_fused_base_add(width):
    nb, br = 3, 128
    syms = _symbols(nb, br, min(width, 20))
    base = RNG.integers(-1000, 1000, nb).astype(np.int64)
    words = pack_words(syms, width)
    want = ref.bitunpack_ref(words, width, br, base)
    got_xla = bitunpack_xla(jnp.asarray(words), width, br, jnp.asarray(base))
    got_pl = bitunpack_pallas(jnp.asarray(words), width, br,
                              jnp.asarray(base), interpret=True)
    np.testing.assert_array_equal(np.asarray(got_xla), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(want))


@pytest.mark.parametrize("width", [1, 3, 8, 12, 27, 32])
def test_gather_unpack_random_positions(width):
    nb, br = 4, 256
    syms = _symbols(nb, br, width)
    words = jnp.asarray(pack_words(syms, width))
    n = 300
    b = RNG.integers(0, nb, n)
    r = RNG.integers(0, br, n)
    got = gather_unpack(words, width, jnp.asarray(b), jnp.asarray(r))
    want = syms[b, r].astype(np.uint32).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_ops_dispatch_env_gate(monkeypatch):
    syms = _symbols(2, 64, 9)
    words = jnp.asarray(pack_words(syms, 9))
    want = np.asarray(ops.bitunpack(words, 9, 64, force_ref=True))
    monkeypatch.delenv("REPRO_BITUNPACK", raising=False)
    np.testing.assert_array_equal(np.asarray(ops.bitunpack(words, 9, 64)),
                                  want)
    monkeypatch.setenv("REPRO_BITUNPACK", "pallas")
    np.testing.assert_array_equal(np.asarray(ops.bitunpack(words, 9, 64)),
                                  want)


def test_host_unpack_words_inverse():
    for width in (1, 2, 9, 15, 22, 30, 32):
        syms = _symbols(3, 70, width)
        np.testing.assert_array_equal(
            unpack_words(pack_words(syms, width), width, 70), syms)


@pytest.mark.parametrize("nb,B", [(1, 128), (4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("env", ["", "pallas"])
def test_delta_decode_oracle_both_paths(nb, B, dtype, env, monkeypatch):
    if env:
        monkeypatch.setenv("REPRO_DELTA_DECODE", env)
    else:
        monkeypatch.delenv("REPRO_DELTA_DECODE", raising=False)
    first = jnp.asarray(RNG.integers(0, 1000, (nb, 1)), dtype)
    deltas = jnp.asarray(RNG.integers(-5, 6, (nb, B)), dtype)
    got = ops.delta_decode(first, deltas)
    want = ref.delta_decode_ref(first, deltas)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
