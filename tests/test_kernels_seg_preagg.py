"""Pallas packed-domain pre-aggregation kernel vs its jnp oracle.

Three-way agreement: the Pallas grid kernel (interpret mode on CPU), the
scatter-based oracle (kernels/ref.seg_preagg_ref) and the engine's XLA
path (operators.groupby_dense).  Comparison policy mirrors the kernel
contract: int32 outputs (counts, int sums, int min/max) are exact; float
sums differ only by summation order (rtol 1e-5)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.engine import operators as ops
from repro.kernels import seg_preagg as sp
from repro.kernels.ref import seg_preagg_ref

AGGS = (("n", "*", "count"), ("sq", "qty", "sum"), ("mq", "qty", "min"),
        ("xp", "price", "max"), ("sp", "price", "sum"))


def _mkdata(rng, n, domain, key_lo=0):
    keys = jnp.asarray(rng.integers(key_lo, domain, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    values = {
        "qty": jnp.asarray(rng.integers(-50, 50, n), jnp.int32),
        "price": jnp.asarray(
            np.round(rng.normal(100, 10, n), 2), jnp.float32)}
    return keys, valid, values


def _assert_agree(a, b, label):
    assert set(a) == set(b)
    for name in a:
        av, bv = np.asarray(a[name]), np.asarray(b[name])
        assert av.shape == bv.shape, (label, name)
        if av.dtype.kind in "iub":
            np.testing.assert_array_equal(av, bv, err_msg=f"{label}:{name}")
        else:
            np.testing.assert_allclose(av, bv, rtol=1e-5,
                                       err_msg=f"{label}:{name}")


@pytest.mark.parametrize("n,domain", [(1000, 37), (4096, 256), (77, 1),
                                      (513, 1000)])
def test_kernel_matches_oracle_and_engine(n, domain):
    rng = np.random.default_rng(n + domain)
    keys, valid, values = _mkdata(rng, n, domain)
    got = sp.seg_preagg_pallas(keys, valid, values, domain, AGGS,
                               interpret=True)
    want = seg_preagg_ref(keys, valid, values, domain, AGGS)
    engine = ops.groupby_dense(keys, valid, values, domain, AGGS)
    _assert_agree(got, want, "kernel-vs-ref")
    _assert_agree(got, engine, "kernel-vs-engine")


def test_negative_keys_clip_to_group_zero():
    """Negative packed keys (a group key below the planner's assumed
    low bound) clip into group 0 on every path -- never out-of-bounds."""
    rng = np.random.default_rng(3)
    domain = 16
    keys, valid, values = _mkdata(rng, 500, domain, key_lo=-8)
    got = sp.seg_preagg_pallas(keys, valid, values, domain, AGGS,
                               interpret=True)
    want = seg_preagg_ref(keys, valid, values, domain, AGGS)
    _assert_agree(got, want, "negative-keys")
    # the clipped mass really lands in group 0
    kn = np.asarray(keys)
    vn = np.asarray(valid)
    assert int(np.asarray(got["group_count"])[0]) \
        == int((vn & (kn <= 0)).sum())


def test_all_invalid_rows_yield_sentinels():
    rng = np.random.default_rng(4)
    keys, _, values = _mkdata(rng, 256, 8)
    valid = jnp.zeros(256, bool)
    got = sp.seg_preagg_pallas(keys, valid, values, 8, AGGS,
                               interpret=True)
    want = seg_preagg_ref(keys, valid, values, 8, AGGS)
    _assert_agree(got, want, "all-invalid")
    assert int(np.asarray(got["group_count"]).sum()) == 0


def test_dispatch_declines_large_domains_and_cpu():
    # near-int32 packed domains can never fit the kernel's VMEM budget
    assert not sp._use_kernel(2**31 - 10, ("count", "sum"))
    assert not sp._use_kernel(sp._DOMAIN_CAP + 1, ("count",))
    # CPU without the env override keeps the XLA scatter
    assert not sp._use_kernel(64, ("count", "sum"))
    # unsupported aggregate kinds always decline
    assert not sp._use_kernel(64, ("count", "median"))


def test_seg_preagg_dispatcher_env_forced(monkeypatch):
    """REPRO_SEG_PREAGG=pallas forces the kernel on CPU (interpret mode);
    the dispatcher's three routes agree on the same inputs."""
    rng = np.random.default_rng(11)
    keys, valid, values = _mkdata(rng, 700, 64)
    baseline = sp.seg_preagg(keys, valid, values, 64, AGGS)  # XLA scatter
    monkeypatch.setenv("REPRO_SEG_PREAGG", "pallas")
    assert sp._use_kernel(64, tuple(a[2] for a in AGGS))
    forced = sp.seg_preagg(keys, valid, values, 64, AGGS)    # kernel
    oracle = sp.seg_preagg(keys, valid, values, 64, AGGS, force_ref=True)
    _assert_agree(forced, baseline, "forced-vs-xla")
    _assert_agree(forced, oracle, "forced-vs-oracle")


def test_large_domain_falls_back_and_matches():
    """Past the VMEM domain cap the dispatcher must keep the XLA scatter
    and still produce groupby_dense's exact outputs."""
    rng = np.random.default_rng(12)
    domain = sp._DOMAIN_CAP * 4
    keys, valid, values = _mkdata(rng, 2048, domain)
    got = sp.seg_preagg(keys, valid, values, domain, AGGS)
    want = ops.groupby_dense(keys, valid, values, domain, AGGS)
    _assert_agree(got, want, "large-domain")
