"""The lock matrices must match the paper's Tables 1 and 2 exactly, and the
manager must enforce them."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.locks import (COMPATIBLE, CONVERT, MODES, LockError,
                              LockManager)

# Table 1 rows as printed in the paper (requested x granted)
PAPER_COMPAT = {
    "S":  dict(S=1, I=0, SI=0, X=0, T=1, U=1, O=0),
    "I":  dict(S=0, I=1, SI=0, X=0, T=1, U=1, O=0),
    "SI": dict(S=0, I=0, SI=0, X=0, T=1, U=1, O=0),
    "X":  dict(S=0, I=0, SI=0, X=0, T=0, U=1, O=0),
    "T":  dict(S=1, I=1, SI=1, X=0, T=1, U=1, O=0),
    "U":  dict(S=1, I=1, SI=1, X=1, T=1, U=1, O=0),
    "O":  dict(S=0, I=0, SI=0, X=0, T=0, U=0, O=0),
}

PAPER_CONVERT = {
    "S":  dict(S="S", I="SI", SI="SI", X="X", T="S", U="S", O="O"),
    "I":  dict(S="SI", I="I", SI="SI", X="X", T="I", U="I", O="O"),
    "SI": dict(S="SI", I="SI", SI="SI", X="X", T="SI", U="SI", O="O"),
    "X":  dict(S="X", I="X", SI="X", X="X", T="X", U="X", O="O"),
    "T":  dict(S="S", I="I", SI="SI", X="X", T="T", U="T", O="O"),
    "U":  dict(S="S", I="I", SI="SI", X="X", T="T", U="U", O="O"),
    "O":  dict(S="O", I="O", SI="O", X="O", T="O", U="O", O="O"),
}


def test_compat_matches_paper_table1():
    for r in MODES:
        for g in MODES:
            assert COMPATIBLE[r][g] == bool(PAPER_COMPAT[r][g]), (r, g)


def test_convert_matches_paper_table2():
    for r in MODES:
        for g in MODES:
            assert CONVERT[r][g] == PAPER_CONVERT[r][g], (r, g)


def test_parallel_inserts_allowed():
    lm = LockManager()
    assert lm.acquire("t", "txn1", "I") == "I"
    assert lm.acquire("t", "txn2", "I") == "I"  # bulk loads in parallel (§5)


def test_exclusive_blocks_insert():
    lm = LockManager()
    lm.acquire("t", "txn1", "X")
    with pytest.raises(LockError):
        lm.acquire("t", "txn2", "I")


def test_tuple_mover_compatible_with_loads():
    lm = LockManager()
    lm.acquire("t", "load", "I")
    assert lm.acquire("t", "tm", "U")  # U compatible with everything but O


def test_owner_blocks_all():
    lm = LockManager()
    lm.acquire("t", "ddl", "O")
    for m in MODES:
        with pytest.raises(LockError):
            lm.acquire("t", "x", m)


def test_same_holder_converts():
    lm = LockManager()
    lm.acquire("t", "txn", "S")
    assert lm.acquire("t", "txn", "I") == "SI"  # S + I -> SI (Table 2)


def test_release_restores():
    lm = LockManager()
    lm.acquire("t", "a", "X")
    lm.release("t", "a")
    assert lm.acquire("t", "b", "I") == "I"


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(MODES), st.sampled_from(MODES))
def test_conversion_idempotent_on_self(r, g):
    # converting into the same mode twice is stable
    once = CONVERT[r][g]
    assert CONVERT[r][once] == CONVERT[r][once]
    # X and O absorb everything except U-over-X special cases in Table 1
    assert CONVERT["O"][g] == "O"
