"""Chaos property tests: random seeded fault schedules spliced into the
differential query corpus and the DML/recovery stream.

THE invariant (the whole point of typed degradation): under ANY fault
schedule, every query either returns the byte-identical answer the
never-failed cluster returns, or raises a typed AvailabilityError --
never, ever a silently wrong answer.

Seeds come from ``REPRO_CHAOS_SEEDS`` (comma-separated ints) so the
verify.sh chaos tier pins an exact reproducible schedule; default is a
small fixed set to keep tier-1 fast.
"""
import os

import numpy as np
import pytest

from repro.core import (AvailabilityError, CrashNode, RecoverySourceLostError,
                        Transient, VerticaDB)
from repro.core.faults import NodeCrashError
from repro.core.recovery import recover_node
from repro.engine import col, execute

from test_crash_replay_props import (N_KEYS, _agg, _apply, _mk_db,
                                     _commit_batch, _tuples)
from test_segmented_exec import assert_match, gen_query, make_db

CHAOS_SEEDS = tuple(
    int(s) for s in os.environ.get("REPRO_CHAOS_SEEDS", "11,23").split(","))

# every query-path injection point the chaos schedule may hit
QUERY_POINTS = ("segmented.slab_build", "segmented.buddy_read",
                "exchange.resegment", "exchange.broadcast")
DML_POINTS = ("commit.apply", "tuple_mover.moveout",
              "tuple_mover.mergeout")


def repair_all(db, suspend=True):
    """Bring every node back to serving, retrying so interdependent buddy
    pairs recover in whatever order works.  ``suspend=False`` leaves the
    injector live, so recovery-path faults (transient buddy reads) are
    exercised too -- they surface as RecoverySourceLostError and the next
    round retries."""
    import contextlib
    cm = db.faults.suspended() if suspend else contextlib.nullcontext()
    with cm:
        for _ in range(6):
            pending = [n.id for n in db.nodes if not n.serving()]
            if not pending:
                return
            for nid in pending:
                try:
                    recover_node(db, nid)
                except RecoverySourceLostError:
                    continue          # its source recovers a later round
    assert all(n.serving() for n in db.nodes), "cluster unrepairable"


# ---------------------------------------------------------------------------
# targeted: a crash at each query-path point fails over transparently
# ---------------------------------------------------------------------------

def _point_query(db, point):
    if point == "exchange.resegment":       # parts is the resegment join
        return (db.query("sales")
                .join("parts", on=("partkey", "p_partkey"), cols=("p_cat",))
                .group_by("p_cat").agg(n=("*", "count")))
    if point == "exchange.broadcast":       # promo is the broadcast join
        return (db.query("sales")
                .join("promo", on=("day", "pr_day"), cols=("pr_kind",))
                .group_by("pr_kind").agg(n=("*", "count")))
    return (db.query("sales").group_by("suppkey")
            .agg(n=("*", "count"), s=("qty", "sum")))


@pytest.mark.parametrize("point", ("segmented.slab_build",
                                   "exchange.resegment",
                                   "exchange.broadcast"))
def test_mid_query_crash_fails_over_per_point(point):
    db = make_db()
    db.attach_mesh()
    try:
        qb = _point_query(db, point)
        ref, _ = execute(db, qb.to_ir())
        inj = db.enable_faults(seed=13)
        inj.on(point, CrashNode(), hit=1)
        out, stats = execute(db, qb.to_ir())    # no error may surface
        assert stats.failovers >= 1, point
        assert stats.faults_injected >= 1
        assert not db.epochs.pins
        assert_match(ref, out, ordered=False, label=point)
    finally:
        db.disable_faults()
        db.detach_mesh()


def test_failover_retries_at_pinned_epoch():
    """A commit that lands BETWEEN the crash and the retry must stay
    invisible: the failover replans at the query's pinned snapshot."""
    db = make_db()
    db.attach_mesh()

    class CommitThenCrash:
        def __call__(self, action_db, point, ctx, rng):
            with action_db.faults.suspended():
                t = action_db.begin()
                action_db.insert(t, "sales", {
                    "sale_id": np.arange(50000, 50100, dtype=np.int64),
                    "custkey": np.zeros(100, np.int64),
                    "suppkey": np.zeros(100, np.int64),
                    "partkey": np.zeros(100, np.int64),
                    "day": np.zeros(100, np.int64),
                    "qty": np.ones(100, np.int64),
                    "delta": np.zeros(100, np.int64),
                    "price": np.ones(100)})
                action_db.commit(t)
            action_db.fail_node(1)
            raise NodeCrashError(1, point)

    try:
        qb = db.query("sales").agg(n=("*", "count"))
        ref, _ = execute(db, qb.to_ir())
        assert int(ref["n"][0]) == 4000
        inj = db.enable_faults(seed=1)
        inj.on("segmented.slab_build", CommitThenCrash(), hit=1)
        out, stats = execute(db, qb.to_ir())
        assert stats.failovers == 1
        # the retry saw the PINNED snapshot: 4000 rows, not 4100
        assert int(out["n"][0]) == 4000
        db.disable_faults()
        # a fresh query (new pin) sees the mid-flight commit
        out2, _ = execute(db, qb.to_ir())
        assert int(out2["n"][0]) == 4100
    finally:
        db.disable_faults()
        db.detach_mesh()


# ---------------------------------------------------------------------------
# chaos over the differential query corpus
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_chaos_query_corpus_right_or_typed_error(chaos_seed):
    """The 20-query differential corpus under a seeded probabilistic
    fault schedule: every query matches the never-failed oracle exactly,
    or raises a typed AvailabilityError.  Zero wrong answers."""
    db = make_db()
    rng = np.random.default_rng(2024)
    corpus = [gen_query(db, rng) for _ in range(20)]

    # never-failed oracle answers first (no faults, single-node)
    db.detach_mesh()
    refs = [execute(db, qb.to_ir())[0] for qb in corpus]

    inj = db.enable_faults(seed=chaos_seed)
    inj.chaos(QUERY_POINTS, p=0.04)                       # seeded crashes
    inj.chaos(QUERY_POINTS, p=0.10, action=Transient())   # seeded blips
    db.attach_mesh()
    typed, matched = 0, 0
    try:
        for i, qb in enumerate(corpus):
            repair_all(db)
            ir = qb.to_ir()
            try:
                out, _ = execute(db, ir)
            except AvailabilityError:
                typed += 1            # loud, typed degradation: allowed
                continue
            matched += 1
            assert_match(refs[i], out, ordered=bool(ir.order_by),
                         label=f"chaos{chaos_seed}-q{i}")
            assert not db.epochs.pins
    finally:
        db.disable_faults()
        db.detach_mesh()
    assert matched > 0                # the schedule must not reject all
    assert inj.hit_count("segmented.slab_build") > 0


# ---------------------------------------------------------------------------
# chaos over the DML / tuple-mover / recovery stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chaos_seed", CHAOS_SEEDS)
def test_chaos_dml_stream_equals_never_failed(chaos_seed):
    """A trickle-load + delete + tuple-mover stream with seeded crashes
    and transients spliced into commit/mover/recovery paths converges to
    byte-identical state with the never-failed reference cluster."""
    rng = np.random.default_rng(chaos_seed)
    ref = _mk_db()
    crashy = _mk_db()
    base = 0
    for db in (ref, crashy):
        _commit_batch(db, 7, base)
        db.run_tuple_mover(force_moveout=True)
    base += 10 ** 6

    inj = crashy.enable_faults(seed=chaos_seed)
    # K=1 tolerates exactly one failure: a buddy-pair double crash loses
    # both WOS copies of a segment (cluster-down by design), so the DML
    # chaos schedule crashes at most one node at a time
    inj.chaos(DML_POINTS, p=0.05,
              action=CrashNode(respect_k_safety=True))
    inj.chaos(DML_POINTS + ("recovery.replay", "recovery.buddy_read"),
              p=0.08, action=Transient())
    try:
        for k in range(12):
            kind = ("commit", "commit", "delete", "moveout",
                    "mover")[int(rng.integers(5))]
            op = (kind, int(rng.integers(2 ** 20)))
            _apply(ref, op, base)
            for attempt in range(4):
                try:
                    _apply(crashy, op, base)
                    break
                except AvailabilityError:
                    # a refused commit must not half-apply: repair and
                    # re-apply the SAME op so both streams stay aligned
                    # (chaos may refuse the retry again; budget of 4)
                    assert attempt < 3, "op refused 4 times"
                    repair_all(crashy)
            base += 10 ** 6
            repair_all(crashy, suspend=False)   # recovery faults live
    finally:
        crashy.disable_faults()
    repair_all(crashy)

    assert _tuples(crashy.read_table("events")) == \
        _tuples(ref.read_table("events"))
    assert _agg(crashy) == _agg(ref)
    # every node serves its own segments again: knock each buddy host
    # out in turn on clones of the final state and compare
    expect = _tuples(ref.read_table("events"))
    for node in range(4):
        crashy.fail_node(node)
        assert _tuples(crashy.read_table("events")) == expect, node
        recover_node(crashy, node)
