"""Deterministic fault-injection harness: schedules, retry/degradation
taxonomy, mid-query failover, typed availability errors, cache hygiene.

Single-fault unit coverage lives here; the randomized chaos property
tests (never a wrong answer, only right-or-typed-error) are in
test_fault_chaos.py.
"""
import numpy as np
import pytest

from repro.core import (AvailabilityError, ColumnDef, CrashNode,
                        FaultInjector, Hang, QueryRejectedError,
                        RecoverySourceLostError, SQLType,
                        SegmentUnavailableError, TableSchema, Transient,
                        TransientFaultError, VerticaDB)
from repro.core.block_cache import KIND_SEG
from repro.core.recovery import recover_node
from repro.engine import col, execute

from test_segmented_exec import assert_match, make_db


def _tuples(rows):
    cols = sorted(rows)
    return sorted(zip(*[np.asarray(rows[c]).tolist() for c in cols]))


# ---------------------------------------------------------------------------
# the injector itself: deterministic schedules
# ---------------------------------------------------------------------------

def test_nth_hit_schedule_fires_exactly_once():
    inj = FaultInjector(seed=1)
    inj.on("x", Transient(), hit=3)
    for k in range(1, 6):
        if k == 3:
            with pytest.raises(TransientFaultError):
                inj.fire("x")
        else:
            inj.fire("x")
    assert inj.fired("x") == 1
    assert inj.hit_count("x") == 5


def test_node_filter_and_times_window():
    inj = FaultInjector(seed=1)
    inj.on("p", Transient(), node=2, times=2)
    inj.fire("p", node=0)
    inj.fire("p", node=1)          # other nodes never match
    for _ in range(2):
        with pytest.raises(TransientFaultError):
            inj.fire("p", node=2)
    inj.fire("p", node=2)          # times=2 exhausted
    assert inj.fired("p") == 2


def test_probabilistic_rules_are_seed_deterministic():
    def run(seed):
        inj = FaultInjector(seed=seed)
        inj.on("x", Transient(), p=0.4)
        pattern = []
        for _ in range(40):
            try:
                inj.fire("x")
                pattern.append(0)
            except TransientFaultError:
                pattern.append(1)
        return pattern

    a, b = run(123), run(123)
    assert a == b and sum(a) > 0     # identical schedule, some firings
    assert run(7) != a               # a different seed reschedules


def test_suspended_pauses_without_resetting_counters():
    inj = FaultInjector(seed=1)
    inj.on("x", Transient(), hit=2)
    inj.fire("x")
    with inj.suspended():
        inj.fire("x")                # counted as a hit, never fires
        inj.fire("x")
    assert inj.fired("x") == 0
    assert inj.hit_count("x") == 3


# ---------------------------------------------------------------------------
# retry taxonomy through a real query (1-device degenerate mesh is fine)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fault_db():
    return make_db()


def _count_query(db):
    return db.query("sales").agg(n=("*", "count"))


def test_transient_faults_retry_in_place(fault_db):
    db = fault_db
    db.attach_mesh()
    try:
        ref, _ = execute(db, _count_query(db).to_ir())
        inj = db.enable_faults(seed=3)
        inj.on("segmented.slab_build", Transient(), times=2)
        out, stats = execute(db, _count_query(db).to_ir())
        assert stats.fault_retries >= 2
        assert stats.failovers == 0
        assert_match(ref, out, ordered=False, label="transient")
    finally:
        db.disable_faults()
        db.detach_mesh()


def test_hang_converts_to_timeout_and_retries(fault_db):
    db = fault_db
    db.attach_mesh()
    try:
        ref, _ = execute(db, _count_query(db).to_ir())
        inj = db.enable_faults(seed=3, attempt_timeout_s=0.01)
        inj.on("segmented.slab_build", Hang(0.05), times=1)
        out, stats = execute(db, _count_query(db).to_ir())
        assert stats.fault_retries >= 1      # the timed-out attempt
        assert_match(ref, out, ordered=False, label="hang")
    finally:
        db.disable_faults()
        db.detach_mesh()


def test_exhausted_transients_reject_query_and_release_pin(fault_db):
    db = fault_db
    db.attach_mesh()
    try:
        inj = db.enable_faults(seed=3)
        inj.on("segmented.slab_build", Transient())   # every attempt
        with pytest.raises(QueryRejectedError) as exc:
            execute(db, _count_query(db).to_ir())
        assert exc.value.epoch is not None
        assert not db.epochs.pins              # pin released on failure
    finally:
        db.disable_faults()
        db.detach_mesh()


def test_mid_query_crash_fails_over_at_pinned_epoch(fault_db):
    db = fault_db
    db.attach_mesh()
    try:
        qb = (db.query("sales").where(col("day") < 200)
              .group_by("suppkey").agg(n=("*", "count"),
                                       s=("qty", "sum")))
        ref, _ = execute(db, qb.to_ir())
        inj = db.enable_faults(seed=3)
        inj.on("segmented.slab_build", CrashNode(), node=1, hit=1)
        out, stats = execute(db, qb.to_ir())     # no error surfaces
        assert stats.failovers == 1
        assert not db.nodes[1].up
        assert not db.epochs.pins
        assert_match(ref, out, ordered=False, label="failover")
    finally:
        db.disable_faults()
        db.detach_mesh()
        if not db.nodes[1].serving():        # repair the shared fixture
            recover_node(db, 1)


def test_failover_budget_exhaustion_is_typed(fault_db):
    db = fault_db
    db.attach_mesh()
    try:
        inj = db.enable_faults(seed=3)
        # every attempt crashes another node: 1 initial + 2 failovers
        # burns the budget, the 4th node loss surfaces as a rejection
        inj.on("segmented.slab_build", CrashNode())
        with pytest.raises((QueryRejectedError, AvailabilityError)) as exc:
            execute(db, _count_query(db).to_ir())
        if isinstance(exc.value, QueryRejectedError):
            assert exc.value.attempts >= 1
        assert not db.epochs.pins
    finally:
        db.disable_faults()
        db.detach_mesh()
        for n in db.nodes:                   # repair the shared fixture
            if not n.serving():
                recover_node(db, n.id)


# ---------------------------------------------------------------------------
# commit-path and recovery-path faults (typed degradation, K-safety)
# ---------------------------------------------------------------------------

def test_mid_commit_crash_ejects_node_commit_survives(sales_db):
    db, _ = sales_db
    before = _tuples(db.read_table("sales"))
    new = {"sale_id": np.arange(9000, 9050),
           "cid": np.full(50, 21, np.int64),
           "date": np.full(50, 123, np.int64),
           "price": np.ones(50)}
    inj = db.enable_faults(seed=5)
    inj.on("commit.apply", CrashNode(), node=2, hit=1)
    t = db.begin()
    db.insert(t, "sales", new)
    db.commit(t)                     # quorum commit: survivors proceed
    db.disable_faults()
    assert not db.nodes[2].up
    expect = sorted(before + _tuples(new))
    assert _tuples(db.read_table("sales")) == expect
    recover_node(db, 2)              # replay brings node 2 current
    assert _tuples(db.read_table("sales")) == expect
    db.fail_node(3)                  # node 2 must now serve seg 2 itself
    assert _tuples(db.read_table("sales")) == expect


def test_commit_refused_when_staged_segment_loses_all_copies():
    """Both copy-holders of a staged segment die during commit phase 1:
    the WHOLE commit is refused (typed), nothing is applied anywhere, and
    after repair the same batch commits cleanly.  5 nodes so quorum
    (3) still holds with the buddy pair 1+2 down -- the refusal comes
    from the redundancy check, not the quorum check."""
    db = VerticaDB(n_nodes=5, k_safety=1, block_rows=64)
    db.create_table(TableSchema("events", (
        ColumnDef("eid"), ColumnDef("val", SQLType.FLOAT))),
        sort_order=("eid",), segment_by=("eid",))
    seed = {"eid": np.arange(200, dtype=np.int64),
            "val": np.ones(200)}
    t = db.begin()
    db.insert(t, "events", seed)
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    before = _tuples(db.read_table("events"))

    inj = db.enable_faults(seed=2)
    inj.on("commit.apply", CrashNode(), node=1, hit=1)
    inj.on("commit.apply", CrashNode(), node=2, hit=1)
    batch = {"eid": np.arange(1000, 1200, dtype=np.int64),
             "val": np.full(200, 2.0)}
    t = db.begin()
    db.insert(t, "events", batch)
    with pytest.raises(SegmentUnavailableError) as exc:
        db.commit(t)
    db.disable_faults()
    assert 1 in exc.value.segments
    assert not db.nodes[1].up and not db.nodes[2].up
    # clean abort: nothing missed, recovery is trivial even with the
    # buddy still down, and the visible state is exactly the old one
    recover_node(db, 1)
    recover_node(db, 2)
    assert _tuples(db.read_table("events")) == before
    # the identical batch now commits fine
    t = db.begin()
    db.insert(t, "events", batch)
    db.commit(t)
    assert _tuples(db.read_table("events")) == \
        sorted(before + _tuples(batch))


def test_double_buddy_failure_raises_typed_segment_error(sales_db):
    db, _ = sales_db
    oracle = _tuples(db.read_table("sales"))
    db.fail_node(1)
    db.fail_node(2)                  # node 2 hosted segment 1's buddy
    with pytest.raises(SegmentUnavailableError) as exc:
        db.read_table("sales")
    assert 1 in exc.value.segments
    assert exc.value.projection == "sales_super"
    # rejoin + recover restores full service, byte-identical to oracle
    recover_node(db, 2)              # seg 2 replays from buddy on node 3
    recover_node(db, 1)
    assert _tuples(db.read_table("sales")) == oracle
    db.fail_node(0)                  # spot-check failover still works
    assert _tuples(db.read_table("sales")) == oracle


def test_recovery_replay_source_crash_is_typed(sales_db):
    db, _ = sales_db
    db.fail_node(1)
    t = db.begin()
    db.insert(t, "sales", {"sale_id": np.arange(9900, 9950),
                           "cid": np.full(50, 17, np.int64),
                           "date": np.full(50, 77, np.int64),
                           "price": np.ones(50)})
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    oracle = _tuples(db.read_table("sales"))
    inj = db.enable_faults(seed=9)
    # the replay source (node 2 holds seg 1's buddy) dies mid-replay
    inj.on("recovery.buddy_read", CrashNode(), node=2, hit=1)
    with pytest.raises(RecoverySourceLostError) as exc:
        recover_node(db, 1)
    db.disable_faults()
    assert exc.value.node == 1 and 1 in exc.value.segments
    assert db.nodes[1].recovering    # stays recovering: retryable
    recover_node(db, 2)
    recover_node(db, 1)              # retry completes once buddy is back
    assert _tuples(db.read_table("sales")) == oracle


# ---------------------------------------------------------------------------
# cache hygiene: fail_node evicts slabs built over the dead node's stores
# ---------------------------------------------------------------------------

def test_fail_node_evicts_stale_seg_slabs():
    db = make_db()
    db.attach_mesh()
    try:
        execute(db, db.query("sales").group_by("suppkey")
                .agg(n=("*", "count")).to_ir())   # warm a KIND_SEG slab

        def seg_keys_touching(node):
            out = []
            for key in db.block_cache.keys():
                cid, colk, kind = key
                if kind != KIND_SEG:
                    continue
                items = colk[2][0]
                if any(host == node for host, _o, _ids in items):
                    out.append(key)
            return out

        assert seg_keys_touching(1), "warm slab should reference node 1"
        db.fail_node(1)
        assert not seg_keys_touching(1), \
            "failed node's slabs must be evicted"
        # and the rebuilt slab (buddy routing) still answers correctly
        out, stats = execute(db, db.query("sales").group_by("suppkey")
                             .agg(n=("*", "count")).to_ir())
        assert int(np.asarray(out["n"]).sum()) == 4000
    finally:
        db.detach_mesh()
