"""Differential oracle harness for segmented multi-device execution.

Every query in a seeded generated corpus runs twice -- single-node
(mesh detached) and segmented over a jax device mesh (engine/segmented.py)
-- and the results must match row-for-row: same groups, same counts, same
aggregates (floats to tolerance; partial sums merge in a different order).

The mesh spans every device the process sees: 1 under plain tier-1
pytest (the degenerate but still fully exercised 1-shard path), 8 under
``scripts/verify.sh``'s segmented tier, which re-runs this file with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

The star schema is built so the planner's three exchange strategies all
occur across the corpus:

  customer  segmented by c_custkey = fact's segmentation -> co-located
  supplier  replicated                                   -> co-located
  parts     large, segmented by p_partkey != fact seg    -> resegment
  promo     small, segmented by pr_day   != fact seg     -> broadcast
"""
import jax
import numpy as np
import pytest

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.core.recovery import recover_node
from repro.engine import col, execute
from repro.engine.exchange import resegment
from repro.planner import plan_query

N_FACT = 4000
N_CUST, N_SUPP, N_PART, N_PROMO = 300, 40, 2000, 30


def make_db(k_safety=1, n_nodes=4, seed=7):
    rng = np.random.default_rng(seed)
    db = VerticaDB(n_nodes=n_nodes, k_safety=k_safety, block_rows=64)
    db.create_table(TableSchema("sales", (
        ColumnDef("sale_id"), ColumnDef("custkey"), ColumnDef("suppkey"),
        ColumnDef("partkey"), ColumnDef("day"), ColumnDef("qty"),
        ColumnDef("delta"), ColumnDef("price", SQLType.FLOAT))),
        sort_order=("day",), segment_by=("custkey",))
    db.create_table(TableSchema("customer", (
        ColumnDef("c_custkey"), ColumnDef("c_nation"))),
        sort_order=("c_custkey",), segment_by=("c_custkey",))
    db.create_table(TableSchema("supplier", (
        ColumnDef("s_suppkey"), ColumnDef("s_region"))),
        sort_order=("s_suppkey",), segment_by=())        # replicated
    db.create_table(TableSchema("parts", (
        ColumnDef("p_partkey"), ColumnDef("p_cat"))),
        sort_order=("p_partkey",), segment_by=("p_partkey",))
    db.create_table(TableSchema("promo", (
        ColumnDef("pr_day"), ColumnDef("pr_kind"))),
        sort_order=("pr_day",), segment_by=("pr_day",))
    t = db.begin()
    db.insert(t, "sales", {
        "sale_id": np.arange(N_FACT, dtype=np.int64),
        "custkey": rng.integers(0, N_CUST, N_FACT),
        "suppkey": rng.integers(0, N_SUPP, N_FACT),
        "partkey": rng.integers(0, N_PART, N_FACT),
        "day": rng.integers(0, 365, N_FACT),
        "qty": rng.integers(1, 50, N_FACT),
        "delta": rng.integers(-40, 40, N_FACT),      # negative group keys
        "price": np.round(rng.normal(100, 10, N_FACT), 2)})
    db.insert(t, "customer", {
        "c_custkey": np.arange(N_CUST, dtype=np.int64),
        "c_nation": rng.integers(0, 12, N_CUST)})
    db.insert(t, "supplier", {
        "s_suppkey": np.arange(N_SUPP, dtype=np.int64),
        "s_region": rng.integers(0, 5, N_SUPP)})
    db.insert(t, "parts", {
        "p_partkey": np.arange(N_PART, dtype=np.int64),
        "p_cat": rng.integers(0, 9, N_PART)})
    db.insert(t, "promo", {
        "pr_day": np.arange(N_PROMO, dtype=np.int64) * 12,
        "pr_kind": rng.integers(0, 4, N_PROMO)})
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    return db


@pytest.fixture(scope="module")
def star_db():
    return make_db()


# -- join templates: (dim, on, carried col, forced exchange strategy) --
JOINS = {
    "customer": (("custkey", "c_custkey"), "c_nation", "local"),
    "supplier": (("suppkey", "s_suppkey"), "s_region", "local"),
    "parts": (("partkey", "p_partkey"), "p_cat", "resegment"),
    "promo": (("day", "pr_day"), "pr_kind", "broadcast"),
}


def gen_query(db, rng):
    """One random corpus member: filters, 0-3 joins, 1-3 group keys,
    aggregates, sometimes HAVING / ORDER BY / LIMIT."""
    qb = db.query("sales")
    if rng.random() < 0.7:
        lo = int(rng.integers(0, 280))
        hi = lo + int(rng.integers(30, 200))
        qb = qb.where((col("day") >= lo) & (col("day") < hi))
    if rng.random() < 0.3:
        qb = qb.where(col("qty") > int(rng.integers(1, 25)))
    dims = [d for d in JOINS if rng.random() < 0.45][:3]
    pool = ["suppkey", "delta", "day"]
    for d in dims:
        on, carried, _ = JOINS[d]
        where = None
        if d == "customer" and rng.random() < 0.5:
            where = col("c_nation") < int(rng.integers(4, 12))
        qb = qb.join(d, on=on, cols=(carried,), where=where)
        pool.append(carried)
    k = int(rng.integers(1, min(3, len(pool)) + 1))
    keys = [pool[i] for i in rng.choice(len(pool), size=k, replace=False)]
    qb = qb.group_by(*keys)
    qb = qb.agg(n=("*", "count"))
    for name, spec in (("s", ("qty", "sum")), ("mn", ("price", "min")),
                       ("mx", ("price", "max")), ("a", ("price", "avg"))):
        if rng.random() < 0.4:
            qb = qb.agg(**{name: spec})
    if rng.random() < 0.25:
        qb = qb.having(col("n") > int(rng.integers(1, 4)))
    if rng.random() < 0.4:
        # deterministic total order: count desc, then every group key
        qb = qb.order_by("-n", *keys).limit(int(rng.integers(5, 25)))
    return qb


def canon(out, ordered):
    """Sorted row-set view (already-ordered outputs keep their order)."""
    cols = sorted(out)
    if not cols or len(next(iter(out.values()))) == 0:
        return {c: np.asarray(out[c]) for c in cols}
    if ordered:
        return {c: np.asarray(out[c]) for c in cols}
    order = np.lexsort([np.asarray(out[c]) for c in cols])
    return {c: np.asarray(out[c])[order] for c in cols}


def assert_match(ref, seg, ordered, label):
    a, b = canon(ref, ordered), canon(seg, ordered)
    assert set(a) == set(b), (label, sorted(a), sorted(b))
    for c in a:
        av, bv = a[c], b[c]
        assert av.shape == bv.shape, (label, c, av.shape, bv.shape)
        if av.dtype.kind in "iub" and bv.dtype.kind in "iub":
            assert (av == bv).all(), (label, c, av[:8], bv[:8])
        else:
            assert np.allclose(np.asarray(av, np.float64),
                               np.asarray(bv, np.float64),
                               rtol=1e-3, atol=1e-2), \
                (label, c, av[:8], bv[:8])


def run_both(db, qb):
    db.detach_mesh()
    ref, _ = execute(db, qb.to_ir())
    db.attach_mesh()
    out, stats = execute(db, qb.to_ir())
    db.detach_mesh()
    return ref, out, stats


# ---------------------------------------------------------------------------
# the corpus
# ---------------------------------------------------------------------------

def test_differential_corpus(star_db):
    """~20 seeded queries: segmented == single-node, exactly, and all
    three exchange strategies occur across the corpus."""
    db = star_db
    rng = np.random.default_rng(2024)
    exchanges_seen = set()
    for i in range(20):
        qb = gen_query(db, rng)
        ir = qb.to_ir()
        ref, out, stats = run_both(db, qb)
        assert stats.segmented, (i, ir.signature())
        assert stats.n_shards == jax.device_count()
        exchanges_seen.update(e for e in stats.exchange.split(";") if e)
        assert stats.reseg_overflow == 0
        assert_match(ref, out, ordered=bool(ir.order_by), label=f"q{i}")
    assert {"local", "broadcast", "resegment"} <= exchanges_seen, \
        exchanges_seen


def test_exchange_strategy_per_join(star_db):
    """The planner's per-join exchange choice matches the physical design
    each dimension was built for."""
    db = star_db
    for dim, (on, carried, expected) in JOINS.items():
        qb = (db.query("sales").join(dim, on=on, cols=(carried,))
              .group_by(carried).agg(n=("*", "count")))
        plan = plan_query(db, qb.to_ir())
        assert plan.join_exchanges == (expected,), (dim, plan.join_strategy)
        ref, out, stats = run_both(db, qb)
        assert stats.exchange == expected
        assert_match(ref, out, ordered=False, label=dim)


def test_scalar_and_snowflake(star_db):
    db = star_db
    # scalar aggregate, no group keys
    qb = db.query("sales").where(col("day") > 200).agg(
        n=("*", "count"), s=("qty", "sum"), a=("price", "avg"))
    ref, out, stats = run_both(db, qb)
    assert stats.segmented
    assert_match(ref, out, ordered=False, label="scalar")
    # snowflake: the second join's key only exists after the first join,
    # so the planner must demote it to broadcast
    db.create_table(TableSchema("nation", (
        ColumnDef("n_nation"), ColumnDef("n_cont"))),
        sort_order=("n_nation",), segment_by=("n_nation",))
    t = db.begin()
    db.insert(t, "nation", {"n_nation": np.arange(12, dtype=np.int64),
                            "n_cont": np.arange(12, dtype=np.int64) % 3})
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    qb = (db.query("sales")
          .join("customer", on=("custkey", "c_custkey"), cols=("c_nation",))
          .join("nation", on=("c_nation", "n_nation"), cols=("n_cont",))
          .group_by("n_cont").agg(n=("*", "count")))
    plan = plan_query(db, qb.to_ir())
    assert plan.join_exchanges[1] == "broadcast", plan.join_strategy
    ref, out, stats = run_both(db, qb)
    assert stats.segmented
    assert_match(ref, out, ordered=False, label="snowflake")


def test_repeat_resegment_key_becomes_local(star_db):
    """Two joins probing the SAME fact key, both of which would resegment:
    after the first exchange the probe side is already placed by that key,
    so the second join must run local (one exchange, not two -- and not a
    crash on the consumed destination column)."""
    db = star_db
    rng = np.random.default_rng(5)
    db.create_table(TableSchema("partsx", (
        ColumnDef("px_partkey"), ColumnDef("px_weight"))),
        sort_order=("px_partkey",), segment_by=("px_weight",))
    t = db.begin()
    db.insert(t, "partsx", {
        "px_partkey": np.arange(N_PART, dtype=np.int64),
        "px_weight": rng.integers(0, 7, N_PART)})
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    qb = (db.query("sales")
          .join("parts", on=("partkey", "p_partkey"), cols=("p_cat",))
          .join("partsx", on=("partkey", "px_partkey"),
                cols=("px_weight",))
          .group_by("p_cat", "px_weight").agg(n=("*", "count")))
    plan = plan_query(db, qb.to_ir())
    assert plan.join_exchanges == ("resegment", "local"), \
        plan.join_strategy
    ref, out, stats = run_both(db, qb)
    assert stats.segmented
    assert_match(ref, out, ordered=False, label="repeat-reseg-key")


def test_plan_cache_hit_keyed_by_mesh(star_db):
    db = star_db
    qb = (db.query("sales").where(col("qty") > 10)
          .group_by("suppkey").agg(n=("*", "count"), s=("qty", "sum")))
    _, _, s1 = run_both(db, qb)
    _, out2, s2 = run_both(db, qb)
    assert s1.segmented and s2.segmented
    assert s2.plan_cache == "hit"
    # the second run must also still be correct (cached program + slab)
    db.detach_mesh()
    ref, _ = execute(db, qb.to_ir())
    assert_match(ref, out2, ordered=False, label="warm")


def test_failover_to_buddy_shards():
    """fail_node(): scans transparently route to buddy-projection shards
    (k_safety=1) and the segmented result is unchanged."""
    db = make_db(k_safety=1, seed=11)
    queries = [
        db.query("sales").where(col("day") < 180)
          .group_by("suppkey").agg(n=("*", "count"), s=("qty", "sum")),
        db.query("sales")
          .join("customer", on=("custkey", "c_custkey"), cols=("c_nation",))
          .group_by("c_nation").agg(n=("*", "count")),
        db.query("sales")
          .join("parts", on=("partkey", "p_partkey"), cols=("p_cat",))
          .group_by("p_cat").agg(n=("*", "count"), mx=("price", "max")),
    ]
    refs = [execute(db, qb.to_ir())[0] for qb in queries]
    db.fail_node(1)
    for qb, ref in zip(queries, refs):
        plan = plan_query(db, qb.to_ir())
        assert any(owner.endswith("_b1") for _, owner in plan.sources), \
            "expected a buddy store in the failover routing"
        db.attach_mesh()
        out, stats = execute(db, qb.to_ir())
        db.detach_mesh()
        assert stats.segmented
        assert_match(ref, out, ordered=False, label="failover")


def test_plan_cache_distinguishes_build_placement():
    """Two databases with identically-named tables but different dim
    segmentation (segmented-by-key vs replicated) produce the same
    logical signature and exchange plan ('local'), yet need different
    shard_map in_specs -- the plan cache must not hand one the other's
    executable."""
    def mk(replicated):
        rng = np.random.default_rng(3)
        db = VerticaDB(n_nodes=4, k_safety=0, block_rows=64)
        db.create_table(TableSchema("f", (
            ColumnDef("k"), ColumnDef("v"))),
            sort_order=("k",), segment_by=("k",))
        db.create_table(TableSchema("d", (
            ColumnDef("dk"), ColumnDef("attr"))),
            sort_order=("dk",),
            segment_by=() if replicated else ("dk",))
        t = db.begin()
        db.insert(t, "f", {"k": rng.integers(0, 50, 1000),
                           "v": rng.integers(0, 100, 1000)})
        db.insert(t, "d", {"dk": np.arange(50, dtype=np.int64),
                           "attr": np.arange(50, dtype=np.int64) % 5})
        db.commit(t)
        db.run_tuple_mover(force_moveout=True)
        return db
    for replicated in (False, True):
        db = mk(replicated)
        qb = (db.query("f").join("d", on=("k", "dk"), cols=("attr",))
              .group_by("attr").agg(n=("*", "count"), s=("v", "sum")))
        ref, out, stats = run_both(db, qb)
        assert stats.segmented
        assert stats.exchange == "local"
        assert_match(ref, out, ordered=False,
                     label=f"placement-{replicated}")


def test_fallback_outside_segmented_subset(star_db):
    """Plain selects fall back to the single-node pipeline untouched."""
    db = star_db
    qb = db.query("sales").where(col("day") == 17).select("sale_id", "qty")
    db.detach_mesh()
    ref, _ = execute(db, qb.to_ir())
    db.attach_mesh()
    out, stats = execute(db, qb.to_ir())
    db.detach_mesh()
    assert not stats.segmented
    assert_match(ref, out, ordered=False, label="select")


# ---------------------------------------------------------------------------
# distributed trickle load: writes interleaved between segmented queries
# ---------------------------------------------------------------------------

def _trickle(db, rng, n=60, base=100_000):
    """One small committed batch into the fact table (lands in per-shard
    WOS slabs, ring-tagged at commit)."""
    t = db.begin()
    db.insert(t, "sales", {
        "sale_id": base + np.arange(n, dtype=np.int64),
        "custkey": rng.integers(0, N_CUST, n),
        "suppkey": rng.integers(0, N_SUPP, n),
        "partkey": rng.integers(0, N_PART, n),
        "day": rng.integers(0, 365, n),
        "qty": rng.integers(1, 50, n),
        "delta": rng.integers(-40, 40, n),
        "price": np.round(rng.normal(100, 10, n), 2)})
    return db.commit(t)


def test_trickle_load_interleaved_oracle():
    """The 20-query differential corpus with trickle-load commits BETWEEN
    queries: segmented results must keep matching single-node, and after
    the first query per container-state the cached ROS slab must stay
    warm (only the small WOS delta re-slabs) even though every commit
    advances the cluster epoch."""
    db = make_db(seed=31)
    rng = np.random.default_rng(77)
    base = 100_000
    ros_state_seen = False
    for i in range(20):
        if i % 2 == 1:           # trickle between queries
            _trickle(db, rng, base=base)
            base += 1000
        if i == 13:              # a moveout mid-stream: containers change
            db.run_tuple_mover(force_moveout=True)
        qb = gen_query(db, rng)
        ir = qb.to_ir()
        ref, out, stats = run_both(db, qb)
        assert stats.segmented, (i, ir.signature())
        assert_match(ref, out, ordered=bool(ir.order_by), label=f"t{i}")
        if i >= 1 and "+wos" in stats.seg_slab:
            ros_state_seen = True
    assert ros_state_seen, "no query observed a WOS delta slab"


def test_trickle_commit_keeps_ros_slab_warm():
    """A commit that lands purely in the WOS must NOT invalidate the
    cached ROS slab: the epoch advances but ROS visibility (its epoch
    ceiling) is unchanged, so the warm query re-slabs only the delta."""
    db = make_db(seed=32)
    rng = np.random.default_rng(5)
    qb = (db.query("sales").where(col("qty") > 5)
          .group_by("suppkey").agg(n=("*", "count"), s=("qty", "sum")))
    _, _, s1 = run_both(db, qb)
    assert s1.seg_slab == "miss"
    _trickle(db, rng)                       # epoch advances, WOS only
    ref, out, s2 = run_both(db, qb)
    assert s2.seg_slab == "hit+wos", s2.seg_slab
    assert_match(ref, out, ordered=False, label="warm-ros+wos")
    # moveout drains the WOS into new containers: the old slab is evicted
    # precisely (key carries the container set) and the next run misses
    db.run_tuple_mover(force_moveout=True)
    ref, out, s3 = run_both(db, qb)
    assert s3.seg_slab == "miss", s3.seg_slab
    assert_match(ref, out, ordered=False, label="post-moveout")


def test_fail_load_rejoin_recover_cycle():
    """The full distributed-ingest availability story: fail a node, keep
    trickle-loading (buddy serves its segments), REJOIN it (it receives
    new commits but serves no reads), keep loading, then incremental
    recovery replays ONLY the epochs missed while down -- adopting
    segment-aligned buddy containers wholesale -- and the differential
    oracle holds at every stage."""
    db = make_db(k_safety=1, seed=41)
    rng = np.random.default_rng(13)
    queries = [
        db.query("sales").where(col("day") < 250)
          .group_by("suppkey").agg(n=("*", "count"), s=("qty", "sum")),
        db.query("sales")
          .join("customer", on=("custkey", "c_custkey"),
                cols=("c_nation",))
          .group_by("c_nation").agg(n=("*", "count")),
        db.query("sales")
          .join("parts", on=("partkey", "p_partkey"), cols=("p_cat",))
          .group_by("p_cat").agg(n=("*", "count"), s=("qty", "sum")),
    ]

    def check(label):
        for qi, qb in enumerate(queries):
            ref, out, stats = run_both(db, qb)
            assert stats.segmented
            assert_match(ref, out, ordered=False, label=f"{label}-{qi}")

    db.fail_node(1)
    _trickle(db, rng, base=200_000)        # loads route around the corpse
    check("down")
    # move the while-down loads into ROS on the buddy (moveout only: a
    # mergeout would fold them into pre-failure containers): recovery can
    # then adopt whole segment-aligned containers instead of replaying rows
    db.run_tuple_mover(force_moveout=True, do_mergeout=False)
    e_join = db.rejoin_node(1)
    assert db.nodes[1].up and db.nodes[1].recovering
    # rejoined-but-recovering: reads still route to the buddy...
    plan = plan_query(db, queries[0].to_ir())
    assert any(owner.endswith("_b1") for _, owner in plan.sources)
    # ...but NEW commits land on node 1 live (its WOS fills again)
    _trickle(db, rng, base=300_000)
    assert db.nodes[1].stores["sales_super"].wos.n_rows > 0
    check("recovering")
    replayed = recover_node(db, 1)
    assert not db.nodes[1].recovering
    rec = db.nodes[1].last_recovery
    assert rec["replay_hi"] == e_join      # only missed epochs replayed
    assert rec["adopted_containers"] > 0   # wholesale container copies
    assert replayed.get("sales_super", 0) > 0
    check("recovered")
    # node 1 must now serve its own segment: fail its buddy host and the
    # oracle still holds (would raise AvailabilityError pre-recovery)
    db.fail_node(2)
    check("buddy-down")


# ---------------------------------------------------------------------------
# exchange overflow is reported, never silent (satellite: resegment fix)
# ---------------------------------------------------------------------------

def test_resegment_overflow_is_reported():
    from repro.distributed.mesh import make_query_mesh
    mesh = make_query_mesh()
    n_shards = mesh.shape["data"]
    n = 64 * n_shards
    keys = np.arange(n, dtype=np.int32)
    dest = np.zeros(n, np.int32)            # everything wants shard 0
    import jax as _jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(mesh, P("data"))
    cols = {"k": _jax.device_put(keys, sharding)}
    dest_dev = _jax.device_put(dest, sharding)
    capacity = (n // 2 // n_shards) * n_shards   # half the needed slots
    out, valid, overflow = resegment(mesh, "data", cols, dest_dev,
                                     capacity)
    # capacity//n_shards slots per (source, dest) bucket: every source
    # holds n/n_shards rows for shard 0 and can ship only per of them
    per = capacity // n_shards
    per_source = n // n_shards
    dropped = (per_source - per) * n_shards
    ov = np.asarray(overflow)
    assert ov.shape == (n_shards,)
    # all overflow is on shard 0, and it is REPORTED, not silent
    assert int(ov[0]) == dropped
    assert int(ov.sum()) == dropped
    kept = np.asarray(out["k"])[np.asarray(valid)]
    assert kept.size == n - dropped
    # ample capacity -> zero overflow, every tuple arrives exactly once
    out2, valid2, overflow2 = resegment(mesh, "data", cols, dest_dev,
                                        n * n_shards)
    assert int(np.asarray(overflow2).sum()) == 0
    kept2 = np.asarray(out2["k"])[np.asarray(valid2)]
    assert sorted(kept2.tolist()) == keys.tolist()


# ---------------------------------------------------------------------------
# empty-snapshot scans: the slab build must degrade, never raise
# ---------------------------------------------------------------------------

def test_segmented_all_rows_deleted():
    """Deleting every fact row empties the snapshot: the slab build has
    zero visible rows (``v.min()`` on an empty partition used to raise)
    and the query must still answer -- falling back to the single-node
    shape of an empty aggregate, identically on both paths."""
    db = make_db(seed=51)
    t = db.begin()
    db.delete(t, "sales", lambda r: r["sale_id"] >= 0)
    db.commit(t)
    qb = (db.query("sales").where(col("qty") > 0)
          .group_by("suppkey").agg(n=("*", "count"), s=("qty", "sum")))
    ref, out, _stats = run_both(db, qb)
    assert len(ref["n"]) == 0
    assert_match(ref, out, ordered=False, label="all-deleted")


def test_segmented_wos_only_snapshot():
    """A projection whose every row still sits in the WOS (no moveout
    yet) has no ROS slab at all: the segmented path must run off the
    trickle buffers alone and match single-node."""
    db = make_db(seed=52)
    rng = np.random.default_rng(9)
    t = db.begin()
    db.delete(t, "sales", lambda r: r["sale_id"] >= 0)
    db.commit(t)
    _trickle(db, rng, n=120)                # WOS-only visible rows
    qb = (db.query("sales").group_by("suppkey")
          .agg(n=("*", "count"), s=("qty", "sum"), a=("price", "avg")))
    ref, out, stats = run_both(db, qb)
    assert len(ref["n"]) > 0
    assert stats.segmented, stats.seg_slab
    assert "+wos" in stats.seg_slab, stats.seg_slab
    assert_match(ref, out, ordered=False, label="wos-only")


def test_segmented_pruned_to_empty():
    """A predicate outside every block's SMA range prunes ALL slab
    blocks: the all-pads program must yield exactly the empty result the
    predicate implies, not raise or mis-shape."""
    db = make_db(seed=53)
    qb = (db.query("sales").where(col("day") >= 100_000)
          .group_by("suppkey").agg(n=("*", "count")))
    ref, out, stats = run_both(db, qb)
    assert len(ref["n"]) == 0
    assert stats.segmented
    assert stats.blocks_total > 0
    assert stats.blocks_pruned == stats.blocks_total
    assert_match(ref, out, ordered=False, label="pruned-empty")


def test_segmented_pruning_differential(star_db):
    """Selective range predicates drive the slab-block pruner; results
    must stay exact and the pruned-block telemetry must move."""
    db = star_db
    qb = (db.query("sales").where((col("day") >= 40) & (col("day") < 80))
          .group_by("suppkey").agg(n=("*", "count"), s=("qty", "sum")))
    ref, out, stats = run_both(db, qb)
    assert stats.segmented
    assert stats.blocks_total > 0
    assert stats.blocks_pruned < stats.blocks_total
    assert_match(ref, out, ordered=False, label="pruned-range")


# ---------------------------------------------------------------------------
# shard-index-column cache: bounded, oldest-first eviction
# ---------------------------------------------------------------------------

def test_shard_index_cache_retention(star_db):
    from repro.engine import segmented as seg

    db = star_db
    db.attach_mesh()
    try:
        mesh, axis = db.mesh, db.mesh_axis
        n_shards = int(mesh.shape[axis])
        seg._SHARD_IDX_CACHE.clear()
        first = seg._shard_index_col(mesh, axis, n_shards, 8)
        # warm re-request returns the SAME device array (no rebuild)
        assert seg._shard_index_col(mesh, axis, n_shards, 8) is first
        for w in range(2, 2 + seg._SHARD_IDX_CAP + 10):
            seg._shard_index_col(mesh, axis, n_shards, 8 * w)
        # bounded: never grows past the cap
        assert len(seg._SHARD_IDX_CACHE) <= seg._SHARD_IDX_CAP
        # oldest-first: the width-8 entry fell out, the newest survive
        # (a wholesale clear() would have left exactly one entry)
        sig = seg._mesh_sig(mesh, axis)
        assert (sig, 8) not in seg._SHARD_IDX_CACHE
        last_w = 8 * (2 + seg._SHARD_IDX_CAP + 9)
        newest = seg._SHARD_IDX_CACHE[(sig, last_w)]
        assert seg._shard_index_col(mesh, axis, n_shards, last_w) is newest
        assert len(seg._SHARD_IDX_CACHE) > 1
    finally:
        db.detach_mesh()
