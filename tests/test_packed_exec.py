"""Differential + property tests for real bit-packed storage and
compressed-domain execution.

Two guarantees are under test (DESIGN.md §9):

1.  **Packing is lossless** -- property tests (real hypothesis or the
    deterministic shim) round-trip every width 1..32, negative values,
    empty inputs and FLOAT_SCALED through the actual packed word streams.

2.  **Code-domain execution is byte-identical** -- a 20-query seeded
    corpus runs twice, ``db.exec_mode = "decoded"`` (legacy decode-then-
    filter) vs ``"compressed"`` (code-domain predicates, code-space GROUP
    BY, late materialization; engine/compressed.py), and every output
    column must match exactly -- assert_array_equal, not allclose.
"""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import (ColumnDef, Encoding, SQLType, TableSchema,
                        VerticaDB)
from repro.core.encodings import (MAX_PACK_BITS, encode, pack_words,
                                  symbol_width, unpack_words)
from repro.core.projection import super_projection
from repro.engine import col, execute

# ---------------------------------------------------------------------------
# property tests: packing round-trips
# ---------------------------------------------------------------------------


@settings(max_examples=25)
@given(st.integers(1, MAX_PACK_BITS),
       st.integers(1, 200), st.integers(0, 2 ** 31))
def test_pack_words_round_trip(width, n, seed):
    rng = np.random.default_rng(seed)
    syms = rng.integers(0, 1 << width, (2, n), dtype=np.uint64) \
        .astype(np.int64)
    words = pack_words(syms, width)
    assert words.dtype == np.uint32
    assert words.shape == (2, ((n + 31) // 32) * width)
    np.testing.assert_array_equal(unpack_words(words, width, n), syms)


@settings(max_examples=25)
@given(st.lists(st.integers(-2 ** 40, 2 ** 40), min_size=0, max_size=300),
       st.sampled_from([e for e in Encoding
                        if e not in (Encoding.AUTO, Encoding.FLOAT_SCALED)]))
def test_int_encodings_round_trip_any_values(xs, enc):
    """Every integer encoding round-trips bit-exactly through its real
    packed buffers -- negatives, wide deltas (>32-bit fallback), empty."""
    values = np.asarray(xs, dtype=np.int64)
    c = encode(values, SQLType.INT, enc, block_rows=64)
    np.testing.assert_array_equal(c.decode(), values)
    assert c.packed_bytes >= 0


@settings(max_examples=15)
@given(st.lists(st.integers(-10 ** 6, 10 ** 6), min_size=1, max_size=200))
def test_packed_device_decode_matches_host(xs):
    """decode_jnp (device bit-unpack kernel path) == decode (host numpy)
    for every encoding that packs, byte-identical."""
    from repro.core.encodings import decode_jnp
    values = np.asarray(xs, dtype=np.int64)
    for enc in (Encoding.DELTA_VALUE, Encoding.BLOCK_DICT,
                Encoding.DELTA_RANGE, Encoding.COMMON_DELTA):
        c = encode(values, SQLType.INT, enc, block_rows=64)
        dev = np.asarray(decode_jnp(c)).reshape(-1)[: values.size]
        host = c.decode().astype(np.int32)      # device lanes are int32
        np.testing.assert_array_equal(dev.astype(np.int64), host)


@settings(max_examples=15)
@given(st.lists(st.integers(-10 ** 4, 10 ** 4), min_size=1, max_size=150),
       st.integers(0, 2))
def test_float_scaled_round_trip(xs, k):
    values = np.asarray(xs, dtype=np.float64) / (10.0 ** k)
    c = encode(values, SQLType.FLOAT, Encoding.FLOAT_SCALED, block_rows=64)
    np.testing.assert_array_equal(c.decode(), values)


def test_empty_column_every_encoding():
    for enc in Encoding:
        if enc == Encoding.FLOAT_SCALED:
            continue
        c = encode(np.zeros(0, np.int64), SQLType.INT, enc, block_rows=64)
        assert c.decode().size == 0


def test_symbol_width_edges():
    assert symbol_width(0) == 1
    assert symbol_width(1) == 1
    assert symbol_width(2) == 2
    assert symbol_width((1 << 32) - 1) == 32


# ---------------------------------------------------------------------------
# differential corpus: compressed vs decoded execution, byte-identical
# ---------------------------------------------------------------------------

N_ROWS = 3000
N_DIM = 120


def _build_db():
    rng = np.random.default_rng(11)
    db = VerticaDB(n_nodes=4, k_safety=0, block_rows=64)
    schema = TableSchema("sales", (
        ColumnDef("sale_id"), ColumnDef("cid"), ColumnDef("day"),
        ColumnDef("qty"), ColumnDef("price", SQLType.FLOAT)))
    db.catalog.add_table(schema)
    # force BLOCK_DICT on the low-cardinality filter/group column so the
    # code-range predicate rewrite actually engages
    db.create_projection(super_projection(
        schema, ("day",), ("sale_id",),
        encodings={"cid": Encoding.BLOCK_DICT}))
    db.create_table(TableSchema("customer", (
        ColumnDef("c_cid"), ColumnDef("c_nation"))),
        sort_order=("c_cid",), segment_by=())
    t = db.begin()
    db.insert(t, "sales", {
        "sale_id": np.arange(N_ROWS, dtype=np.int64),
        "cid": rng.integers(0, N_DIM, N_ROWS),
        "day": rng.integers(0, 365, N_ROWS),
        "qty": rng.integers(1, 50, N_ROWS),
        "price": np.round(rng.normal(100, 10, N_ROWS), 2)})
    db.insert(t, "customer", {
        "c_cid": np.arange(N_DIM, dtype=np.int64),
        "c_nation": rng.integers(0, 8, N_DIM)})
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    return db


@pytest.fixture(scope="module")
def packed_db():
    return _build_db()


def _corpus(db, rng):
    """One seeded corpus query: int-interval filters (dict + non-dict
    columns), 1-2 group keys, mixed aggregates, sometimes a join."""
    qb = db.query("sales")
    r = rng.random()
    if r < 0.4:                       # dict-column interval (code range)
        lo = int(rng.integers(0, 80))
        qb = qb.where((col("cid") >= lo)
                      & (col("cid") <= lo + int(rng.integers(5, 60))))
    elif r < 0.7:                     # mixed dict + sorted column
        qb = qb.where((col("cid") < int(rng.integers(20, 100)))
                      & (col("day") >= int(rng.integers(0, 200))))
    elif r < 0.9:                     # equality on the dict column
        qb = qb.where(col("cid") == int(rng.integers(0, N_DIM)))
    # else: no predicate -> ineligible, must still match via decoded path
    if rng.random() < 0.3:
        qb = qb.join("customer", on=("cid", "c_cid"), cols=("c_nation",))
        keys = ["c_nation"]
    else:
        keys = ["cid"] if rng.random() < 0.6 else ["day"]
        if rng.random() < 0.3:
            keys.append("qty")
    qb = qb.group_by(*keys).agg(n=("*", "count"))
    for name, spec in (("s", ("qty", "sum")), ("mn", ("price", "min")),
                       ("mx", ("price", "max")), ("a", ("price", "avg"))):
        if rng.random() < 0.4:
            qb = qb.agg(**{name: spec})
    return qb


def _run_mode(db, q, mode):
    # exec_mode "decoded"/"compressed" force their scan path regardless of
    # cache residency, and the compressed plan signature carries a "cdom"
    # suffix -- the two modes can share warm caches without collisions
    db.exec_mode = mode
    out, stats = execute(db, q)
    return out, stats


def test_differential_corpus_byte_identical(packed_db):
    db = packed_db
    rng = np.random.default_rng(5)
    n_compressed = 0
    db.block_cache.clear()
    try:
        for i in range(20):
            q = _corpus(db, rng).to_ir()
            ref, _ = _run_mode(db, q, "decoded")
            out, st = _run_mode(db, q, "compressed")
            n_compressed += bool(st.compressed_scan)
            assert set(ref) == set(out), (i, sorted(ref), sorted(out))
            for c in ref:
                np.testing.assert_array_equal(
                    np.asarray(ref[c]), np.asarray(out[c]),
                    err_msg=f"query {i} column {c}")
    finally:
        db.exec_mode = "auto"
    # the corpus must actually exercise the code-domain path
    assert n_compressed >= 8, n_compressed


def test_compressed_with_deleted_tail_blocks(packed_db):
    """All-deleted tail blocks: survivors must respect delete vectors and
    the padded tail, byte-identically."""
    db = _build_db()
    t = db.begin()
    db.delete(t, "sales", lambda r: r["day"] >= 300)   # kills tail blocks
    db.commit(t)
    q = (db.query("sales")
         .where((col("cid") >= 10) & (col("cid") <= 90))
         .group_by("cid").agg(n=("*", "count"), s=("qty", "sum"))
         .to_ir())
    ref, _ = _run_mode(db, q, "decoded")
    out, st = _run_mode(db, q, "compressed")
    assert st.compressed_scan
    for c in ref:
        np.testing.assert_array_equal(np.asarray(ref[c]),
                                      np.asarray(out[c]), err_msg=c)


def test_zero_survivors(packed_db):
    db = packed_db
    q = (db.query("sales").where(col("cid") == N_DIM + 5)
         .group_by("cid").agg(n=("*", "count")).to_ir())
    ref, _ = _run_mode(db, q, "decoded")
    out, st = _run_mode(db, q, "compressed")
    db.exec_mode = "auto"
    assert set(ref) == set(out)
    for c in ref:
        np.testing.assert_array_equal(np.asarray(ref[c]),
                                      np.asarray(out[c]), err_msg=c)


def test_auto_mode_prefers_warm_decoded(packed_db):
    """auto: a budget too small for the decoded working set takes the
    compressed scan; a comfortable budget keeps the legacy path (same
    plan signature cold and warm, so repeats stay plan-cache hits)."""
    db = packed_db
    db.exec_mode = "auto"
    q = (db.query("sales")
         .where((col("cid") >= 5) & (col("cid") <= 50))
         .group_by("cid").agg(n=("*", "count")).to_ir())
    db.block_cache.clear()
    old_budget = db.block_cache.budget_bytes
    try:
        # constrained: decoded residency unattainable -> code domain
        db.block_cache.budget_bytes = 1 << 14
        _, st_cold = execute(db, q)
        assert st_cold.compressed_scan
        # comfortable budget: legacy decode-and-cache, cold AND warm
        db.block_cache.budget_bytes = old_budget
        db.block_cache.clear()
        _, st_cold2 = execute(db, q)
        assert not st_cold2.compressed_scan
        _, st_warm = execute(db, q)
        assert not st_warm.compressed_scan
    finally:
        db.block_cache.budget_bytes = old_budget
        db.exec_mode = "auto"


def test_plan_signature_includes_symbol_width(packed_db):
    """Dictionary growth changes the packed symbol width, which must be
    part of the compressed plan identity (width_signature)."""
    c = encode(np.arange(10, dtype=np.int64), SQLType.INT,
               Encoding.BLOCK_DICT, block_rows=64)
    c2 = encode(np.arange(40, dtype=np.int64) % 33, SQLType.INT,
                Encoding.BLOCK_DICT, block_rows=64)
    assert c.width_signature() != c2.width_signature()
    assert c.widths["codes_packed"] == symbol_width(9)
