"""Per-arch smoke tests (reduced configs): one train step + one decode step
on CPU, shapes + finiteness; head-layout properties; SSD oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import build_model, init_params
from repro.models.attention import resolve_head_layout
from repro.models.ssm import (resolve_ssm_layout, ssd_apply, ssd_reference,
                              ssm_decls)
from repro.models.params import init_params as raw_init
from repro.configs.base import RunConfig
from repro.train.train_step import init_train_state, make_train_step


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg, tp=2)
    state = init_train_state(model, jax.random.key(0))
    step = make_train_step(model, RunConfig(total_steps=10, warmup_steps=1))
    batch = _batch(cfg)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert np.isfinite(float(metrics["grad_norm"]))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_decode(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg, tp=2)
    params = init_params(model.decls, jax.random.key(0))
    batch = _batch(cfg)
    B, S = batch["tokens"].shape
    logits, cache = model.prefill(
        params, {k: v for k, v in batch.items() if k != "labels"},
        max_len=S + 2)
    assert logits.shape[:2] == (B, 1)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache = model.decode_step(params, cache, tok,
                                       jnp.asarray(S, jnp.int32))
    assert logits2.shape[:2] == (B, 1)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-130m", "hymba-1.5b"])
def test_decode_matches_prefill(arch):
    cfg = configs.get(arch).reduced()
    model = build_model(cfg, tp=2)
    params = init_params(model.decls, jax.random.key(0))
    rng = np.random.default_rng(3)
    B, S = 2, 32
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                      jnp.int32)
    _, cache = model.prefill(params, {"tokens": tok[:, :S]}, max_len=S + 4)
    ld, _ = model.decode_step(params, cache, tok[:, S:],
                              jnp.asarray(S, jnp.int32))
    lf, _ = model.prefill(params, {"tokens": tok})
    assert float(jnp.abs(ld - lf).max()) < 0.5  # bf16 path tolerance


# ---------------------------------------------------------------------------
# HeadLayout properties: every real q head appears exactly once, mapped to
# its true kv head; layout is even over the model axis.
# ---------------------------------------------------------------------------

head_cases = st.tuples(st.sampled_from([1, 2, 4, 5, 8, 16, 25, 32, 36]),
                       st.sampled_from([1, 2, 4, 8, 16]))


@settings(max_examples=60, deadline=None)
@given(head_cases, st.sampled_from([1, 2, 4, 8, 16]))
def test_head_layout_properties(hq_hkv, tp):
    hq, hkv = hq_hkv
    if hq % hkv != 0:
        hkv = 1
    lo = resolve_head_layout(hq, hkv, 64, tp)
    assert lo.kv_eff % tp == 0
    seen = [q for q in lo.q_map if q >= 0]
    assert sorted(seen) == list(range(hq))          # exactly once each
    group = hq // hkv
    for slot, q in enumerate(lo.q_map):
        if q >= 0:
            kv_slot = slot // lo.g_eff
            assert lo.kv_map[kv_slot] == q // group  # right kv head
    assert len(lo.alive) == lo.kv_eff * lo.g_eff


def test_ssd_matches_sequential_oracle():
    cfg = configs.get("mamba2-130m").reduced()
    lo = resolve_ssm_layout(cfg.d_model, cfg.ssm, 2)
    p = raw_init(ssm_decls(cfg.d_model, lo), jax.random.key(1))
    u = jax.random.normal(jax.random.key(2), (2, 96, cfg.d_model))
    y_chunk = ssd_apply(p, u, lo, cfg.ssm.chunk)
    y_seq = ssd_reference(p, u, lo)
    assert float(jnp.abs(y_chunk - y_seq).max()) < 1e-4


def test_ssd_state_handoff():
    """prefill state + decode step == prefill of S+1 (state correctness)."""
    cfg = configs.get("mamba2-130m").reduced()
    model = build_model(cfg, tp=1)
    params = init_params(model.decls, jax.random.key(0))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 33)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": tok[:, :32]})
    ld, _ = model.decode_step(params, cache, tok[:, 32:],
                              jnp.asarray(32, jnp.int32))
    lf, _ = model.prefill(params, {"tokens": tok})
    assert float(jnp.abs(ld - lf).max()) < 0.05


def test_vocab_padding_masked():
    cfg = configs.get("granite-3-8b").reduced()  # vocab 256 -> padded
    model = build_model(cfg, tp=2)
    params = init_params(model.decls, jax.random.key(0))
    logits, _ = model.prefill(params, {"tokens": jnp.zeros((1, 8),
                                                           jnp.int32)})
    # reduced vocab=256 pads to 256: use full cfg check on layer fn instead
    from repro.models.layers import pad_vocab
    assert pad_vocab(49155) == 49280 or pad_vocab(49155) % 256 == 0


def test_moe_routing_conservation():
    """Every kept (token, expert) contributes gate-weighted output; gates
    renormalize to 1 over top-k."""
    from repro.models.moe import _route
    from repro.configs.base import MoEConfig
    import repro.models.moe as moe_mod
    cfg = configs.get("olmoe-1b-7b").reduced()
    d, e = cfg.d_model, cfg.moe.num_experts
    p = raw_init(moe_mod.moe_decls(d, cfg.moe), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (64, d))
    gates, experts, aux = _route(p, x, cfg.moe)
    assert gates.shape == (64, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-3)
    assert int(experts.max()) < e
    assert float(aux) > 0
