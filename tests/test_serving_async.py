"""Async overlapped serving tests (engine/serving.py pipelined core):
dispatch/drain byte-identity, bulkhead invariants, token-bucket rate
limiting, SMA cost-model admission, and crash-during-drain failover.

The differential tests reuse test_serving.py's corpus and exact-equality
helper: overlapped dispatch (futures parked, one batched device->host
transfer per unit at drain) must produce BYTE-IDENTICAL results to
independent execution -- the async rebuild may change scheduling, never
bytes.  Schedules run on a VirtualClock, so nothing here sleeps on the
wall clock and every replay is deterministic.
"""
import time

import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import CrashNode, Hang, QueryRejectedError, Transient
from repro.engine import col, execute
from repro.engine import serving
from repro.engine.serving import TokenBucket, VirtualClock

from test_serving import assert_identical, corpus, make_db, wave_rows


@pytest.fixture(scope="module")
def async_db():
    return make_db()


@pytest.fixture
def transfer_meter(monkeypatch):
    """Counts the drain stage's batched device->host transfers and flags
    any stray per-member host sync: ``_shared_general`` is the only
    serving code that still calls ``np.asarray`` on device arrays (the
    pre-async collect path, kept for WOS/overflow fallbacks), so the
    normal ROS path must never enter it."""
    class Meter:
        def __init__(self):
            self.t0 = serving.device_transfer_count()
            self.stray_syncs = []

        def transfers(self):
            return serving.device_transfer_count() - self.t0

    meter = Meter()
    real = serving.QueryService._shared_general

    def spy(self, q, plan, cols, valid, es):
        meter.stray_syncs.append(getattr(q, "table", "?"))
        return real(self, q, plan, cols, valid, es)

    monkeypatch.setattr(serving.QueryService, "_shared_general", spy)
    return meter


# ---------------------------------------------------------------------------
# (a) differential byte-identity: overlapped == cooperative, ROS and WOS
# ---------------------------------------------------------------------------

def test_overlapped_differential_byte_identical_ros(async_db):
    db = async_db
    qs = corpus(db)
    refs = [execute(db, q)[0] for q in qs]

    svc = db.serve(queue_depth=len(qs) + 1, max_coalesce=4,
                   max_concurrent=3, max_in_flight=8,
                   clock=VirtualClock())
    tickets = [svc.submit(q) for q in qs]
    svc.drain()

    for q, ref, t in zip(qs, refs, tickets):
        assert_identical(ref, t.result(), label=str(t.id))
    # the rebuild actually overlapped: units were parked in flight and
    # each harvested flight cost exactly one batched transfer
    assert svc.stats.async_units >= 1
    assert svc.stats.drains == svc.stats.async_units
    assert svc.stats.device_transfers == svc.stats.drains
    assert any(t.stats.async_dispatch for t in tickets)
    assert db.epochs.n_pinned() == 0


def test_overlapped_differential_byte_identical_with_pending_wos():
    """Same corpus with uncommitted-to-ROS WOS rows pending: members take
    the side-scan (dispatch-time) path, selects still park device refs."""
    db = make_db(waves=2, n_per_wave=800)
    rng = np.random.default_rng(21)
    t = db.begin()
    db.insert(t, "sales", wave_rows(rng, 50_000, 300))
    db.commit(t)                       # stays in WOS: no moveout

    qs = corpus(db)
    refs = [execute(db, q)[0] for q in qs]
    svc = db.serve(queue_depth=len(qs) + 1, max_coalesce=len(qs),
                   max_concurrent=2, clock=VirtualClock())
    tickets = [svc.submit(q) for q in qs]
    svc.drain()
    for ref, t in zip(refs, tickets):
        assert_identical(ref, t.result(), label=str(t.id))
    assert db.epochs.n_pinned() == 0


# ---------------------------------------------------------------------------
# satellite: ONE device->host transfer per coalesced group, no stray syncs
# ---------------------------------------------------------------------------

def test_shared_collect_one_transfer_per_group(async_db, transfer_meter):
    """The old collect path ran three ``np.asarray`` syncs per select
    member; the drain stage batches every member of a coalesced group
    into ONE ``jax.device_get``."""
    db = async_db
    q = db.query
    selects = [
        q("sales").where(col("day") == 33)
        .select("sale_id", "cid", "price").to_ir(),
        q("sales").where(col("day") == 33).select("sale_id", "qty").to_ir(),
        q("sales").where((col("day") > 100) & (col("day") < 104))
        .select("sale_id", "day", "price").to_ir(),
        q("sales").select(margin=col("price") * col("qty"))
        .where(col("day") == 200).to_ir(),
    ]
    refs = [execute(db, s)[0] for s in selects]

    svc = db.serve(queue_depth=8, max_coalesce=8, max_concurrent=1,
                   clock=VirtualClock())
    tickets = [svc.submit(s) for s in selects]
    svc.drain()

    for ref, t in zip(refs, tickets):
        assert_identical(ref, t.result(), label=str(t.id))
    assert all(t.stats.share_group == len(selects) for t in tickets)
    # one coalesced unit -> one flight -> one batched transfer
    assert svc.stats.drains == 1
    assert transfer_meter.transfers() == 1
    assert transfer_meter.stray_syncs == []     # sync fallback never ran
    assert db.epochs.n_pinned() == 0


# ---------------------------------------------------------------------------
# (b) bulkhead invariant: per-class in-flight never exceeds max_in_flight
# ---------------------------------------------------------------------------

def test_bulkhead_bounds_in_flight_under_flood(async_db):
    db = async_db
    caps = {"interactive": 3, "batch": 2}
    svc = db.serve(queue_depth=64, max_coalesce=1, max_concurrent=8,
                   max_in_flight=caps, clock=VirtualClock())
    rng = np.random.default_rng(50)
    q = db.query("sales").group_by("cid").agg(n=("*", "count")).to_ir()
    tickets = []
    for _ in range(50):
        pr = "batch" if rng.random() < 0.5 else "interactive"
        tickets.append(svc.submit(q, priority=pr))
        svc.step()
        for cls, cap in caps.items():
            assert svc.in_flight(cls) <= cap, (cls, svc.in_flight(cls))
    while svc.pending() or svc._inflight:
        svc.step()
        for cls, cap in caps.items():
            assert svc.in_flight(cls) <= cap, (cls, svc.in_flight(cls))
    assert svc.stats.completed == 50
    # the flood actually pressed against the bulkheads
    assert svc.stats.peak_in_flight.get("interactive", 0) >= 1
    assert all(svc.stats.peak_in_flight.get(c, 0) <= cap
               for c, cap in caps.items())
    ref = execute(db, q)[0]
    assert_identical(ref, tickets[0].result())
    assert db.epochs.n_pinned() == 0


# ---------------------------------------------------------------------------
# (c) token bucket: refill/consume determinism + typed pin-free rejection
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(st.integers(1, 20), st.integers(1, 10),
       st.lists(st.integers(0, 30), min_size=1, max_size=60))
def test_token_bucket_deterministic_and_bounded(rate, burst, gaps):
    """Two buckets fed the identical virtual-time schedule agree on
    every decision; tokens stay within [0, burst]; total acceptances
    never exceed burst + rate x elapsed (no token is minted twice)."""
    c1, c2 = VirtualClock(), VirtualClock()
    b1 = TokenBucket(rate, burst, clock=c1)
    b2 = TokenBucket(rate, burst, clock=c2)
    accepted = 0
    for g in gaps:
        dt = g * 0.1
        c1.advance(dt)
        c2.advance(dt)
        r1, r2 = b1.try_consume(), b2.try_consume()
        assert r1 == r2                       # deterministic replay
        assert -1e-9 <= b1.tokens <= burst + 1e-9
        accepted += r1
    elapsed = sum(gaps) * 0.1
    assert accepted <= burst + rate * elapsed + 1e-6


def test_rate_limited_rejection_is_typed_and_never_pins():
    db = make_db(waves=1, n_per_wave=400)
    inj = db.enable_faults(seed=9)        # no rules: just count hits
    clock = VirtualClock()
    svc = db.serve(queue_depth=16, clock=clock)
    q = db.query("sales").agg(n=("*", "count")).to_ir()

    s = svc.session("interactive", rate_limit=(1.0, 2.0))
    accepted, rejected = [], []
    for _ in range(5):                    # burst of 2, no time passes
        try:
            accepted.append(s.submit(q))
        except QueryRejectedError as e:
            assert e.reason.startswith("rate_limited")
            rejected.append(e)
    assert len(accepted) == 2 and len(rejected) == 3
    assert svc.stats.rejected_rate_limited == 3
    # a throttled submit never pinned: only the admitted queue holds pins
    assert db.epochs.n_pinned() == len(accepted)
    assert inj.hit_count("serving.rate_limit") == 3

    clock.advance(1.5)                    # refill 1.5 tokens -> one more
    accepted.append(s.submit(q))
    with pytest.raises(QueryRejectedError):
        s.submit(q)
    svc.drain()
    for t in accepted:
        assert int(t.result()["n"][0]) == 400
    assert db.epochs.n_pinned() == 0
    s.close()


# ---------------------------------------------------------------------------
# (d) cost model: SMA pricing vs raw row counts, both directions
# ---------------------------------------------------------------------------

def _prices(db, q):
    """(sma, raw) admission prices of q, read off a free-running serve."""
    svc = db.serve(queue_depth=4)
    t = svc.submit(q)
    svc.drain()
    t.result()
    return t.stats.cost_bytes, svc._raw_working_set_bytes(t.plan,
                                                          t.scan_need)


def test_cost_model_rejects_padded_scan_raw_rows_would_admit():
    """Fragmented store: every tiny trickle wave is its own container
    whose single decoded block is block_rows lanes of mostly padding.
    SMA pricing counts the blocks the scan will actually decode; raw row
    counts see almost nothing."""
    db = make_db(waves=6, n_per_wave=30, block_rows=256)
    q = db.query("sales").group_by("cid").agg(n=("*", "count")).to_ir()
    sma, raw = _prices(db, q)
    assert sma > raw * 2, (sma, raw)     # padding dominates the true cost

    ceiling = (raw + sma) // 2           # raw-priced admission would admit
    svc = db.serve(queue_depth=4, max_cost_bytes=ceiling)
    t = svc.submit(q)
    svc.drain()
    with pytest.raises(QueryRejectedError) as ei:
        t.result()
    assert "max_cost_bytes" in ei.value.reason
    assert t.stats.rejected_reason == "cost"
    assert svc.stats.rejected_cost == 1
    assert raw <= ceiling                # the raw pricer WOULD have admitted
    assert db.epochs.n_pinned() == 0


def test_cost_model_admits_pruned_scan_raw_rows_would_reject():
    """Heavily-pruned predicate: the sort column's SMAs eliminate almost
    every block, so the SMA price is a fraction of the raw-row price --
    admission keyed to raw rows would starve exactly the queries pruning
    makes cheap."""
    db = make_db(waves=3, n_per_wave=2000, block_rows=64)
    q = db.query("sales").where(col("day") < 5).group_by("cid") \
        .agg(n=("*", "count")).to_ir()
    sma, raw = _prices(db, q)
    assert sma * 2 < raw, (sma, raw)     # pruning made it cheap

    ceiling = (sma + raw) // 2           # raw-priced admission would reject
    svc = db.serve(queue_depth=4, max_cost_bytes=ceiling)
    t = svc.submit(q)
    svc.drain()
    ref = execute(db, q)[0]
    assert_identical(ref, t.result())    # admitted AND correct
    assert raw > ceiling                 # the raw pricer would have refused
    assert db.epochs.n_pinned() == 0


def test_cheap_batch_query_boosted_into_interactive_queue():
    db = make_db(waves=3, n_per_wave=2000, block_rows=64)
    heavy = db.query("sales").group_by("cid").agg(s=("price", "sum")).to_ir()
    cheap = db.query("sales").where(col("day") < 5).group_by("cid") \
        .agg(n=("*", "count")).to_ir()
    heavy_price, _ = _prices(db, heavy)
    cheap_price, _ = _prices(db, cheap)
    assert cheap_price < heavy_price
    svc = db.serve(queue_depth=16, max_coalesce=1, max_concurrent=1,
                   boost_cost_bytes=(cheap_price + heavy_price) // 2,
                   clock=VirtualClock())
    t_heavy = [svc.submit(heavy, priority="batch") for _ in range(3)]
    t_cheap = svc.submit(cheap, priority="batch")
    svc.drain()
    assert t_cheap.stats.cost_boosted
    assert svc.stats.cost_boosts == 1
    # the boosted ticket jumped the batch queue it was submitted behind
    assert t_cheap.stats.dispatch_seq < max(t.stats.dispatch_seq
                                            for t in t_heavy)
    assert db.epochs.n_pinned() == 0


# ---------------------------------------------------------------------------
# (e) crash during drain: fails over once, byte-identical
# ---------------------------------------------------------------------------

def test_drain_crash_fails_over_once_byte_identical():
    db = make_db()
    qs = corpus(db)[:6]
    refs = [execute(db, q)[0] for q in qs]
    inj = db.enable_faults(seed=11)
    inj.on("serving.drain", CrashNode(node=2), hit=1)

    svc = db.serve(queue_depth=len(qs) + 1, max_coalesce=len(qs),
                   max_concurrent=2, clock=VirtualClock())
    tickets = [svc.submit(q) for q in qs]
    svc.drain()

    for ref, t in zip(refs, tickets):
        assert_identical(ref, t.result(), label=str(t.id))
    # the crashed flight's members each failed over exactly once (the
    # solo re-run replans onto buddies at the still-pinned epoch)
    crashed = [t for t in tickets if t.stats.failovers]
    assert crashed and all(t.stats.failovers == 1 for t in crashed)
    assert inj.fired("serving.drain") == 1
    assert db.epochs.n_pinned() == 0


def test_drain_transient_exhaustion_rejects_typed():
    db = make_db(waves=1, n_per_wave=400)
    inj = db.enable_faults(seed=13)
    inj.on("serving.drain", Transient(), times=inj.max_attempts)
    svc = db.serve(queue_depth=8, max_coalesce=1, clock=VirtualClock())
    q = db.query("sales").agg(n=("*", "count")).to_ir()
    t = svc.submit(q)
    svc.drain()
    with pytest.raises(QueryRejectedError):
        t.result()
    assert t.stats.rejected_reason == "unavailable"
    assert db.epochs.n_pinned() == 0
    # budget consumed: the next query drains clean
    t2 = svc.submit(q)
    svc.drain()
    assert int(t2.result()["n"][0]) == 400


# ---------------------------------------------------------------------------
# satellite: deterministic harness -- Hang advances virtual time, not wall
# ---------------------------------------------------------------------------

def test_hang_at_dispatch_and_drain_advances_virtual_clock_only():
    db = make_db(waves=1, n_per_wave=400)
    inj = db.enable_faults(seed=5)
    inj.on("serving.dispatch", Hang(2.5), hit=1)
    inj.on("serving.drain", Hang(1.25), hit=1)
    clock = VirtualClock()
    svc = db.serve(queue_depth=8, max_coalesce=1, clock=clock)
    q = db.query("sales").agg(n=("*", "count")).to_ir()

    wall0 = time.time()
    t = svc.submit(q)
    svc.drain()
    assert int(t.result()["n"][0]) == 400
    # both hangs landed on the virtual clock...
    assert clock.now() >= 3.75
    assert t.stats.exec_s >= 1.25        # the drain hang is execution time
    # ...and none of it was wall time (generous slack for real compute)
    assert time.time() - wall0 < 2.0
    assert db.epochs.n_pinned() == 0


def test_virtual_clock_timeout_expiry_is_deterministic():
    db = make_db(waves=1, n_per_wave=400)
    clock = VirtualClock()
    svc = db.serve(queue_depth=8, default_timeout_s=10.0, clock=clock)
    q = db.query("sales").agg(n=("*", "count")).to_ir()
    stale = svc.submit(q)
    clock.advance(11.0)                  # exceeds the queue timeout
    fresh = svc.submit(q)
    svc.drain()
    with pytest.raises(QueryRejectedError):
        stale.result()
    assert stale.stats.rejected_reason == "timeout"
    assert int(fresh.result()["n"][0]) == 400
    assert db.epochs.n_pinned() == 0


def test_injection_point_registry_covers_async_serving():
    from repro.core import INJECTION_POINTS
    for pt in ("serving.dispatch", "serving.drain", "serving.rate_limit"):
        assert pt in INJECTION_POINTS
