"""Training substrate: optimizer, checkpoint round-trip + buddy restore,
quorum gradients, int8 compression, token-store epoch pinning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, RunConfig
from repro.data import TokenStore, token_corpus
from repro.models import build_model
from repro.train.checkpoint import (CheckpointStore, shard_state,
                                    unshard_state)
from repro.train.fault_tolerance import (DPSimulator, compress_grads_int8,
                                         compressed_allreduce,
                                         decompress_grads_int8,
                                         quorum_combine)
from repro.train.optim import lr_schedule
from repro.train.train_step import init_train_state, make_train_step

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16)


def _state_and_step():
    model = build_model(TINY, tp=1)
    state = init_train_state(model, jax.random.key(0))
    rc = RunConfig(total_steps=50, warmup_steps=5)
    return model, state, jax.jit(make_train_step(model, rc))


def test_loss_decreases_on_fixed_batch():
    model, state, step = _state_and_step()
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, 128, (4, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8


def test_lr_schedule_shape():
    rc = RunConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(rc, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup
    assert lrs[2] > lrs[3] > lrs[4]          # cosine decay
    assert lrs[4] >= 0.09e-3                 # floor at 10%


def test_checkpoint_roundtrip_and_buddy(tmp_path):
    model, state, step = _state_and_step()
    ck = CheckpointStore(tmp_path, n_shards=4)
    np_state = jax.tree.map(np.asarray, state)
    for s in range(4):
        ck.save_shard(7, s, shard_state(np_state, s, 4))
    ck.commit_epoch(7)
    assert ck.last_good_epoch() == 7
    # primary path
    shards = [ck.restore_shard(7, s, shard_state(np_state, s, 4))
              for s in range(4)]
    full = unshard_state(shards, np_state)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(np_state)):
        np.testing.assert_array_equal(a, b)
    # node 2 lost: shard 2's primary gone, buddy on node 3 serves it
    shards = [ck.restore_shard(7, s, shard_state(np_state, s, 4),
                               lost_nodes=(2,)) for s in range(4)]
    full = unshard_state(shards, np_state)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(np_state)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_gc_respects_lge(tmp_path):
    model, state, _ = _state_and_step()
    ck = CheckpointStore(tmp_path, n_shards=2)
    np_state = jax.tree.map(np.asarray, state)
    for e in (1, 2, 3):
        for s in range(2):
            ck.save_shard(e, s, shard_state(np_state, s, 2))
        ck.commit_epoch(e)
    dropped = ck.advance_ahm(3)
    assert dropped == [1, 2]
    assert ck.last_good_epoch() == 3


def test_quorum_combine():
    g = {"w": np.ones(4)}
    out, n = quorum_combine([g, g, None, g])
    assert n == 3
    np.testing.assert_allclose(out["w"], 1.0)
    with pytest.raises(RuntimeError):
        quorum_combine([g, None, None, None])


def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    g = {"a": rng.normal(0, 0.1, (64, 64)).astype(np.float32),
         "b": rng.normal(0, 2.0, (128,)).astype(np.float32)}
    p, s = compress_grads_int8(g)
    back = decompress_grads_int8(p, s)
    for k in g:
        err = np.abs(back[k] - g[k]).max()
        assert err <= np.abs(g[k]).max() / 127 + 1e-7
    avg = compressed_allreduce([g, g, g])
    np.testing.assert_allclose(avg["a"], back["a"], atol=1e-6)


def test_dp_simulator_elastic_split():
    sim = DPSimulator(4)
    batch = {"x": np.arange(64)}
    parts = sim.split_batch(batch)
    assert sum(p is not None for p in parts) == 4
    assert sum(len(p["x"]) for p in parts if p is not None) == 64
    sim.fail(2)
    parts = sim.split_batch(batch)
    assert parts[2] is None
    assert sum(len(p["x"]) for p in parts if p is not None) > 60


def test_tokenstore_epoch_pinning():
    store = TokenStore.create(n_nodes=2, block_rows=128)
    e1 = store.ingest(token_corpus(16, 64, 100, seed=0))
    b1 = list(store.batches(2, 16, as_of=e1, seed=0))
    # ingest MORE data; epoch-e1 stream must be bit-identical
    store.ingest(token_corpus(16, 64, 100, seed=9))
    b2 = list(store.batches(2, 16, as_of=e1, seed=0))
    assert len(b1) == len(b2)
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # and the latest snapshot sees both ingests
    assert store.n_tokens() == 2 * store.n_tokens(as_of=e1)


def test_tokenstore_compression():
    store = TokenStore.create(n_nodes=2, block_rows=1024)
    store.ingest(token_corpus(32, 256, 512, seed=0))
    st = store.storage_stats()
    assert st["ratio"] > 2.0  # zipf tokens + sorted doc/pos compress well
