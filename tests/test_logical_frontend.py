"""Logical-plan IR + fluent builder front-end vs numpy SQL semantics:
multi-join star queries, composite group-by keys, derived projections,
HAVING, multi-key ORDER BY, plan-cache signatures, and the legacy
Query/JoinSpec compat shim."""
import numpy as np
import pytest

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.engine import (PLAN_CACHE, JoinSpec, LogicalJoin, LogicalQuery,
                          Query, col, execute, lower)
from repro.engine import logical as L


def star_db(n=4000, direct=True, seed=0):
    rng = np.random.default_rng(seed)
    fact = {"a": rng.integers(0, 40, n), "b": rng.integers(0, 8, n),
            "c": rng.integers(0, 5, n),
            "v": np.round(rng.normal(10, 3, n), 3)}
    dim = {"k": np.arange(30), "attr": rng.integers(0, 7, 30)}
    dim2 = {"k2": np.arange(8), "region": rng.integers(0, 3, 8)}
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=64)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("b"), ColumnDef("c"),
        ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    db.create_table(TableSchema("d", (ColumnDef("k"), ColumnDef("attr"))),
                    sort_order=("k",), segment_by=())
    db.create_table(TableSchema("d2", (ColumnDef("k2"),
                                       ColumnDef("region"))),
                    sort_order=("k2",), segment_by=())
    t = db.begin(direct_to_ros=direct)
    db.insert(t, "f", fact)
    db.insert(t, "d", dim)
    db.insert(t, "d2", dim2)
    db.commit(t)
    return db, fact, dim, dim2


def oracle_rows(fact, dim, dim2, pred_mask):
    """Joined (attr, region, v) rows surviving both inner joins."""
    m = pred_mask & np.isin(fact["a"], dim["k"]) \
        & np.isin(fact["b"], dim2["k2"])
    attr = np.full(64, -1)
    attr[dim["k"]] = dim["attr"]
    region = np.full(64, -1)
    region[dim2["k2"]] = dim2["region"]
    return attr[fact["a"][m]], region[fact["b"][m]], fact["v"][m]


def group_oracle(keys_cols, values):
    exp = {}
    for row in zip(*keys_cols, values):
        *k, v = row
        k = tuple(int(x) for x in k)
        cnt, s = exp.get(k, (0, 0.0))
        exp[k] = (cnt + 1, s + v)
    return exp


def test_two_join_two_col_groupby_matches_numpy():
    db, fact, dim, dim2 = star_db()
    qb = (db.query("f")
          .where(col("a") >= 5)
          .join("d", on=("a", "k"), cols=("attr",))
          .join("d2", on=("b", "k2"), cols=("region",))
          .group_by("attr", "region")
          .agg(n=("*", "count"), s=("v", "sum")))
    out = qb.collect()
    ga, gr, gv = oracle_rows(fact, dim, dim2, fact["a"] >= 5)
    exp = group_oracle((ga, gr), gv)
    got = {(int(a), int(r)): (int(n), float(s))
           for a, r, n, s in zip(out["attr"], out["region"],
                                 out["n"], out["s"])}
    assert set(got) == set(exp)
    for k, (cnt, s) in exp.items():
        assert got[k][0] == cnt
        assert abs(got[k][1] - s) < 1e-2


def test_repeat_builder_query_hits_plan_cache():
    db, *_ = star_db()
    qb = (db.query("f")
          .where(col("a") >= 5)
          .join("d", on=("a", "k"), cols=("attr",))
          .join("d2", on=("b", "k2"), cols=("region",))
          .group_by("attr", "region")
          .agg(n=("*", "count")))
    qb.collect()
    first = qb.stats
    qb.collect()
    assert first.fused and qb.stats.fused
    assert qb.stats.plan_cache == "hit"
    # the cache key is derived from the IR's canonical exec signature
    assert any(qb.to_ir().exec_signature() in sig
               for sig in PLAN_CACHE._fns)
    # HAVING/ORDER BY/LIMIT shape host-side: varying them reuses the
    # same fused program instead of re-tracing
    q2 = qb.limit(7)
    q2.collect()
    assert q2.stats.plan_cache == "hit"


def test_three_col_groupby_cold_path_matches_numpy():
    # non-direct insert leaves rows in the WOS -> the fused executor
    # declines and the general pipeline (runtime-packed keys) runs
    db, fact, dim, dim2 = star_db(direct=False)
    qb = (db.query("f").group_by("a", "b", "c")
          .agg(n=("*", "count"), s=("v", "sum")))
    out = qb.collect()
    assert not qb.stats.fused
    exp = group_oracle((fact["a"], fact["b"], fact["c"]), fact["v"])
    got = {(int(a), int(b), int(c)): (int(n), float(s))
           for a, b, c, n, s in zip(out["a"], out["b"], out["c"],
                                    out["n"], out["s"])}
    assert set(got) == set(exp)
    for k, (cnt, s) in exp.items():
        assert got[k][0] == cnt
        assert abs(got[k][1] - s) < 1e-2


def test_derived_projection_having_order_limit():
    db, fact, dim, dim2 = star_db()
    qb = (db.query("f")
          .select(double_v=col("v") * 2)
          .group_by("b")
          .agg(s=("double_v", "sum"), n=("*", "count"))
          .having(col("n") > 10)
          .order_by("-s")
          .limit(3))
    out = qb.collect()
    exp = group_oracle((fact["b"],), 2 * fact["v"])
    rows = [(k[0], c, s) for k, (c, s) in exp.items() if c > 10]
    rows.sort(key=lambda r: -r[2])
    rows = rows[:3]
    assert out["b"].tolist() == [r[0] for r in rows]
    np.testing.assert_allclose(out["s"], [r[2] for r in rows], rtol=1e-4)


def test_multi_key_order_by():
    db, fact, *_ = star_db()
    out = (db.query("f").group_by("b", "c").agg(n=("*", "count"))
           .order_by("b", "-c").collect())
    pairs = list(zip(out["b"].tolist(), out["c"].tolist()))
    assert pairs == sorted(pairs, key=lambda p: (p[0], -p[1]))


def test_negative_group_keys_pack_correctly():
    rng = np.random.default_rng(3)
    n = 1000
    fact = {"a": np.arange(n) % 7, "b": rng.integers(-5, 5, n),
            "c": np.zeros(n, np.int64), "v": np.ones(n)}
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=64)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("b"), ColumnDef("c"),
        ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    t = db.begin(direct_to_ros=True)
    db.insert(t, "f", fact)
    db.commit(t)
    out = db.query("f").group_by("a", "b").agg(n=("*", "count")).collect()
    exp = group_oracle((fact["a"], fact["b"]), fact["v"])
    got = {(int(a), int(b)): int(c)
           for a, b, c in zip(out["a"], out["b"], out["n"])}
    assert got == {k: c for k, (c, _) in exp.items()}


def test_snowflake_chain_join():
    # second join probes a column produced by the first join
    rng = np.random.default_rng(4)
    n = 2000
    fact = {"a": rng.integers(0, 20, n), "v": np.ones(n)}
    dim = {"k": np.arange(20), "cust": rng.integers(0, 6, 20)}
    dim2 = {"cust_id": np.arange(6), "seg": rng.integers(0, 3, 6)}
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=64)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    db.create_table(TableSchema("d", (ColumnDef("k"), ColumnDef("cust"))),
                    sort_order=("k",), segment_by=())
    db.create_table(TableSchema("d2", (ColumnDef("cust_id"),
                                       ColumnDef("seg"))),
                    sort_order=("cust_id",), segment_by=())
    t = db.begin(direct_to_ros=True)
    db.insert(t, "f", fact)
    db.insert(t, "d", dim)
    db.insert(t, "d2", dim2)
    db.commit(t)
    out = (db.query("f")
           .join("d", on=("a", "k"), cols=("cust",))
           .join("d2", on=("cust", "cust_id"), cols=("seg",))
           .group_by("seg").agg(n=("*", "count")).collect())
    seg_of = dim2["seg"][dim["cust"][fact["a"]]]
    exp = {int(s): int((seg_of == s).sum()) for s in np.unique(seg_of)}
    got = dict(zip(out["seg"].tolist(), out["n"].tolist()))
    assert got == exp


def test_signatures_of_distinct_plans_never_collide():
    base = LogicalQuery("f", group_by=("a",),
                        aggs=(("n", "*", "count"),))
    variants = [
        base,
        LogicalQuery("f", group_by=("a", "b"),
                     aggs=(("n", "*", "count"),)),
        LogicalQuery("f", group_by=("a",), aggs=(("n", "v", "sum"),)),
        LogicalQuery("f", predicate=col("a") > 3, group_by=("a",),
                     aggs=(("n", "*", "count"),)),
        LogicalQuery("f", predicate=col("a") > 4, group_by=("a",),
                     aggs=(("n", "*", "count"),)),
        LogicalQuery("f", joins=(LogicalJoin("d", "a", "k"),),
                     group_by=("a",), aggs=(("n", "*", "count"),)),
        LogicalQuery("f", joins=(LogicalJoin("d", "a", "k",
                                             dim_columns=("attr",)),),
                     group_by=("a",), aggs=(("n", "*", "count"),)),
        LogicalQuery("f", group_by=("a",), aggs=(("n", "*", "count"),),
                     having=col("n") > 1),
        LogicalQuery("f", group_by=("a",), aggs=(("n", "*", "count"),),
                     order_by=(("n", True),)),
        LogicalQuery("f", group_by=("a",), aggs=(("n", "*", "count"),),
                     limit=5),
        LogicalQuery("f", derived=(("w", col("v") * 2),),
                     group_by=("a",), aggs=(("s", "w", "sum"),)),
    ]
    sigs = [q.signature() for q in variants]
    assert len(set(sigs)) == len(sigs), "distinct IR plans collided"
    # identical plans produce identical (hashable, cache-usable) keys
    assert base.signature() == LogicalQuery(
        "f", group_by=("a",), aggs=(("n", "*", "count"),)).signature()
    assert hash(base.signature()) is not None


def test_node_tree_lowering_roundtrip():
    spec = LogicalJoin("d", "a", "k", dim_columns=("attr",))
    tree = L.Limit(
        L.Sort(
            L.Filter(
                L.Aggregate(
                    L.Join(L.Filter(L.Scan("f", ("a", "b", "v")),
                                    col("a") > 2),
                           spec),
                    ("attr", "b"), (("n", "*", "count"),)),
                col("n") > 1),            # post-aggregate Filter = HAVING
            (("n", True),)),
        5)
    q = lower(tree)
    assert q.table == "f"
    assert q.joins == (spec,)
    assert q.group_by == ("attr", "b")
    assert q.having is not None and q.predicate is not None
    assert q.order_by == (("n", True),) and q.limit == 5
    # builder produces the same canonical signature
    db, *_ = star_db(n=100)
    qb = (db.query("f").where(col("a") > 2)
          .join("d", on=("a", "k"), cols=("attr",))
          .group_by("attr", "b").agg(n=("*", "count"))
          .having(col("n") > 1).order_by("-n").limit(5))
    assert qb.to_ir().signature() == q.signature()


def test_ir_validation_errors():
    with pytest.raises(ValueError):
        LogicalQuery("f", aggs=(("s", "*", "sum"),)).validate()
    with pytest.raises(ValueError):
        LogicalQuery("f", aggs=(("s", "v", "median"),)).validate()
    with pytest.raises(ValueError):
        LogicalQuery("f", group_by=("a",), aggs=(("n", "*", "count"),),
                     having=col("zzz") > 0).validate()
    with pytest.raises(ValueError):
        LogicalQuery("f", columns=("b",), group_by=("a",),
                     aggs=(("n", "*", "count"),)).validate()


def test_legacy_query_shim_equivalent_to_builder():
    db, fact, dim, _ = star_db()
    legacy = Query("f", predicate=col("a") >= 10,
                   join=JoinSpec("d", "a", "k", dim_columns=("attr",)),
                   group_by="attr", aggs=(("cnt", "attr", "count"),))
    out_l, stats_l = execute(db, legacy)
    qb = (db.query("f").where(col("a") >= 10)
          .join("d", on=("a", "k"), cols=("attr",))
          .group_by("attr").agg(cnt=("attr", "count")))
    out_b = qb.collect()
    assert legacy.to_ir().signature() == qb.to_ir().signature()
    np.testing.assert_array_equal(np.sort(out_l["attr"]),
                                  np.sort(out_b["attr"]))
    got_l = dict(zip(out_l["attr"].tolist(), out_l["cnt"].tolist()))
    got_b = dict(zip(out_b["attr"].tolist(), out_b["cnt"].tolist()))
    assert got_l == got_b
    # JoinSpec IS the IR join node (field-for-field)
    assert JoinSpec is LogicalJoin


def test_builder_select_rows_with_derived():
    db, fact, *_ = star_db(n=500)
    out = (db.query("f").select("a", "v", vx=col("v") * 10)
           .where(col("a") < 5).order_by("a").collect())
    m = fact["a"] < 5
    assert len(out["a"]) == int(m.sum())
    np.testing.assert_allclose(np.sort(out["vx"]),
                               np.sort(10 * fact["v"][m]), rtol=1e-5)


def test_frontend_overhead_recorded():
    db, *_ = star_db(n=200)
    qb = db.query("f").group_by("b").agg(n=("*", "count"))
    qb.collect()
    assert qb.stats.frontend_s >= 0.0
    assert qb.stats.wall_s >= qb.stats.frontend_s


def test_plan_cache_misses_when_key_domains_grow():
    # the fused closure bakes pack radices from SMA domains; widening a
    # key's range after a commit must MISS (stale radices would merge or
    # mislabel groups)
    db, fact, dim, dim2 = star_db()
    qb = (db.query("f")
          .join("d", on=("a", "k"), cols=("attr",))
          .join("d2", on=("b", "k2"), cols=("region",))
          .group_by("attr", "region").agg(n=("*", "count")))
    out1 = qb.collect()
    total1 = int(np.sum(out1["n"]))
    # widen the 'region' domain and add rows routed to the new value
    t = db.begin(direct_to_ros=True)
    db.insert(t, "d2", {"k2": np.asarray([50]),
                        "region": np.asarray([9])})
    db.insert(t, "f", {"a": np.asarray([1, 2]),
                       "b": np.asarray([50, 50]),
                       "c": np.asarray([0, 0]),
                       "v": np.asarray([1.0, 1.0])})
    db.commit(t)
    out2 = qb.collect()
    assert int(np.sum(out2["n"])) == total1 + 2
    assert 9 in out2["region"].tolist()
    row = (out2["region"] == 9)
    assert int(out2["n"][row].sum()) == 2


def test_order_by_unknown_column_rejected():
    with pytest.raises(ValueError):
        LogicalQuery("f", group_by=("a",), aggs=(("n", "*", "count"),),
                     order_by=(("price", False),)).validate()
    with pytest.raises(ValueError):
        LogicalQuery("f", columns=("a",),
                     order_by=(("b", False),)).validate()


def test_descending_order_no_precision_loss():
    # int64 keys beyond 2^53 must keep exact descending order
    big = 1 << 60
    vals = np.asarray([big + 3, big + 1, big + 2, 5], np.int64)
    from repro.engine.pipeline import _finalize
    q = LogicalQuery("f", columns=("x",), order_by=(("x", True),))
    out = _finalize(q, {"x": vals})
    assert out["x"].tolist() == sorted(vals.tolist(), reverse=True)


def test_join_build_cache_invalidated_on_drop_partition():
    # build sides are cached per (dim, join-sig, epoch); drop_partition
    # bypasses MVCC (same epoch, fewer rows) and must evict them
    rng = np.random.default_rng(5)
    n = 1000
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=64)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    db.create_table(TableSchema("d", (ColumnDef("k"), ColumnDef("attr"))),
                    sort_order=("k",), segment_by=(),
                    partition_by=("k", "div_1000"))
    t = db.begin(direct_to_ros=True)
    db.insert(t, "f", {"a": rng.integers(0, 20, n), "v": np.ones(n)})
    db.insert(t, "d", {"k": np.arange(20), "attr": np.arange(20) % 3})
    db.commit(t)
    qb = (db.query("f").join("d", on=("a", "k"), cols=("attr",))
          .group_by("attr").agg(c=("*", "count")))
    out1 = qb.collect()
    assert int(np.sum(out1["c"])) == n
    db.drop_partition("d", 0)        # all dim rows live in partition 0
    out2 = qb.collect()
    assert len(out2["attr"]) == 0    # inner join now drops every row


def test_bare_count_star_no_predicate():
    # count(*) with no predicate/group-by has an empty natural column
    # set; the scan must still produce one column for row validity
    db, fact, *_ = star_db(n=500)
    out = db.query("f").agg(n=("*", "count")).collect()
    assert int(out["n"][0]) == 500
    # and with WOS rows pending (non-direct insert)
    db2, fact2, *_ = star_db(n=300, direct=False)
    out2 = db2.query("f").agg(n=("*", "count")).collect()
    assert int(out2["n"][0]) == 300


def test_left_join_unmatched_rows_null_group():
    db, fact, dim, _ = star_db()
    out = (db.query("f")
           .join("d", on=("a", "k"), cols=("attr",), how="left")
           .group_by("attr").agg(n=("*", "count")).collect())
    unmatched = int((fact["a"] >= 30).sum())      # dim keys stop at 29
    got = dict(zip(out["attr"].tolist(), out["n"].tolist()))
    assert got.pop(-1) == unmatched               # NULL sentinel group
    attr_of = np.full(64, -1)
    attr_of[dim["k"]] = dim["attr"]
    m = fact["a"] < 30
    for a in np.unique(attr_of[fact["a"][m]]):
        assert got[int(a)] == int((attr_of[fact["a"][m]] == a).sum())
    # plain select does not leak the internal _matched column
    sel = (db.query("f")
           .join("d", on=("a", "k"), cols=("attr",), how="left")
           .limit(5).collect())
    assert "_matched" not in sel


def test_empty_scan_keeps_result_schema():
    db, *_ = star_db(n=200)
    out = (db.query("f").select("a", m=col("v") + col("v"))
           .where(col("a") > 10_000).collect())
    assert set(out) == {"a", "m"}
    assert len(out["a"]) == 0 and len(out["m"]) == 0
