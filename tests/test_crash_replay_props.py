"""Crash-replay property test (paper §4.4/§5): ANY interleaving of
trickle commits, deletes, moveouts and mergeouts, with a node failure +
rejoin + incremental recovery spliced in at arbitrary points, must yield
byte-identical query results to the same commit sequence applied to a
cluster that never failed.

Two clusters receive the identical DML stream; the "crashy" one
additionally runs a fail_node -> (more commits) -> rejoin_node -> (more
commits) -> recover_node cycle at positions chosen by the strategy.
Comparisons are exact: raw snapshot reads compare as sorted tuple sets
(identical values, container layout may legally differ), and aggregate
queries restrict to integer columns so no float summation order can
differ.

Runs under the real ``hypothesis`` when installed, else the
deterministic mini-shim (repro/_compat, installed by conftest.py).
"""
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.core.recovery import recover_node, rejoin_node
from repro.engine import col

N_KEYS = 24


def _mk_db():
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=32)
    db.create_table(TableSchema("events", (
        ColumnDef("eid"), ColumnDef("key"), ColumnDef("bucket"),
        ColumnDef("val"))),
        sort_order=("bucket",), segment_by=("eid",))
    return db


def _commit_batch(db, seed, base):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 40))
    t = db.begin()
    db.insert(t, "events", {
        "eid": base + np.arange(n, dtype=np.int64),
        "key": rng.integers(0, N_KEYS, n),
        "bucket": rng.integers(0, 50, n),
        "val": rng.integers(-100, 100, n)})
    db.commit(t)
    return n


def _apply(db, op, base):
    """Apply one DML/maintenance op; returns rows inserted (0 if none)."""
    kind = op[0]
    if kind == "commit":
        return _commit_batch(db, op[1], base)
    if kind == "delete":
        t = db.begin()
        k = op[1] % N_KEYS
        db.delete(t, "events", lambda r: r["key"] == k)
        db.commit(t)
    elif kind == "moveout":
        db.run_tuple_mover(force_moveout=True)
    elif kind == "mover":
        db.run_tuple_mover()            # moveout-if-saturated + mergeouts
    return 0


def _tuples(rows):
    cols = sorted(rows)
    return sorted(zip(*[np.asarray(rows[c]).tolist() for c in cols]))


def _agg(db):
    q = (db.query("events").where(col("bucket") < 40)
         .group_by("key")
         .agg(n=("*", "count"), s=("val", "sum")))
    out = q.collect()
    order = np.argsort(np.asarray(out["key"]))
    return [(int(out["key"][i]), int(out["n"][i]), int(out["s"][i]))
            for i in order]


_OP = st.tuples(st.sampled_from(["commit", "commit", "delete", "moveout",
                                 "mover"]),
                st.integers(0, 2 ** 20))


@settings(max_examples=12)
@given(st.lists(_OP, min_size=3, max_size=10),
       st.integers(0, 3),              # node to crash
       st.integers(0, 2 ** 10),        # where in the stream it fails
       st.integers(0, 2 ** 10),        # ... rejoins
       st.integers(0, 2 ** 10))        # ... recovers
def test_crash_replay_equals_never_failed(ops, node, p_fail, p_rejoin,
                                          p_recover):
    ref = _mk_db()
    crashy = _mk_db()
    # seed both with one identical committed + moved-out batch
    base = 0
    for db in (ref, crashy):
        _commit_batch(db, 7, base)
        db.run_tuple_mover(force_moveout=True)
    base += 10 ** 6

    n_ops = len(ops)
    fail_at = p_fail % n_ops
    rejoin_at = fail_at + 1 + (p_rejoin % max(n_ops - fail_at, 1))
    recover_at = rejoin_at + (p_recover % max(n_ops - rejoin_at + 1, 1))

    for i, op in enumerate(ops):
        if i == fail_at:
            crashy.fail_node(node)
        if i == rejoin_at:
            rejoin_node(crashy, node)
        if i == recover_at:
            recover_node(crashy, node)
        _apply(ref, op, base)
        _apply(crashy, op, base)
        base += 10 ** 6
    if not crashy.nodes[node].serving():
        recover_node(crashy, node)

    # byte-identical visible state and (integer) aggregates
    assert _tuples(crashy.read_table("events")) == \
        _tuples(ref.read_table("events"))
    assert _agg(crashy) == _agg(ref)
    # the recovered node serves its own segment again: take its buddy
    # host down and the data must still all be there
    buddy_host = (node + 1) % 4
    ref.fail_node(buddy_host)
    crashy.fail_node(buddy_host)
    assert _tuples(crashy.read_table("events")) == \
        _tuples(ref.read_table("events"))
