"""End-to-end system behaviour: the paper's lifecycle (load -> query while
loading -> mergeout -> failure -> recovery) and the training integration
(columnar corpus -> train -> checkpoint -> failure -> bit-identical
resume)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB
from repro.core.recovery import recover_node
from repro.configs.base import ArchConfig, RunConfig
from repro.data import TokenStore, token_corpus
from repro.engine import Query, col, execute
from repro.models import build_model
from repro.train.checkpoint import (CheckpointStore, shard_state,
                                    unshard_state)
from repro.train.train_step import init_train_state, make_train_step


def test_ingest_query_fail_recover_lifecycle():
    rng = np.random.default_rng(0)
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=128)
    db.create_table(TableSchema("m", (
        ColumnDef("metric"), ColumnDef("meter"), ColumnDef("ts"),
        ColumnDef("value", SQLType.FLOAT))),
        sort_order=("metric", "meter", "ts"), segment_by=("meter",))

    total = 0
    for wave in range(3):
        t = db.begin()
        n = 1500
        db.insert(t, "m", {
            "metric": rng.integers(0, 10, n),
            "meter": rng.integers(0, 50, n),
            "ts": np.sort(rng.integers(0, 10**6, n)),
            "value": rng.normal(size=n)})
        db.commit(t)
        total += n
        # query WHILE loading (parallel load: I locks; reads: no locks)
        out, _ = execute(db, Query("m", group_by="metric",
                                   aggs=(("c", "metric", "count"),)))
        assert out["c"].sum() == total
        db.run_tuple_mover(force_moveout=True)

    out0, _ = execute(db, Query(
        "m", predicate=col("metric") == 3,
        aggs=(("c", "metric", "count"), ("s", "value", "sum"))))
    db.fail_node(1)
    out1, _ = execute(db, Query(
        "m", predicate=col("metric") == 3,
        aggs=(("c", "metric", "count"), ("s", "value", "sum"))))
    assert out0["c"][0] == out1["c"][0]
    assert abs(out0["s"][0] - out1["s"][0]) < 1e-2
    recover_node(db, 1)
    out2, _ = execute(db, Query(
        "m", predicate=col("metric") == 3,
        aggs=(("c", "metric", "count"),)))
    assert out2["c"][0] == out0["c"][0]


def test_train_checkpoint_resume_bit_identical(tmp_path):
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                     head_dim=16)
    model = build_model(cfg, tp=1)
    rc = RunConfig(total_steps=20, warmup_steps=2)
    step = jax.jit(make_train_step(model, rc))

    store = TokenStore.create(n_nodes=2, block_rows=256)
    epoch = store.ingest(token_corpus(32, 65, cfg.vocab_size, seed=0))
    batches = list(store.batches(4, 32, as_of=epoch, seed=0))[:10]
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]

    # run A: straight through
    state = init_train_state(model, jax.random.key(0))
    for b in batches:
        state, _ = step(state, b)
    final_a = jax.tree.map(np.asarray, state)

    # run B: checkpoint at 5, "crash", restore (via buddy), replay 5..10
    state = init_train_state(model, jax.random.key(0))
    ck = CheckpointStore(tmp_path, n_shards=2)
    for i, b in enumerate(batches[:5]):
        state, _ = step(state, b)
    np_state = jax.tree.map(np.asarray, state)
    for s in range(2):
        ck.save_shard(5, s, shard_state(np_state, s, 2))
    ck.commit_epoch(5)
    del state
    shards = [ck.restore_shard(5, s, shard_state(np_state, s, 2),
                               lost_nodes=(0,)) for s in range(2)]
    state = jax.tree.map(jnp.asarray, unshard_state(shards, np_state))
    for b in batches[5:]:
        state, _ = step(state, b)
    final_b = jax.tree.map(np.asarray, state)

    for a, b in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
        np.testing.assert_array_equal(a, b)


def test_dbd_designs_help_query_cost():
    rng = np.random.default_rng(5)
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=128)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("b"), ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    t = db.begin(direct_to_ros=True)
    n = 20_000
    db.insert(t, "f", {"a": rng.integers(0, 1000, n),
                       "b": np.sort(rng.integers(0, 100, n)),
                       "v": rng.normal(size=n)})
    db.commit(t)
    from repro.planner import design, plan_query
    q = Query("f", predicate=col("b") == 7, aggs=(("c", "b", "count"),))
    before = plan_query(db, q).estimated.bytes_scanned
    rep = design(db, [q], policy="query-optimized", deploy=True)
    after = plan_query(db, q).estimated.bytes_scanned
    assert rep.proposed, "DBD should propose a b-sorted projection"
    assert after <= before
    out, _ = execute(db, q)
    rows = db.read_table("f")
    assert out["c"][0] == (rows["b"] == 7).sum()


def test_dbd_scores_two_column_sort_keys():
    """Paper §6.3: the DBD scores candidate 2-column sort keys against the
    workload's group-by sets instead of taking the first projection column
    alphabetically."""
    rng = np.random.default_rng(9)
    db = VerticaDB(n_nodes=2, k_safety=0, block_rows=128)
    db.create_table(TableSchema("f", (
        ColumnDef("a"), ColumnDef("b"), ColumnDef("g"),
        ColumnDef("v", SQLType.FLOAT))),
        sort_order=("a",), segment_by=("a",))
    t = db.begin(direct_to_ros=True)
    n = 10_000
    db.insert(t, "f", {"a": rng.integers(0, 10 ** 6, n),
                       "b": rng.integers(0, 40, n),
                       "g": rng.integers(0, 8, n),
                       "v": rng.normal(size=n)})
    db.commit(t)
    from repro.planner import design
    q = (db.query("f").where(col("b") < 20)
         .group_by("b", "g").agg(s=("v", "sum")).to_ir())
    rep = design(db, [q], policy="query-optimized")
    # naive choice would be ("b", "a"); group-by coverage must pick g
    assert rep.sort_choices.get("f_dbd_b") == ("b", "g"), rep.sort_choices
