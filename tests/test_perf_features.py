"""The §Perf optimizations must preserve semantics: expert-local MoE ==
scatter MoE; int8 KV decode stays consistent; FLOAT_SCALED round-trips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core.encodings import Encoding, encode
from repro.core.types import SQLType
from repro.distributed.sharding import activation_hints, rules_for
from repro.launch.mesh import make_host_mesh
from repro.models import build_model, init_params
from repro.models.moe import moe_apply, moe_decls
from repro.models.params import init_params as raw_init


def test_expert_local_matches_scatter():
    cfg = configs.get("olmoe-1b-7b").reduced()
    d = cfg.d_model
    p = raw_init(moe_decls(d, cfg.moe), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, d), jnp.float32)
    o1, a1 = moe_apply(p, x, cfg.moe)
    moe_el = dataclasses.replace(cfg.moe, dispatch="a2a")
    mesh = make_host_mesh(1, 1)
    with activation_hints(rules_for(cfg, "train"), mesh):
        o2, a2 = moe_apply(p, x, moe_el)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_expert_local_grads_match():
    cfg = configs.get("olmoe-1b-7b").reduced()
    d = cfg.d_model
    p = raw_init(moe_decls(d, cfg.moe), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, d), jnp.float32)

    def loss_scatter(p):
        return moe_apply(p, x, cfg.moe)[0].sum()

    moe_el = dataclasses.replace(cfg.moe, dispatch="a2a")
    mesh = make_host_mesh(1, 1)

    def loss_el(p):
        with activation_hints(rules_for(cfg, "train"), mesh):
            return moe_apply(p, x, moe_el)[0].sum()

    g1 = jax.grad(loss_scatter)(p)
    g2 = jax.grad(loss_el)(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-4b", "hymba-1.5b"])
def test_kv_quant_decode_consistent(arch):
    cfg = configs.get(arch).reduced()
    m = build_model(cfg, tp=2, kv_quant=True)
    params = init_params(m.decls, jax.random.key(0))
    rng = np.random.default_rng(3)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 33)), jnp.int32)
    _, cache = m.prefill(params, {"tokens": tok[:, :32]}, max_len=36)
    ld, _ = m.decode_step(params, cache, tok[:, 32:],
                          jnp.asarray(32, jnp.int32))
    lf, _ = m.prefill(params, {"tokens": tok})
    assert float(jnp.abs(ld - lf).max()) < 0.6  # int8 quantization noise


def test_kv_quant_cache_decls_are_int8():
    cfg = configs.get("qwen3-4b").reduced()
    m = build_model(cfg, tp=2, kv_quant=True)
    decls = m.cache_decls(2, 64)
    leaf = decls["layers"]["attn"]["k"]
    assert leaf["q"][2] == jnp.int8
    assert leaf["s"][0][-1] == 1  # one scale per (token, head)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=300),
       st.integers(0, 3))
def test_float_scaled_roundtrip(data, k):
    v = np.round(np.asarray(data, np.float64), k)
    col = encode(v, SQLType.FLOAT, Encoding.AUTO, block_rows=64)
    np.testing.assert_array_equal(col.decode(), v)
    if col.encoding == Encoding.FLOAT_SCALED:
        assert col.inner is not None


def test_float_scaled_compresses_quantized():
    rng = np.random.default_rng(0)
    v = np.round(rng.normal(100, 1, 50_000), 2)
    col = encode(v, SQLType.FLOAT, Encoding.AUTO, block_rows=4096)
    plain = encode(v, SQLType.FLOAT, Encoding.PLAIN, block_rows=4096)
    assert col.packed_bytes < 0.5 * plain.packed_bytes
