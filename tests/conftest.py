"""Shared fixtures. NOTE: no XLA_FLAGS here -- tests see 1 CPU device;
only launch/dryrun.py requests 512 placeholder devices (assignment rule)."""
import numpy as np
import pytest

try:                       # property tests prefer the real hypothesis;
    import hypothesis      # noqa: F401
except ImportError:        # image without it: a deterministic mini-shim
    from repro._compat import install_hypothesis_stub
    install_hypothesis_stub()

from repro.core import ColumnDef, SQLType, TableSchema, VerticaDB


@pytest.fixture
def sales_db():
    rng = np.random.default_rng(7)
    db = VerticaDB(n_nodes=4, k_safety=1, block_rows=64)
    db.create_table(
        TableSchema("sales", (
            ColumnDef("sale_id"), ColumnDef("cid"), ColumnDef("date"),
            ColumnDef("price", SQLType.FLOAT))),
        sort_order=("date",), segment_by=("sale_id",),
        partition_by=("date", "div_1000"))
    n = 2000
    data = {
        "sale_id": np.arange(n, dtype=np.int64),
        "cid": rng.integers(0, 20, n),
        "date": rng.integers(0, 3000, n),
        "price": np.round(rng.normal(100, 10, n), 2),
    }
    t = db.begin()
    db.insert(t, "sales", data)
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)
    return db, data


def visible_rows(db, table="sales", as_of=None):
    return db.read_table(table, as_of=as_of)


def sorted_tuples(rows):
    cols = sorted(rows)
    arr = np.stack([np.asarray(rows[c], np.float64) for c in cols])
    order = np.lexsort(arr)
    return arr[:, order]
