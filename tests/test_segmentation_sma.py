"""Segmentation is a deterministic partition; buddies never collide; SMA
pruning never drops a matching row."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.segmentation import (SegmentationSpec, hash_columns,
                                     rebalance_plan)
from repro.core.sma import ColumnSMA

vals = st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=300)


@settings(max_examples=30, deadline=None)
@given(vals, st.integers(2, 16))
def test_placement_partition(data, n_nodes):
    v = {"k": np.asarray(data, np.int64)}
    seg = SegmentationSpec("hash", ("k",))
    nodes, segs = seg.place(v, n_nodes)
    assert nodes.shape == (len(data),)
    assert ((nodes >= 0) & (nodes < n_nodes)).all()
    assert ((segs >= 0) & (segs < seg.n_local_segments)).all()
    # deterministic
    n2, s2 = seg.place(v, n_nodes)
    np.testing.assert_array_equal(nodes, n2)
    np.testing.assert_array_equal(segs, s2)


@settings(max_examples=30, deadline=None)
@given(vals, st.integers(2, 16))
def test_buddy_never_same_node(data, n_nodes):
    v = {"k": np.asarray(data, np.int64)}
    seg = SegmentationSpec("hash", ("k",))
    buddy = SegmentationSpec("hash", ("k",), offset=1)
    n1, _ = seg.place(v, n_nodes)
    n2, _ = buddy.place(v, n_nodes)
    assert (n1 != n2).all()  # K-safety: no row on the same node twice


def test_even_distribution():
    v = {"k": np.arange(100_000, dtype=np.int64)}
    seg = SegmentationSpec("hash", ("k",))
    nodes, _ = seg.place(v, 8)
    counts = np.bincount(nodes, minlength=8)
    assert counts.min() > 0.8 * counts.mean()
    assert counts.max() < 1.2 * counts.mean()


def test_rebalance_plan_whole_segments_only():
    moves = rebalance_plan(4, 6, 3)
    assert all(0 <= old < 4 and 0 <= seg < 3 and 0 <= new < 6
               for old, seg, new in moves)
    assert len(moves) > 0
    assert len(set(moves)) == len(moves)


@settings(max_examples=30, deadline=None)
@given(vals, st.integers(-2**40, 2**40), st.integers(-2**40, 2**40))
def test_sma_pruning_no_false_drops(data, a, b):
    lo, hi = min(a, b), max(a, b)
    v = np.asarray(data, np.int64)
    sma = ColumnSMA.build(v, block_rows=32)
    keep = sma.prune_blocks(lo, hi)
    for i in range(keep.shape[0]):
        blk = v[i * 32:(i + 1) * 32]
        has_match = ((blk >= lo) & (blk <= hi)).any()
        if has_match:
            assert keep[i], "pruned a block containing matches"
