"""Multi-device distribution tests, run in subprocesses with 8 placeholder
CPU devices (the main test process must keep 1 device -- assignment rule).

Covers: (a) Send/Recv resegmentation moves every tuple to its hash shard
exactly once across real device boundaries; (b) a sharded train step on an
(4 data x 2 model) mesh matches the single-device step numerically;
(c) the expert-local MoE dispatch equals the scatter oracle under a real
model-axis split.
"""
import subprocess
import sys

import pytest

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == 8, jax.device_count()
"""


def _run(body: str):
    r = subprocess.run([sys.executable, "-c", _PRELUDE + body],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_resegment_8_shards():
    out = _run("""
from repro.launch.mesh import make_host_mesh
from repro.engine.exchange import resegment
mesh = make_host_mesh(data=8, model=1)
rng = np.random.default_rng(0)
n = 8192
keys = jnp.asarray(rng.integers(0, 10_000, n), jnp.int32)
vals = jnp.asarray(rng.normal(size=n), jnp.float32)
dest = keys % 8
out, valid, overflow = resegment(mesh, "data", {"k": keys, "v": vals},
                                 dest, capacity=4 * n)
assert int(np.asarray(overflow).sum()) == 0
kept = np.asarray(out["k"])[np.asarray(valid)]
assert sorted(kept.tolist()) == sorted(np.asarray(keys).tolist())
# every row landed on its hash shard: shard i holds keys % 8 == i
# (global output = n_shards x capacity rows, one capacity block per shard)
shards = np.asarray(out["k"]).reshape(8, -1)
vmask = np.asarray(valid).reshape(8, -1)
for i in range(8):
    assert (shards[i][vmask[i]] % 8 == i).all()
print("RESEG_OK", len(kept))
""")
    assert "RESEG_OK 8192" in out


def test_sharded_train_step_matches_single_device():
    out = _run("""
from jax.sharding import NamedSharding
from repro.configs.base import ArchConfig, RunConfig
from repro.distributed.sharding import (activation_hints, resolve_spec,
                                        rules_for)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.train_step import (init_train_state, make_train_step,
                                    train_state_axes)
from repro.launch.dryrun import _axes_leaf

cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 head_dim=16)
rc = RunConfig(total_steps=10, warmup_steps=1)
rng = np.random.default_rng(0)
tok = jnp.asarray(rng.integers(0, 256, (8, 32)), jnp.int32)
batch = {"tokens": tok, "labels": tok}

# single device reference
m1 = build_model(cfg, tp=1)
s1 = init_train_state(m1, jax.random.key(0))
s1, met1 = jax.jit(make_train_step(m1, rc))(s1, batch)

# 4x2 mesh, fully sharded
mesh = make_host_mesh(data=4, model=2)
m2 = build_model(cfg, tp=2)
s2 = init_train_state(m2, jax.random.key(0))
rules = rules_for(cfg, "train")
st_axes = train_state_axes(m2)
st_specs = jax.tree.map(
    lambda a: NamedSharding(mesh, resolve_spec(a, rules, mesh.axis_names)),
    st_axes, is_leaf=_axes_leaf)
b_specs = {k: NamedSharding(mesh, resolve_spec(("batch", "seq"), rules,
                                               mesh.axis_names))
           for k in batch}
with activation_hints(rules, mesh):
    step = jax.jit(make_train_step(m2, rc), in_shardings=(st_specs, b_specs),
                   out_shardings=(st_specs, None))
    s2 = jax.device_put(s2, st_specs)
    b2 = jax.device_put(batch, b_specs)
    s2, met2 = step(s2, b2)

# params differ in LAYOUT (HeadLayout tp=2 vs tp=1) but loss must match
d = abs(float(met1["loss"]) - float(met2["loss"]))
assert d < 5e-2, (float(met1["loss"]), float(met2["loss"]))
g = abs(float(met1["grad_norm"]) - float(met2["grad_norm"]))
assert g / max(float(met1["grad_norm"]), 1e-6) < 0.05
print("TRAIN_OK", float(met1["loss"]), float(met2["loss"]))
""")
    assert "TRAIN_OK" in out


def test_expert_local_moe_on_real_model_axis():
    out = _run("""
import dataclasses
from repro import configs
from repro.distributed.sharding import activation_hints, rules_for
from repro.launch.mesh import make_host_mesh
from repro.models.moe import moe_apply, moe_decls
from repro.models.params import init_params

cfg = configs.get("olmoe-1b-7b").reduced()   # 4 experts
d = cfg.d_model
p = init_params(moe_decls(d, cfg.moe), jax.random.key(0))
x = jax.random.normal(jax.random.key(1), (4, 16, d), jnp.float32)
# drop-free capacity: under overflow the two paths drop DIFFERENT tokens
# (the oracle budgets capacity over the global batch, the expert-local
# path per DP shard -- both standard GShard policies), so dispatch
# equivalence is only defined with no drops on either side
moe_ref = dataclasses.replace(
    cfg.moe, capacity_factor=float(cfg.moe.num_experts))
o_ref, a_ref = moe_apply(p, x, moe_ref)      # scatter oracle, 1 device

mesh = make_host_mesh(data=2, model=4)       # experts split 4 ways
moe_el = dataclasses.replace(moe_ref, dispatch="a2a")
with activation_hints(rules_for(cfg, "train"), mesh):
    o2, a2 = moe_apply(p, x, moe_el)
err = float(jnp.abs(o_ref - o2).max())
assert err < 1e-4, err
# aux is the standard per-DP-shard load-balance estimator under sharding;
# it differs from the global-batch estimator by O(1/shards) sampling noise
assert abs(float(a_ref) - float(a2)) < 0.5 * float(a_ref)
print("MOE_OK", err)
""")
    assert "MOE_OK" in out
