"""Property tests: every encoding round-trips bit-exactly; AUTO never loses
to PLAIN; sorted data compresses at least as well as storage_bytes claims;
device decode == host decode."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.encodings import (Encoding, decode_jnp, encode)
from repro.core.types import SQLType

INT_ENCS = [Encoding.PLAIN, Encoding.RLE, Encoding.DELTA_VALUE,
            Encoding.BLOCK_DICT, Encoding.DELTA_RANGE,
            Encoding.COMMON_DELTA]

ints = st.lists(st.integers(-2**40, 2**40), min_size=1, max_size=400)
floats = st.lists(st.floats(allow_nan=False, allow_infinity=False,
                            width=32), min_size=1, max_size=300)


@settings(max_examples=40, deadline=None)
@given(data=ints, enc=st.sampled_from(INT_ENCS))
def test_int_roundtrip(data, enc):
    v = np.asarray(data, np.int64)
    col = encode(v, SQLType.INT, enc, block_rows=64)
    np.testing.assert_array_equal(col.decode(), v)


@settings(max_examples=25, deadline=None)
@given(data=floats, enc=st.sampled_from(
    [Encoding.PLAIN, Encoding.RLE, Encoding.BLOCK_DICT,
     Encoding.DELTA_RANGE]))
def test_float_roundtrip(data, enc):
    v = np.asarray(data, np.float64)
    col = encode(v, SQLType.FLOAT, enc, block_rows=64)
    np.testing.assert_array_equal(col.decode(), v)


@settings(max_examples=25, deadline=None)
@given(data=ints)
def test_auto_never_worse_than_plain(data):
    v = np.asarray(data, np.int64)
    auto = encode(v, SQLType.INT, Encoding.AUTO, block_rows=64)
    plain = encode(v, SQLType.INT, Encoding.PLAIN, block_rows=64)
    assert auto.packed_bytes <= plain.packed_bytes + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=60, max_size=400))
def test_rle_wins_on_sorted_low_cardinality(data):
    v = np.sort(np.asarray(data, np.int64))
    rle = encode(v, SQLType.INT, Encoding.RLE, block_rows=128)
    plain = encode(v, SQLType.INT, Encoding.PLAIN, block_rows=128)
    # low-cardinality sorted with real runs: RLE must not lose (paper §3.4
    # 'best for low cardinality sorted columns'). With >= 60 rows over <= 6
    # distinct values, runs are ~10x shorter than rows.
    n_runs = 1 + int((v[1:] != v[:-1]).sum())
    if n_runs * 2 <= len(v):
        assert rle.packed_bytes <= plain.packed_bytes


@settings(max_examples=15, deadline=None)
@given(data=ints, enc=st.sampled_from(INT_ENCS))
def test_device_decode_matches_host(data, enc):
    v = np.asarray(data, np.int64)
    # keep magnitudes in the 32-bit device range (jax x64 disabled)
    v = np.clip(v, -2**31 + 1, 2**31 - 1)
    col = encode(v, SQLType.INT, enc, block_rows=64)
    host = col.decode_blocks()
    dev = np.asarray(decode_jnp(col))
    np.testing.assert_array_equal(dev.astype(np.int64), host)


def test_sorted_timestamps_common_delta_compresses():
    # the paper's timestamp case: periodic with occasional breaks
    ts = 1_600_000_000 + 300 * np.arange(5000, dtype=np.int64)
    ts[::97] += 17
    col = encode(ts, SQLType.INT, Encoding.AUTO, block_rows=4096)
    assert col.packed_bytes < 0.2 * ts.nbytes  # >5x on near-periodic data


def test_explicit_inapplicable_encoding_falls_back():
    v = np.asarray([1.5, 2.5, 3.5])
    col = encode(v, SQLType.FLOAT, Encoding.COMMON_DELTA, block_rows=64)
    assert col.encoding in (Encoding.PLAIN,)  # int-only scheme
    np.testing.assert_array_equal(col.decode(), v)
