"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nb,R", [(1, 128), (4, 128), (3, 384), (8, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_rle_filter_agg(nb, R, dtype):
    rv = jnp.asarray(RNG.integers(0, 100, (nb, R)), dtype)
    rl = jnp.asarray(RNG.integers(0, 20, (nb, R)), dtype)
    got = ops.rle_filter_agg(rv, rl, lo=25.0, hi=75.0)
    pad = (-R) % 128
    rvp = jnp.pad(rv, ((0, 0), (0, pad)))
    rlp = jnp.pad(rl, ((0, 0), (0, pad)))
    want = ref.rle_filter_agg_ref(rvp, rlp, 25.0, 75.0)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("nb,R,domain", [(1, 128, 16), (4, 130, 64),
                                         (2, 384, 1000), (3, 40, 7)])
@pytest.mark.parametrize("bounded", [True, False])
def test_rle_grouped_agg(nb, R, domain, bounded):
    # keys partly OUT of [0, domain): must be dropped, not clipped in
    rv = jnp.asarray(RNG.integers(0, domain + 3, (nb, R)), jnp.int32)
    rl = jnp.asarray(RNG.integers(0, 20, (nb, R)), jnp.int32)
    val = jnp.asarray(RNG.normal(size=(nb, R)), jnp.float32)
    lo, hi = (2.0, float(domain)) if bounded else (-3.0e38, 3.0e38)
    got = ops.rle_grouped_agg(rv, rl, val, domain=domain, lo=lo, hi=hi)
    want = ref.rle_grouped_agg_ref(rv, rl, val, domain, lo, hi)
    assert got.shape == (4, domain)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rle_grouped_agg_default_values_is_key():
    rv = jnp.asarray(RNG.integers(0, 8, (2, 128)), jnp.int32)
    rl = jnp.asarray(RNG.integers(0, 5, (2, 128)), jnp.int32)
    got = ops.rle_grouped_agg(rv, rl, domain=8)
    want = ref.rle_grouped_agg_ref(rv, rl, rv, 8, -3.0e38, 3.0e38)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # count of key k == total run length with that key
    flat_rv, flat_rl = np.asarray(rv).ravel(), np.asarray(rl).ravel()
    for k in range(8):
        assert got[0, k] == flat_rl[flat_rv == k].sum()


@pytest.mark.parametrize("nb,B,domain", [(1, 128, 16), (4, 256, 64),
                                         (2, 512, 128), (3, 128, 1000)])
def test_onehot_groupby(nb, B, domain):
    k = jnp.asarray(RNG.integers(0, domain, (nb, B)), jnp.int32)
    v = jnp.asarray(RNG.normal(size=(nb, B)), jnp.float32)
    got = ops.onehot_groupby(k, v, domain=domain)
    want = ref.onehot_groupby_ref(k, v, domain)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nb,B", [(1, 128), (5, 256), (2, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_delta_decode(nb, B, dtype):
    first = jnp.asarray(RNG.integers(0, 1000, (nb, 1)), dtype)
    deltas = jnp.asarray(RNG.integers(-5, 6, (nb, B)), dtype)
    got = ops.delta_decode(first, deltas)
    want = ref.delta_decode_ref(first, deltas)
    np.testing.assert_allclose(got, want)


@pytest.mark.parametrize("nb,B,S", [(1, 128, 100), (3, 256, 128),
                                    (2, 512, 1000)])
def test_semijoin_probe(nb, B, S):
    keys = jnp.asarray(RNG.integers(0, 2000, (nb, B)), jnp.int32)
    build = jnp.asarray(RNG.choice(2000, S, replace=False), jnp.int32)
    got = ops.semijoin_probe(keys, build)
    pad = (-S) % 128
    want = ref.semijoin_probe_ref(
        keys, jnp.pad(build, (0, pad), constant_values=-1))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("S,T,d", [(128, 128, 64), (256, 256, 128),
                                   (128, 384, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(S, T, d, causal, dtype):
    if causal and S != T:
        pytest.skip("causal requires square here")
    q = jnp.asarray(RNG.normal(size=(S, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(T, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(T, d)), dtype)
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_batched():
    q = jnp.asarray(RNG.normal(size=(2, 3, 128, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 3, 128, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 3, 128, 64)), jnp.float32)
    got = ops.flash_attention(q, k, v)
    want = ops.flash_attention(q, k, v, force_ref=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
