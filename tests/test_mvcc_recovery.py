"""MVCC snapshots, quorum commit, K-safety, recovery, rebalance, backup."""
import numpy as np
import pytest

from repro.core import (AvailabilityError, ColumnDef,
                        RecoverySourceLostError, TableSchema, VerticaDB)
from repro.core.recovery import backup, rebalance, recover_node, restore


def _tuples(rows):
    cols = sorted(rows)
    return sorted(zip(*[np.asarray(rows[c]).tolist() for c in cols]))


def test_snapshot_isolation(sales_db):
    db, _ = sales_db
    e0 = db.epochs.latest_queryable()
    n0 = len(db.read_table("sales")["cid"])
    t = db.begin()
    db.delete(t, "sales", lambda r: r["cid"] == 3)
    e1 = db.commit(t)
    assert len(db.read_table("sales", as_of=e0)["cid"]) == n0
    now = db.read_table("sales")
    assert (now["cid"] != 3).all()


def test_uncommitted_invisible_and_rollback(sales_db):
    db, _ = sales_db
    n0 = len(db.read_table("sales")["cid"])
    t = db.begin()
    db.insert(t, "sales", {"sale_id": np.arange(9000, 9010),
                           "cid": np.zeros(10, np.int64),
                           "date": np.zeros(10, np.int64),
                           "price": np.ones(10)})
    assert len(db.read_table("sales")["cid"]) == n0  # staged, not visible
    db.rollback(t)
    assert len(db.read_table("sales")["cid"]) == n0


def test_update_is_delete_plus_insert(sales_db):
    db, _ = sales_db
    e0 = db.epochs.latest_queryable()
    t = db.begin()
    db.update(t, "sales", lambda r: r["cid"] == 5, {"price": 1234.0})
    db.commit(t)
    rows = db.read_table("sales")
    assert (rows["price"][rows["cid"] == 5] == 1234.0).all()
    old = db.read_table("sales", as_of=e0)
    assert not (old["price"][old["cid"] == 5] == 1234.0).any()


def test_quorum_commit_fails_below_majority(sales_db):
    db, _ = sales_db
    db.fail_node(0)
    db.fail_node(1)  # 2/4 up < quorum(3)
    t = db.begin()
    db.insert(t, "sales", {"sale_id": np.arange(9100, 9101),
                           "cid": np.zeros(1, np.int64),
                           "date": np.zeros(1, np.int64),
                           "price": np.ones(1)})
    with pytest.raises(AvailabilityError):
        db.commit(t)


def test_ksafety_read_through_buddy(sales_db):
    db, _ = sales_db
    before = _tuples(db.read_table("sales"))
    db.fail_node(2)
    assert _tuples(db.read_table("sales")) == before


def test_two_failures_lose_segment(sales_db):
    db, _ = sales_db
    db.fail_node(2)
    db.fail_node(3)  # node 3 hosted node 2's buddy rows
    with pytest.raises(AvailabilityError):
        db.read_table("sales")


def test_recovery_replays_missed_commits(sales_db):
    db, _ = sales_db
    db.fail_node(1)
    t = db.begin()
    db.insert(t, "sales", {"sale_id": np.arange(9200, 9400),
                           "cid": np.full(200, 11, np.int64),
                           "date": np.full(200, 42, np.int64),
                           "price": np.ones(200)})
    db.commit(t)
    t = db.begin()
    db.delete(t, "sales", lambda r: r["cid"] == 7)
    db.commit(t)
    expect = _tuples(db.read_table("sales"))
    recover_node(db, 1)
    assert _tuples(db.read_table("sales")) == expect
    # and node 1 now serves its own segment again
    db.fail_node(2)
    assert _tuples(db.read_table("sales")) == expect


def test_recovery_waits_for_buddy_source(sales_db):
    """A node whose replay source is unavailable must NOT flip back to
    serving with its missed epochs unreplayed: it stays in recovering
    state (loud AvailabilityError on reads of its segments, never a
    silently incomplete answer) and a later recover_node retry -- once
    the buddy is back -- completes.  The incomplete recovery is now a
    typed RecoverySourceLostError naming exactly which projections and
    segments have no replay source."""
    db, _ = sales_db
    db.fail_node(1)
    t = db.begin()
    db.insert(t, "sales", {"sale_id": np.arange(9800, 9900),
                           "cid": np.full(100, 13, np.int64),
                           "date": np.full(100, 99, np.int64),
                           "price": np.ones(100)})
    db.commit(t)
    db.run_tuple_mover(force_moveout=True)   # persist to buddy ROS
    expect = _tuples(db.read_table("sales"))
    db.fail_node(2)                # hosts node 1's buddy segments
    with pytest.raises(RecoverySourceLostError) as exc:
        recover_node(db, 1)
    assert exc.value.node == 1
    assert 1 in exc.value.segments
    assert "sales_super" in exc.value.projections
    assert db.nodes[1].up and db.nodes[1].recovering
    assert db.nodes[1].last_recovery["complete"] is False
    with pytest.raises(AvailabilityError):
        db.read_table("sales")     # segment 1 has no serving copy
    recover_node(db, 2)            # buddy host returns (via node 3)
    assert not db.nodes[2].recovering
    recover_node(db, 1)            # retry now completes
    assert not db.nodes[1].recovering
    assert _tuples(db.read_table("sales")) == expect
    db.fail_node(2)                # node 1 serves its own segment again
    assert _tuples(db.read_table("sales")) == expect


def test_replicated_routing_raises_when_no_serving_replica():
    """Planner + reads on a replicated projection raise AvailabilityError
    (not a bare StopIteration) when every node is down or recovering."""
    from repro.planner import plan_query

    db = VerticaDB(n_nodes=2, k_safety=1, block_rows=32)
    db.create_table(TableSchema("dim", (ColumnDef("k"), ColumnDef("a"))),
                    sort_order=("k",), segment_by=())    # replicated
    t = db.begin()
    db.insert(t, "dim", {"k": np.arange(10), "a": np.arange(10) % 3})
    db.commit(t)
    db.fail_node(0)
    db.rejoin_node(0)              # up but recovering: not serving
    db.fail_node(1)
    q = db.query("dim").group_by("a").agg(n=("*", "count")).to_ir()
    with pytest.raises(AvailabilityError):
        plan_query(db, q)
    with pytest.raises(AvailabilityError):
        db.read_table("dim")


def test_rebalance_preserves_data(sales_db):
    db, _ = sales_db
    expect = _tuples(db.read_table("sales"))
    rebalance(db, 6)
    assert _tuples(db.read_table("sales")) == expect
    rebalance(db, 3)
    assert _tuples(db.read_table("sales")) == expect


def test_backup_restore(sales_db):
    db, _ = sales_db
    img = backup(db)
    expect = _tuples(db.read_table("sales"))
    t = db.begin()
    db.delete(t, "sales", lambda r: r["cid"] >= 0)  # delete everything
    db.commit(t)
    assert len(db.read_table("sales")["cid"]) == 0
    restore(db, img)
    assert _tuples(db.read_table("sales")) == expect


def test_lge_capped_by_wos_residue(sales_db):
    """Regression (found by examples/analytics_pipeline.py): the LGE may
    only advance past epochs fully moved to ROS. A node failing with rows
    still in its WOS must replay them from the buddy on recovery."""
    db, _ = sales_db
    # commit rows that stay in the WOS (no forced moveout)
    t = db.begin()
    db.insert(t, "sales", {"sale_id": np.arange(9500, 9700),
                           "cid": np.full(200, 17, np.int64),
                           "date": np.full(200, 7, np.int64),
                           "price": np.ones(200)})
    db.commit(t)
    db.run_tuple_mover()  # WOS below limit: nothing moves; LGE must not jump
    expect = _tuples(db.read_table("sales"))
    db.fail_node(1)       # loses node 1's WOS share of the new rows
    recover_node(db, 1)
    assert _tuples(db.read_table("sales")) == expect
    db.fail_node(0)       # read via buddies: node 1 must serve its segment
    assert _tuples(db.read_table("sales")) == expect
