"""Property-style tests for the segmentation primitives that the
segmented executor's correctness rests on: ring hashing, buddy-offset
placement, mixed-radix key packing, and elastic rebalance coverage.

Runs under the real ``hypothesis`` when installed, else the deterministic
mini-shim (repro/_compat, installed by conftest.py).
"""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

import jax.numpy as jnp

from repro.core.segmentation import (SegmentationSpec, hash_columns,
                                     rebalance_plan, shard_of)
from repro.core.types import C_MAX
from repro.engine import operators as ops

I64_MIN, I64_MAX = -(2 ** 63), 2 ** 63 - 1


# ---------------------------------------------------------------------------
# hash_columns: deterministic, full-range safe, ring-bounded
# ---------------------------------------------------------------------------

@settings(max_examples=30)
@given(st.lists(st.integers(I64_MIN, I64_MAX), min_size=1, max_size=100))
def test_hash_columns_deterministic_and_in_range(xs):
    a = np.asarray(xs, dtype=np.int64)
    h1, h2 = hash_columns(a), hash_columns(a)
    assert h1.dtype == np.uint64
    assert (h1 == h2).all()                      # deterministic
    assert (h1 < np.uint64(C_MAX)).all()         # on the ring


@settings(max_examples=20)
@given(st.lists(st.integers(-10 ** 9, 10 ** 9), min_size=1, max_size=50),
       st.integers(0, 10 ** 6))
def test_hash_columns_multi_column_order_sensitivity(xs, shift):
    """Multi-column hashes mix every column: shifting one column while
    holding the other changes the hash for (almost) every row, and the
    hash of (a, b) is reproducible."""
    a = np.asarray(xs, dtype=np.int64)
    b = a + shift + 1
    h = hash_columns(a, b)
    assert (h == hash_columns(a, b)).all()
    assert (h < np.uint64(C_MAX)).all()


@settings(max_examples=20)
@given(st.lists(st.integers(-10 ** 9, 10 ** 9), min_size=1, max_size=200),
       st.integers(2, 16))
def test_node_of_buddy_offset_disjoint(xs, n_nodes):
    """Paper §5.2: a K=1 buddy's ring offset guarantees that NO row's
    buddy copy lives on the same node as its primary copy."""
    ring = hash_columns(np.asarray(xs, dtype=np.int64))
    primary = SegmentationSpec("hash", ("k",), offset=0)
    buddy = SegmentationSpec("hash", ("k",), offset=1)
    a = primary.node_of(ring, n_nodes)
    b = buddy.node_of(ring, n_nodes)
    assert ((0 <= a) & (a < n_nodes)).all()
    assert ((0 <= b) & (b < n_nodes)).all()
    assert (a != b).all()
    # and the buddy is exactly the primary shifted one ring slot
    assert ((a + 1) % n_nodes == b).all()


@settings(max_examples=20)
@given(st.lists(st.integers(I64_MIN, I64_MAX), min_size=1, max_size=100),
       st.integers(1, 16))
def test_shard_of_is_offset_free_node_of(xs, n):
    """Device shard placement (engine/segmented.py) must agree with the
    offset-0 node map so primary- and buddy-served rows coincide."""
    ring = hash_columns(np.asarray(xs, dtype=np.int64))
    s = shard_of(ring, n)
    assert ((0 <= s) & (s < n)).all()
    spec = SegmentationSpec("hash", ("k",), offset=0)
    assert (s == spec.node_of(ring, n)).all()


# ---------------------------------------------------------------------------
# pack_keys / unpack_keys: mixed-radix round trip incl. negative domains
# ---------------------------------------------------------------------------

@settings(max_examples=25)
@given(st.integers(0, 10 ** 6), st.integers(1, 3))
def test_pack_unpack_roundtrip(seed, ncols):
    rng = np.random.default_rng(seed)
    lows = [int(v) for v in rng.integers(-(2 ** 16), 2 ** 16, ncols)]
    domains = [int(v) for v in rng.integers(1, 1024, ncols)]
    keys = [rng.integers(lo, lo + d, 64).astype(np.int32)
            for lo, d in zip(lows, domains)]
    packed = ops.pack_keys([jnp.asarray(k) for k in keys],
                           tuple(domains), tuple(lows))
    total = 1
    for d in domains:
        total *= d
    p = np.asarray(packed)
    assert (0 <= p).all() and (p < total).all()
    unpacked = ops.unpack_keys(p, domains, lows)
    for orig, rec in zip(keys, unpacked):
        assert (orig == np.asarray(rec)).all()


def test_pack_unpack_near_int32_limit():
    """Product within a hair of 2^31 (the device pack limit) with negative
    lows: the packed intermediate must not overflow int32."""
    domains = (1 << 15, 1 << 15)                 # product = 2^30
    lows = (-(1 << 14), -(1 << 14))
    rng = np.random.default_rng(3)
    keys = [rng.integers(lo, lo + d, 256).astype(np.int32)
            for lo, d in zip(lows, domains)]
    # include the exact corners
    keys[0][:2] = [lows[0], lows[0] + domains[0] - 1]
    keys[1][:2] = [lows[1], lows[1] + domains[1] - 1]
    packed = np.asarray(ops.pack_keys([jnp.asarray(k) for k in keys],
                                      domains, lows))
    assert packed.max() < (1 << 30)
    assert packed.min() >= 0
    unpacked = ops.unpack_keys(packed, domains, lows)
    for orig, rec in zip(keys, unpacked):
        assert (orig == np.asarray(rec)).all()


def test_pack_clips_out_of_domain_values():
    """Out-of-domain values clip to the domain edge (callers bound the
    domain; clipping keeps the scatter in range rather than corrupting a
    neighbor's bucket)."""
    packed = np.asarray(ops.pack_keys(
        [jnp.asarray(np.array([-5, 0, 9, 42], np.int32))], (10,), (0,)))
    assert packed.tolist() == [0, 0, 9, 9]


# ---------------------------------------------------------------------------
# rebalance_plan: moved segments exactly cover the ranges that changed owner
# ---------------------------------------------------------------------------

def _center_owner(node: int, seg: int, n_old: int, n_local: int,
                  n_new: int) -> int:
    """Independent owner-of-center check via the ring map itself."""
    width = float(C_MAX) / n_old
    point = node * width + (seg + 0.5) * width / n_local
    ring = np.asarray([min(point, float(C_MAX) - 1)])
    return int(shard_of(ring, n_new)[0])


@pytest.mark.parametrize("n_old,n_new", [
    (4, 8), (8, 4),          # double / halve
    (3, 5), (5, 3),          # coprime grow / shrink
    (6, 2), (2, 6), (2, 3), (7, 8),
])
@pytest.mark.parametrize("n_local", [1, 3, 4])
def test_rebalance_moves_exactly_changed_ranges(n_old, n_new, n_local):
    moves = rebalance_plan(n_old, n_new, n_local)
    moved = {}
    for old_node, seg, new_node in moves:
        assert (old_node, seg) not in moved, "duplicate move"
        moved[(old_node, seg)] = new_node
    for node in range(n_old):
        for seg in range(n_local):
            owner = _center_owner(node, seg, n_old, n_local, n_new)
            if owner != node:
                # range changed owner: must move, and to that owner
                assert moved.get((node, seg)) == owner, \
                    (node, seg, owner, moves)
            else:
                assert (node, seg) not in moved, (node, seg)


def test_rebalance_identity_topology_moves_nothing():
    for n in (1, 2, 4, 7):
        assert rebalance_plan(n, n, 3) == []


def test_rebalance_grow_shrink_roundtrip_is_consistent():
    """A segment's ring center owned by node i under the old topology is
    owned by i again after growing and shrinking back -- whole-segment
    moves are invertible."""
    n_local = 3
    for node in range(4):
        for seg in range(n_local):
            width4 = float(C_MAX) / 4
            point = node * width4 + (seg + 0.5) * width4 / n_local
            assert int(shard_of(np.asarray([point]), 4)[0]) == node
