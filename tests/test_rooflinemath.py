"""Unit tests for the dry-run analysis stack: loop-aware HLO costs,
spec resolution, MODEL_FLOPS sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import SHAPES_BY_NAME
from repro.distributed.sharding import BASE_RULES, resolve_spec
from repro.launch.hlo_cost import analyze_hlo, shape_bytes
from repro.launch.roofline_math import model_flops

MESH_AXES = ("data", "model")


def test_resolve_spec_basics():
    from jax.sharding import PartitionSpec as P
    assert resolve_spec(("batch", "seq"), BASE_RULES, MESH_AXES) == \
        P("data")
    assert resolve_spec(("embed", "mlp"), BASE_RULES, MESH_AXES) == \
        P("data", "model")
    # pod dropped on the single-pod mesh
    assert resolve_spec(("batch",), BASE_RULES,
                        ("pod", "data", "model")) == P(("pod", "data"))
    # duplicate mesh axis: later dim loses
    rules = dict(BASE_RULES, seq="data")
    assert resolve_spec(("batch", "seq"), rules, MESH_AXES) == P("data")


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("(s32[], bf16[16,8]{1,0})") == 4 + 16 * 8 * 2
    assert shape_bytes("pred[100]") == 100


def test_hlo_cost_multiplies_loop_trips():
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def body(c, _):
        return c @ c, None

    one = jax.jit(lambda x: x @ x).lower(a).compile()
    loop = jax.jit(lambda x: jax.lax.scan(body, x, None,
                                          length=7)[0]).lower(a).compile()
    c1 = analyze_hlo(one.as_text())
    c7 = analyze_hlo(loop.as_text())
    assert abs(c7.flops - 7 * c1.flops) < 0.01 * c7.flops
    # and xla's own cost_analysis does NOT (the reason hlo_cost exists);
    # newer jax returns a per-device list instead of a bare dict
    ca = loop.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < 2 * c1.flops


def test_hlo_cost_nested_loops():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        return jax.lax.scan(inner, c, None, length=3)[0], None

    f = jax.jit(lambda x: jax.lax.scan(outer, x, None, length=5)[0])
    costs = analyze_hlo(f.lower(a).compile().as_text())
    expect = 15 * 2 * 64**3
    assert abs(costs.flops - expect) < 0.05 * expect


def test_model_flops_dense_close_to_6nd():
    cfg = configs.get("granite-3-8b")
    shape = SHAPES_BY_NAME["train_4k"]
    mf = model_flops(cfg, shape)
    # 6 * N * D with N ~ 8B params, D = 4096*256 tokens
    n_params = cfg.n_layers * (
        cfg.d_model * (cfg.n_heads + cfg.n_kv_heads) *
        cfg.resolved_head_dim * 2 + 3 * cfg.d_model * cfg.d_ff) \
        + 2 * cfg.vocab_size * cfg.d_model
    six_nd = 6 * n_params * shape.seq_len * shape.global_batch
    assert 0.7 < mf / six_nd < 1.3


@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_model_flops_ordering(shape):
    cfg = configs.get("qwen3-4b")
    mf = model_flops(cfg, SHAPES_BY_NAME[shape])
    assert mf > 0
    if shape == "decode_32k":
        assert mf < model_flops(cfg, SHAPES_BY_NAME["prefill_32k"])


def test_subquadratic_skip_policy():
    for name in configs.ARCH_NAMES:
        cfg = configs.get(name)
        ok, reason = cfg.supports_shape(SHAPES_BY_NAME["long_500k"])
        assert ok == cfg.subquadratic
        assert ok == (cfg.family in ("ssm", "hybrid"))
