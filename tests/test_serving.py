"""Serving-layer tests (engine/serving.py): admission control, priority
queues, epoch-pin lifecycle, shared-scan byte-identity, memory budget,
fault injection at the serving points.

The headline differential test proves coalesced shared-scan results are
BYTE-IDENTICAL to independent execution -- not allclose: the shared scan
skips SMA pruning and predicate pushdown, and the claim is that masked
aggregation makes that invisible bitwise (see serving._shared_once).
Float test data is quarter-valued so sums are exact regardless of
accumulation order; the comparison is exact equality after dtype
normalization (int->int64, float->float64 -- device arrays are 32-bit).
"""
import numpy as np
import pytest

from repro.core import (ColumnDef, CrashNode, QueryRejectedError, SQLType,
                        TableSchema, Transient, VerticaDB)
from repro.core.recovery import recover_node
from repro.engine import col, execute

from test_fault_chaos import repair_all


def make_db(n_nodes=4, k_safety=1, block_rows=64, n_per_wave=1000,
            waves=3, seed=7, n_cids=50):
    """A K-safe cluster with several ROS containers per store (one per
    trickle wave) so shared scans have real concat work to coalesce."""
    rng = np.random.default_rng(seed)
    db = VerticaDB(n_nodes=n_nodes, k_safety=k_safety,
                   block_rows=block_rows)
    db.create_table(
        TableSchema("sales", (ColumnDef("sale_id"), ColumnDef("cid"),
                              ColumnDef("day"), ColumnDef("qty"),
                              ColumnDef("price", SQLType.FLOAT))),
        sort_order=("day",), segment_by=("sale_id",))
    off = 0
    for _ in range(waves):
        t = db.begin()
        db.insert(t, "sales", wave_rows(rng, off, n_per_wave, n_cids))
        db.commit(t)
        off += n_per_wave
        db.run_tuple_mover(force_moveout=True, do_mergeout=False)
    return db


def wave_rows(rng, off, n, n_cids=50):
    return {
        "sale_id": np.arange(off, off + n),
        "cid": rng.integers(0, n_cids, n),
        "day": np.sort(rng.integers(0, 365, n)),
        "qty": rng.integers(1, 10, n),
        # quarter-valued floats: sums/avgs are exact in float32, so
        # byte-identity cannot be broken by accumulation order
        "price": rng.integers(0, 400, n).astype(np.float64) / 4}


def corpus(db):
    """Shareable query shapes spanning every per-member execution path:
    fused dense/sort groupbys, scalar aggregates, composite keys,
    derived columns, HAVING/ORDER/LIMIT, plain selects."""
    q = db.query
    return [
        q("sales").group_by("cid").agg(n=("*", "count")).to_ir(),
        q("sales").where(col("day") < 180).group_by("cid")
        .agg(rev=("price", "sum"), n=("*", "count")).to_ir(),
        q("sales").where((col("cid") >= 10) & (col("cid") < 40))
        .group_by("day").agg(mx=("price", "max")).to_ir(),
        q("sales").agg(total=("qty", "sum")).to_ir(),
        q("sales").where(col("qty") > 5).agg(n=("*", "count"),
                                             lo=("price", "min")).to_ir(),
        q("sales").group_by("cid", "qty").agg(s=("price", "sum")).to_ir(),
        q("sales").where(col("day") >= 300).group_by("cid")
        .agg(avg_p=("price", "avg")).having(col("avg_p") > 40)
        .order_by("-avg_p").limit(7).to_ir(),
        q("sales").select(margin=col("price") * col("qty"))
        .group_by("cid").agg(m=("margin", "sum")).to_ir(),
        q("sales").where(col("day") == 33)
        .select("sale_id", "cid", "price").to_ir(),
        q("sales").where(col("cid") == 7).group_by("day")
        .agg(n=("*", "count")).order_by("day").to_ir(),
        # pruned-to-empty predicate: the structured-empty parity case
        q("sales").where(col("day") > 9000).group_by("cid")
        .agg(s=("price", "sum")).to_ir(),
        q("sales").where(col("day") > 9000).agg(lo=("price", "min")).to_ir(),
    ]


def assert_identical(ref, out, label=""):
    """Exact equality after dtype normalization -- NOT allclose."""
    assert set(ref) == set(out), (label, set(ref), set(out))
    for c in ref:
        a, b = np.asarray(ref[c]), np.asarray(out[c])
        assert a.shape == b.shape, (label, c, a.shape, b.shape)
        if a.dtype.kind == "f" or b.dtype.kind == "f":
            a, b = a.astype(np.float64), b.astype(np.float64)
        else:
            a, b = a.astype(np.int64), b.astype(np.int64)
        assert np.array_equal(a, b), (label, c, a[:8], b[:8])


@pytest.fixture(scope="module")
def serving_db():
    return make_db()


# ---------------------------------------------------------------------------
# tentpole: shared scans are byte-identical to independent execution
# ---------------------------------------------------------------------------

def test_shared_scan_differential_byte_identical(serving_db):
    db = serving_db
    qs = corpus(db)
    refs = [execute(db, q)[0] for q in qs]

    svc = db.serve(queue_depth=len(qs) + 1, max_coalesce=len(qs),
                   max_concurrent=2)
    with svc.session("interactive") as s:
        tickets = [s.submit(q) for q in qs]
    svc.drain()

    shared = 0
    for q, ref, t in zip(qs, refs, tickets):
        assert_identical(ref, t.result(), label=str(t.id))
        shared += bool(t.stats.shared_scan)
    # the corpus is one table + one projection + one epoch: it coalesces
    assert shared >= len(qs) - 2, [t.stats.shared_scan for t in tickets]
    assert svc.stats.shared_scans >= 1
    assert svc.stats.shared_hit_rate() > 0
    assert db.epochs.n_pinned() == 0


def test_shared_scan_differential_with_pending_wos(serving_db):
    """Trickle-loaded rows sitting in the WOS (fused path ineligible for
    everyone) still coalesce byte-identically via the general path."""
    db = serving_db
    rng = np.random.default_rng(99)
    t = db.begin()
    db.insert(t, "sales", wave_rows(rng, 50_000, 120))
    db.commit(t)
    try:
        qs = corpus(db)
        refs = [execute(db, q)[0] for q in qs]
        svc = db.serve(queue_depth=len(qs) + 1, max_coalesce=len(qs))
        tickets = [svc.submit(q) for q in qs]
        svc.drain()
        for q, ref, tk in zip(qs, refs, tickets):
            assert_identical(ref, tk.result(), label=str(tk.id))
            assert not (tk.stats.exec_stats and tk.stats.exec_stats.fused)
        assert db.epochs.n_pinned() == 0
    finally:
        db.run_tuple_mover(force_moveout=True, do_mergeout=False)


def test_shared_plan_cache_hits_across_services(serving_db):
    """The 'shared'-prefixed fused programs are plan-cached: a second
    service running the same mix hits instead of re-tracing."""
    db = serving_db
    qs = [q for q in corpus(db) if q.aggs]
    for _ in range(2):
        svc = db.serve(queue_depth=len(qs) + 1, max_coalesce=len(qs))
        tickets = [svc.submit(q) for q in qs]
        svc.drain()
    hits = [t.stats.exec_stats.plan_cache == "hit" for t in tickets
            if t.stats.exec_stats is not None and t.stats.exec_stats.fused]
    assert hits and all(hits)


# ---------------------------------------------------------------------------
# satellite: epoch-pin lifecycle under rejection
# ---------------------------------------------------------------------------

def test_queue_flood_leaves_zero_stray_pins(serving_db):
    db = serving_db
    assert db.epochs.n_pinned() == 0
    svc = db.serve(queue_depth=3, max_coalesce=1, max_concurrent=1)
    q = db.query("sales").group_by("cid").agg(n=("*", "count")).to_ir()
    accepted, rejected = [], 0
    for _ in range(20):
        try:
            accepted.append(svc.submit(q))
        except QueryRejectedError:
            rejected += 1
    assert rejected == 20 - 3
    # rejected submissions never pinned; queued ones hold exactly one each
    assert db.epochs.n_pinned() == len(accepted) == 3
    svc.drain()
    assert all(t.done for t in accepted)
    assert db.epochs.n_pinned() == 0
    assert svc.stats.rejected_queue_full == rejected


def test_queue_timeout_rejects_typed_and_unpins(serving_db):
    db = serving_db
    svc = db.serve(queue_depth=8, default_timeout_s=0.0)
    q = db.query("sales").agg(n=("*", "count")).to_ir()
    t = svc.submit(q)
    assert db.epochs.n_pinned() == 1
    import time
    time.sleep(0.01)
    svc.step()
    assert t.state == "rejected"
    assert t.stats.rejected_reason == "timeout"
    with pytest.raises(QueryRejectedError):
        t.result()
    assert db.epochs.n_pinned() == 0
    assert svc.stats.rejected_timeout == 1


def test_ahm_unblocked_after_flood(serving_db):
    """After a flood + drain, the AHM can advance past every epoch the
    flood pinned (the regression the satellite names: a stray pin would
    cap advance_ahm forever)."""
    db = serving_db
    svc = db.serve(queue_depth=4)
    q = db.query("sales").agg(n=("*", "count")).to_ir()
    for _ in range(10):
        try:
            svc.submit(q)
        except QueryRejectedError:
            pass
    svc.drain()
    assert db.epochs.n_pinned() == 0
    db.epochs.advance_ahm(db.epochs.latest_queryable())
    assert db.epochs.ahm == db.epochs.latest_queryable()


# ---------------------------------------------------------------------------
# satellite: priority ordering + serving semantics under load
# ---------------------------------------------------------------------------

def test_priority_ordering_with_batch_boost(serving_db):
    db = serving_db
    svc = db.serve(queue_depth=16, max_coalesce=1, max_concurrent=1,
                   batch_boost_after=2)
    q = db.query("sales").agg(n=("*", "count")).to_ir()
    batch = [svc.submit(q, priority="batch") for _ in range(4)]
    inter = [svc.submit(q, priority="interactive") for _ in range(4)]
    svc.drain()
    iseq = [t.stats.dispatch_seq for t in inter]
    bseq = [t.stats.dispatch_seq for t in batch]
    # interactive queries all finish before the LAST batch query ...
    assert max(iseq) < max(bseq)
    # ... but the anti-starvation boost let batch through mid-stream
    assert min(bseq) < max(iseq)
    assert svc.stats.batch_boosts >= 1
    assert db.epochs.n_pinned() == 0


def test_snapshot_consistency_under_trickle_commits():
    """Queries pinned before a commit never see it, even when they are
    dispatched after it; commits are all-or-nothing per snapshot."""
    db = make_db(waves=2, n_per_wave=500)
    rng = np.random.default_rng(5)
    svc = db.serve(queue_depth=16)
    q = db.query("sales").agg(n=("*", "count")).to_ir()

    t_before = svc.submit(q)
    n_before = int(execute(db, q, as_of=t_before.pinned)[0]["n"][0])
    for k in range(3):       # trickle while queued: 3 commits of 100 rows
        t = db.begin()
        db.insert(t, "sales", wave_rows(rng, 10_000 + 100 * k, 100))
        db.commit(t)
        t_mid = svc.submit(q)
        # every snapshot counts a whole number of 100-row commits
        got = int(execute(db, q, as_of=t_mid.pinned)[0]["n"][0])
        assert (got - n_before) % 100 == 0
    t_after = svc.submit(q)
    svc.drain()
    assert int(t_before.result()["n"][0]) == n_before
    assert int(t_after.result()["n"][0]) == n_before + 300
    assert db.epochs.n_pinned() == 0


def test_session_pool_bounded(serving_db):
    svc = serving_db.serve(max_sessions=2)
    s1, s2 = svc.session(), svc.session("batch")
    with pytest.raises(QueryRejectedError):
        svc.session()
    s1.close()
    s3 = svc.session()          # freed slot is reusable
    with pytest.raises(QueryRejectedError):
        s1.submit(serving_db.query("sales").agg(n=("*", "count")))
    s2.close(), s3.close()


# ---------------------------------------------------------------------------
# memory budget
# ---------------------------------------------------------------------------

def test_memory_budget_bounds_coalescing_and_concurrency(serving_db):
    db = serving_db
    qs = [db.query("sales").group_by("cid").agg(n=("*", "count")).to_ir(),
          db.query("sales").group_by("cid")
          .agg(s=("price", "sum")).to_ir()] * 3
    # generous budget: everything coalesces into one reservation
    svc = db.serve(queue_depth=16, memory_budget_bytes=1 << 30)
    tickets = [svc.submit(q) for q in qs]
    svc.drain()
    assert all(t.stats.share_group >= 2 for t in tickets)
    assert db.block_cache.stats.reserved_bytes == 0      # all released
    assert db.block_cache.stats.peak_reserved_bytes > 0
    assert all(not t.stats.oversized for t in tickets)

    # starvation budget: nothing coalesces (each unit alone overflows,
    # admitted solo + flagged oversized), answers still correct
    ref = execute(db, qs[0])[0]
    svc2 = db.serve(queue_depth=16, memory_budget_bytes=1024)
    tickets2 = [svc2.submit(q) for q in qs]
    svc2.drain()
    assert all(t.stats.share_group == 1 for t in tickets2)
    assert all(t.stats.oversized for t in tickets2)
    assert_identical(ref, tickets2[0].result())
    assert db.block_cache.stats.reserved_bytes == 0
    assert db.epochs.n_pinned() == 0


# ---------------------------------------------------------------------------
# satellite: fault injection at the serving points
# ---------------------------------------------------------------------------

def test_admit_transient_exhaustion_rejects_typed():
    db = make_db(waves=1, n_per_wave=400)
    inj = db.enable_faults(seed=3)
    inj.on("serving.admit", Transient(), times=inj.max_attempts)
    svc = db.serve(queue_depth=8)
    q = db.query("sales").agg(n=("*", "count")).to_ir()
    with pytest.raises(QueryRejectedError):
        svc.submit(q)
    assert db.epochs.n_pinned() == 0       # rejected before any pin
    assert svc.stats.rejected_admission == 1
    # the budget consumed the schedule: the next submit sails through
    t = svc.submit(q)
    svc.drain()
    assert int(t.result()["n"][0]) == 400
    db.disable_faults()


def test_admit_transient_blip_retries_through():
    db = make_db(waves=1, n_per_wave=400)
    inj = db.enable_faults(seed=3)
    inj.on("serving.admit", Transient(), times=1)   # one blip < budget
    svc = db.serve(queue_depth=8)
    t = svc.submit(db.query("sales").agg(n=("*", "count")))
    svc.drain()
    assert int(t.result()["n"][0]) == 400
    assert inj.fired("serving.admit") == 1
    db.disable_faults()


def test_mid_shared_scan_crash_fails_over_once():
    db = make_db()
    qs = [db.query("sales").group_by("cid").agg(n=("*", "count")).to_ir(),
          db.query("sales").where(col("day") < 180).group_by("cid")
          .agg(rev=("price", "sum")).to_ir(),
          db.query("sales").agg(total=("qty", "sum")).to_ir()]
    refs = [execute(db, q)[0] for q in qs]

    inj = db.enable_faults(seed=11)
    inj.on("serving.shared_scan", CrashNode(node=1), hit=1)
    svc = db.serve(queue_depth=8, max_coalesce=8)
    tickets = [svc.submit(q) for q in qs]
    svc.drain()
    assert not db.nodes[1].up
    for ref, t in zip(refs, tickets):
        assert_identical(ref, t.result(), label=str(t.id))
        assert t.stats.failovers == 1      # one crash, one group replan
    assert db.epochs.n_pinned() == 0
    db.disable_faults()
    repair_all(db)


def test_serving_chaos_right_answer_or_typed(serving_db=None):
    """Seeded chaos over BOTH serving points at once: every ticket either
    matches the post-repair oracle at its own pinned epoch or rejected
    with the typed error -- never a silently wrong answer."""
    for seed in (7, 19):
        db = make_db(waves=2, n_per_wave=600)
        qs = corpus(db)[:6]
        inj = db.enable_faults(seed=seed)
        inj.chaos(("serving.admit", "serving.shared_scan"), p=0.25,
                  action=CrashNode(respect_k_safety=True))
        inj.chaos(("serving.admit", "serving.shared_scan"), p=0.15,
                  action=Transient())
        svc = db.serve(queue_depth=32, max_coalesce=4, max_concurrent=2)
        tickets = []
        for rnd in range(2):
            for q in qs:
                try:
                    tickets.append((q, svc.submit(q)))
                except QueryRejectedError:
                    pass                    # typed admission rejection: fine
            svc.drain()
        db.disable_faults()
        repair_all(db)
        done = 0
        for q, t in tickets:
            assert t.done
            if t.state == "rejected":
                assert isinstance(t.error, Exception), t.error
                continue
            oracle = execute(db, q, as_of=t.stats.snapshot_epoch)[0]
            assert_identical(oracle, t._result, label=f"seed{seed}:{t.id}")
            done += 1
        assert done >= 1, f"seed {seed}: every ticket rejected"
        assert db.epochs.n_pinned() == 0


def test_injection_point_registry_covers_serving():
    from repro.core import INJECTION_POINTS
    assert "serving.admit" in INJECTION_POINTS
    assert "serving.shared_scan" in INJECTION_POINTS
